# Reproduce CI locally before pushing: `make ci` runs the same commands
# .github/workflows/ci.yml runs (tier-1 verify = build + test).

CARGO ?= cargo
PY ?= python3

.PHONY: ci build examples test fmt clippy bench-smoke bench-search \
        bench-service python-test artifacts

ci: build examples test fmt clippy bench-smoke python-test

build:
	$(CARGO) build --release

# CI builds these too: examples are documentation that must keep compiling.
examples:
	$(CARGO) build --release --examples

test:
	$(CARGO) test -q

# Blocking since PR 2 (CI mirrors this; run `cargo fmt` to fix).
fmt:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# Benches compile everywhere; running them is a local-only activity.
bench-smoke:
	$(CARGO) bench --no-run

# The perf-tracking benches CI runs and archives per commit
# (BENCH_search.json / BENCH_service.json); OSDP_BENCH_STRICT=1 adds
# timing assertions for toolchain-equipped local runs.
bench-search:
	$(CARGO) bench --bench search_time

bench-service:
	$(CARGO) bench --bench service_throughput

# pytest exit 5 = nothing collected/selected (e.g. hypothesis missing):
# not a failure for this gate.
python-test:
	@if $(PY) -c "import jax" 2>/dev/null; then \
		$(PY) -m pytest python/tests -q -m "not perf"; \
		rc=$$?; test $$rc -eq 0 -o $$rc -eq 5; \
	else \
		echo "JAX unavailable - skipping python kernel tests"; \
	fi

# AOT-compile the JAX/Pallas artifacts the training runtime executes.
artifacts:
	cd python && $(PY) compile/aot.py --out ../artifacts
