# Reproduce CI locally before pushing: `make ci` runs the same commands
# .github/workflows/ci.yml runs (tier-1 verify = build + test).

CARGO ?= cargo
PY ?= python3

.PHONY: ci build examples test fmt clippy bench-smoke bench-search \
        bench-service serve-drive serve-mirror chaos chaos-mirror \
        tier-drive tier-mirror observability python-test artifacts

ci: build examples test fmt clippy bench-smoke serve-drive serve-mirror \
    chaos chaos-mirror tier-drive tier-mirror observability python-test

build:
	$(CARGO) build --release

# CI builds these too: examples are documentation that must keep compiling.
examples:
	$(CARGO) build --release --examples

test:
	$(CARGO) test -q

# Blocking since PR 2 (CI mirrors this; run `cargo fmt` to fix).
fmt:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# Benches compile everywhere; CI runs them with OSDP_BENCH_STRICT=1 so
# the timing assertions block (see bench-search / bench-service).
bench-smoke:
	$(CARGO) bench --no-run

# The perf-tracking benches CI runs, asserts on (OSDP_BENCH_STRICT=1),
# and archives per commit (BENCH_search.json / BENCH_service.json).
bench-search:
	OSDP_BENCH_STRICT=1 $(CARGO) bench --bench search_time

bench-service:
	OSDP_BENCH_STRICT=1 $(CARGO) bench --bench service_throughput

# End-to-end served-concurrency proof: start the release binary on an
# ephemeral port, drive it with 8 parallel stdlib-python clients, and
# assert through the protocol's own stats verb that 8 identical
# concurrent queries ran exactly one planner search.
serve-drive: build
	$(PY) python/tests/drive_frontend.py --bin target/release/osdp \
		--workers 8

# Toolchain-free twin of the above: the pure-python mirror of the
# bounded channel / framing / telemetry machinery, self-checked with
# real threads and sockets. Runs in containers with no cargo.
serve-mirror:
	$(PY) python/mirror/frontend_mirror.py
	$(PY) python/tests/drive_frontend.py --mirror

# CI's fault-injection job: chaos-drive the release binary under three
# fixed OSDP_FAULTS seeds — the server must stay responsive, resurrect
# panicked workers, keep the telemetry invariants exact, and exit 0.
chaos: build
	for seed in 1117 7 4242; do \
		$(PY) python/tests/drive_frontend.py --bin target/release/osdp \
			--workers 4 --chaos --fault-seed $$seed || exit 1; \
	done

# The same chaos contract against the pure-python mirror (no cargo).
chaos-mirror:
	for seed in 1117 7 4242; do \
		$(PY) python/tests/drive_frontend.py --mirror \
			--chaos --fault-seed $$seed || exit 1; \
	done

# CI's cache-tier job: one `osdp cache-serve` plus two plan services
# attached via --remote. Proves cross-instance sharing (B answers A's
# queries bit-identically, zero planner runs), then re-runs the chaos
# contract with the remote fault sites firing.
tier-drive: build
	$(PY) python/tests/drive_frontend.py --bin target/release/osdp \
		--workers 4 --tier
	for seed in 1117 7 4242; do \
		$(PY) python/tests/drive_frontend.py --bin target/release/osdp \
			--workers 4 --tier --chaos --fault-seed $$seed || exit 1; \
	done

# The same topology against the pure-python mirror (no cargo).
tier-mirror:
	$(PY) python/tests/drive_frontend.py --mirror --tier
	for seed in 1117 7 4242; do \
		$(PY) python/tests/drive_frontend.py --mirror \
			--tier --chaos --fault-seed $$seed || exit 1; \
	done

# CI's observability job: trace span trees + Prometheus-equals-stats in
# process, the no_trace compile-out gate with the inertness property,
# then the release binary driven end to end with --trace (trace verb,
# metrics verb, and the --metrics-listen HTTP scrape).
observability: build
	$(CARGO) test --release --test plan_service trace
	$(CARGO) test --release --test plan_service prometheus
	$(CARGO) test --release --test service_frontend metrics
	$(CARGO) test --release --test planner_properties \
		tracing_is_provably_inert
	$(PY) python/tests/drive_frontend.py --bin target/release/osdp \
		--workers 4 --trace
	# last: this build replaces target/release/osdp with the traceless
	# binary, so the --trace drive above must already have run
	$(CARGO) build --release --features no_trace
	$(CARGO) test --release --features no_trace \
		--test planner_properties tracing_is_provably_inert

# pytest exit 5 = nothing collected/selected (e.g. hypothesis missing):
# not a failure for this gate.
python-test:
	@if $(PY) -c "import jax" 2>/dev/null; then \
		$(PY) -m pytest python/tests -q -m "not perf"; \
		rc=$$?; test $$rc -eq 0 -o $$rc -eq 5; \
	else \
		echo "JAX unavailable - skipping python kernel tests"; \
	fi

# AOT-compile the JAX/Pallas artifacts the training runtime executes.
artifacts:
	cd python && $(PY) compile/aot.py --out ../artifacts
