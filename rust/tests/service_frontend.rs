//! Socket front-end acceptance tests (ISSUE 6):
//!
//! * 8 concurrent **identical** socket queries observe exactly one
//!   planner execution — proven through the wire via the `stats` verb,
//!   not by peeking at internals;
//! * N concurrent **distinct** socket queries are bit-identical (full
//!   choice vectors) to the same queries answered serially by a plain
//!   in-process [`PlanService`];
//! * telemetry consistency under concurrent load: every histogram
//!   observation corresponds to exactly one dispatched query, and
//!   `hits + misses == queries − rejected`;
//! * framing hardening: oversized lines and idle connections get a
//!   structured error and a closed socket, never a hung worker;
//! * `shutdown` drains in-flight work, acks, and closes the listener.

use osdp::config::GIB;
use osdp::cost::Profiler;
use osdp::service::{Counter, Frontend, FrontendConfig, MetricsHandler,
                    PlanQuery, PlanService, Telemetry, server};
use osdp::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::Duration;

const TINY: &str = "gpt:3000,64,6,192,4";

fn tiny_service_profiler() -> Profiler {
    let q = PlanQuery::batch(TINY, 8.0, 1);
    let cluster = q.cluster.resolve().unwrap();
    let model = osdp::service::resolve_setting(TINY).unwrap();
    Profiler::new(&model, &cluster, &q.search)
}

/// A limit (in GiB) around `frac` of the tiny model's all-DP peak at
/// `b` — same construction as the plan_service tests, so limits land in
/// the interesting (mixed-plan) region.
fn tiny_mem_gib(frac: f64, b: usize) -> f64 {
    let p = tiny_service_profiler();
    p.evaluate(&p.index_of(|d| d.is_pure_dp()), b).peak_mem * frac / GIB
}

fn start_frontend(workers: usize, idle: Duration)
                  -> (Frontend, Arc<PlanService>, Arc<Telemetry>) {
    let service = Arc::new(PlanService::in_memory());
    let telemetry = Arc::new(Telemetry::new());
    let frontend = Frontend::start(
        Arc::clone(&service),
        Arc::clone(&telemetry),
        FrontendConfig {
            addr: "127.0.0.1:0".into(),
            workers,
            idle_timeout: idle,
            queue_cap: 64,
        },
    )
    .expect("bind an ephemeral loopback port");
    (frontend, service, telemetry)
}

/// Send `lines` on one connection and read one JSON response per line.
fn roundtrip(addr: std::net::SocketAddr, lines: &[&str]) -> Vec<Json> {
    let stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut out = Vec::with_capacity(lines.len());
    for line in lines {
        writeln!(writer, "{line}").unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).expect("read response line");
        assert!(resp.ends_with('\n'), "responses are newline-framed");
        out.push(Json::parse(resp.trim_end())
                     .expect("every response line is JSON"));
    }
    out
}

// ---------------------------------------------------------------------
// the concurrency guarantee, proven through the wire
// ---------------------------------------------------------------------

#[test]
fn eight_identical_socket_queries_run_one_search() {
    let (frontend, _service, _telemetry) =
        start_frontend(8, Duration::from_secs(60));
    let addr = frontend.local_addr();
    let mem = tiny_mem_gib(0.5, 2);
    let line =
        format!("query setting={TINY} mem={mem} batch=2 threads=1");

    let barrier = Barrier::new(8);
    let responses: Vec<Json> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let line = line.as_str();
                let barrier = &barrier;
                scope.spawn(move || {
                    barrier.wait();
                    roundtrip(addr, &[line]).pop().unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for r in &responses {
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r:?}");
        assert_eq!(r.get("choice"), responses[0].get("choice"),
                   "coalesced answers must be bit-identical");
        assert_eq!(r.get("time_s"), responses[0].get("time_s"));
    }

    // the proof goes through the protocol: the `stats` verb on a fresh
    // connection must report exactly one planner execution
    let stats = roundtrip(addr, &["stats"]).pop().unwrap();
    assert_eq!(stats.get("planner_runs").as_usize(), Some(1),
               "8 identical concurrent socket queries must run exactly \
                one search: {stats:?}");
    assert_eq!(
        stats.get("hits").as_usize().unwrap()
            + stats.get("coalesced").as_usize().unwrap(),
        7,
        "everyone but the leader shares: {stats:?}"
    );
    assert_eq!(stats.get("telemetry").get("queries").as_usize(), Some(8),
               "telemetry rides along on the stats verb: {stats:?}");

    let ack = roundtrip(addr, &["shutdown"]).pop().unwrap();
    assert_eq!(ack.get("kind").as_str(), Some("shutdown"));
    frontend.join();
}

// ---------------------------------------------------------------------
// concurrent distinct queries == serial in-process queries, bit for bit
// ---------------------------------------------------------------------

#[test]
fn concurrent_distinct_socket_queries_match_serial_service() {
    // distinct (mem, batch) points, including a sweep: limits span loose
    // to tight so plans differ across the set
    let mut lines: Vec<String> = Vec::new();
    for (frac, b) in [(0.45, 1), (0.55, 1), (0.65, 2), (0.8, 2),
                      (0.9, 3)]
    {
        let mem = tiny_mem_gib(frac, b);
        lines.push(format!(
            "query setting={TINY} mem={mem} batch={b} threads=1"
        ));
    }
    let sweep_mem = tiny_mem_gib(0.7, 1);
    lines.push(format!(
        "sweep setting={TINY} mem={sweep_mem} batch-cap=3 threads=1"
    ));

    // serial ground truth: the same protocol lines against a plain
    // in-process service, one at a time, on this thread
    let serial = PlanService::in_memory();
    let reference: Vec<Json> = lines
        .iter()
        .map(|l| {
            let (resp, _) = server::handle_line(&serial, l);
            Json::parse(&resp).unwrap()
        })
        .collect();

    let (frontend, _service, _telemetry) =
        start_frontend(4, Duration::from_secs(60));
    let addr = frontend.local_addr();
    let barrier = Barrier::new(lines.len());
    let concurrent: Vec<Json> = std::thread::scope(|scope| {
        let handles: Vec<_> = lines
            .iter()
            .map(|line| {
                let line = line.as_str();
                let barrier = &barrier;
                scope.spawn(move || {
                    barrier.wait();
                    roundtrip(addr, &[line]).pop().unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (got, want) in concurrent.iter().zip(&reference) {
        assert_eq!(want.get("ok").as_bool(), Some(true), "{want:?}");
        assert_eq!(got.get("ok").as_bool(), Some(true), "{got:?}");
        // sources may differ (warm-start opportunities depend on arrival
        // order) but the answers must not: full choice vectors and
        // bit-exact times
        assert_eq!(got.get("choice"), want.get("choice"));
        assert_eq!(got.get("time_s"), want.get("time_s"));
        assert_eq!(got.get("throughput"), want.get("throughput"));
        assert_eq!(got.get("candidates"), want.get("candidates"));
        assert_eq!(got.get("best_batch"), want.get("best_batch"));
    }

    frontend.shutdown();
    frontend.join();
}

// ---------------------------------------------------------------------
// telemetry consistency under concurrent, partly hostile load
// ---------------------------------------------------------------------

#[test]
fn telemetry_is_consistent_under_concurrent_load() {
    let (frontend, service, telemetry) =
        start_frontend(4, Duration::from_secs(60));
    let addr = frontend.local_addr();
    let mem = tiny_mem_gib(0.6, 1);
    let good = format!("query setting={TINY} mem={mem} batch=1 threads=1");
    // a *different* limit for the sweep, so its per-batch cache fills
    // never collide with the batch query's key (that would make
    // planner_runs depend on arrival order)
    let sweep_mem = tiny_mem_gib(0.75, 1);
    let sweep = format!(
        "sweep setting={TINY} mem={sweep_mem} batch-cap=2 threads=1"
    );

    // 6 connections, 3 lines each: a good query, junk, and a rejected
    // query (unknown setting) — interleaved across the worker pool
    let scripts: Vec<Vec<String>> = (0..6)
        .map(|i| {
            vec![
                if i % 2 == 0 { good.clone() } else { sweep.clone() },
                "frobnicate the planner".into(),
                "query setting=nope mem=4 batch=1".into(),
            ]
        })
        .collect();
    let barrier = Barrier::new(scripts.len());
    std::thread::scope(|scope| {
        for script in &scripts {
            let barrier = &barrier;
            scope.spawn(move || {
                barrier.wait();
                let lines: Vec<&str> =
                    script.iter().map(|s| s.as_str()).collect();
                let responses = roundtrip(addr, &lines);
                assert_eq!(responses[0].get("ok").as_bool(), Some(true));
                assert_eq!(responses[1].get("error").as_str(),
                           Some("bad-request"));
                assert_eq!(responses[2].get("error").as_str(),
                           Some("unknown-setting"));
            });
        }
    });
    // two sequential degenerate replans ride behind the storm (same
    // hardware respelled — served from cache), so the replan latency
    // lane is live when the lane-sum invariant is checked
    let replan = format!(
        "replan setting={TINY} mem={mem} batch=1 threads=1 new-devices=8"
    );
    for r in &roundtrip(addr, &[replan.as_str(), replan.as_str()]) {
        assert_eq!(r.get("ok").as_bool(), Some(true), "{r:?}");
    }
    frontend.shutdown();
    frontend.join();

    // every protocol line was counted: 3 per storm connection + the
    // replan connection
    assert_eq!(telemetry.get(Counter::Requests), 20);
    assert_eq!(telemetry.get(Counter::Connections), 7);
    // queries = the parsed query/sweep/replan lines (junk never
    // dispatches)
    assert_eq!(telemetry.queries(), 14);
    assert_eq!(telemetry.get(Counter::BadRequests), 6);
    assert_eq!(telemetry.get(Counter::Rejected), 6,
               "the unknown-setting queries are rejected pre-cache");
    // exactly one histogram observation per dispatched query, binned by
    // shape — replans in their own lane, not batch's
    assert_eq!(telemetry.batch_latency.count(), 9,
               "3 good batch queries + 6 rejected (batch-shaped)");
    assert_eq!(telemetry.sweep_latency.count(), 3);
    assert_eq!(telemetry.replan_latency.count(), 2);
    assert_eq!(
        telemetry.batch_latency.count() + telemetry.sweep_latency.count()
            + telemetry.replan_latency.count(),
        telemetry.queries()
    );
    // the service core saw every query that passed validation (no
    // remote tier here, so remote_hits is 0 — included to pin the
    // three-way form of the invariant)
    let s = service.stats();
    assert_eq!(
        s.hits + s.remote_hits + s.misses,
        telemetry.queries() - telemetry.get(Counter::Rejected),
        "hits + remote_hits + misses must equal dispatched-and-validated queries: {}",
        s.describe()
    );
    // 2 distinct cacheable queries -> exactly 2 planner runs, however
    // the 6 copies interleaved
    assert_eq!(s.planner_runs, 2, "{}", s.describe());
}

// ---------------------------------------------------------------------
// the scrape endpoint: Prometheus over a socket, perturbation-free
// ---------------------------------------------------------------------

/// The `--metrics-listen` wiring over real sockets: a second frontend
/// wraps the same service + telemetry in a [`MetricsHandler`]; both an
/// HTTP `GET` and a bare line get the full exposition back, and the
/// scrapes themselves never move the counters they report (the scrape
/// frontend carries its own throwaway transport telemetry).
#[test]
fn metrics_endpoint_scrapes_without_perturbing_the_counters() {
    let (frontend, service, telemetry) =
        start_frontend(2, Duration::from_secs(60));
    let addr = frontend.local_addr();
    let mem = tiny_mem_gib(0.6, 1);
    let line = format!("query setting={TINY} mem={mem} batch=1 threads=1");
    let responses = roundtrip(addr, &[line.as_str()]);
    assert_eq!(responses[0].get("ok").as_bool(), Some(true));

    let metrics = Frontend::start_with(
        Arc::new(MetricsHandler {
            service: Arc::clone(&service),
            telemetry: Arc::clone(&telemetry),
        }),
        Arc::new(Telemetry::new()),
        FrontendConfig {
            addr: "127.0.0.1:0".into(),
            workers: 1,
            idle_timeout: Duration::from_secs(5),
            queue_cap: 16,
        },
    )
    .expect("bind the scrape endpoint");
    let maddr = metrics.local_addr();

    // one request, one response, then the endpoint closes — read to EOF
    let scrape = |request: &str| -> String {
        let mut stream = TcpStream::connect(maddr).expect("connect");
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .unwrap();
        write!(stream, "{request}").unwrap();
        stream.flush().unwrap();
        let mut page = String::new();
        BufReader::new(stream)
            .read_to_string(&mut page)
            .expect("read the scrape response to EOF");
        page
    };

    // HTTP framing for real Prometheus scrapers
    let http = scrape("GET /metrics HTTP/1.0\r\n\r\n");
    assert!(http.starts_with("HTTP/1.0 200 OK\r\n"), "{http:?}");
    assert!(http.contains("text/plain; version=0.0.4"));
    let body = http.split_once("\r\n\r\n").expect("header/body split").1;
    // bare-line framing for the wire protocol's `metrics` cousin
    let plain = scrape("metrics\n");
    for page in [body, plain.as_str()] {
        assert!(page.contains("osdp_service_planner_runs_total 1"),
                "one query ran one planner: {page:?}");
        assert!(page.contains("osdp_net_queries_total 1"));
        assert!(page.contains("osdp_breaker_state{state=\"closed\"} 1"));
        assert!(page.contains(
            "osdp_latency_seconds_count{shape=\"batch\"} 1"
        ));
    }
    if osdp::service::trace::Tracer::enabled() {
        assert!(plain.contains("osdp_span_seconds_count{span=\"query\"} 1"),
                "the traced query rolls up into the span histograms");
    }

    // the scrapes moved nothing on the service's own telemetry: still
    // one connection, one request, one query from the roundtrip above
    assert_eq!(telemetry.get(Counter::Connections), 1);
    assert_eq!(telemetry.get(Counter::Requests), 1);
    assert_eq!(telemetry.queries(), 1);

    metrics.shutdown();
    metrics.join();
    frontend.shutdown();
    frontend.join();
}

// ---------------------------------------------------------------------
// framing hardening: oversized lines, idle timeouts
// ---------------------------------------------------------------------

#[test]
fn oversized_lines_get_a_structured_error_and_a_closed_socket() {
    let (frontend, _service, telemetry) =
        start_frontend(2, Duration::from_secs(60));
    let addr = frontend.local_addr();

    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    // 64 KiB of garbage, no newline: framing is unrecoverable, so the
    // server must answer once and hang up
    writer.write_all(&[b'x'; 64 * 1024]).unwrap();
    writer.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let doc = Json::parse(resp.trim_end()).expect("structured error");
    assert_eq!(doc.get("ok").as_bool(), Some(false));
    assert_eq!(doc.get("error").as_str(), Some("bad-request"));
    let mut rest = String::new();
    reader.read_to_string(&mut rest).unwrap();
    assert!(rest.is_empty(), "socket closes after an oversized line");
    assert!(telemetry.get(Counter::BadRequests) >= 1);

    // the pool survives: a well-behaved connection still gets served
    let stats = roundtrip(addr, &["stats"]).pop().unwrap();
    assert_eq!(stats.get("kind").as_str(), Some("stats"));

    frontend.shutdown();
    frontend.join();
}

#[test]
fn idle_connections_time_out_without_wedging_a_worker() {
    // a 1-worker pool: if the idle connection wedged its worker, the
    // follow-up request could never be served
    let (frontend, _service, telemetry) =
        start_frontend(1, Duration::from_millis(200));
    let addr = frontend.local_addr();

    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let doc = Json::parse(resp.trim_end()).expect("structured timeout");
    assert_eq!(doc.get("error").as_str(), Some("timeout"));
    let mut rest = String::new();
    reader.read_to_string(&mut rest).unwrap();
    assert!(rest.is_empty(), "idle socket is closed after the timeout");
    assert_eq!(telemetry.get(Counter::ConnTimeouts), 1);

    let stats = roundtrip(addr, &["stats"]).pop().unwrap();
    assert_eq!(stats.get("kind").as_str(), Some("stats"),
               "the worker must be free again after the timeout");

    frontend.shutdown();
    frontend.join();
}

// ---------------------------------------------------------------------
// graceful shutdown
// ---------------------------------------------------------------------

#[test]
fn shutdown_acks_drains_and_closes_the_listener() {
    let (frontend, service, _telemetry) =
        start_frontend(2, Duration::from_secs(60));
    let addr = frontend.local_addr();
    let mem = tiny_mem_gib(0.55, 1);

    // in-flight work on the same connection completes before the ack
    let query = format!("query setting={TINY} mem={mem} batch=1 threads=1");
    let responses = roundtrip(addr, &[query.as_str(), "shutdown"]);
    assert_eq!(responses[0].get("ok").as_bool(), Some(true));
    assert_eq!(responses[1].get("kind").as_str(), Some("shutdown"));
    assert_eq!(responses[1].get("ok").as_bool(), Some(true));

    // join returns (drain), and the port stops accepting new work: a
    // late connect either fails outright or sees immediate EOF
    frontend.join();
    assert_eq!(service.stats().planner_runs, 1);
    if let Ok(stream) = TcpStream::connect(addr) {
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        let n = reader.read_line(&mut line).unwrap_or(0);
        assert_eq!(n, 0, "no worker serves after shutdown: {line:?}");
    }
}
