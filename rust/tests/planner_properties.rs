//! Property-based integration tests on the planner (proptest-style via
//! util::prop): optimality, feasibility, monotonicity, and dominance
//! invariants over randomized models, clusters and limits.

use osdp::config::{Cluster, SearchConfig};
use osdp::cost::Profiler;
use osdp::model::{GptDims, build_gpt};
use osdp::planner::{Engine, ExecutionPlan, ParallelConfig, dfs_search,
                    exhaustive_search, frontier, greedy_search,
                    parallel_search};
use osdp::util::prop;
use osdp::util::rng::Rng;

#[derive(Debug, Clone)]
struct Instance {
    layers: usize,
    hidden: usize,
    n_dev: usize,
    b: usize,
    limit_frac: f64,
    grans: Vec<usize>,
}

fn gen_instance(rng: &mut Rng, size: usize) -> Instance {
    Instance {
        layers: rng.range(1, 1 + size / 30),
        hidden: 32 * rng.range(1, 6),
        n_dev: *rng.pick(&[2usize, 4, 8]),
        b: rng.range(1, 4),
        limit_frac: 0.25 + rng.f64() * 1.1,
        grans: if rng.chance(0.5) { vec![0] } else { vec![0, 2] },
    }
}

fn build(inst: &Instance) -> (Profiler, f64) {
    let m = build_gpt(&GptDims::uniform("p", 1000, 64, inst.layers,
                                        inst.hidden, 2));
    let c = Cluster::rtx_titan(inst.n_dev, 8.0);
    let s = SearchConfig { granularities: inst.grans.clone(),
                           ..Default::default() };
    let p = Profiler::new(&m, &c, &s);
    let dp_mem = p.evaluate(&p.index_of(|d| d.is_pure_dp()), inst.b).peak_mem;
    (p, dp_mem * inst.limit_frac)
}

/// DFS equals brute force wherever brute force is affordable.
#[test]
fn prop_dfs_is_exact() {
    prop::check(0xE1AC7, 20, gen_instance, |inst| {
        let (p, limit) = build(inst);
        if p.log10_plan_space() > 5.5 {
            return Ok(()); // brute force too big; covered by other props
        }
        let brute = exhaustive_search(&p, limit, inst.b);
        let smart = dfs_search(&p, limit, inst.b);
        match (brute, smart) {
            (None, None) => Ok(()),
            (Some((_, bc)), Some((_, sc, _))) => {
                prop::close(bc.time, sc.time, 1e-10)
            }
            (b, s) => Err(format!(
                "feasibility disagreement: brute={:?} dfs={:?}",
                b.is_some(),
                s.is_some()
            )),
        }
    });
}

/// Any returned plan respects the memory limit, and greedy never beats DFS.
#[test]
fn prop_feasible_and_dominant() {
    prop::check(0xFEA51B, 30, gen_instance, |inst| {
        let (p, limit) = build(inst);
        let smart = dfs_search(&p, limit, inst.b);
        let greedy = greedy_search(&p, limit, inst.b);
        match (&smart, &greedy) {
            (Some((_, sc, _)), Some((_, gc))) => {
                if sc.peak_mem > limit {
                    return Err(format!("DFS overflows: {}", sc.peak_mem));
                }
                if gc.peak_mem > limit {
                    return Err(format!("greedy overflows: {}", gc.peak_mem));
                }
                if gc.time < sc.time - 1e-12 {
                    return Err(format!(
                        "greedy {} beat exact {}", gc.time, sc.time
                    ));
                }
                Ok(())
            }
            (None, Some(_)) => {
                Err("greedy feasible but DFS said infeasible".into())
            }
            // greedy may fail where DFS succeeds (heuristic) — but our
            // greedy saturates to min memory, so it shouldn't. Flag it.
            (Some(_), None) => {
                Err("DFS feasible but greedy said infeasible".into())
            }
            (None, None) => Ok(()),
        }
    });
}

/// Loosening the memory limit never slows the optimal plan.
#[test]
fn prop_monotone_in_limit() {
    prop::check(0x300700, 15, gen_instance, |inst| {
        let (p, limit) = build(inst);
        let tighter = dfs_search(&p, limit, inst.b);
        let looser = dfs_search(&p, limit * 1.3, inst.b);
        match (tighter, looser) {
            (Some((_, tc, _)), Some((_, lc, _))) => {
                if lc.time <= tc.time + 1e-12 {
                    Ok(())
                } else {
                    Err(format!("loosening slowed plan: {} -> {}", tc.time,
                                lc.time))
                }
            }
            (Some(_), None) => Err("loosening lost feasibility".into()),
            _ => Ok(()),
        }
    });
}

/// The optimal plan never loses to the fixed all-DP / all-ZDP baselines.
#[test]
fn prop_dominates_fixed_modes() {
    prop::check(0xD031, 25, gen_instance, |inst| {
        let (p, limit) = build(inst);
        let smart = dfs_search(&p, limit, inst.b);
        let Some((choice, sc, _)) = smart else { return Ok(()) };
        let plan = ExecutionPlan::from_choice(&p, choice, inst.b);
        assert_eq!(plan.cost.time, sc.time);
        for pred in [
            |d: &osdp::cost::Decision| d.is_pure_dp(),
            |d: &osdp::cost::Decision| d.is_pure_zdp() && d.granularity == 0,
        ] {
            let fixed = p.index_of(pred);
            let cost = p.evaluate(&fixed, inst.b);
            if cost.peak_mem <= limit && cost.time < sc.time - 1e-12 {
                return Err(format!(
                    "fixed-mode plan beat the search: {} < {}",
                    cost.time, sc.time
                ));
            }
        }
        Ok(())
    });
}

/// Hybrid-scope menus on multi-node clusters change nothing about the
/// engines' agreement: folded B&B == frontier == per-op B&B == exhaustive
/// (full choice vector, bit-for-bit), serially and at 1 and 8 threads.
/// The scope dimension only enriches the menus — `TableKey` canonicalizes
/// by cost bits, so the fold/frontier machinery carries through untouched.
#[test]
fn prop_scoped_menus_keep_engines_bit_identical() {
    #[derive(Debug, Clone)]
    struct ScopedInstance {
        layers: usize,
        hidden: usize,
        n_dev: usize,
        dpn: usize,
        b: usize,
        limit_frac: f64,
        grans: Vec<usize>,
    }
    let gen = |rng: &mut Rng, size: usize| {
        let (n_dev, dpn) = *rng.pick(&[(4usize, 2usize), (8, 4), (8, 2),
                                       (16, 8)]);
        ScopedInstance {
            layers: rng.range(1, 1 + size / 30),
            hidden: 32 * rng.range(1, 6),
            n_dev,
            dpn,
            b: rng.range(1, 4),
            limit_frac: 0.25 + rng.f64() * 1.1,
            grans: if rng.chance(0.5) { vec![0] } else { vec![0, 2] },
        }
    };
    let mut compared = 0;
    prop::check(0x5C09E, 20, gen, |inst| {
        let m = build_gpt(&GptDims::uniform("p", 1000, 64, inst.layers,
                                            inst.hidden, 2));
        let c = Cluster {
            n_devices: inst.n_dev,
            devices_per_node: inst.dpn,
            ..Cluster::two_server_a100(8.0)
        };
        c.validate().map_err(|e| e.to_string())?;
        let s = SearchConfig { granularities: inst.grans.clone(),
                               ..Default::default() };
        let p = Profiler::new(&m, &c, &s);
        // the scope dimension must actually be on the menus
        if !p.tables.iter().any(|t| {
            t.options.iter().any(|o| o.decision.is_node_scoped())
        }) {
            return Err("no node-scoped menu entries generated".into());
        }
        let dp_mem =
            p.evaluate(&p.index_of(|d| d.is_pure_dp()), inst.b).peak_mem;
        let limit = dp_mem * inst.limit_frac;
        let folded = dfs_search(&p, limit, inst.b);
        let front = frontier::search(&p, limit, inst.b);
        match (&folded, &front) {
            (None, None) => return Ok(()),
            (Some((fc, fcost, fst)), Some((rc, rcost, rst))) => {
                if !(fst.complete && rst.complete) {
                    return Ok(());
                }
                if fc != rc || fcost.time.to_bits() != rcost.time.to_bits() {
                    return Err(format!(
                        "frontier != folded on scoped menus: {rc:?} vs {fc:?}"
                    ));
                }
                for threads in [1usize, 8] {
                    for engine in [Engine::Frontier, Engine::FoldedBb,
                                   Engine::UnfoldedBb] {
                        let cfg = ParallelConfig { threads, engine,
                                                   ..Default::default() };
                        let par = parallel_search(&p, limit, inst.b, &cfg);
                        let Some((pc, pcost, pst)) = par else {
                            return Err(format!(
                                "{engine:?}@{threads}t lost feasibility"
                            ));
                        };
                        if !pst.complete {
                            return Ok(());
                        }
                        if &pc != fc
                            || pcost.time.to_bits() != fcost.time.to_bits()
                        {
                            return Err(format!(
                                "{engine:?}@{threads}t diverged on scoped \
                                 menus"
                            ));
                        }
                    }
                }
                if p.log10_plan_space() <= 5.5 {
                    let brute = exhaustive_search(&p, limit, inst.b)
                        .ok_or("exhaustive lost feasibility")?;
                    if &brute.0 != fc
                        || brute.1.time.to_bits() != fcost.time.to_bits()
                    {
                        return Err("exhaustive diverged on scoped menus"
                            .into());
                    }
                }
                compared += 1;
                Ok(())
            }
            (f, r) => Err(format!(
                "feasibility disagreement: folded={:?} frontier={:?}",
                f.is_some(),
                r.is_some()
            )),
        }
    });
    assert!(compared >= 5, "only {compared} full comparisons ran");
}

/// Tracing is provably inert (PR 10 tentpole): running a search with a
/// [`SearchTrace`] attached returns the bit-identical plan — choice
/// vector, time bits, node count, completeness — as the untraced call,
/// at 1 and 8 threads. The convergence timeline itself is well-formed
/// (node offsets non-decreasing, incumbent times strictly improving,
/// a nodes=0 seed event only from greedy/warm), bit-reproducible
/// across two traced runs at threads=1 for batch searches, and
/// bit-reproducible at *any* thread count for the scheduler's sweep
/// (each per-batch search runs serially inside its task, so thread
/// count only changes which worker runs it, not what it logs).
#[test]
fn tracing_is_provably_inert() {
    use osdp::planner::{Improvement, ImprovementSource, Scheduler,
                        SearchTrace, parallel_search_traced,
                        parallel_search_with_stats};

    // under --features no_trace the recorder is compiled out and every
    // timeline is legitimately empty; the bit-identity half of the
    // property still runs in full
    let recording = osdp::service::trace::Tracer::enabled();

    fn well_formed(tl: &[Improvement], feasible: bool)
                   -> Result<(), String> {
        if feasible && tl.is_empty() {
            return Err("feasible search with an empty timeline".into());
        }
        for e in tl {
            if matches!(e.source,
                        ImprovementSource::Greedy | ImprovementSource::Warm)
                && e.nodes != 0
            {
                return Err(format!("seed event at nodes={}", e.nodes));
            }
        }
        for w in tl.windows(2) {
            if w[1].nodes < w[0].nodes {
                return Err("node offsets must be non-decreasing".into());
            }
            if f64::from_bits(w[1].time_bits)
                >= f64::from_bits(w[0].time_bits)
            {
                return Err("incumbents must strictly improve".into());
            }
        }
        Ok(())
    }

    let mut sweeps_compared = 0;
    prop::check(0x77ACE, 15, gen_instance, |inst| {
        let (p, limit) = build(inst);
        for threads in [1usize, 8] {
            let cfg = ParallelConfig { threads, ..Default::default() };
            let (plain, pstats) =
                parallel_search_with_stats(&p, limit, inst.b, &cfg, None);
            let mut t1 = SearchTrace::default();
            let (traced, tstats) = parallel_search_traced(
                &p, limit, inst.b, &cfg, None, Some(&mut t1));
            match (&plain, &traced) {
                (None, None) => {}
                (Some((pc, pcost)), Some((tc, tcost))) => {
                    if pc != tc
                        || pcost.time.to_bits() != tcost.time.to_bits()
                    {
                        return Err(format!(
                            "tracing changed the plan at {threads} \
                             threads: {tc:?} vs {pc:?}"
                        ));
                    }
                }
                _ => {
                    return Err(format!(
                        "tracing changed feasibility at {threads} threads"
                    ));
                }
            }
            if pstats.nodes != tstats.nodes
                || pstats.complete != tstats.complete
            {
                return Err(format!(
                    "tracing changed the search shape at {threads} \
                     threads: {} vs {} nodes",
                    tstats.nodes, pstats.nodes
                ));
            }
            well_formed(&t1.timeline, traced.is_some() && recording)?;
            if threads == 1 {
                // serial batch searches: the timeline itself is
                // bit-reproducible, event for event
                let mut t2 = SearchTrace::default();
                parallel_search_traced(&p, limit, inst.b, &cfg, None,
                                       Some(&mut t2));
                if t1.timeline != t2.timeline {
                    return Err(format!(
                        "two traced serial runs diverged: {:?} vs {:?}",
                        t1.timeline, t2.timeline
                    ));
                }
            }
        }

        // the sweep's winner timeline is deterministic at any thread
        // count, and run() == run_traced(None) == run_traced(Some)
        let mut s1 = SearchTrace::default();
        let mut s8 = SearchTrace::default();
        let cap = 4;
        let r1 = Scheduler::new(&p, limit, cap)
            .with_threads(1)
            .run_traced(Some(&mut s1));
        let r8 = Scheduler::new(&p, limit, cap)
            .with_threads(8)
            .run_traced(Some(&mut s8));
        let plain = Scheduler::new(&p, limit, cap).with_threads(8).run();
        match (&r1, &r8, &plain) {
            (Err(_), Err(_), Err(_)) => {}
            (Ok(a), Ok(b), Ok(c)) => {
                if !(a.stats.complete && b.stats.complete
                     && c.stats.complete)
                {
                    return Ok(());
                }
                let best = |r: &osdp::planner::SchedulerResult| {
                    let w = &r.candidates[r.best];
                    (w.plan.choice.clone(), w.plan.cost.time.to_bits())
                };
                if best(a) != best(b) || best(b) != best(c) {
                    return Err("sweep diverged across thread counts / \
                                tracing".into());
                }
                well_formed(&s1.timeline, recording)?;
                if s1.timeline != s8.timeline {
                    return Err(format!(
                        "sweep timelines diverged across thread counts: \
                         {:?} vs {:?}",
                        s1.timeline, s8.timeline
                    ));
                }
                sweeps_compared += 1;
            }
            _ => return Err("sweep feasibility diverged".into()),
        }
        Ok(())
    });
    assert!(sweeps_compared >= 5,
            "only {sweeps_compared} sweep comparisons ran");
}

/// Enlarging the decision menu (splitting granularities) never hurts.
#[test]
fn prop_bigger_menu_never_hurts() {
    prop::check(0xB16, 15, gen_instance, |inst| {
        let m = build_gpt(&GptDims::uniform("p", 1000, 64, inst.layers,
                                            inst.hidden, 2));
        let c = Cluster::rtx_titan(inst.n_dev, 8.0);
        let base_cfg = SearchConfig { granularities: vec![0],
                                      ..Default::default() };
        let big_cfg = SearchConfig { granularities: vec![0, 2, 4],
                                     ..Default::default() };
        let pb = Profiler::new(&m, &c, &base_cfg);
        let pg = Profiler::new(&m, &c, &big_cfg);
        let dp_mem =
            pb.evaluate(&pb.index_of(|d| d.is_pure_dp()), inst.b).peak_mem;
        let limit = dp_mem * inst.limit_frac;
        let base = dfs_search(&pb, limit, inst.b);
        let big = dfs_search(&pg, limit, inst.b);
        match (base, big) {
            (Some((_, bc, _)), Some((_, gc, _))) => {
                if gc.time <= bc.time + 1e-9 {
                    Ok(())
                } else {
                    Err(format!("bigger menu slower: {} vs {}", gc.time,
                                bc.time))
                }
            }
            (Some(_), None) => Err("bigger menu lost feasibility".into()),
            _ => Ok(()),
        }
    });
}
