//! End-to-end integration over the real runtime: AOT artifacts → PJRT →
//! fabric collectives → training. Skips politely when artifacts are absent
//! (`make artifacts`).

use osdp::fabric::Topology;
use osdp::config::Cluster;
use osdp::runtime::{artifacts_available, default_artifact_dir};
use osdp::train::{ShardMode, TrainConfig, train};

fn cfg(mode: ShardMode, workers: usize, steps: usize) -> TrainConfig {
    let c = Cluster::rtx_titan(workers, 8.0);
    TrainConfig {
        model: "tiny".into(),
        n_workers: workers,
        steps,
        mode,
        seed: 11,
        topology: Topology::from_cluster(&c),
        mem_limit: c.mem_limit,
        log_every: 0,
        device_flops: c.flops,
        reshard_after_forward: true,
    }
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: run `make artifacts` first");
            return;
        }
    };
}

/// Four-way ZDP training descends and matches the corpus structure.
#[test]
fn zdp_four_workers_descends() {
    require_artifacts!();
    let rep = train(default_artifact_dir(), cfg(ShardMode::Zdp, 4, 25))
        .expect("training");
    assert_eq!(rep.steps.len(), 25);
    assert!(
        rep.last_loss() < rep.first_loss() * 0.95,
        "expected descent: {} -> {}",
        rep.first_loss(),
        rep.last_loss()
    );
}

/// DP and ZDP make identical optimization trajectories at every worker
/// count — sharding changes the layout, never the math.
#[test]
fn dp_equals_zdp_across_worker_counts() {
    require_artifacts!();
    for workers in [1usize, 2, 4] {
        let dp = train(default_artifact_dir(), cfg(ShardMode::Dp, workers, 5))
            .expect("dp");
        let zdp =
            train(default_artifact_dir(), cfg(ShardMode::Zdp, workers, 5))
                .expect("zdp");
        for (a, b) in dp.steps.iter().zip(&zdp.steps) {
            assert!(
                (a.loss - b.loss).abs() < 5e-4,
                "workers={workers} step {}: {} vs {}",
                a.step,
                a.loss,
                b.loss
            );
        }
    }
}

/// Changing the worker count preserves the *global* computation when the
/// global batch is fixed by construction? It is not (batch per worker is
/// fixed), so instead check determinism: same config twice = same losses.
#[test]
fn training_is_deterministic() {
    require_artifacts!();
    let a = train(default_artifact_dir(), cfg(ShardMode::Zdp, 2, 4)).unwrap();
    let b = train(default_artifact_dir(), cfg(ShardMode::Zdp, 2, 4)).unwrap();
    for (x, y) in a.steps.iter().zip(&b.steps) {
        assert_eq!(x.loss, y.loss, "nondeterminism at step {}", x.step);
    }
}

/// ZDP moves more bytes than DP (the 1.5× of Figure 1) and the simulated
/// clock reflects the (α,β) charges.
#[test]
fn zdp_pays_more_communication() {
    require_artifacts!();
    let dp = train(default_artifact_dir(), cfg(ShardMode::Dp, 4, 3)).unwrap();
    let zdp = train(default_artifact_dir(), cfg(ShardMode::Zdp, 4, 3)).unwrap();
    let ratio = zdp.bytes_sent_per_worker as f64
        / dp.bytes_sent_per_worker as f64;
    // DP all-reduce sends 2·(N−1)/N·P per worker; ZDP gather+gather+RS
    // sends (N−1)/N·(P + P + P) = 1.5× — allow loose bounds for the loss
    // collective etc.
    assert!(
        (1.3..1.7).contains(&ratio),
        "ZDP/DP bytes ratio {ratio} (expected ≈1.5)"
    );
    assert!(zdp.sim_seconds > dp.sim_seconds * 0.9);
}

/// Memory tracker: ZDP peak (shards + transient gather) sits well under
/// DP peak (full states) for the tiny model at 4 workers.
#[test]
fn tracked_memory_reflects_sharding() {
    require_artifacts!();
    let dp = train(default_artifact_dir(), cfg(ShardMode::Dp, 4, 2)).unwrap();
    let zdp = train(default_artifact_dir(), cfg(ShardMode::Zdp, 4, 2)).unwrap();
    // tiny: P = 136960 f32. DP states = 16·P bytes; ZDP = 4·P + gather 4·P.
    let p_bytes = 136_960.0 * 4.0;
    assert!((dp.peak_mem - 4.0 * p_bytes).abs() < 1.0);
    assert!((zdp.peak_mem - (p_bytes + p_bytes)).abs() < 1.0);
}
