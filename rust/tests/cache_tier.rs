//! Second-cache-tier acceptance tests (ISSUE 8):
//!
//! * a healthy shared tier serves **cross-instance** hits: instance A
//!   plans, instance B answers the same query from the tier without
//!   running a planner — bit-identical to A's answer;
//! * every hostile remote — dead address, mid-response socket reset,
//!   garbage payloads, version-skewed entries, a slow-loris server —
//!   yields plans **bit-identical** to a remote-less service, with the
//!   failure counted in exactly one of `remote_errors` /
//!   `remote_timeouts` / `remote_quarantined`;
//! * consecutive failures trip the circuit breaker
//!   (closed → open → half-open → closed), and a tripped breaker bounds
//!   the added per-query latency to (nearly) nothing;
//! * the stats invariant extends across tiers:
//!   `hits + remote_hits + misses == queries − rejected`;
//! * best-of-K warm starts never visit more nodes than the old
//!   single-neighbor policy, and never change the answer.

use osdp::config::GIB;
use osdp::cost::Profiler;
use osdp::service::{Answer, CacheServerHandler, Frontend, FrontendConfig,
                    PlanQuery, PlanService, RemoteConfig, RemoteOutcome,
                    RemoteTier, Source, Telemetry, handle_line_full};
use osdp::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

const TINY: &str = "gpt:3000,64,6,192,4";

fn tiny_service_profiler() -> Profiler {
    let q = PlanQuery::batch(TINY, 8.0, 1);
    let cluster = q.cluster.resolve().unwrap();
    let model = osdp::service::resolve_setting(TINY).unwrap();
    Profiler::new(&model, &cluster, &q.search)
}

/// A limit (in GiB) around `frac` of the tiny model's all-DP peak at
/// `b` — the same construction the service tests use, so limits land
/// in the interesting (mixed-plan) region.
fn tiny_mem_gib(frac: f64, b: usize) -> f64 {
    let p = tiny_service_profiler();
    p.evaluate(&p.index_of(|d| d.is_pure_dp()), b).peak_mem * frac / GIB
}

fn choice_of(resp: &osdp::service::QueryResponse) -> Vec<usize> {
    match &resp.answer {
        Answer::Plan { plan, .. } => plan.choice.clone(),
        Answer::Sweep { plans, best, .. } => plans[*best].choice.clone(),
    }
}

fn nodes_of(resp: &osdp::service::QueryResponse) -> u64 {
    match &resp.answer {
        Answer::Plan { stats, .. } => stats.nodes,
        Answer::Sweep { stats, .. } => stats.nodes,
    }
}

/// A remote-tier client with test-friendly knobs: generous deadline for
/// healthy-server tests, short cooldown so breaker recovery is testable.
fn test_remote(addr: &str) -> RemoteConfig {
    let mut cfg = RemoteConfig::new(addr);
    cfg.deadline = Duration::from_millis(250);
    cfg.cooldown = Duration::from_millis(50);
    cfg
}

/// Serve `handler_fn` on an ephemeral loopback port: each accepted
/// connection is handed to the closure (hostile servers misbehave per
/// connection). The acceptor stops after `max_conns` connections.
fn hostile_server(
    max_conns: usize,
    handler_fn: impl Fn(TcpStream) + Send + 'static,
) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    thread::spawn(move || {
        for stream in listener.incoming().take(max_conns) {
            let Ok(stream) = stream else { continue };
            handler_fn(stream);
        }
    });
    addr
}

/// An address that is bound, then immediately released: connecting to
/// it fails fast and deterministically (no listener, no firewall hang).
fn dead_addr() -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    listener.local_addr().unwrap()
}

fn read_request_line(stream: &mut TcpStream) {
    let mut one = [0u8; 1];
    while let Ok(1) = stream.read(&mut one) {
        if one[0] == b'\n' {
            break;
        }
    }
}

/// The baseline answers for a batch of queries on a remote-less service.
fn baseline(queries: &[PlanQuery]) -> Vec<Vec<usize>> {
    let service = PlanService::in_memory();
    queries
        .iter()
        .map(|q| choice_of(&service.query(q).unwrap()))
        .collect()
}

fn queries() -> Vec<PlanQuery> {
    let mem = tiny_mem_gib(0.6, 2);
    let mut out = Vec::new();
    for b in [1usize, 2, 3] {
        let mut q = PlanQuery::batch(TINY, mem, b);
        q.threads = 1;
        out.push(q);
    }
    out
}

/// Run `queries` on a service wired to `remote_addr` and assert every
/// answer is bit-identical to the remote-less baseline. Returns the
/// service for counter assertions.
fn assert_identical_under(remote_addr: &str) -> PlanService {
    let qs = queries();
    let want = baseline(&qs);
    let mut service = PlanService::in_memory();
    service.attach_remote(RemoteTier::start(test_remote(remote_addr)));
    for (q, want) in qs.iter().zip(&want) {
        let got = service.query(q).unwrap();
        assert_eq!(
            &choice_of(&got),
            want,
            "a hostile remote must never change an answer"
        );
    }
    service
}

// ---------------------------------------------------------------------
// healthy tier: cross-instance sharing
// ---------------------------------------------------------------------

#[test]
fn two_instances_share_plans_through_the_tier() {
    let telemetry = Arc::new(Telemetry::new());
    let tier_frontend = Frontend::start_with(
        Arc::new(CacheServerHandler::new(1024)),
        Arc::clone(&telemetry),
        FrontendConfig { workers: 2, ..Default::default() },
    )
    .expect("bind the cache tier");
    let tier_addr = tier_frontend.local_addr().to_string();

    let qs = queries();
    let want = baseline(&qs);

    // instance A plans everything and writes behind to the tier
    let mut a = PlanService::in_memory();
    a.attach_remote(RemoteTier::start(test_remote(&tier_addr)));
    for q in &qs {
        a.query(q).unwrap();
    }
    a.remote().unwrap().flush(Duration::from_secs(5));
    let sa = a.stats();
    assert_eq!(sa.planner_runs as usize, qs.len());
    assert_eq!(sa.remote_hits, 0, "a fresh tier cannot hit");
    assert_eq!(sa.remote_misses as usize, qs.len(),
               "every A-side miss asked the tier first: {sa:?}");

    // instance B answers the same traffic from the tier: zero planner
    // runs, source=remote, bit-identical choices
    let mut b = PlanService::in_memory();
    b.attach_remote(RemoteTier::start(test_remote(&tier_addr)));
    for (q, want) in qs.iter().zip(&want) {
        let got = b.query(q).unwrap();
        assert_eq!(got.source, Source::Remote, "B must hit the tier");
        assert_eq!(got.source.label(), "remote");
        assert_eq!(&choice_of(&got), want,
                   "a tier hit must be bit-identical to A's plan");
    }
    let sb = b.stats();
    assert_eq!(sb.planner_runs, 0, "B never plans: {sb:?}");
    assert_eq!(sb.remote_hits as usize, qs.len());
    assert_eq!(sb.misses, 0,
               "remote hits reclassify the provisional miss: {sb:?}");
    // a second pass on B is now a pure L1 hit (the read-through
    // populated the local cache)
    let again = b.query(&qs[0]).unwrap();
    assert_eq!(again.source, Source::Cache);

    assert_eq!(b.breaker_state(), "closed");
    tier_frontend.shutdown();
    tier_frontend.join();
}

// ---------------------------------------------------------------------
// hostile remotes: bit-identity + exact failure accounting
// ---------------------------------------------------------------------

#[test]
fn dead_remote_is_invisible_and_counts_errors() {
    let addr = dead_addr();
    let service = assert_identical_under(&addr.to_string());
    let s = service.stats();
    assert!(s.remote_errors > 0, "dead-address connects must count: {s:?}");
    assert_eq!(s.remote_hits + s.remote_misses, 0);
    assert_eq!(service.stats().planner_runs as usize, queries().len());
}

#[test]
fn mid_response_reset_is_an_error_not_a_wrong_answer() {
    let addr = hostile_server(64, |mut stream| {
        read_request_line(&mut stream);
        // half a JSON object, then a hard close
        let _ = stream.write_all(br#"{"ok":tru"#);
        drop(stream);
    });
    let service = assert_identical_under(&addr.to_string());
    let s = service.stats();
    assert!(s.remote_errors > 0,
            "a torn response is an I/O error: {s:?}");
    assert_eq!(s.remote_quarantined, 0,
               "a torn response never parses far enough to quarantine");
}

#[test]
fn garbage_payloads_quarantine_and_never_propagate() {
    let addr = hostile_server(64, |mut stream| {
        read_request_line(&mut stream);
        let _ = stream.write_all(b"!!not json at all!!\n");
    });
    let service = assert_identical_under(&addr.to_string());
    let s = service.stats();
    assert!(s.remote_quarantined > 0,
            "garbage bytes must quarantine: {s:?}");
    assert_eq!(s.remote_errors + s.remote_timeouts, 0,
               "the transport worked; only the payload was rotten: {s:?}");
    assert_eq!(service.breaker_state(), "closed",
               "the breaker tracks availability, not payload quality");
}

#[test]
fn version_skewed_entries_quarantine() {
    // a "hit" whose entry comes from another cost-model epoch: the
    // entry must be rejected wholesale, exactly like the disk cache
    let addr = hostile_server(64, |mut stream| {
        read_request_line(&mut stream);
        let entry = r#"{"choice":[0,0],"epoch":999,"key":"x","kind":"plan","req":"r","schema":1}"#;
        let resp = format!(
            "{{\"entry\":{entry},\"hit\":true,\"kind\":\"entry\",\"ok\":true}}\n"
        );
        let _ = stream.write_all(resp.as_bytes());
    });
    let service = assert_identical_under(&addr.to_string());
    let s = service.stats();
    assert!(s.remote_quarantined > 0,
            "epoch-skewed entries must quarantine: {s:?}");
    assert_eq!(s.remote_hits, 0, "never served: {s:?}");
}

#[test]
fn slow_loris_times_out_within_the_deadline_budget() {
    let addr = hostile_server(64, |mut stream| {
        read_request_line(&mut stream);
        // trickle one byte at a time, far slower than any budget
        for _ in 0..200 {
            if stream.write_all(b"x").is_err() {
                return; // client gave up (that's the point)
            }
            thread::sleep(Duration::from_millis(10));
        }
    });
    let qs = queries();
    let want = baseline(&qs);
    let mut service = PlanService::in_memory();
    let mut cfg = test_remote(&addr.to_string());
    cfg.deadline = Duration::from_millis(40);
    cfg.breaker_threshold = u32::MAX; // keep probing; we time every get
    service.attach_remote(RemoteTier::start(cfg));
    for (q, want) in qs.iter().zip(&want) {
        let started = Instant::now();
        let got = service.query(q).unwrap();
        let elapsed = started.elapsed();
        assert_eq!(&choice_of(&got), want);
        // generous slack for CI schedulers: the point is that a 2s
        // trickle cannot hold a 40ms budget hostage
        assert!(
            elapsed < Duration::from_secs(1),
            "slow-loris remote held a query for {elapsed:?}"
        );
    }
    let s = service.stats();
    assert!(s.remote_timeouts > 0,
            "budget exhaustion must count as a timeout: {s:?}");
}

// ---------------------------------------------------------------------
// the circuit breaker: transitions and the latency cap
// ---------------------------------------------------------------------

#[test]
fn breaker_trips_open_then_recovers_through_half_open() {
    // phase 1: a dead remote trips the breaker after exactly
    // `threshold` consecutive failures
    let dead = dead_addr();
    let mut cfg = test_remote(&dead.to_string());
    cfg.breaker_threshold = 3;
    cfg.cooldown = Duration::from_millis(40);
    let tier = RemoteTier::start(cfg.clone());
    let k = osdp::service::QueryKey::for_query(
        &tiny_service_profiler(),
        8.0 * GIB,
        osdp::service::QueryShape::Batch(2),
    );
    assert_eq!(tier.breaker_state(), "closed");
    for _ in 0..3 {
        let out = tier.get(&k, "plan setting=x mem=8 batch=2");
        assert!(matches!(out, RemoteOutcome::Error | RemoteOutcome::Timeout),
                "dead remote: {out:?}");
    }
    assert_eq!(tier.breaker_state(), "open",
               "3 consecutive failures must trip the breaker");
    assert_eq!(tier.breaker_open_count(), 1);

    // phase 2: while open, operations are Skipped at (near) zero cost —
    // the tripped breaker caps added latency per query
    let started = Instant::now();
    for _ in 0..100 {
        assert_eq!(tier.get(&k, "plan x"), RemoteOutcome::Skipped);
    }
    assert!(
        started.elapsed() < Duration::from_millis(100),
        "100 open-breaker lookups must cost ~nothing, took {:?}",
        started.elapsed()
    );

    // phase 3: stand a healthy server on a *new* tier's address and
    // watch open → half-open (probe) → closed
    let telemetry = Arc::new(Telemetry::new());
    let frontend = Frontend::start_with(
        Arc::new(CacheServerHandler::new(64)),
        telemetry,
        FrontendConfig { workers: 1, ..Default::default() },
    )
    .unwrap();
    let mut cfg2 = test_remote(&frontend.local_addr().to_string());
    cfg2.breaker_threshold = 1;
    cfg2.cooldown = Duration::from_millis(30);
    let healing = RemoteTier::start(cfg2);
    // healthy server: a miss is a *successful* operation, breaker stays
    // closed
    assert_eq!(healing.get(&k, "plan x"), RemoteOutcome::Miss);
    assert_eq!(healing.breaker_state(), "closed");
    frontend.shutdown();
    frontend.join();
    // the server is gone now: the next failure trips (threshold 1),
    // and after the cooldown the tier probes (half-open) and re-opens
    // on the failed probe — the full open → half-open → open walk
    let out = healing.get(&k, "plan x");
    assert!(matches!(out, RemoteOutcome::Error | RemoteOutcome::Timeout));
    assert_eq!(healing.breaker_state(), "open");
    let opens_before = healing.breaker_open_count();
    thread::sleep(Duration::from_millis(35));
    let out = healing.get(&k, "plan x");
    assert!(matches!(out, RemoteOutcome::Error | RemoteOutcome::Timeout),
            "the cooldown admits exactly one probe: {out:?}");
    assert_eq!(healing.breaker_state(), "open",
               "a failed probe re-opens");
    assert!(healing.breaker_open_count() > opens_before);
}

// ---------------------------------------------------------------------
// the cross-tier stats invariant, measured through the protocol layer
// ---------------------------------------------------------------------

#[test]
fn stats_invariant_holds_across_tiers() {
    let telemetry = Arc::new(Telemetry::new());
    let tier_frontend = Frontend::start_with(
        Arc::new(CacheServerHandler::new(1024)),
        Arc::clone(&telemetry),
        FrontendConfig { workers: 1, ..Default::default() },
    )
    .unwrap();
    let tier_addr = tier_frontend.local_addr().to_string();

    // warm the tier from instance A
    let mut a = PlanService::in_memory();
    a.attach_remote(RemoteTier::start(test_remote(&tier_addr)));
    let mem = tiny_mem_gib(0.6, 2);
    for line in [
        format!("query setting={TINY} mem={mem} batch=2 threads=1"),
        format!("query setting={TINY} mem={mem} batch=3 threads=1"),
    ] {
        let t = Telemetry::new();
        handle_line_full(&a, Some(&t), &line);
    }
    a.remote().unwrap().flush(Duration::from_secs(5));

    // instance B sees a mix: remote hits, L1 hits, real misses,
    // rejected junk — the invariant must hold exactly
    let mut b = PlanService::in_memory();
    b.attach_remote(RemoteTier::start(test_remote(&tier_addr)));
    let t = Telemetry::new();
    let lines = [
        format!("query setting={TINY} mem={mem} batch=2 threads=1"), // remote hit
        format!("query setting={TINY} mem={mem} batch=2 threads=1"), // L1 hit
        format!("query setting={TINY} mem={mem} batch=4 threads=1"), // miss
        format!("query setting=nope mem={mem} batch=2"),             // rejected
        format!("query setting={TINY} mem={mem} batch=3 threads=1"), // remote hit
    ];
    for line in &lines {
        handle_line_full(&b, Some(&t), line);
    }
    let s = b.stats();
    assert_eq!(s.remote_hits, 2, "{s:?}");
    assert_eq!(s.hits, 1, "{s:?}");
    assert_eq!(s.misses, 1, "{s:?}");
    assert_eq!(s.planner_runs, 1, "{s:?}");
    let rejected = t.get(osdp::service::Counter::Rejected);
    assert_eq!(
        s.hits + s.remote_hits + s.misses,
        t.queries() - rejected,
        "hits + remote_hits + misses == queries - rejected: {s:?}"
    );
    // the stats verb surfaces the new counters and the breaker state
    let (resp, _) = handle_line_full(&b, Some(&t), "stats");
    let doc = Json::parse(&resp).unwrap();
    assert_eq!(doc.get("remote_hits").as_usize(), Some(2), "{resp}");
    assert_eq!(doc.get("breaker").as_str(), Some("closed"), "{resp}");
    tier_frontend.shutdown();
    tier_frontend.join();
}

// ---------------------------------------------------------------------
// best-of-K warm starts: node-count non-regression, answers unchanged
// ---------------------------------------------------------------------

#[test]
fn best_of_k_warm_start_never_visits_more_nodes_than_single_neighbor() {
    let mem_lo = tiny_mem_gib(0.45, 4);
    let mem_hi = tiny_mem_gib(0.9, 4);
    let mk = |b: usize, mem: f64| {
        let mut q = PlanQuery::batch(TINY, mem, b);
        q.threads = 1;
        q
    };
    let target = mk(4, mem_hi);

    // cold truth
    let cold_service = PlanService::in_memory();
    let cold = cold_service.query(&target).unwrap();

    // single-neighbor policy ≈ a cache holding only the nearest
    // neighbor (batch 3 at the same limit)
    let single = PlanService::in_memory();
    single.query(&mk(3, mem_hi)).unwrap();
    let single_resp = single.query(&target).unwrap();

    // K-nearest sees strictly more candidates: batches 2, 3 and a
    // tighter-limit batch 4 entry
    let multi = PlanService::in_memory();
    multi.query(&mk(2, mem_hi)).unwrap();
    multi.query(&mk(3, mem_hi)).unwrap();
    multi.query(&mk(4, mem_lo)).unwrap();
    let warm_seeded_before = multi.stats().warm_seeded;
    let multi_resp = multi.query(&target).unwrap();

    assert_eq!(choice_of(&multi_resp), choice_of(&cold),
               "warm starts must never change the answer");
    assert_eq!(choice_of(&single_resp), choice_of(&cold));
    assert!(
        nodes_of(&multi_resp) <= nodes_of(&single_resp),
        "best-of-K ({}) must prune at least as hard as the single \
         neighbor ({})",
        nodes_of(&multi_resp),
        nodes_of(&single_resp),
    );
    assert!(nodes_of(&multi_resp) <= nodes_of(&cold));
    assert_eq!(multi.stats().warm_seeded, warm_seeded_before + 1,
               "the target query must have been warm-seeded: {:?}",
               multi.stats());
}
