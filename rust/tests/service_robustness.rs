//! Hostile-environment robustness (ISSUE 7 satellites): the plan
//! service must degrade, never die, when the disk under it misbehaves.
//!
//! * a corrupt / zero-length / wrong-epoch cache file never aborts
//!   startup — it quarantines (or harvests) and the service serves
//!   misses with the right counters;
//! * an unwritable cache directory costs bounded retries and a
//!   `persist_errors` tick per miss, never an error surfaced to the
//!   querying client;
//! * crash-safe persistence: a leftover truncated temp file neither
//!   corrupts nor shadows the live cache across a service restart.

use osdp::service::{CacheConfig, PlanQuery, PlanService};
use osdp::util::json::Json;

const TINY: &str = "gpt:3000,64,6,192,4";

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "osdp-robust-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn cfg(dir: &std::path::Path) -> CacheConfig {
    CacheConfig { capacity: 16, disk_dir: Some(dir.to_path_buf()) }
}

#[test]
fn corrupt_cache_files_never_abort_startup() {
    for (tag, payload) in [("garbage", "}{ not json at all"),
                           ("empty", "")]
    {
        let dir = tmp_dir(tag);
        let path = dir.join("plan_cache.json");
        std::fs::write(&path, payload).unwrap();

        let (service, stale) = PlanService::open(cfg(&dir));
        assert!(stale.is_empty(), "nothing to harvest from {tag}");
        let s = service.stats();
        assert_eq!(s.stale_rejected, 1, "{tag}");
        assert_eq!(s.quarantined_entries, 1, "{tag}");
        assert!(!path.exists(),
                "the corpse moves aside so it cannot shadow ({tag})");
        assert!(path.with_extension("json.bad").exists(),
                "evidence is preserved, not deleted ({tag})");

        // and the service actually serves: a query is a plain miss
        let resp =
            service.query(&PlanQuery::batch(TINY, 8.0, 1)).unwrap();
        assert!(matches!(resp.answer,
                         osdp::service::Answer::Plan { .. }));
        let s = service.stats();
        assert_eq!((s.hits, s.misses), (0, 1), "{tag}");
        assert_eq!(s.persist_errors, 0,
                   "a quarantined predecessor must not break persistence");
        // the fresh persist produced a healthy file
        Json::parse(&std::fs::read_to_string(&path).unwrap())
            .expect("rewritten cache file is valid JSON");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn unwritable_cache_dir_degrades_to_memory_only_with_counters() {
    // the configured "directory" is a regular file: every persist
    // attempt must fail, burn its bounded retries, and leave the
    // serve path entirely unharmed
    let dir = tmp_dir("unwritable");
    let blocker = dir.join("blocker");
    std::fs::write(&blocker, "i am a file, not a directory").unwrap();

    let service = PlanService::new(cfg(&blocker));
    let resp = service.query(&PlanQuery::batch(TINY, 8.0, 1)).unwrap();
    assert!(matches!(resp.answer, osdp::service::Answer::Plan { .. }));
    let s = service.stats();
    assert_eq!(s.misses, 1);
    assert_eq!(s.persist_errors, 1,
               "the failed persist is counted once");
    assert_eq!(s.cache_write_retries, 2,
               "3 attempts = 2 retries before giving up");

    // the cache still works in memory: same query is now a hit — and
    // the restored dirty flag means the service keeps *trying* to
    // persist (and keeps failing, and keeps serving)
    let again = service.query(&PlanQuery::batch(TINY, 8.0, 1)).unwrap();
    assert_eq!(again.source, osdp::service::Source::Cache);
    let s = service.stats();
    assert_eq!((s.hits, s.persist_errors, s.cache_write_retries),
               (1, 2, 4),
               "unpersisted data is retried on the next query, not dropped");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_temp_from_a_crashed_writer_is_harmless() {
    let dir = tmp_dir("torn-temp");
    let service = PlanService::new(cfg(&dir));
    service.query(&PlanQuery::batch(TINY, 8.0, 1)).unwrap();
    drop(service);

    let path = dir.join("plan_cache.json");
    assert!(path.exists());
    assert!(!path.with_extension("json.tmp").exists(),
            "a successful persist leaves no temp behind");

    // simulate a crash mid-write next to the live file
    let live = std::fs::read_to_string(&path).unwrap();
    std::fs::write(path.with_extension("json.tmp"), &live[..12]).unwrap();

    let (service, stale) = PlanService::open(cfg(&dir));
    assert!(stale.is_empty());
    let s = service.stats();
    assert_eq!((s.stale_rejected, s.quarantined_entries), (0, 0),
               "the loader never reads temp files");
    let hit = service.query(&PlanQuery::batch(TINY, 8.0, 1)).unwrap();
    assert_eq!(hit.source, osdp::service::Source::Cache,
               "the live file was not shadowed by the torn temp");

    // the next persist clears the corpse
    service.query(&PlanQuery::batch(TINY, 8.0, 2)).unwrap();
    assert!(!path.with_extension("json.tmp").exists());
    let _ = std::fs::remove_dir_all(&dir);
}
