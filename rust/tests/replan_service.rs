//! Elastic-replan acceptance (ISSUE 7):
//!
//! * re-planning onto a changed cluster is **bit-identical** to a cold
//!   search on that cluster (full choice vector + time bits), serial
//!   and 8-threaded, across shrink / grow / topology-change events —
//!   including a whole-node loss that removes the node-scope dimension
//!   from the search space;
//! * on the 24L model, a replan seeded from the old cluster's optimum
//!   visits strictly fewer nodes than a cold search somewhere on the
//!   limit scan (and never more);
//! * `replans` / `replan_repairs` count what actually happened, the
//!   degenerate same-hardware replan included;
//! * the capacity sweep walks the device ladder, locates the hardware
//!   floor, and keeps the telemetry invariants exact per rung.

use osdp::config::GIB;
use osdp::cost::Profiler;
use osdp::service::{Answer, ClusterSpec, Counter, PlanError, PlanQuery,
                    PlanService, QueryShape, Source, Telemetry,
                    resolve_setting};

const TINY: &str = "gpt:3000,64,6,192,4";
const DEEP: &str = "gpt:5000,128,24,256,4";

fn spec(preset: &str, devices: Option<usize>, mem_gib: f64) -> ClusterSpec {
    ClusterSpec { preset: preset.into(), devices, mem_gib }
}

fn profiler_for(q: &PlanQuery) -> Profiler {
    let cluster = q.cluster.resolve().unwrap();
    let model = resolve_setting(&q.setting).unwrap();
    Profiler::new(&model, &cluster, &q.search)
}

/// All-DP peak (GiB) at `b` — device-count independent (DP replicates
/// every state), so one number prices a limit for both clusters of a
/// replan event.
fn dp_peak_gib(q: &PlanQuery, b: usize) -> f64 {
    let p = profiler_for(q);
    p.evaluate(&p.index_of(|d| d.is_pure_dp()), b).peak_mem / GIB
}

fn zdp_peak_gib(q: &PlanQuery, b: usize) -> f64 {
    let p = profiler_for(q);
    p.evaluate(&p.index_of(|d| d.is_pure_zdp()), b).peak_mem / GIB
}

// ---------------------------------------------------------------------
// bit-identity across cluster-change events
// ---------------------------------------------------------------------

#[test]
fn replan_is_bit_identical_to_a_cold_search_on_the_new_cluster() {
    // (old preset, old devices, new preset, new devices)
    let events: &[(&str, Option<usize>, &str, Option<usize>)] = &[
        ("rtx_titan", Some(8), "rtx_titan", Some(4)), // lose half
        ("rtx_titan", Some(4), "rtx_titan", Some(8)), // devices rejoin
        ("rtx_titan", Some(8), "rtx_titan", Some(6)), // partial loss
        // whole-node loss: the @node scope dimension disappears from
        // the new search space and projected decisions must degrade
        ("two_server_a100", None, "rtx_titan", Some(8)),
        // scale out across nodes: the scope dimension appears
        ("rtx_titan", Some(8), "two_server_a100", None),
    ];
    for &(old_preset, old_dev, new_preset, new_dev) in events {
        for threads in [1usize, 8] {
            for frac in [0.45, 0.7] {
                let mut old_q = PlanQuery::batch(TINY, 8.0, 2);
                old_q.cluster = spec(old_preset, old_dev, 8.0);
                old_q.search.granularities = vec![0, 2];
                old_q.threads = threads;
                let mem = dp_peak_gib(&old_q, 2) * frac;
                old_q.cluster.mem_gib = mem;
                let new_spec = spec(new_preset, new_dev, mem);

                let service = PlanService::in_memory();
                // old-cluster answer lands in the cache (when feasible)
                // and becomes the projection source
                let _ = service.query(&old_q);
                let replanned = service.replan(&old_q, &new_spec);

                let mut new_q = old_q.clone();
                new_q.cluster = new_spec.clone();
                let cold_service = PlanService::in_memory();
                let cold = cold_service.query(&new_q);

                let ctx = format!(
                    "{old_preset}:{old_dev:?} -> {new_preset}:{new_dev:?} \
                     threads={threads} frac={frac}"
                );
                match (&replanned, &cold) {
                    (Ok(r), Ok(c)) => {
                        assert_eq!(r.key, c.key, "{ctx}");
                        let (Answer::Plan { plan: rp, stats: rs },
                             Answer::Plan { plan: cp, stats: cs }) =
                            (&r.answer, &c.answer)
                        else {
                            panic!("batch queries answer plans ({ctx})");
                        };
                        assert_eq!(rp.choice, cp.choice,
                                   "choice diverged: {ctx}");
                        assert_eq!(rp.cost.time.to_bits(),
                                   cp.cost.time.to_bits(), "{ctx}");
                        assert_eq!(rp.cost.peak_mem.to_bits(),
                                   cp.cost.peak_mem.to_bits(), "{ctx}");
                        if threads == 1 {
                            assert!(rs.nodes <= cs.nodes,
                                    "replan explored more: {} > {} ({ctx})",
                                    rs.nodes, cs.nodes);
                        }
                    }
                    (Err(PlanError::Infeasible { batch: a }),
                     Err(PlanError::Infeasible { batch: b })) => {
                        assert_eq!(a, b, "{ctx}");
                    }
                    _ => panic!("feasibility changed by replan ({ctx}): \
                                 {replanned:?} vs {cold:?}"),
                }
                assert_eq!(service.stats().replans, 1, "{ctx}");
            }
        }
    }
}

#[test]
fn sweep_shaped_replans_are_bit_identical_too() {
    let mut old_q = PlanQuery::batch(TINY, 8.0, 1);
    old_q.shape = QueryShape::Sweep { max_batch: 4 };
    old_q.cluster.devices = Some(8);
    old_q.search.granularities = vec![0];
    old_q.threads = 1;
    let mem = dp_peak_gib(&old_q, 1) * 0.6;
    old_q.cluster.mem_gib = mem;
    let new_spec = spec("rtx_titan", Some(4), mem);

    let service = PlanService::in_memory();
    service.query(&old_q).unwrap();
    let replanned = service.replan(&old_q, &new_spec).unwrap();

    let mut new_q = old_q.clone();
    new_q.cluster = new_spec;
    let cold = PlanService::in_memory().query(&new_q).unwrap();

    let (Answer::Sweep { plans: rp, best: rb, .. },
         Answer::Sweep { plans: cp, best: cb, .. }) =
        (&replanned.answer, &cold.answer)
    else {
        panic!("sweep queries answer sweeps");
    };
    assert_eq!(rb, cb);
    assert_eq!(rp.len(), cp.len());
    for (a, b) in rp.iter().zip(cp) {
        assert_eq!(a.choice, b.choice);
        assert_eq!(a.cost.time.to_bits(), b.cost.time.to_bits());
    }
}

// ---------------------------------------------------------------------
// the 24L model: projected seeds actually prune
// ---------------------------------------------------------------------

#[test]
fn replanning_the_24l_model_prunes_against_cold_search() {
    let mut strict_seen = false;
    for frac in [0.35, 0.45, 0.55, 0.65, 0.75] {
        let mut old_q = PlanQuery::batch(DEEP, 8.0, 2);
        old_q.cluster.devices = Some(8);
        old_q.search.granularities = vec![0];
        old_q.threads = 1;
        let mem = dp_peak_gib(&old_q, 2) * frac;
        old_q.cluster.mem_gib = mem;
        let new_spec = spec("rtx_titan", Some(4), mem);

        let service = PlanService::in_memory();
        if service.query(&old_q).is_err() {
            continue; // nothing cached to project from
        }
        let Ok(replanned) = service.replan(&old_q, &new_spec) else {
            continue; // half the hardware no longer fits this limit
        };
        let mut new_q = old_q.clone();
        new_q.cluster = new_spec;
        let cold = PlanService::in_memory().query(&new_q).unwrap();
        let (Answer::Plan { plan: rp, stats: rs },
             Answer::Plan { plan: cp, stats: cs }) =
            (&replanned.answer, &cold.answer)
        else {
            panic!("batch queries answer plans");
        };
        assert_eq!(rp.choice, cp.choice, "frac={frac}");
        assert_eq!(rp.cost.time.to_bits(), cp.cost.time.to_bits());
        assert!(rs.nodes <= cs.nodes,
                "replan explored more at frac={frac}: {} > {}",
                rs.nodes, cs.nodes);
        if rs.nodes < cs.nodes {
            strict_seen = true;
        }
    }
    assert!(
        strict_seen,
        "no 24L replan strictly reduced visited nodes — the projected \
         seed is not actually pruning"
    );
}

// ---------------------------------------------------------------------
// counters + capacity sweep
// ---------------------------------------------------------------------

#[test]
fn replan_counters_track_repairs_and_degenerate_replans() {
    // a limit only all-ZDP@8 satisfies: feasible on 8 devices, nothing
    // fits on 4 (halving the group doubles every sharded state)
    let mut old_q = PlanQuery::batch(TINY, 8.0, 2);
    old_q.cluster.devices = Some(8);
    old_q.search.granularities = vec![0];
    old_q.threads = 1;
    old_q.cluster.mem_gib = zdp_peak_gib(&old_q, 2) * 1.02;

    let service = PlanService::in_memory();
    service.query(&old_q).unwrap();
    let r = service.replan(
        &old_q, &spec("rtx_titan", Some(4), old_q.cluster.mem_gib));
    assert!(matches!(r, Err(PlanError::Infeasible { .. })));
    let s = service.stats();
    assert_eq!(s.replans, 1);
    assert_eq!(s.replan_repairs, 1,
               "an unrepairable projection counts as a repair");

    // degenerate replan: the same hardware respelled — counted, served
    // from cache, and no repair
    let again = service.replan(&old_q, &old_q.cluster.clone()).unwrap();
    assert_eq!(again.source, Source::Cache);
    let s = service.stats();
    assert_eq!(s.replans, 2);
    assert_eq!(s.replan_repairs, 1);
}

#[test]
fn capacity_sweep_walks_the_ladder_and_finds_the_floor() {
    let mut old_q = PlanQuery::batch(TINY, 8.0, 2);
    old_q.cluster.devices = Some(8);
    old_q.search.granularities = vec![0];
    old_q.threads = 1;
    // only the full 8-device cluster holds this limit
    old_q.cluster.mem_gib = zdp_peak_gib(&old_q, 2) * 1.02;

    let service = PlanService::in_memory();
    let telemetry = Telemetry::new();
    let rungs = service
        .replan_sweep_clusters(&old_q, &old_q.cluster, Some(&telemetry))
        .unwrap();
    assert_eq!(rungs.iter().map(|r| r.devices).collect::<Vec<_>>(),
               vec![8, 4, 2, 1]);
    assert!(rungs[0].outcome.is_ok(), "the full cluster still fits");
    for r in &rungs[1..] {
        assert!(matches!(r.outcome, Err(PlanError::Infeasible { .. })),
                "N={} cannot fit an all-ZDP@8-sized limit", r.devices);
    }

    // every rung is one observed query and the pinned invariant holds
    let s = service.stats();
    assert_eq!(telemetry.queries(), 4);
    assert_eq!(s.hits + s.misses,
               telemetry.queries() - telemetry.get(Counter::Rejected));
    assert_eq!(s.replans, 4);
    // rungs land in the dedicated replan latency lane, not batch/sweep
    assert_eq!(telemetry.replan_latency.count(), 4,
               "capacity-sweep rungs observe into the replan lane");
    assert_eq!(telemetry.batch_latency.count()
                   + telemetry.sweep_latency.count(), 0);

    // the fixed two-server topology has no ladder to walk
    let err = service
        .replan_sweep_clusters(&old_q, &spec("two_server_a100", None, 8.0),
                               None)
        .unwrap_err();
    assert!(matches!(err, PlanError::BadRequest(_)));
}
