//! Tentpole guarantees of the parallel branch-and-bound planner:
//!
//! * exactness — parallel B&B equals brute-force enumeration on random
//!   profiler instances (seeded via `util::rng`);
//! * determinism — results are identical for `threads = 1` and
//!   `threads = 8` (the shared incumbent accelerates pruning but never
//!   decides a tie);
//! * serial equivalence — parallel results are bit-identical to the
//!   serial DFS, which shares the same bound machinery;
//! * menu safety — dominance filtering never removes the optimal plan.

use osdp::config::{Cluster, SearchConfig};
use osdp::cost::Profiler;
use osdp::model::{GptDims, build_gpt};
use osdp::planner::{Engine, ParallelConfig, exhaustive_search,
                    parallel_search};
use osdp::util::prop;
use osdp::util::rng::Rng;

#[derive(Debug, Clone)]
struct Instance {
    layers: usize,
    hidden: usize,
    n_dev: usize,
    b: usize,
    limit_frac: f64,
    grans: Vec<usize>,
}

fn gen_instance(rng: &mut Rng, size: usize) -> Instance {
    Instance {
        layers: rng.range(1, 1 + size / 30),
        hidden: 32 * rng.range(1, 6),
        n_dev: *rng.pick(&[2usize, 4, 8]),
        b: rng.range(1, 4),
        limit_frac: 0.25 + rng.f64() * 1.1,
        grans: if rng.chance(0.5) { vec![0] } else { vec![0, 2] },
    }
}

fn build(inst: &Instance) -> (Profiler, f64) {
    let m = build_gpt(&GptDims::uniform("p", 1000, 64, inst.layers,
                                        inst.hidden, 2));
    let c = Cluster::rtx_titan(inst.n_dev, 8.0);
    let s = SearchConfig { granularities: inst.grans.clone(),
                           ..Default::default() };
    let p = Profiler::new(&m, &c, &s);
    let dp_mem = p.evaluate(&p.index_of(|d| d.is_pure_dp()), inst.b).peak_mem;
    (p, dp_mem * inst.limit_frac)
}

/// Unlimited node budget: exactness/determinism are only guaranteed for
/// complete searches, so the tests make completeness structural instead of
/// asserting their way around per-task budget slicing.
fn cfg(threads: usize, split_depth: usize) -> ParallelConfig {
    ParallelConfig {
        threads,
        split_depth,
        node_budget: u64::MAX,
        engine: Engine::FoldedBb,
    }
}

/// Parallel B&B equals brute force wherever brute force is affordable.
#[test]
fn prop_parallel_bnb_is_exact() {
    prop::check(0x9A8A11E1, 20, gen_instance, |inst| {
        let (p, limit) = build(inst);
        if p.log10_plan_space() > 5.5 {
            return Ok(()); // brute force too big; covered by other props
        }
        let brute = exhaustive_search(&p, limit, inst.b);
        let smart = parallel_search(&p, limit, inst.b, &cfg(4, 2));
        match (brute, smart) {
            (None, None) => Ok(()),
            (Some((_, bc)), Some((_, sc, stats))) => {
                if !stats.complete {
                    return Err("budget expired on a tiny instance".into());
                }
                if sc.peak_mem > limit {
                    return Err(format!("overflows: {}", sc.peak_mem));
                }
                prop::close(bc.time, sc.time, 1e-10)
            }
            (b, s) => Err(format!(
                "feasibility disagreement: brute={:?} parallel={:?}",
                b.is_some(),
                s.is_some()
            )),
        }
    });
}

/// Parallel results are bit-identical to the serial DFS (shared bound
/// machinery, shared canonical tie-break) on random instances.
#[test]
fn prop_parallel_matches_serial_bitwise() {
    prop::check(0x5E71A1, 25, gen_instance, |inst| {
        let (p, limit) = build(inst);
        let serial =
            osdp::planner::dfs::search_with_budget(&p, limit, inst.b,
                                                   u64::MAX);
        let par = parallel_search(&p, limit, inst.b, &cfg(4, 3));
        match (serial, par) {
            (None, None) => Ok(()),
            (Some((sc, scost, sst)), Some((pc, pcost, pst))) => {
                if !(sst.complete && pst.complete) {
                    return Err("budget expired".into());
                }
                if sc != pc {
                    return Err(format!("choice differs: {sc:?} vs {pc:?}"));
                }
                if scost.time.to_bits() != pcost.time.to_bits()
                    || scost.peak_mem.to_bits() != pcost.peak_mem.to_bits()
                {
                    return Err(format!(
                        "cost differs: {:?} vs {:?}", scost, pcost
                    ));
                }
                Ok(())
            }
            (s, p) => Err(format!(
                "feasibility disagreement: serial={:?} parallel={:?}",
                s.is_some(),
                p.is_some()
            )),
        }
    });
}

/// The `--threads 1` and `--threads 8` results are identical — choice
/// vector and cost bits — across a sweep of memory limits.
#[test]
fn determinism_one_vs_eight_threads() {
    let m = build_gpt(&GptDims::uniform("det", 4000, 128, 4, 256, 4));
    let c = Cluster::rtx_titan(8, 8.0);
    let s = SearchConfig { granularities: vec![0, 2],
                           ..Default::default() };
    let p = Profiler::new(&m, &c, &s);
    let dp_mem = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 2).peak_mem;
    let mut feasible = 0;
    for frac in [0.35, 0.5, 0.65, 0.8, 0.95, 1.1] {
        let limit = dp_mem * frac;
        let one = parallel_search(&p, limit, 2, &cfg(1, 3));
        // repeat the 8-thread run to also catch run-to-run nondeterminism
        for _ in 0..3 {
            let eight = parallel_search(&p, limit, 2, &cfg(8, 3));
            match (&one, &eight) {
                (None, None) => {}
                (Some((c1, cost1, st1)), Some((c8, cost8, st8))) => {
                    assert!(st1.complete && st8.complete);
                    assert_eq!(c1, c8, "choice diverged at frac {frac}");
                    assert_eq!(cost1.time.to_bits(), cost8.time.to_bits());
                    assert_eq!(cost1.peak_mem.to_bits(),
                               cost8.peak_mem.to_bits());
                    feasible += 1;
                }
                _ => panic!("feasibility diverged at frac {frac}"),
            }
        }
    }
    assert!(feasible > 0, "sweep must exercise feasible limits");
}

/// Dominance filtering never removes the optimal plan: exhaustive search
/// over raw menus and Pareto-filtered menus returns the same optimum on
/// random small instances.
#[test]
fn prop_dominance_preserves_optimum() {
    prop::check(0xD0317A7E, 15, gen_instance, |inst| {
        let m = build_gpt(&GptDims::uniform("p", 1000, 64, inst.layers,
                                            inst.hidden, 2));
        let c = Cluster::rtx_titan(inst.n_dev, 8.0);
        let s = SearchConfig { granularities: inst.grans.clone(),
                               ..Default::default() };
        let raw = Profiler::with_pruning(&m, &c, &s, false);
        if raw.log10_plan_space() > 5.5 {
            return Ok(());
        }
        let pruned = Profiler::new(&m, &c, &s);
        let dp_mem = raw
            .evaluate(&raw.index_of(|d| d.is_pure_dp()), inst.b)
            .peak_mem;
        let limit = dp_mem * inst.limit_frac;
        let a = exhaustive_search(&raw, limit, inst.b);
        let b = exhaustive_search(&pruned, limit, inst.b);
        match (a, b) {
            (None, None) => Ok(()),
            (Some((_, ca)), Some((_, cb))) => {
                prop::close(ca.time, cb.time, 1e-10)
            }
            (a, b) => Err(format!(
                "pruning changed feasibility: raw={:?} pruned={:?}",
                a.is_some(),
                b.is_some()
            )),
        }
    });
}
