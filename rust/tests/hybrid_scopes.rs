//! Acceptance tests for the per-operator sharding-scope dimension
//! (ISSUE 4): on the paper's two-server topology with a memory limit that
//! forces sharding, the swept plan must place at least one operator at
//! node-local scope, strictly beat both the all-global-ZDP baseline and
//! the best scope-free plan, and keep every exact engine bit-identical on
//! the full choice vector (scope included) at 1 and 8 threads.

use osdp::config::{Cluster, SearchConfig};
use osdp::cost::{Decision, Profiler, Scope};
use osdp::model::{GptDims, ModelDesc, build_gpt};
use osdp::planner::{Engine, ExecutionPlan, ParallelConfig, Scheduler,
                    exhaustive_search, parallel_search};

fn model() -> ModelDesc {
    build_gpt(&GptDims::uniform("accept", 4000, 128, 4, 512, 8))
}

/// The two-server cluster with a limit between the all-DP and sharded
/// footprints, so the planner *must* shard somewhere.
fn forcing_cluster(m: &ModelDesc) -> Cluster {
    let base = Cluster::two_server_a100(16.0);
    Cluster { mem_limit: m.state_bytes() * 0.6, ..base }
}

fn search_cfg(hybrid_scopes: bool) -> SearchConfig {
    SearchConfig {
        max_batch: 8,
        granularities: vec![0],
        paper_granularity: true,
        hybrid_scopes,
        ..Default::default()
    }
}

#[test]
fn swept_plan_uses_node_scope_and_beats_global_and_scope_free() {
    let m = model();
    let c = forcing_cluster(&m);
    let scoped = Profiler::new(&m, &c, &search_cfg(true));
    let flat = Profiler::new(&m, &c, &search_cfg(false));

    // sharding is genuinely forced: all-DP does not fit at b=1
    let dp = scoped.evaluate(&scoped.index_of(|d| d.is_pure_dp()), 1);
    assert!(dp.peak_mem > c.mem_limit, "limit must force sharding");

    let res = Scheduler::new(&scoped, c.mem_limit, 8).run()
        .expect("scoped sweep feasible");
    let best = res.best_plan();
    assert!(
        best.node_scoped_ops() >= 1,
        "the swept plan must use node-local scope somewhere: {}",
        best.describe(&scoped)
    );
    let best_tp = res.best_throughput();

    // strictly beats the all-global-ZDP plan at its best batch size
    let zdp_choice =
        scoped.index_of(|d| d.is_pure_zdp() && d.scope == Scope::Global);
    let mut zdp_best = 0.0f64;
    for b in 1..=8usize {
        let cost = scoped.evaluate(&zdp_choice, b);
        if cost.peak_mem <= c.mem_limit {
            zdp_best = zdp_best.max(cost.throughput(b, c.n_devices));
        }
    }
    assert!(zdp_best > 0.0, "all-global-ZDP must be feasible somewhere");
    assert!(
        best_tp > zdp_best,
        "scoped plan {best_tp} must strictly beat all-global-ZDP {zdp_best}"
    );

    // ... and the best plan of the scope-free search space
    let flat_res = Scheduler::new(&flat, c.mem_limit, 8).run()
        .expect("scope-free sweep feasible");
    assert!(
        best_tp > flat_res.best_throughput(),
        "scoped plan {best_tp} must strictly beat the best scope-free plan {}",
        flat_res.best_throughput()
    );
}

#[test]
fn engines_agree_bitwise_on_scoped_space_at_1_and_8_threads() {
    let m = model();
    let c = forcing_cluster(&m);
    let p = Profiler::new(&m, &c, &search_cfg(true));
    let res = Scheduler::new(&p, c.mem_limit, 8).run().unwrap();
    let best = res.best_plan();
    let b = best.batch;

    // ground truth: the folded exhaustive enumerator over the scoped space
    let (brute_choice, brute_cost) =
        exhaustive_search(&p, c.mem_limit, b).expect("exhaustive feasible");
    assert_eq!(brute_choice, best.choice, "sweep != exhaustive");
    assert_eq!(brute_cost.time.to_bits(), best.cost.time.to_bits());

    // every engine, 1 and 8 threads: identical full choice vector
    for threads in [1usize, 8] {
        for engine in
            [Engine::Frontier, Engine::FoldedBb, Engine::UnfoldedBb]
        {
            let cfg =
                ParallelConfig { threads, engine, ..Default::default() };
            let (choice, cost, stats) =
                parallel_search(&p, c.mem_limit, b, &cfg)
                    .unwrap_or_else(|| {
                        panic!("{engine:?} at {threads} threads infeasible")
                    });
            assert!(stats.complete, "{engine:?}@{threads}t budget expired");
            assert_eq!(choice, brute_choice,
                       "{engine:?}@{threads}t diverged");
            assert_eq!(cost.time.to_bits(), brute_cost.time.to_bits());
            assert_eq!(cost.peak_mem.to_bits(),
                       brute_cost.peak_mem.to_bits());
            // the agreed-on plan really is scoped
            let plan = ExecutionPlan::from_choice(&p, choice, b);
            assert!(plan.node_scoped_ops() >= 1);
            assert!(plan.decisions.iter().any(|d| d.is_node_scoped()
                && d.label().ends_with("@node")));
        }
    }
}

#[test]
fn scope_dimension_respects_memory_semantics() {
    // Node scope trades state memory for comm: at equal batch the scoped
    // optimum uses no more time and no less states than the global-only
    // optimum, and both respect the limit.
    let m = model();
    let c = forcing_cluster(&m);
    let scoped = Profiler::new(&m, &c, &search_cfg(true));
    let flat = Profiler::new(&m, &c, &search_cfg(false));
    for b in 1..=4usize {
        let s = osdp::planner::dfs_search(&scoped, c.mem_limit, b);
        let f = osdp::planner::dfs_search(&flat, c.mem_limit, b);
        let (Some((_, sc, _)), Some((_, fc, _))) = (s, f) else {
            continue;
        };
        assert!(sc.peak_mem <= c.mem_limit);
        assert!(fc.peak_mem <= c.mem_limit);
        // superset space: scoped time can only match or improve
        assert!(sc.time <= fc.time + 1e-15, "b={b}: {} > {}", sc.time,
                fc.time);
    }
}

#[test]
fn disabling_scopes_recovers_the_paper_space() {
    let m = model();
    let c = forcing_cluster(&m);
    let flat = Profiler::new(&m, &c, &search_cfg(false));
    for t in &flat.tables {
        for o in &t.options {
            assert_eq!(o.decision.scope, Scope::Global,
                       "{}: scope-free menus must be all-global", t.name);
        }
    }
    // and on a single node the scoped profiler generates no node entries
    // even when enabled, so the paper's single-server experiments are
    // untouched
    let single = Cluster::rtx_titan(8, 8.0);
    let p = Profiler::new(&m, &single, &search_cfg(true));
    for t in &p.tables {
        assert!(t.options.iter().all(|o| !o.decision.is_node_scoped()));
    }
    let _ = Decision::ZDP_NODE; // the label surface is covered elsewhere
}
