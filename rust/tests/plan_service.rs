//! Plan-service acceptance tests (ISSUE 5):
//!
//! * warm-started and coalesced queries are **bit-identical** to cold
//!   planning (full choice-vector equality, across engines and thread
//!   counts);
//! * a warm-start from a neighboring batch strictly reduces visited
//!   nodes on the 24L sweep;
//! * ≥8 concurrent identical queries observe exactly one planner
//!   execution;
//! * cache-key canonicalization: equivalent config spellings collide,
//!   search-relevant changes split;
//! * error-path hardening: hostile requests come back as structured
//!   `PlanError`s, never panics;
//! * the serve loop scripts cleanly and the disk cache survives a
//!   restart.

use osdp::config::{Cluster, GIB, RunConfig, SearchConfig};
use osdp::cost::Profiler;
use osdp::model::{GptDims, build_gpt};
use osdp::planner::{self, Engine, Scheduler};
use osdp::service::key::fingerprint;
use osdp::service::{Answer, CacheConfig, Counter, PlanError, PlanQuery,
                    PlanService, QueryKey, QueryShape, Source, StaleEntry,
                    Telemetry, WarmupReport, server};
use osdp::util::json::Json;

fn tiny_profiler(layers: usize, hidden: usize, grans: Vec<usize>)
                 -> Profiler {
    let m = build_gpt(&GptDims::uniform("t", 3000, 64, layers, hidden, 4));
    let c = Cluster::rtx_titan(8, 8.0);
    // coarse 2-ops/layer graph keeps the unfolded ground-truth engine's
    // unbudgeted searches test-sized
    let s = SearchConfig {
        granularities: grans,
        paper_granularity: true,
        ..Default::default()
    };
    Profiler::new(&m, &c, &s)
}

fn dp_peak(p: &Profiler, b: usize) -> f64 {
    p.evaluate(&p.index_of(|d| d.is_pure_dp()), b).peak_mem
}

// ---------------------------------------------------------------------
// cache-key canonicalization
// ---------------------------------------------------------------------

#[test]
fn equivalent_config_spellings_share_a_key() {
    let m = build_gpt(&GptDims::uniform("t", 2000, 64, 4, 128, 4));
    let prof = |toml: &str| {
        let cfg = RunConfig::from_str(toml).unwrap();
        Profiler::new(&m, &cfg.cluster, &cfg.search)
    };
    // baseline spelling
    let a = prof(
        "[cluster]\npreset = \"rtx_titan\"\nn_devices = 8\n\
         [search]\ngranularities = [0, 4]",
    );
    // field order swapped, defaults written out explicitly
    let b = prof(
        "[search]\ngranularities = [0, 4]\ncheckpointing = false\n\
         hybrid_scopes = true\n[cluster]\nmem_limit_gib = 8.0\n\
         n_devices = 8\npreset = \"rtx_titan\"",
    );
    // the preset spelled out as a custom cluster, field by field
    let c = prof(
        "[cluster]\npreset = \"custom\"\nn_devices = 8\n\
         alpha_intra = 1e-5\nbeta_intra = 8.333333333333334e-11\n\
         alpha_inter = 1e-5\nbeta_inter = 8.333333333333334e-11\n\
         flops = 14e12\n[search]\ngranularities = [0, 4]",
    );
    assert_eq!(fingerprint(&a), fingerprint(&b),
               "field order / explicit defaults must not split the key");
    assert_eq!(fingerprint(&a), fingerprint(&c),
               "preset vs spelled-out cluster must not split the key");

    // search-relevant changes split the key
    let grans = prof(
        "[cluster]\npreset = \"rtx_titan\"\nn_devices = 8\n\
         [search]\ngranularities = [0, 2, 4]",
    );
    let ckpt = prof(
        "[cluster]\npreset = \"rtx_titan\"\nn_devices = 8\n\
         [search]\ngranularities = [0, 4]\ncheckpointing = true",
    );
    assert_ne!(fingerprint(&a), fingerprint(&grans));
    assert_ne!(fingerprint(&a), fingerprint(&ckpt));

    // hybrid_scopes is search-irrelevant on a single node (menus are
    // identical) but search-relevant on a multi-node cluster
    let single_off = prof(
        "[cluster]\npreset = \"rtx_titan\"\nn_devices = 8\n\
         [search]\ngranularities = [0, 4]\nhybrid_scopes = false",
    );
    assert_eq!(fingerprint(&a), fingerprint(&single_off),
               "scopes knob is irrelevant on one node");
    let two_on = prof(
        "[cluster]\npreset = \"two_server_a100\"\n\
         [search]\ngranularities = [0, 4]",
    );
    let two_off = prof(
        "[cluster]\npreset = \"two_server_a100\"\n\
         [search]\ngranularities = [0, 4]\nhybrid_scopes = false",
    );
    assert_ne!(fingerprint(&two_on), fingerprint(&two_off),
               "scopes knob is search-relevant across nodes");

    // limit and shape live outside the structure (warm-start neighbors)
    let ka = QueryKey::for_query(&a, 4.0 * GIB, QueryShape::Batch(2));
    let kb = QueryKey::for_query(&a, 6.0 * GIB, QueryShape::Batch(2));
    let kc = QueryKey::for_query(&a, 4.0 * GIB,
                                 QueryShape::Sweep { max_batch: 8 });
    assert_eq!(ka.structure, kb.structure);
    assert_eq!(ka.structure, kc.structure);
    assert_ne!(ka, kb);
    assert_ne!(ka, kc);
}

// ---------------------------------------------------------------------
// warm-start bit-identity (engines x threads x seed provenance)
// ---------------------------------------------------------------------

#[test]
fn warm_seeding_never_changes_the_result() {
    for (layers, hidden, grans) in
        [(4usize, 256usize, vec![0usize]), (6, 192, vec![0, 2]),
         (3, 320, vec![0, 4])]
    {
        let p = tiny_profiler(layers, hidden, grans);
        let dp = dp_peak(&p, 2);
        for frac in [0.4, 0.65, 0.9] {
            let limit = dp * frac;
            // candidate warm seeds: the optima of neighboring batches
            // and limits (what the cache would hold), a feasible-ish
            // all-ZDP plan, and malformed junk the search must shrug off
            let mut seeds: Vec<Vec<usize>> = Vec::new();
            for (nb, nlimit) in
                [(1usize, limit), (3, limit), (2, limit * 0.8),
                 (2, limit * 1.3)]
            {
                if let Some((choice, _, _)) =
                    planner::dfs_search_warm(&p, nlimit, nb, u64::MAX,
                                             Engine::FoldedBb, None)
                {
                    seeds.push(choice);
                }
            }
            seeds.push(p.index_of(|d| d.is_pure_zdp()));
            seeds.push(vec![0; p.n_ops() + 3]); // wrong length
            seeds.push(vec![usize::MAX; p.n_ops()]); // wild indices
            for engine in
                [Engine::Frontier, Engine::FoldedBb, Engine::UnfoldedBb]
            {
                let cold = planner::dfs_search_warm(&p, limit, 2, u64::MAX,
                                                    engine, None);
                for seed in &seeds {
                    let warm = planner::dfs_search_warm(
                        &p, limit, 2, u64::MAX, engine, Some(seed));
                    match (&cold, &warm) {
                        (None, None) => {}
                        (Some((cc, ccost, cst)), Some((wc, wcost, wst))) => {
                            assert_eq!(cc, wc,
                                       "choice changed: {engine:?} \
                                        frac={frac}");
                            assert_eq!(ccost.time.to_bits(),
                                       wcost.time.to_bits());
                            assert_eq!(ccost.peak_mem.to_bits(),
                                       wcost.peak_mem.to_bits());
                            assert!(wst.nodes <= cst.nodes,
                                    "warm explored more: {} > {}",
                                    wst.nodes, cst.nodes);
                        }
                        _ => panic!(
                            "feasibility changed by warm seed \
                             ({engine:?}, frac={frac})"
                        ),
                    }
                }
                // and through the parallel engine at 8 threads
                let cfg = planner::ParallelConfig {
                    threads: 8,
                    engine,
                    ..Default::default()
                };
                let par_cold =
                    planner::parallel_search_seeded(&p, limit, 2, &cfg,
                                                    None);
                let par_warm = planner::parallel_search_seeded(
                    &p, limit, 2, &cfg, seeds.first().map(|s| s.as_slice()));
                match (&cold, &par_cold, &par_warm) {
                    (None, None, None) => {}
                    (Some((cc, ccost, _)), Some((pc, pcost, _)),
                     Some((wc, wcost, _))) => {
                        assert_eq!(cc, pc);
                        assert_eq!(cc, wc);
                        assert_eq!(ccost.time.to_bits(),
                                   pcost.time.to_bits());
                        assert_eq!(ccost.time.to_bits(),
                                   wcost.time.to_bits());
                    }
                    _ => panic!("parallel/seeded feasibility mismatch"),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// the 24L sweep: warm-start node reduction (strict) + sweep identity
// ---------------------------------------------------------------------

#[test]
fn warm_start_reduces_nodes_on_the_24l_sweep() {
    let m = build_gpt(&GptDims::uniform("deep", 5000, 128, 24, 256, 4));
    let c = Cluster::rtx_titan(8, 8.0);
    let s = SearchConfig {
        granularities: vec![0],
        paper_granularity: true,
        ..Default::default()
    };
    let p = Profiler::new(&m, &c, &s);
    let dp = dp_peak(&p, 1);

    let mut strict_seen = false;
    for frac in [0.3, 0.35, 0.425, 0.5, 0.575, 0.65, 0.725, 0.8] {
        let limit = dp * frac;
        let Ok(cold) =
            Scheduler::new(&p, limit, 8).with_threads(1).run()
        else {
            continue;
        };
        // sweep-level: warm-starting the whole sweep from the b=1 winner
        // (what the service's cache hands the Scheduler) is bit-identical
        // and never explores more
        let warm_sweep = Scheduler::new(&p, limit, 8)
            .with_threads(1)
            .with_warm(cold.candidates[0].plan.choice.clone())
            .run()
            .unwrap();
        assert_eq!(cold.candidates.len(), warm_sweep.candidates.len());
        for (a, b) in cold.candidates.iter().zip(&warm_sweep.candidates) {
            assert_eq!(a.plan.choice, b.plan.choice);
            assert_eq!(a.plan.cost.time.to_bits(),
                       b.plan.cost.time.to_bits());
        }
        assert!(warm_sweep.total_nodes <= cold.total_nodes);

        // per-batch: warm-start each batch from its *neighboring* batch's
        // winner; identical plan, never more nodes, strictly fewer
        // somewhere on the sweep (asserted across the scan below)
        for b in 1..=cold.candidates.len() {
            for nb in [b.saturating_sub(1), b + 1] {
                if nb < 1 || nb > cold.candidates.len() || nb == b {
                    continue;
                }
                let seed = &cold.candidates[nb - 1].plan.choice;
                let cold_one = planner::dfs_search_warm(
                    &p, limit, b, u64::MAX, Engine::Frontier, None)
                    .expect("swept batch is feasible");
                let warm_one = planner::dfs_search_warm(
                    &p, limit, b, u64::MAX, Engine::Frontier,
                    Some(seed))
                    .expect("warm seed cannot break feasibility");
                assert_eq!(cold_one.0, warm_one.0);
                assert_eq!(cold_one.1.time.to_bits(),
                           warm_one.1.time.to_bits());
                assert!(warm_one.2.nodes <= cold_one.2.nodes);
                if warm_one.2.nodes < cold_one.2.nodes {
                    strict_seen = true;
                }
            }
        }
    }
    assert!(
        strict_seen,
        "no neighboring-batch warm start strictly reduced nodes anywhere \
         on the 24L sweep — the warm path is not actually pruning"
    );
}

// ---------------------------------------------------------------------
// the service: sources, bit-identity, coalescing, sweeps, errors
// ---------------------------------------------------------------------

const TINY: &str = "gpt:3000,64,6,192,4";

fn tiny_service_profiler() -> Profiler {
    let q = PlanQuery::batch(TINY, 8.0, 1);
    let cluster = q.cluster.resolve().unwrap();
    let model = osdp::service::resolve_setting(TINY).unwrap();
    Profiler::new(&model, &cluster, &q.search)
}

/// A limit (in GiB) around `frac` of the tiny model's all-DP peak at
/// `b`, computed through the same profiler the service will build.
fn tiny_mem_gib(frac: f64, b: usize) -> f64 {
    dp_peak(&tiny_service_profiler(), b) * frac / GIB
}

/// A limit (in GiB) just above the tiny model's all-ZDP peak at `b` —
/// memory terms are non-decreasing in the batch, so a sweep under this
/// limit is feasible through `b` and hits the memory wall shortly after.
fn tiny_wall_gib(b: usize) -> f64 {
    let p = tiny_service_profiler();
    let zdp = p.evaluate(&p.index_of(|d| d.is_pure_zdp()), b).peak_mem;
    zdp * 1.02 / GIB
}

#[test]
fn service_sources_cache_then_warm_are_bit_identical() {
    let mem_a = tiny_mem_gib(0.55, 2);
    let mem_b = tiny_mem_gib(0.75, 2);
    let q_a = PlanQuery::batch(TINY, mem_a, 2);
    let q_b = PlanQuery::batch(TINY, mem_b, 2);

    // cold then cache
    let service = PlanService::in_memory();
    let cold = service.query(&q_b).unwrap();
    assert_eq!(cold.source, Source::Cold);
    let hit = service.query(&q_b).unwrap();
    assert_eq!(hit.source, Source::Cache);
    let (Answer::Plan { plan: cold_plan, stats: cold_stats },
         Answer::Plan { plan: hit_plan, .. }) =
        (&cold.answer, &hit.answer)
    else {
        panic!("batch query must answer a plan");
    };
    assert_eq!(cold_plan.choice, hit_plan.choice);
    assert_eq!(cold_plan.cost.time.to_bits(),
               hit_plan.cost.time.to_bits());
    assert!(cold_stats.nodes > 0);
    let s = service.stats();
    assert_eq!((s.hits, s.misses, s.planner_runs), (1, 1, 1));

    // warm from a tighter-limit neighbor: its plan is feasible at the
    // looser limit by construction, so the source is deterministically
    // Warm — and the answer is bit-identical to the cold run above
    let warm_service = PlanService::in_memory();
    warm_service.query(&q_a).unwrap();
    let warm = warm_service.query(&q_b).unwrap();
    assert_eq!(warm.source, Source::Warm);
    let Answer::Plan { plan: warm_plan, stats: warm_stats } = &warm.answer
    else {
        panic!("batch query must answer a plan");
    };
    assert_eq!(warm_plan.choice, cold_plan.choice,
               "warm-started answer must equal the cold answer");
    assert_eq!(warm_plan.cost.time.to_bits(),
               cold_plan.cost.time.to_bits());
    assert!(warm_stats.nodes <= cold_stats.nodes);
    let ws = warm_service.stats();
    assert_eq!(ws.warm_seeded, 1);
    assert_eq!(ws.planner_runs, 2);

    // no-warm opt-out plans cold and still matches
    let cold_service = PlanService::in_memory();
    cold_service.query(&q_a).unwrap();
    let mut q_nw = q_b.clone();
    q_nw.warm = false;
    let nw = cold_service.query(&q_nw).unwrap();
    assert_eq!(nw.source, Source::Cold);
    let Answer::Plan { plan: nw_plan, .. } = &nw.answer else {
        panic!()
    };
    assert_eq!(nw_plan.choice, cold_plan.choice);
}

/// ISSUE 9: cache hits and warm starts stay bit-identical on a
/// *wide-class* instance — deep uniform stack x granularities
/// {0, 2, 4, 8}, the production-scale shape whose composition count
/// exceeds the retired one-shot ceiling (2^18) and used to forfeit the
/// frontier prebuild. Every class prebuilds incrementally now, and the
/// served answers must not move a bit.
#[test]
fn wide_class_queries_cache_and_warm_bit_identically() {
    const WIDE: &str = "gpt:3000,64,192,192,4";
    let wide_query = |mem_gib: f64| {
        let mut q = PlanQuery::batch(WIDE, mem_gib, 2);
        q.search.granularities = vec![0, 2, 4, 8];
        q
    };
    // the profiler exactly as the service will build it: the shape must
    // genuinely be wide, and every class must still prebuild
    let probe = wide_query(8.0);
    let cluster = probe.cluster.resolve().unwrap();
    let model = osdp::service::resolve_setting(WIDE).unwrap();
    let p = Profiler::new(&model, &cluster, &probe.search);
    let fr = planner::frontier_report(&p);
    assert_eq!(fr.too_wide, 0, "every class must prebuild");
    assert!(fr.per_class.iter().any(|c| c.raw > 1 << 18),
            "instance must exceed the old one-shot ceiling (widest: {})",
            fr.per_class.iter().map(|c| c.raw).max().unwrap_or(0));
    let mem_a = dp_peak(&p, 2) * 0.55 / GIB;
    let mem_b = dp_peak(&p, 2) * 0.75 / GIB;

    // cold then cache
    let service = PlanService::in_memory();
    let cold = service.query(&wide_query(mem_b)).unwrap();
    assert_eq!(cold.source, Source::Cold);
    let hit = service.query(&wide_query(mem_b)).unwrap();
    assert_eq!(hit.source, Source::Cache);
    let (Answer::Plan { plan: cold_plan, .. },
         Answer::Plan { plan: hit_plan, .. }) = (&cold.answer, &hit.answer)
    else {
        panic!("batch query must answer a plan");
    };
    assert_eq!(cold_plan.choice, hit_plan.choice);
    assert_eq!(cold_plan.cost.time.to_bits(),
               hit_plan.cost.time.to_bits());

    // warm from the tighter-limit neighbor (feasible at the looser limit
    // by construction, so the source is deterministically Warm)
    let warm_service = PlanService::in_memory();
    warm_service.query(&wide_query(mem_a)).unwrap();
    let warm = warm_service.query(&wide_query(mem_b)).unwrap();
    assert_eq!(warm.source, Source::Warm);
    let Answer::Plan { plan: warm_plan, .. } = &warm.answer else {
        panic!("batch query must answer a plan");
    };
    assert_eq!(warm_plan.choice, cold_plan.choice,
               "warm answer must equal cold on the wide instance");
    assert_eq!(warm_plan.cost.time.to_bits(),
               cold_plan.cost.time.to_bits());

    // the folded ground-truth engine serves the same bits
    let mut q_bb = wide_query(mem_b);
    q_bb.engine = Engine::FoldedBb;
    let bb = PlanService::in_memory().query(&q_bb).unwrap();
    let Answer::Plan { plan: bb_plan, stats: bb_stats } = &bb.answer else {
        panic!("batch query must answer a plan");
    };
    if bb_stats.complete {
        assert_eq!(bb_plan.choice, cold_plan.choice,
                   "folded engine must agree on the wide instance");
        assert_eq!(bb_plan.cost.time.to_bits(),
                   cold_plan.cost.time.to_bits());
    }
}

#[test]
fn eight_concurrent_identical_queries_run_one_search() {
    let mem = tiny_mem_gib(0.5, 2);
    let mut q = PlanQuery::batch(TINY, mem, 2);
    q.threads = 1; // keep each (single) search serial and deterministic
    let service = PlanService::in_memory();
    let barrier = std::sync::Barrier::new(8);
    let results: Vec<_> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let q = &q;
                let service = &service;
                let barrier = &barrier;
                scope.spawn(move || {
                    barrier.wait();
                    service.query(q).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let s = service.stats();
    assert_eq!(s.planner_runs, 1,
               "8 concurrent identical queries must run exactly one \
                search (got {} runs; stats: {})",
               s.planner_runs, s.describe());
    assert_eq!(s.hits + s.coalesced, 7,
               "everyone but the leader shares: {}", s.describe());
    let led: Vec<_> = results
        .iter()
        .filter(|r| matches!(r.source, Source::Cold | Source::Warm))
        .collect();
    assert_eq!(led.len(), 1, "exactly one caller led the flight");
    let Answer::Plan { plan: first, .. } = &results[0].answer else {
        panic!()
    };
    for r in &results {
        let Answer::Plan { plan, .. } = &r.answer else { panic!() };
        assert_eq!(plan.choice, first.choice,
                   "coalesced answers must be bit-identical");
        assert_eq!(plan.cost.time.to_bits(), first.cost.time.to_bits());
    }
}

#[test]
fn sweep_matches_direct_scheduler_and_populates_batches() {
    let mem = tiny_wall_gib(3); // walls after a few batch sizes
    let q = PlanQuery::sweep(TINY, mem, 16);
    let service = PlanService::in_memory();
    let resp = service.query(&q).unwrap();
    assert_eq!(resp.source, Source::Cold);
    let Answer::Sweep { plans, best, stats } = &resp.answer else {
        panic!("sweep query must answer a sweep");
    };
    assert!(stats.complete);
    assert!(!plans.is_empty());

    // ground truth: the scheduler run directly on an identically-built
    // profiler
    let cluster = q.cluster.resolve().unwrap();
    let model = osdp::service::resolve_setting(TINY).unwrap();
    let p = Profiler::new(&model, &cluster, &q.search);
    let direct = Scheduler::new(&p, cluster.mem_limit, 16).run().unwrap();
    assert_eq!(plans.len(), direct.candidates.len());
    assert_eq!(*best, direct.best);
    for (a, b) in plans.iter().zip(&direct.candidates) {
        assert_eq!(a.choice, b.plan.choice);
        assert_eq!(a.cost.time.to_bits(), b.plan.cost.time.to_bits());
    }
    let n = plans.len();
    assert!(n < 16, "limit must wall the sweep for this test to bite");

    // the sweep populated every per-batch entry plus the wall
    let hits_before = service.stats().hits;
    for b in 1..=n {
        let resp = service.query(&PlanQuery::batch(TINY, mem, b)).unwrap();
        assert_eq!(resp.source, Source::Cache, "b={b} must hit");
        let Answer::Plan { plan, .. } = &resp.answer else { panic!() };
        assert_eq!(plan.choice, direct.candidates[b - 1].plan.choice);
    }
    let wall = service.query(&PlanQuery::batch(TINY, mem, n + 1));
    assert_eq!(wall.unwrap_err(),
               PlanError::Infeasible { batch: Some(n + 1) });
    let s = service.stats();
    assert_eq!(s.hits, hits_before + n as u64 + 1,
               "wall entry must be served from cache too: {}",
               s.describe());

    // the sweep itself hits on repeat
    let again = service.query(&q).unwrap();
    assert_eq!(again.source, Source::Cache);
    let Answer::Sweep { plans: cached_plans, .. } = &again.answer else {
        panic!()
    };
    for (a, b) in cached_plans.iter().zip(plans) {
        assert_eq!(a.choice, b.choice);
        assert_eq!(a.cost.time.to_bits(), b.cost.time.to_bits());
    }
}

#[test]
fn hostile_queries_return_structured_errors() {
    let service = PlanService::in_memory();
    let cases: Vec<(PlanQuery, &str)> = vec![
        (PlanQuery::batch(TINY, 8.0, 0), "bad-request"),
        (PlanQuery::sweep(TINY, 8.0, 0), "bad-request"),
        (PlanQuery::batch("no-such-model", 8.0, 1), "unknown-setting"),
        (PlanQuery::batch("gpt:1,2", 8.0, 1), "bad-request"),
        (PlanQuery::batch(TINY, f64::NAN, 1), "bad-request"),
        (PlanQuery::batch(TINY, -2.0, 1), "bad-request"),
        (
            {
                let mut q = PlanQuery::batch(TINY, 8.0, 1);
                q.cluster.preset = "warp-drive".into();
                q
            },
            "invalid-cluster",
        ),
        (
            {
                let mut q = PlanQuery::batch(TINY, 8.0, 1);
                q.cluster.preset = "two_server_a100".into();
                q.cluster.devices = Some(8);
                q
            },
            "invalid-cluster",
        ),
        (
            {
                let mut q = PlanQuery::batch(TINY, 8.0, 1);
                q.cluster.devices = Some(0);
                q
            },
            "invalid-cluster",
        ),
        (
            {
                let mut q = PlanQuery::batch(TINY, 8.0, 1);
                q.search.granularities = vec![0, 1 << 30];
                q
            },
            "bad-request",
        ),
        // unbounded batch/sweep requests must be capped, not served
        (PlanQuery::batch(TINY, 8.0, usize::MAX), "bad-request"),
        (PlanQuery::sweep(TINY, 8.0, 100_000_000), "bad-request"),
        // memory wall at every option: structured infeasibility
        (PlanQuery::batch(TINY, 1e-9, 1), "infeasible"),
        (PlanQuery::sweep(TINY, 1e-9, 4), "infeasible"),
    ];
    for (q, kind) in cases {
        match service.query(&q) {
            Err(e) => assert_eq!(e.kind(), kind, "query {q:?} -> {e}"),
            Ok(_) => panic!("query {q:?} must fail with {kind}"),
        }
    }
    // infeasibility is cached: the repeat is a hit, still structured
    let before = service.stats();
    let again = service.query(&PlanQuery::batch(TINY, 1e-9, 1));
    assert_eq!(again.unwrap_err(),
               PlanError::Infeasible { batch: Some(1) });
    let after = service.stats();
    assert_eq!(after.hits, before.hits + 1);
    assert_eq!(after.planner_runs, before.planner_runs);
}

#[test]
fn serve_loop_scripts_cleanly() {
    let mem = tiny_mem_gib(0.7, 1);
    let service = PlanService::in_memory();
    let script = format!(
        "\n# a comment, then two identical queries, then assorted errors\n\
         query setting={TINY} mem={mem} batch=1 threads=1\n\
         query setting={TINY} mem={mem} batch=1 threads=1\n\
         frobnicate the planner\n\
         query setting=nope mem=4 batch=1\n\
         query setting={TINY} mem=1e-9 batch=1\n\
         sweep setting={TINY} mem={mem} batch-cap=2 threads=1\n\
         stats\n\
         quit\n\
         query setting={TINY} mem={mem} batch=1\n"
    );
    let mut out = Vec::new();
    server::serve_loop(&service, script.as_bytes(), &mut out).unwrap();
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<Json> = text
        .lines()
        .map(|l| Json::parse(l).expect("every response line is JSON"))
        .collect();
    assert_eq!(lines.len(), 8, "8 responses then quit stops the loop:\n{text}");
    assert_eq!(lines[0].get("ok").as_bool(), Some(true));
    assert_eq!(lines[0].get("source").as_str(), Some("cold"));
    assert_eq!(lines[1].get("source").as_str(), Some("cache"));
    // identical answers, down to the choice vector
    assert_eq!(lines[0].get("choice"), lines[1].get("choice"));
    assert_eq!(lines[0].get("time_s"), lines[1].get("time_s"));
    assert_eq!(lines[2].get("error").as_str(), Some("bad-request"));
    assert_eq!(lines[3].get("error").as_str(), Some("unknown-setting"));
    assert_eq!(lines[4].get("error").as_str(), Some("infeasible"));
    assert_eq!(lines[5].get("kind").as_str(), Some("sweep"));
    assert!(lines[5].get("candidates").as_arr().is_some());
    assert_eq!(lines[6].get("kind").as_str(), Some("stats"));
    assert_eq!(lines[6].get("hits").as_usize(), Some(1));
    // three planner runs: the first query, the infeasible probe, the sweep
    assert_eq!(lines[6].get("planner_runs").as_usize(), Some(3));
    assert_eq!(lines[7].get("kind").as_str(), Some("bye"));
}

#[test]
fn disk_cache_survives_a_restart_and_rejects_foreign_epochs() {
    let dir = std::env::temp_dir().join(format!(
        "osdp-service-test-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = CacheConfig { capacity: 64, disk_dir: Some(dir.clone()) };
    let mem = tiny_mem_gib(0.6, 2);
    let q = PlanQuery::batch(TINY, mem, 2);

    let first = PlanService::new(cfg.clone());
    let cold = first.query(&q).unwrap();
    assert_eq!(cold.source, Source::Cold);
    assert_eq!(first.stats().persist_errors, 0);
    drop(first);

    let second = PlanService::new(cfg.clone());
    let hit = second.query(&q).unwrap();
    assert_eq!(hit.source, Source::Cache,
               "restart must serve from the persisted cache");
    let (Answer::Plan { plan: a, .. }, Answer::Plan { plan: b, .. }) =
        (&cold.answer, &hit.answer)
    else {
        panic!()
    };
    assert_eq!(a.choice, b.choice);
    assert_eq!(a.cost.time.to_bits(), b.cost.time.to_bits());
    let s = second.stats();
    assert_eq!((s.planner_runs, s.hits), (0, 1));
    drop(second);

    // a file from another cost-model epoch is rejected wholesale
    let path = dir.join("plan_cache.json");
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let mut obj = doc.as_obj().unwrap().clone();
    obj.insert("epoch".into(), Json::Num(9999.0));
    std::fs::write(&path, osdp::util::json::to_string(&Json::Obj(obj)))
        .unwrap();
    let third = PlanService::new(cfg);
    assert!(third.stats().stale_rejected > 0);
    let replan = third.query(&q).unwrap();
    assert!(matches!(replan.source, Source::Cold | Source::Warm),
            "stale cache must not serve hits");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// epoch-bump warm-up: stale entries are harvested and replayed
// ---------------------------------------------------------------------

/// Rewrite the persisted cache file's epoch field in place.
fn tamper_epoch(dir: &std::path::Path, epoch: f64) {
    let path = dir.join("plan_cache.json");
    let doc =
        Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let mut obj = doc.as_obj().unwrap().clone();
    obj.insert("epoch".into(), Json::Num(epoch));
    std::fs::write(&path, osdp::util::json::to_string(&Json::Obj(obj)))
        .unwrap();
}

#[test]
fn epoch_bump_warm_up_replays_hottest_stale_entries() {
    let dir = std::env::temp_dir().join(format!(
        "osdp-warmup-test-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = CacheConfig { capacity: 64, disk_dir: Some(dir.clone()) };
    let q_hot = PlanQuery::batch(TINY, tiny_mem_gib(0.6, 2), 2);
    let q_cool = PlanQuery::batch(TINY, tiny_mem_gib(0.8, 1), 1);

    // session one: the hot query is served three times, the cool one once
    let first = PlanService::new(cfg.clone());
    let hot_cold = first.query(&q_hot).unwrap();
    first.query(&q_hot).unwrap();
    first.query(&q_hot).unwrap();
    first.query(&q_cool).unwrap();
    drop(first);

    // a cost-model deploy bumps the epoch: values are garbage now, but
    // the request lines (and old choice vectors, as seeds) are not
    tamper_epoch(&dir, 9999.0);
    let (second, stale) = PlanService::open(cfg.clone());
    assert_eq!(second.cache_len(), 0, "stale values must not be served");
    assert_eq!(second.stats().stale_rejected, 2);
    assert_eq!(stale.len(), 2, "both entries harvested for replay");

    // K=1 replays only the hottest entry, seeded with its old choice
    let report = second.warm_up(&stale, 1, None);
    assert_eq!(report,
               WarmupReport { candidates: 1, replanned: 1, failed: 0 });
    let s = second.stats();
    assert_eq!(s.planner_runs, 1);
    assert_eq!(s.warm_seeded, 1,
               "the replay must be seeded with the previous-epoch choice");
    let hot = second.query(&q_hot).unwrap();
    assert_eq!(hot.source, Source::Cache,
               "warm-up pre-filled the hot entry before traffic");
    let (Answer::Plan { plan: a, .. }, Answer::Plan { plan: b, .. }) =
        (&hot_cold.answer, &hot.answer)
    else {
        panic!()
    };
    assert_eq!(a.choice, b.choice,
               "the cost model did not actually change here, so the \
                replayed plan is bit-identical");
    assert_eq!(a.cost.time.to_bits(), b.cost.time.to_bits());
    let cool = second.query(&q_cool).unwrap();
    assert!(matches!(cool.source, Source::Cold | Source::Warm),
            "the cool entry was beyond K and must re-plan");
    drop(second);

    // a second bump, replayed with telemetry attached and K large
    // enough for everything
    tamper_epoch(&dir, 4242.0);
    let (third, stale) = PlanService::open(cfg);
    let telemetry = Telemetry::new();
    let report = third.warm_up(&stale, 8, Some(&telemetry));
    assert_eq!(report.candidates, stale.len());
    assert_eq!(report.replanned, stale.len());
    assert_eq!(report.failed, 0);
    assert_eq!(telemetry.get(Counter::WarmupReplans), stale.len() as u64);
    assert_eq!(telemetry.get(Counter::WarmupFailures), 0);
    assert_eq!(third.query(&q_hot).unwrap().source, Source::Cache);
    assert_eq!(third.query(&q_cool).unwrap().source, Source::Cache);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_up_is_total_on_hostile_harvests() {
    let service = PlanService::in_memory();
    let mem = tiny_mem_gib(0.7, 1);
    let stale = vec![
        // unparseable request line: counted failed, never dispatched
        StaleEntry { request: "frobnicate the planner".into(),
                     seed: vec![0], hits: 9 },
        // stats is a valid verb but not a replayable query
        StaleEntry { request: "stats".into(), seed: vec![], hits: 8 },
        // replayable, with a garbage seed the engines must shrug off
        StaleEntry {
            request: format!(
                "query setting={TINY} mem={mem} batch=1 threads=1"
            ),
            seed: vec![usize::MAX; 3],
            hits: 7,
        },
        // replayable and provably infeasible: the wall is cached
        // knowledge, so it counts as replanned
        StaleEntry {
            request: format!("query setting={TINY} mem=1e-9 batch=1"),
            seed: vec![],
            hits: 6,
        },
    ];
    let report = service.warm_up(&stale, 8, None);
    assert_eq!(report,
               WarmupReport { candidates: 4, replanned: 2, failed: 2 });
    // the replayed entries serve from cache now
    let q = PlanQuery::batch(TINY, mem, 1);
    assert_eq!(service.query(&q).unwrap().source, Source::Cache);
    assert_eq!(service.query(&PlanQuery::batch(TINY, 1e-9, 1)).unwrap_err(),
               PlanError::Infeasible { batch: Some(1) });
    assert_eq!(service.stats().hits, 2);
}

// ---------------------------------------------------------------------
// observability: the trace verb and the Prometheus exposition
// ---------------------------------------------------------------------

/// ISSUE 10 acceptance: the `trace` verb returns a complete span tree
/// for a just-served query, the convergence timeline rides inside it,
/// and a repeat of the same query traces as a pure cache hit.
#[test]
fn trace_verb_returns_a_complete_span_tree_for_a_just_served_query() {
    if !osdp::service::trace::Tracer::enabled() {
        return; // compiled out under --features no_trace
    }
    let service = PlanService::in_memory();
    let mem = tiny_mem_gib(0.6, 1);
    let line = format!("query setting={TINY} mem={mem} batch=1 threads=1");

    // before any query the ring is empty but the verb still answers
    let (resp, _) = server::handle_line_full(&service, None, "trace");
    let doc = Json::parse(&resp).unwrap();
    assert_eq!(doc.get("kind").as_str(), Some("traces"));
    assert!(doc.get("traces").as_arr().expect("ring listing").is_empty());

    // cold miss: the response carries the trace id of its own trace
    let (resp, _) = server::handle_line_full(&service, None, &line);
    let doc = Json::parse(&resp).unwrap();
    assert_eq!(doc.get("ok").as_bool(), Some(true), "{resp}");
    let id = doc
        .get("trace_id")
        .as_str()
        .expect("query responses carry their trace id")
        .to_string();

    let (resp, _) =
        server::handle_line_full(&service, None, &format!("trace {id}"));
    let doc = Json::parse(&resp).unwrap();
    assert_eq!(doc.get("ok").as_bool(), Some(true), "{resp}");
    let trace = doc.get("trace");
    assert_eq!(trace.get("id").as_str(), Some(id.as_str()));
    assert_eq!(trace.get("complete").as_bool(), Some(true),
               "a served query's trace must be a closed tree");

    let spans = trace.get("spans").as_arr().expect("span tree");
    // the root is the query span; every other span's parent precedes it
    assert_eq!(spans[0].get("name").as_str(), Some("query"));
    assert!(matches!(*spans[0].get("parent"), Json::Null));
    for (i, s) in spans.iter().enumerate().skip(1) {
        let p = s.get("parent").as_f64().expect("non-root spans have a \
                                                 parent") as usize;
        assert!(p < i, "parents precede children in open order");
        assert!(s.get("dur_s").as_f64().unwrap() >= 0.0);
    }
    let names: Vec<&str> =
        spans.iter().filter_map(|s| s.get("name").as_str()).collect();
    for stage in ["canonicalize", "cache", "warm", "build", "descent",
                  "persist"] {
        assert!(names.contains(&stage),
                "miss-path trace lacks the '{stage}' span: {names:?}");
    }
    assert!(!names.contains(&"remote"),
            "no remote span without an attached remote tier");
    let cache_span = spans
        .iter()
        .find(|s| s.get("name").as_str() == Some("cache"))
        .unwrap();
    assert_eq!(cache_span.get("meta").get("outcome").as_str(),
               Some("miss"));

    // the convergence timeline: nodes non-decreasing, times strictly
    // improving, bits rendered as full-width hex
    let timeline = trace.get("timeline").as_arr().expect("timeline");
    assert!(!timeline.is_empty(), "a feasible search improves at least \
                                   once");
    let mut prev: Option<(f64, f64)> = None;
    for e in timeline {
        let nodes = e.get("nodes").as_f64().unwrap();
        let bits = e.get("time_bits").as_str().expect("hex time bits");
        assert!(bits.starts_with("0x") && bits.len() == 18, "{bits}");
        let t = f64::from_bits(
            u64::from_str_radix(&bits[2..], 16).expect("parse hex bits"),
        );
        assert_eq!(Some(t), e.get("time_s").as_f64(),
                   "time_s mirrors time_bits");
        let source = e.get("source").as_str().unwrap();
        assert!(["greedy", "warm", "descent"].contains(&source));
        if let Some((pn, pt)) = prev {
            assert!(nodes >= pn, "nodes regressed in the timeline");
            assert!(t < pt, "non-improving timeline event");
        }
        prev = Some((nodes, t));
    }

    // the repeat is a cache hit: its trace stops at the cache span
    let (resp, _) = server::handle_line_full(&service, None, &line);
    let hit_id = Json::parse(&resp).unwrap()
        .get("trace_id").as_str().unwrap().to_string();
    assert_ne!(hit_id, id, "every request gets a fresh trace id");
    let (resp, _) = server::handle_line_full(
        &service, None, &format!("trace {hit_id}"));
    let trace = Json::parse(&resp).unwrap();
    let trace = trace.get("trace");
    assert_eq!(trace.get("complete").as_bool(), Some(true));
    let names: Vec<String> = trace.get("spans").as_arr().unwrap().iter()
        .filter_map(|s| s.get("name").as_str().map(str::to_string))
        .collect();
    assert!(names.contains(&"cache".to_string()));
    for absent in ["build", "descent", "warm", "persist"] {
        assert!(!names.contains(&absent.to_string()),
                "a cache hit must not run '{absent}': {names:?}");
    }
    assert!(trace.get("timeline").as_arr().expect("timeline").is_empty(),
            "a cache hit runs no search, so no timeline");

    // both traces sit in the ring in finish order; unknown ids miss
    let (resp, _) = server::handle_line_full(&service, None, "trace");
    let doc = Json::parse(&resp).unwrap();
    let ring = doc.get("traces").as_arr().unwrap();
    assert_eq!(ring.len(), 2);
    assert_eq!(ring[0].get("id").as_str(), Some(id.as_str()));
    assert_eq!(ring[1].get("id").as_str(), Some(hit_id.as_str()));
    let (resp, _) =
        server::handle_line_full(&service, None, "trace t999999-nope");
    let doc = Json::parse(&resp).unwrap();
    assert_eq!(doc.get("ok").as_bool(), Some(false));
    assert_eq!(doc.get("error").as_str(), Some("not-found"));
}

/// ISSUE 10 acceptance: under a mixed load (batch + sweep + replan +
/// rejects), every counter on the Prometheus page equals the `stats`
/// verb to the unit, the three latency lanes partition the queries,
/// and the breaker gauge is one-hot.
#[test]
fn prometheus_counters_exactly_match_the_stats_verb() {
    let service = PlanService::in_memory();
    let telemetry = Telemetry::new();
    let drive = |line: &str| {
        server::handle_line_full(&service, Some(&telemetry), line).0
    };

    let mem = tiny_mem_gib(0.6, 1);
    let wall = tiny_wall_gib(2);
    let mut lines = vec![
        format!("query setting={TINY} mem={mem} batch=1 threads=1"),
        format!("query setting={TINY} mem={mem} batch=1 threads=1"), // hit
        format!("sweep setting={TINY} mem={wall} batch-cap=4 threads=1"),
        // degenerate same-hardware replan: counted, served, and — the
        // point here — observed into the replan latency lane
        format!("replan setting={TINY} mem={mem} batch=1 devices=8 \
                 threads=1 new-devices=8"),
        format!("query setting={TINY} mem=1e-9 batch=1"), // infeasible
        "frobnicate the planner".into(),                  // bad request
    ];
    for line in lines.drain(..) {
        let _ = drive(&line);
    }

    let stats = Json::parse(&drive("stats")).unwrap();
    let metrics = Json::parse(&drive("metrics")).unwrap();
    assert_eq!(metrics.get("kind").as_str(), Some("metrics"));
    let page = metrics.get("text").as_str().expect("exposition text");

    // parse the page: every non-comment line is `series value`, no
    // series twice
    let mut m = std::collections::BTreeMap::new();
    for line in page.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (series, value) = line.rsplit_once(' ').unwrap();
        let v: f64 = value.parse()
            .unwrap_or_else(|_| panic!("unparseable value in '{line}'"));
        assert!(m.insert(series.to_string(), v).is_none(),
                "duplicate series '{series}'");
    }
    let metric = |k: &str| {
        *m.get(k).unwrap_or_else(|| panic!("metric '{k}' missing"))
    };

    for field in [
        "hits", "misses", "inserts", "evictions", "coalesced",
        "planner_runs", "warm_seeded", "persist_errors", "replans",
        "replan_repairs", "cache_write_retries", "remote_hits",
        "remote_errors", "breaker_open",
    ] {
        assert_eq!(metric(&format!("osdp_service_{field}_total")),
                   stats.get(field).as_f64().unwrap_or(-1.0),
                   "stats/metrics disagree on '{field}'");
    }
    let t = stats.get("telemetry");
    for counter in ["queries", "rejected", "infeasible", "bad_requests"] {
        assert_eq!(metric(&format!("osdp_net_{counter}_total")),
                   t.get(counter).as_f64().unwrap_or(-1.0),
                   "stats/metrics disagree on net '{counter}'");
    }
    let mut lane_total = 0.0;
    for shape in ["batch", "sweep", "replan"] {
        let count = metric(&format!(
            "osdp_latency_seconds_count{{shape=\"{shape}\"}}"
        ));
        assert_eq!(
            count,
            t.get("latency").get(shape).get("count").as_f64()
                .unwrap_or(-1.0),
            "stats/metrics disagree on the {shape} lane"
        );
        lane_total += count;
    }
    assert_eq!(lane_total, t.get("queries").as_f64().unwrap(),
               "the three lanes partition the observed queries");
    // this drive's exact shape: 3 batch-lane queries (2 feasible + the
    // infeasible one), 1 sweep, 1 replan; the garbage line is a bad
    // request, not a query
    assert_eq!(metric("osdp_latency_seconds_count{shape=\"batch\"}"), 3.0);
    assert_eq!(metric("osdp_latency_seconds_count{shape=\"sweep\"}"), 1.0);
    assert_eq!(metric("osdp_latency_seconds_count{shape=\"replan\"}"),
               1.0);
    assert_eq!(metric("osdp_net_bad_requests_total"), 1.0);
    assert_eq!(metric("osdp_net_infeasible_total"), 1.0);

    assert_eq!(metric("osdp_cache_entries"),
               stats.get("cache_entries").as_f64().unwrap_or(-1.0));
    let breaker = stats.get("breaker").as_str().expect("breaker state");
    assert_eq!(
        metric(&format!("osdp_breaker_state{{state=\"{breaker}\"}}")), 1.0,
        "the breaker gauge must be one-hot on the stats verb's state"
    );
    // histogram shape: every lane's buckets are cumulative and end at
    // +Inf == count
    for shape in ["batch", "sweep", "replan"] {
        let count = metric(&format!(
            "osdp_latency_seconds_count{{shape=\"{shape}\"}}"
        ));
        let infs: Vec<f64> = m.iter()
            .filter(|(k, _)| {
                k.starts_with("osdp_latency_seconds_bucket")
                    && k.contains(&format!("shape=\"{shape}\""))
                    && k.contains("le=\"+Inf\"")
            })
            .map(|(_, v)| *v)
            .collect();
        assert_eq!(infs, vec![count],
                   "the +Inf bucket of the {shape} lane equals its count");
    }
}
