//! Cross-validation: the analytic (α,β,γ) cost model, the discrete-event
//! simulator, and the byte-moving fabric must agree on communication time —
//! three independent implementations of the same physics.

use osdp::collectives::{all_gather, all_reduce, chunk_range,
                        hier_all_gather, hier_gather_model_seconds,
                        node_all_gather, node_grad_sync, reduce_scatter,
                        ring_model_seconds};
use osdp::config::Cluster;
use osdp::cost::{Decision, op_comm_time};
use osdp::fabric::{self, Topology};
use osdp::model::{GptDims, build_gpt};
use osdp::sim;

const ALPHA: f64 = 5e-6;
const BETA: f64 = 2e-10;

fn max_clock(times: Vec<((), f64)>) -> f64 {
    times.into_iter().map(|(_, t)| t).fold(0.0, f64::max)
}

/// Fabric all-reduce realizes the paper's 2(N-1)(α+Sβ/N) within tolerance.
#[test]
fn fabric_all_reduce_matches_analytic_model() {
    for n in [2usize, 4, 8] {
        for len in [1usize << 14, 1 << 18] {
            let topo = Topology::flat(n, ALPHA, BETA);
            let t = max_clock(fabric::run_timed(n, topo, move |ep| {
                all_reduce(ep, &vec![1.0f32; len]);
            }));
            let model =
                ring_model_seconds(2.0, (len * 4) as f64, n, ALPHA, BETA);
            let ratio = t / model;
            assert!(
                (0.7..1.4).contains(&ratio),
                "n={n} len={len}: fabric {t:.6} vs model {model:.6}"
            );
        }
    }
}

/// The ZDP collective sequence (gather + gather + reduce-scatter) costs
/// ≈1.5× the DP sequence on the fabric too, not just in the formula.
#[test]
fn fabric_zdp_sequence_is_1_5x_dp() {
    let n = 8;
    let len = 1 << 18;
    let topo = Topology::flat(n, ALPHA, BETA);
    // DP: one all-reduce (RS + AG)
    let t_dp = max_clock(fabric::run_timed(n, topo.clone(), move |ep| {
        all_reduce(ep, &vec![1.0f32; len]);
    }));
    // ZDP: two all-gathers (fwd + bwd re-gather) + one reduce-scatter
    let t_zdp = max_clock(fabric::run_timed(n, topo, move |ep| {
        let shard = vec![1.0f32; len / 8];
        all_gather(ep, &shard, len);
        all_gather(ep, &shard, len);
        reduce_scatter(ep, &vec![1.0f32; len]);
    }));
    let ratio = t_zdp / t_dp;
    assert!(
        (1.3..1.7).contains(&ratio),
        "ZDP/DP comm ratio {ratio} (expected ≈1.5)"
    );
}

/// Simulator serial-mode iteration time equals the cost model's Σ T_i —
/// on the single-node cluster and, scope included, on the two-server one.
#[test]
fn sim_matches_cost_model_sum() {
    let m = build_gpt(&GptDims::uniform("x", 2000, 128, 3, 256, 4));
    for (c, decisions) in [
        (Cluster::rtx_titan(8, 8.0),
         vec![Decision::DP, Decision::ZDP, Decision::zdp_at(4)]),
        (Cluster::two_server_a100(16.0),
         vec![Decision::ZDP, Decision::ZDP_NODE,
              Decision::zdp_at(4).with_scope(osdp::cost::Scope::Node)]),
    ] {
        for d in decisions {
            let plan = vec![d; m.ops.len()];
            let tl = sim::simulate(&m, &plan, &c, 2, false, false);
            let comm_expected: f64 = m
                .ops
                .iter()
                .map(|op| op_comm_time(op, d, &c, false))
                .sum();
            assert!(
                (tl.comm_busy - comm_expected).abs()
                    / comm_expected.max(1e-12)
                    < 1e-6,
                "{}: sim comm {} vs model {}",
                d.label(),
                tl.comm_busy,
                comm_expected
            );
        }
    }
}

/// Two-server scenario: the *measured* node-scoped collective sequence —
/// two intra-node parameter gathers plus the hierarchical gradient sync
/// (intra reduce-scatter + cross-node shard all-reduce) — realizes the
/// cost model's scoped analytic term `op_comm_time(ZDP@node)` on the
/// byte-moving fabric.
#[test]
fn fabric_node_scoped_sequence_matches_scoped_analytic_term() {
    let m = build_gpt(&GptDims::uniform("x", 2000, 128, 1, 512, 4));
    let op = m.ops.iter().find(|o| o.name == "l0.mlp_up").unwrap().clone();
    let cluster = Cluster::two_server_a100(16.0);
    let topo = Topology::from_cluster(&cluster);
    let n = cluster.n_devices;
    let dpn = cluster.devices_per_node;
    let elems = (op.param_bytes() / 4.0) as usize;
    let t_node = max_clock(fabric::run_timed(n, topo.clone(), move |ep| {
        let local = ep.rank % dpn;
        // chunk `local` of the node's dpn-way partition
        let (_, shard_len) = chunk_range(elems, dpn, local);
        let shard = vec![1.0f32; shard_len];
        node_all_gather(ep, &shard, elems); // fwd param gather
        node_all_gather(ep, &shard, elems); // bwd re-gather
        node_grad_sync(ep, &vec![1.0f32; elems]); // hierarchical grad sync
    }));
    let model = op_comm_time(&op, Decision::ZDP_NODE, &cluster, false);
    let ratio = t_node / model;
    assert!(
        (0.7..1.4).contains(&ratio),
        "fabric {t_node:.6} vs scoped model {model:.6} (ratio {ratio:.3})"
    );
    // and the scope direction is physical, not just analytic: the same
    // ZDP sequence at global scope is far slower on the fabric
    let t_global = max_clock(fabric::run_timed(n, topo, move |ep| {
        let (_, shard_len) = chunk_range(elems, n, ep.rank);
        let shard = vec![1.0f32; shard_len];
        all_gather(ep, &shard, elems);
        all_gather(ep, &shard, elems);
        reduce_scatter(ep, &vec![1.0f32; elems]);
    }));
    assert!(t_node < t_global / 2.0,
            "node-scoped {t_node:.6} vs global {t_global:.6}");
}

/// The two-phase hierarchical all-gather realizes its analytic model and
/// beats the flat ring across the slow link (same bytes, same result).
#[test]
fn fabric_hier_all_gather_matches_model() {
    let topo = Topology {
        n_devices: 8,
        devices_per_node: 4,
        alpha_intra: 1e-6,
        beta_intra: 1e-11,
        alpha_inter: 2e-5,
        beta_inter: 8e-10,
    };
    let total = 1 << 18;
    let timed = fabric::run_timed(8, topo.clone(), move |ep| {
        let (_, len) = chunk_range(total, ep.n, ep.rank);
        hier_all_gather(ep, &vec![1.0f32; len], total)[0]
    });
    for (v, _) in &timed {
        assert_eq!(*v, 1.0);
    }
    let t = timed.iter().map(|(_, c)| *c).fold(0.0, f64::max);
    let model = hier_gather_model_seconds(
        (total * 4) as f64, 8, 4, 1e-6, 1e-11, 2e-5, 8e-10);
    let ratio = t / model;
    assert!((0.7..1.4).contains(&ratio),
            "hier gather {t:.6} vs model {model:.6} (ratio {ratio:.3})");
    let t_flat = max_clock(fabric::run_timed(8, topo, move |ep| {
        let (_, len) = chunk_range(total, ep.n, ep.rank);
        all_gather(ep, &vec![1.0f32; len], total);
    }));
    assert!(t < t_flat, "hier {t:.6} vs flat {t_flat:.6}");
}

/// Hierarchical all-reduce beats the flat ring across a slow inter-node
/// link — and both deliver identical sums.
#[test]
fn hierarchical_wins_across_nodes() {
    use osdp::collectives::hier_all_reduce;
    let topo = Topology {
        n_devices: 8,
        devices_per_node: 4,
        alpha_intra: 1e-6,
        beta_intra: 1e-11,
        alpha_inter: 2e-5,
        beta_inter: 8e-10,
    };
    let len = 1 << 18;
    let flat = fabric::run_timed(8, topo.clone(), move |ep| {
        all_reduce(ep, &vec![ep.rank as f32; len])[0]
    });
    let hier = fabric::run_timed(8, topo, move |ep| {
        hier_all_reduce(ep, &vec![ep.rank as f32; len])[0]
    });
    let want: f32 = (0..8).map(|r| r as f32).sum();
    for (v, _) in &flat {
        assert_eq!(*v, want);
    }
    for (v, _) in &hier {
        assert_eq!(*v, want);
    }
    let t_flat = flat.iter().map(|(_, t)| *t).fold(0.0, f64::max);
    let t_hier = hier.iter().map(|(_, t)| *t).fold(0.0, f64::max);
    assert!(t_hier < t_flat, "hier {t_hier} vs flat {t_flat}");
}
