//! Tentpole guarantees of the composition-frontier planner:
//!
//! * exactness — the frontier engine returns bit-identical
//!   `(choice, time)` to the folded and per-operator branch-and-bound on
//!   random uniform *and* heterogeneous (per-layer-varied) GPTs, serially
//!   and at 1 and 8 worker threads;
//! * ground truth — it still equals brute-force enumeration (choice
//!   vector included, now that the exhaustive enumerator shares the
//!   canonical `(time, lex)` objective) wherever that is affordable;
//! * batch invariance — one frontier build serves every batch size of a
//!   sweep: the scheduler's frontier sweep is bit-identical to the folded
//!   sweep at every batch, while never exploring more nodes;
//! * amortization — on the 24-layer uniform stack the per-batch search
//!   work stays small and bounded after the one-time frontier build.

use osdp::config::{Cluster, SearchConfig};
use osdp::cost::Profiler;
use osdp::model::{GptDims, build_gpt};
use osdp::planner::{Engine, ParallelConfig, Scheduler, exhaustive_search,
                    frontier, parallel_search};
use osdp::util::prop;
use osdp::util::rng::Rng;

/// Node budget for the property runs (see `folded_planner.rs`).
const PROP_BUDGET: u64 = 5_000_000;

#[derive(Debug, Clone)]
struct Instance {
    layers: usize,
    hidden: Vec<usize>,
    n_dev: usize,
    b: usize,
    limit_frac: f64,
    grans: Vec<usize>,
}

fn gen_uniform(rng: &mut Rng, size: usize) -> Instance {
    let layers = rng.range(2, 2 + size / 25);
    Instance {
        layers,
        hidden: vec![32 * rng.range(1, 5); layers],
        n_dev: *rng.pick(&[2usize, 4, 8]),
        b: rng.range(1, 4),
        limit_frac: 0.25 + rng.f64() * 1.1,
        grans: if rng.chance(0.5) { vec![0] } else { vec![0, 2] },
    }
}

fn gen_hetero(rng: &mut Rng, size: usize) -> Instance {
    let layers = rng.range(2, 2 + size / 25);
    let w1 = 32 * rng.range(1, 4);
    let w2 = w1 + 32 * rng.range(1, 3);
    let split = rng.range(1, layers);
    let hidden = (0..layers)
        .map(|l| if l < split { w1 } else { w2 })
        .collect();
    Instance {
        layers,
        hidden,
        n_dev: *rng.pick(&[2usize, 4, 8]),
        b: rng.range(1, 4),
        limit_frac: 0.25 + rng.f64() * 1.1,
        grans: if rng.chance(0.5) { vec![0] } else { vec![0, 2] },
    }
}

fn build(inst: &Instance) -> (Profiler, f64) {
    let m = build_gpt(&GptDims {
        name: "p".into(),
        vocab: 1000,
        seq: 64,
        layers: inst.layers,
        hidden_per_layer: inst.hidden.clone(),
        heads: 2,
        tied_head: false,
    });
    let c = Cluster::rtx_titan(inst.n_dev, 8.0);
    let s = SearchConfig { granularities: inst.grans.clone(),
                           ..Default::default() };
    let p = Profiler::new(&m, &c, &s);
    let dp_mem = p.evaluate(&p.index_of(|d| d.is_pure_dp()), inst.b).peak_mem;
    (p, dp_mem * inst.limit_frac)
}

fn cfg(threads: usize) -> ParallelConfig {
    ParallelConfig {
        threads,
        split_depth: 3,
        node_budget: PROP_BUDGET,
        engine: Engine::Frontier,
    }
}

/// Compare the frontier engine — serial and parallel at 1 and 8 threads —
/// against the folded branch-and-bound on one instance. Returns true when
/// a full (all-engines-complete, feasible) comparison happened.
fn assert_frontier_exact(p: &Profiler, limit: f64, b: usize)
                         -> Result<bool, String> {
    let folded =
        osdp::planner::dfs::search_with_budget(p, limit, b, PROP_BUDGET);
    let front = frontier::search_with_budget(p, limit, b, PROP_BUDGET);
    match (&folded, &front) {
        (None, None) => Ok(false),
        (Some((gc, gcost, gst)), Some((fc, fcost, fst))) => {
            if !(gst.complete && fst.complete) {
                return Ok(false); // anytime results may legitimately differ
            }
            if fc != gc {
                return Err(format!("choice differs: {fc:?} vs {gc:?}"));
            }
            if fcost.time.to_bits() != gcost.time.to_bits()
                || fcost.peak_mem.to_bits() != gcost.peak_mem.to_bits()
            {
                return Err(format!("cost differs: {fcost:?} vs {gcost:?}"));
            }
            if fst.nodes > gst.nodes {
                return Err(format!(
                    "frontier explored more than the fold: {} > {}",
                    fst.nodes, gst.nodes
                ));
            }
            for threads in [1usize, 8] {
                let par = parallel_search(p, limit, b, &cfg(threads));
                match &par {
                    Some((pc, pcost, pst)) => {
                        if !pst.complete {
                            return Ok(false);
                        }
                        if pc != gc {
                            return Err(format!(
                                "parallel({threads}) frontier choice \
                                 differs: {pc:?} vs {gc:?}"
                            ));
                        }
                        if pcost.time.to_bits() != gcost.time.to_bits() {
                            return Err(format!(
                                "parallel({threads}) frontier time differs"
                            ));
                        }
                    }
                    None => {
                        return Err(format!(
                            "parallel({threads}) lost feasibility"
                        ));
                    }
                }
            }
            Ok(true)
        }
        (g, f) => Err(format!(
            "feasibility disagreement: folded={:?} frontier={:?}",
            g.is_some(),
            f.is_some()
        )),
    }
}

/// Frontier == folded, bit-for-bit, on random *uniform* GPTs.
#[test]
fn prop_frontier_is_exact_on_uniform_stacks() {
    let mut compared = 0;
    prop::check(0xF807_0001, 18, gen_uniform, |inst| {
        let (p, limit) = build(inst);
        if assert_frontier_exact(&p, limit, inst.b)? {
            compared += 1;
        }
        Ok(())
    });
    assert!(compared >= 5, "only {compared} full comparisons ran");
}

/// Frontier == folded, bit-for-bit, on random *heterogeneous* GPTs
/// (mixed widths: several classes of multiplicity > 1 plus singletons).
#[test]
fn prop_frontier_is_exact_on_heterogeneous_stacks() {
    let mut compared = 0;
    prop::check(0xF807_0002, 18, gen_hetero, |inst| {
        let (p, limit) = build(inst);
        if assert_frontier_exact(&p, limit, inst.b)? {
            compared += 1;
        }
        Ok(())
    });
    assert!(compared >= 5, "only {compared} full comparisons ran");
}

/// Independent anchor: the frontier engine equals brute-force enumeration
/// — full choice vector, not just time — wherever brute force is
/// affordable.
#[test]
fn prop_frontier_is_exact_vs_exhaustive() {
    prop::check(0xF807_0003, 15, gen_hetero, |inst| {
        let (p, limit) = build(inst);
        if p.log10_plan_space() > 5.5 {
            return Ok(()); // brute force too big; covered by other props
        }
        let brute = exhaustive_search(&p, limit, inst.b);
        let smart = frontier::search(&p, limit, inst.b);
        match (brute, smart) {
            (None, None) => Ok(()),
            (Some((bchoice, bc)), Some((schoice, sc, stats))) => {
                if !stats.complete {
                    return Err("budget expired on a tiny instance".into());
                }
                if schoice != bchoice {
                    return Err(format!(
                        "choice differs: {schoice:?} vs {bchoice:?}"
                    ));
                }
                if sc.time.to_bits() != bc.time.to_bits() {
                    return Err(format!(
                        "time differs: {} vs {}", sc.time, bc.time
                    ));
                }
                if sc.peak_mem > limit {
                    return Err(format!("overflows: {}", sc.peak_mem));
                }
                Ok(())
            }
            (b, s) => Err(format!(
                "feasibility disagreement: brute={:?} frontier={:?}",
                b.is_some(),
                s.is_some()
            )),
        }
    });
}

/// The exhaustive-fold satellite, anchored end-to-end: folded and
/// raw-product enumeration agree on the full choice vector on random
/// instances with real symmetry.
#[test]
fn prop_folded_exhaustive_matches_raw_product() {
    prop::check(0xF807_0004, 12, gen_uniform, |inst| {
        let (p, limit) = build(inst);
        if p.log10_plan_space() > 4.5 {
            return Ok(()); // raw product too big
        }
        let folded = exhaustive_search(&p, limit, inst.b);
        let raw =
            osdp::planner::exhaustive::search_unfolded(&p, limit, inst.b);
        match (folded, raw) {
            (None, None) => Ok(()),
            (Some((fc, fcost)), Some((rc, rcost))) => {
                if fc != rc {
                    return Err(format!("choice differs: {fc:?} vs {rc:?}"));
                }
                if fcost.time.to_bits() != rcost.time.to_bits() {
                    return Err("time differs".into());
                }
                Ok(())
            }
            (f, r) => Err(format!(
                "feasibility disagreement: folded={:?} raw={:?}",
                f.is_some(),
                r.is_some()
            )),
        }
    });
}

/// Batch invariance, end to end: one frontier build serves the whole
/// sweep. The scheduler's frontier sweep returns bit-identical candidates
/// to the folded sweep at every batch size, never explores more nodes,
/// and equals fresh per-batch frontier builds (so sharing the build
/// across batches changes nothing — the invariance claim in practice).
#[test]
fn frontier_sweep_is_bit_identical_across_all_batches() {
    let m = build_gpt(&GptDims::uniform("sweep", 4000, 64, 6, 192, 4));
    let c = Cluster::rtx_titan(8, 8.0);
    let s = SearchConfig { granularities: vec![0, 2],
                           ..Default::default() };
    let p = Profiler::new(&m, &c, &s);
    let dp1 = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1).peak_mem;
    let limit = dp1 * 3.0;
    let fr = Scheduler::new(&p, limit, 64).run().unwrap();
    let fo = Scheduler::new(&p, limit, 64)
        .with_engine(Engine::FoldedBb)
        .run()
        .unwrap();
    assert!(fr.candidates.len() >= 2, "sweep must cover several batches");
    assert_eq!(fr.candidates.len(), fo.candidates.len());
    assert_eq!(fr.best, fo.best);
    let stats = fr.frontier.as_ref().expect("frontier sweep records stats");
    assert!(stats.points > 0 && stats.points <= stats.compositions);
    for (a, b) in fr.candidates.iter().zip(&fo.candidates) {
        assert_eq!(a.plan.batch, b.plan.batch);
        assert_eq!(a.plan.choice, b.plan.choice, "b={}", a.plan.batch);
        assert_eq!(a.plan.cost.time.to_bits(), b.plan.cost.time.to_bits());
        assert_eq!(a.plan.cost.peak_mem.to_bits(),
                   b.plan.cost.peak_mem.to_bits());
        assert!(a.stats.nodes <= b.stats.nodes,
                "frontier explored more at b={}", a.plan.batch);
        // a fresh per-batch frontier build gives the same result as the
        // sweep-shared one
        let fresh = frontier::search(&p, limit, a.plan.batch).unwrap();
        assert_eq!(fresh.0, a.plan.choice);
        assert_eq!(fresh.1.time.to_bits(), a.plan.cost.time.to_bits());
    }
}

/// Handcrafted cost table with a fully controlled menu: one option per
/// `(tf_ms, states, gather)` triple, grid-snapped like the real profiler.
/// The search engines read nothing but the cost fields, so the decision
/// metadata can be a placeholder.
fn wide_table(name: &str, tf_ms: &[f64], st: &[f64], g: &[f64], act: f64,
              ws: f64, gamma: f64) -> osdp::cost::OpCostTable {
    use osdp::cost::time::snap_time;
    use osdp::cost::{Decision, DecisionCost, OpCostTable};
    let options = tf_ms
        .iter()
        .zip(st)
        .zip(g)
        .map(|((&t, &s), &gather)| DecisionCost {
            decision: Decision::DP,
            comm: snap_time(t * 1e-3),
            launch: 0.0,
            states: s,
            gather,
        })
        .collect();
    OpCostTable::new(name.into(), options, act, ws, gamma)
}

/// The acceptance shapes for the incremental Minkowski-sum build: wide
/// `o = 4` menus at multiplicity 96 (the issue's headline class) and 120
/// (`C(123, 3) = 302 621 > 2^18`, strictly above the retired one-shot
/// composition ceiling, where the old build forfeited the prebuild).
/// Every class must prebuild (`too_wide == 0` structurally), and the
/// planned full choice vector must be bit-identical to the folded
/// engine's, serially and at 1 and 8 threads.
#[test]
fn wide_classes_prebuild_and_plan_bit_identically() {
    use osdp::cost::MenuStats;
    for (m, fracs) in [(96usize, &[0.45, 0.8][..]), (120, &[0.45][..])] {
        let layer = wide_table("layer", &[1.0, 2.2, 3.3, 4.7],
                               &[4000.0, 2600.0, 1100.0, 400.0],
                               &[0.0, 1500.0, 900.0, 2100.0],
                               64.0, 16.0, 2e-5);
        let emb = wide_table("emb", &[0.4, 1.8], &[9000.0, 1200.0],
                             &[0.0, 7800.0], 8.0, 4.0, 1e-5);
        let head = wide_table("head", &[0.5, 2.0], &[9000.0, 1150.0],
                              &[0.0, 7900.0], 8.0, 4.0, 1e-5);
        let mut tables = vec![emb];
        tables.extend(std::iter::repeat_with(|| layer.clone()).take(m));
        tables.push(head);
        let n = tables.len();
        let p = Profiler {
            cluster: Cluster::rtx_titan(8, 16.0),
            checkpointing: false,
            menu_stats: vec![MenuStats { raw: 4, kept: 4 }; n],
            tables,
        };

        let r = frontier::report(&p);
        assert_eq!(r.too_wide, 0, "every class prebuilds at m={m}");
        assert_eq!(r.classes, 3, "96+ layers fold into one class at m={m}");
        let widest = r.per_class.iter().map(|c| c.raw).max().unwrap();
        if m == 120 {
            assert!(widest > 1 << 18,
                    "m=120 must exceed the old one-shot ceiling: {widest}");
        }
        assert!(r.max_level_width >= 1 && r.points >= r.max_level_width);
        // the kept frontier is tiny relative to the composition count
        assert!(r.points <= 8 * (m + 2),
                "frontier kept {} points at m={m}", r.points);

        let dp = p.evaluate(&vec![0usize; n], 2).peak_mem;
        let mut compared = 0;
        for &frac in fracs {
            if assert_frontier_exact(&p, dp * frac, 2).unwrap() {
                compared += 1;
            }
        }
        assert!(compared >= 1, "no full comparison ran at m={m}");
    }
}

/// The headline amortization claim on the deep uniform stack the fold
/// test targets: after the one-time frontier build, every per-batch
/// search of the sweep stays within a small node bound (the merge over
/// precomputed Pareto sets), never exceeds the folded engine's work, and
/// the sweep is bit-identical to the folded sweep at the hardest limits.
#[test]
fn per_batch_work_stays_small_on_deep_uniform_sweep() {
    let m = build_gpt(&GptDims::uniform("deep", 5000, 128, 24, 256, 4));
    let c = Cluster::rtx_titan(8, 8.0);
    let s = SearchConfig {
        granularities: vec![0],
        paper_granularity: true,
        ..Default::default()
    };
    let p = Profiler::new(&m, &c, &s);
    let r = frontier::report(&p);
    assert_eq!(r.too_wide, 0, "paper-granularity menus must prebuild");
    assert!(r.points <= r.compositions);

    let dp = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1).peak_mem;
    let zdp = p.evaluate(&p.index_of(|d| d.is_pure_zdp()), 1).peak_mem;
    for frac in [0.2, 0.5, 0.8] {
        let limit = zdp + (dp - zdp) * frac;
        let fr = Scheduler::new(&p, limit, 8).run().unwrap();
        let fo = Scheduler::new(&p, limit, 8)
            .with_engine(Engine::FoldedBb)
            .run()
            .unwrap();
        assert_eq!(fr.candidates.len(), fo.candidates.len());
        for (a, b) in fr.candidates.iter().zip(&fo.candidates) {
            assert_eq!(a.plan.choice, b.plan.choice,
                       "frac {frac} b={}", a.plan.batch);
            assert_eq!(a.plan.cost.time.to_bits(),
                       b.plan.cost.time.to_bits());
            assert!(a.stats.complete, "frontier search must finish");
            assert!(a.stats.nodes <= b.stats.nodes);
            // per-batch work after the build: a merge over small Pareto
            // sets, orders of magnitude under the 2^50 per-op space
            assert!(a.stats.nodes <= 20_000,
                    "per-batch frontier work blew up: {} nodes at b={}",
                    a.stats.nodes, a.plan.batch);
        }
    }
}
