//! Integration: config files load end-to-end; figure generators honor the
//! paper's qualitative invariants at reduced scale; failure paths fail
//! loudly.

use osdp::config::{GIB, RunConfig};
use osdp::figures::{self, Quality};
use osdp::metrics::speedup;

#[test]
fn shipped_config_files_parse() {
    for f in ["configs/rtx_titan_8x8g.toml", "configs/two_server_a100_16g.toml",
              "configs/cpu_testbed.toml"] {
        let cfg = RunConfig::from_file(f)
            .unwrap_or_else(|e| panic!("{f}: {e}"));
        assert!(cfg.cluster.validate().is_ok(), "{f}");
        assert!(cfg.cluster.mem_limit >= 1.0 * GIB);
    }
    // the custom testbed overrides flops
    let cpu = RunConfig::from_file("configs/cpu_testbed.toml").unwrap();
    assert_eq!(cpu.cluster.flops, 5.0e10);
    assert_eq!(cpu.cluster.n_devices, 4);
}

#[test]
fn missing_config_file_is_error() {
    assert!(RunConfig::from_file("configs/nope.toml").is_err());
}

#[test]
fn fig7_is_deterministic() {
    let (_, a) = figures::fig7();
    let (_, b) = figures::fig7();
    assert_eq!(a, b);
}

#[test]
fn fig9_margin_positive_under_memory_pressure() {
    // at 8G (memory-limited) OSDP must beat FSDP with ckpt on — the
    // Figure 9 direction
    let fig = figures::fig9(8.0, Quality::Quick);
    let s = speedup(&fig, "OSDP", "FSDP").expect("both feasible somewhere");
    assert!(s.avg >= 1.0, "avg {}", s.avg);
    assert!(s.max > 1.05, "max {}", s.max);
}

#[test]
fn table1_row_count_matches_zoo() {
    let t = figures::table1();
    // header + separator + 12 settings
    assert_eq!(t.lines().count(), 1 + 2 + 12);
}

#[test]
fn gantt_zdp_charges_three_collectives_worth() {
    let g = figures::fig1_gantt();
    // Figure 1's claim is about *communication*: ZDP pays 3 rounds vs
    // DP's 2 (1.5×). Parse the "comm busy" column of both headers.
    let comm: Vec<f64> = g
        .lines()
        .filter(|l| l.starts_with("iteration"))
        .map(|l| {
            l.split("comm busy").nth(1).unwrap().trim()
                .split_whitespace().next().unwrap()
                .parse::<f64>().unwrap()
        })
        .collect();
    assert_eq!(comm.len(), 2);
    let ratio = comm[1] / comm[0];
    assert!((ratio - 1.5).abs() < 0.01, "ZDP/DP comm ratio {ratio}");
    // and the ZDP iteration is visibly longer end-to-end
    let iters: Vec<f64> = g
        .lines()
        .filter(|l| l.starts_with("iteration"))
        .map(|l| {
            l.split_whitespace().nth(1).unwrap().parse::<f64>().unwrap()
        })
        .collect();
    assert!(iters[1] > iters[0] * 1.02, "{iters:?}");
}
