//! Tentpole guarantees of the symmetry-folded planner:
//!
//! * exactness — the folded engine returns bit-identical `(choice, time)`
//!   to the unfolded per-operator engine on random uniform *and*
//!   heterogeneous (per-layer-varied) GPTs, serially and at 1 and 8
//!   worker threads;
//! * compression — on a deep uniform stack the fold shrinks the explored
//!   tree by at least an order of magnitude;
//! * ground truth — the folded engine still equals brute-force
//!   enumeration wherever that is affordable.

use osdp::config::{Cluster, SearchConfig};
use osdp::cost::Profiler;
use osdp::model::{GptDims, build_gpt};
use osdp::planner::{Engine, ParallelConfig, dfs_search_unfolded,
                    exhaustive_search, parallel_search};
use osdp::util::prop;
use osdp::util::rng::Rng;

/// Node budget for the property runs: far beyond what these instances
/// need, while keeping a hard ceiling on worst-case test time. Instances
/// where any engine expires are skipped (anytime results are legitimately
/// engine-specific), but the suite asserts it verified plenty of full
/// comparisons.
const PROP_BUDGET: u64 = 5_000_000;

#[derive(Debug, Clone)]
struct Instance {
    layers: usize,
    /// Per-layer hidden sizes; all equal for the uniform family.
    hidden: Vec<usize>,
    n_dev: usize,
    b: usize,
    limit_frac: f64,
    grans: Vec<usize>,
}

fn gen_uniform(rng: &mut Rng, size: usize) -> Instance {
    let layers = rng.range(2, 2 + size / 25);
    Instance {
        layers,
        hidden: vec![32 * rng.range(1, 5); layers],
        n_dev: *rng.pick(&[2usize, 4, 8]),
        b: rng.range(1, 4),
        limit_frac: 0.25 + rng.f64() * 1.1,
        grans: if rng.chance(0.5) { vec![0] } else { vec![0, 2] },
    }
}

/// Per-layer-varied widths: several symmetry classes of multiplicity > 1
/// plus stage-transition projections that stay singletons.
fn gen_hetero(rng: &mut Rng, size: usize) -> Instance {
    let layers = rng.range(2, 2 + size / 25);
    let w1 = 32 * rng.range(1, 4);
    let w2 = w1 + 32 * rng.range(1, 3);
    let split = rng.range(1, layers);
    let hidden = (0..layers)
        .map(|l| if l < split { w1 } else { w2 })
        .collect();
    Instance {
        layers,
        hidden,
        n_dev: *rng.pick(&[2usize, 4, 8]),
        b: rng.range(1, 4),
        limit_frac: 0.25 + rng.f64() * 1.1,
        grans: if rng.chance(0.5) { vec![0] } else { vec![0, 2] },
    }
}

fn build(inst: &Instance) -> (Profiler, f64) {
    let m = build_gpt(&GptDims {
        name: "p".into(),
        vocab: 1000,
        seq: 64,
        layers: inst.layers,
        hidden_per_layer: inst.hidden.clone(),
        heads: 2,
        tied_head: false,
    });
    let c = Cluster::rtx_titan(inst.n_dev, 8.0);
    let s = SearchConfig { granularities: inst.grans.clone(),
                           ..Default::default() };
    let p = Profiler::new(&m, &c, &s);
    let dp_mem = p.evaluate(&p.index_of(|d| d.is_pure_dp()), inst.b).peak_mem;
    (p, dp_mem * inst.limit_frac)
}

fn cfg(threads: usize, engine: Engine) -> ParallelConfig {
    ParallelConfig {
        threads,
        split_depth: 3,
        node_budget: PROP_BUDGET,
        engine,
    }
}

/// Compare the folded engine against the unfolded one — serial, and the
/// parallel engine at 1 and 8 threads — on one instance. Returns true
/// when a full (all-engines-complete, feasible) comparison happened.
fn assert_fold_exact(p: &Profiler, limit: f64, b: usize)
                     -> Result<bool, String> {
    let unfolded = dfs_search_unfolded(p, limit, b, PROP_BUDGET);
    let folded =
        osdp::planner::dfs::search_with_budget(p, limit, b, PROP_BUDGET);
    match (&unfolded, &folded) {
        (None, None) => Ok(false),
        (Some((uc, ucost, ust)), Some((fc, fcost, fst))) => {
            if !(ust.complete && fst.complete) {
                return Ok(false); // anytime results may legitimately differ
            }
            if uc != fc {
                return Err(format!("choice differs: {uc:?} vs {fc:?}"));
            }
            if ucost.time.to_bits() != fcost.time.to_bits()
                || ucost.peak_mem.to_bits() != fcost.peak_mem.to_bits()
            {
                return Err(format!("cost differs: {ucost:?} vs {fcost:?}"));
            }
            for threads in [1usize, 8] {
                let par =
                    parallel_search(p, limit, b,
                                    &cfg(threads, Engine::FoldedBb));
                match &par {
                    Some((pc, pcost, pst)) => {
                        if !pst.complete {
                            return Ok(false);
                        }
                        if pc != uc {
                            return Err(format!(
                                "parallel({threads}) folded choice differs: \
                                 {pc:?} vs {uc:?}"
                            ));
                        }
                        if pcost.time.to_bits() != ucost.time.to_bits() {
                            return Err(format!(
                                "parallel({threads}) folded time differs"
                            ));
                        }
                    }
                    None => {
                        return Err(format!(
                            "parallel({threads}) lost feasibility"
                        ));
                    }
                }
            }
            Ok(true)
        }
        (u, f) => Err(format!(
            "feasibility disagreement: unfolded={:?} folded={:?}",
            u.is_some(),
            f.is_some()
        )),
    }
}

/// Folded == unfolded, bit-for-bit, on random *uniform* GPTs (the case
/// the fold is built for: every layer collapses into shared classes).
#[test]
fn prop_fold_is_exact_on_uniform_stacks() {
    let mut compared = 0;
    prop::check(0xF01D_0001, 18, gen_uniform, |inst| {
        let (p, limit) = build(inst);
        if assert_fold_exact(&p, limit, inst.b)? {
            compared += 1;
        }
        Ok(())
    });
    assert!(compared >= 5, "only {compared} full comparisons ran");
}

/// Folded == unfolded, bit-for-bit, on random *heterogeneous* GPTs
/// (mixed widths: several classes per op shape plus singletons).
#[test]
fn prop_fold_is_exact_on_heterogeneous_stacks() {
    let mut compared = 0;
    prop::check(0xF01D_0002, 18, gen_hetero, |inst| {
        let (p, limit) = build(inst);
        if assert_fold_exact(&p, limit, inst.b)? {
            compared += 1;
        }
        Ok(())
    });
    assert!(compared >= 5, "only {compared} full comparisons ran");
}

/// The folded engine still equals brute force wherever brute force is
/// affordable (independent anchor: not just "same as the unfolded DFS").
#[test]
fn prop_folded_planner_is_exact_vs_exhaustive() {
    prop::check(0xF01D_0003, 15, gen_hetero, |inst| {
        let (p, limit) = build(inst);
        if p.log10_plan_space() > 5.5 {
            return Ok(()); // brute force too big; covered by other props
        }
        let brute = exhaustive_search(&p, limit, inst.b);
        let smart = osdp::planner::dfs_search(&p, limit, inst.b);
        match (brute, smart) {
            (None, None) => Ok(()),
            (Some((_, bc)), Some((_, sc, stats))) => {
                if !stats.complete {
                    return Err("budget expired on a tiny instance".into());
                }
                if sc.peak_mem > limit {
                    return Err(format!("overflows: {}", sc.peak_mem));
                }
                prop::close(bc.time, sc.time, 1e-10)
            }
            (b, s) => Err(format!(
                "feasibility disagreement: brute={:?} folded={:?}",
                b.is_some(),
                s.is_some()
            )),
        }
    });
}

/// The headline compression claim: on a 24-layer uniform GPT (paper
/// granularity: 50 ops collapsing to 4 classes) the folded tree is at
/// least 10x smaller than the per-operator tree at the hardest limit of a
/// mid-range sweep. With binary menus the whole folded space has
/// ~25·25·2·2 count compositions, so the folded search provably
/// completes; the per-operator tree over the same 2^50 space must either
/// blow past the node budget or pay combinatorially for the C(48, k)
/// interior selections.
#[test]
fn fold_shrinks_tree_10x_on_deep_uniform_stack() {
    let m = build_gpt(&GptDims::uniform("deep", 5000, 128, 24, 256, 4));
    let c = Cluster::rtx_titan(8, 8.0);
    let s = SearchConfig {
        granularities: vec![0],
        paper_granularity: true,
        ..Default::default()
    };
    let p = Profiler::new(&m, &c, &s);
    assert_eq!(p.n_ops(), 2 * 24 + 2);
    let r = osdp::planner::fold_report(&p);
    assert!(r.classes <= 6, "24 fused layers must fold: {r:?}");
    assert!(r.max_multiplicity >= 24);

    let dp = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1).peak_mem;
    let zdp = p.evaluate(&p.index_of(|d| d.is_pure_zdp()), 1).peak_mem;
    const BUDGET: u64 = 3_000_000;
    let mut best_ratio = 0.0f64;
    let mut hardest = (0u64, 0u64);
    for frac in [0.15, 0.3, 0.45, 0.6, 0.75, 0.9] {
        let limit = zdp + (dp - zdp) * frac;
        let folded =
            osdp::planner::dfs::search_with_budget(&p, limit, 1, BUDGET)
                .expect("above the all-ZDP peak is feasible");
        let unfolded = dfs_search_unfolded(&p, limit, 1, BUDGET)
            .expect("above the all-ZDP peak is feasible");
        assert!(folded.2.complete,
                "folded search must finish within budget (frac {frac}): \
                 {} nodes", folded.2.nodes);
        // wherever the unfolded engine also finished, results are
        // bit-identical
        if unfolded.2.complete {
            assert_eq!(folded.0, unfolded.0, "choice differs at {frac}");
            assert_eq!(folded.1.time.to_bits(), unfolded.1.time.to_bits());
        }
        let (fnodes, unodes) = (folded.2.nodes, unfolded.2.nodes);
        if unodes > hardest.1 {
            hardest = (fnodes, unodes);
        }
        best_ratio = best_ratio.max(unodes as f64 / fnodes.max(1) as f64);
    }
    assert!(
        best_ratio >= 10.0,
        "fold must shrink the deep-uniform tree >=10x somewhere in the \
         sweep; best ratio {best_ratio:.1} (hardest instance: folded {} vs \
         unfolded {} nodes)",
        hardest.0,
        hardest.1,
    );
}
