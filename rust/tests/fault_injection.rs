//! Fault-injected serve path (ISSUE 7): a release `osdp serve` under a
//! deterministic `OSDP_FAULTS` plan — panicking searches, slow
//! searches, cache I/O errors, mid-line socket resets — must keep
//! serving, resurrect panicked workers (`worker_restarts > 0`), keep
//! the pinned telemetry invariants exact, never corrupt the disk
//! cache, and still shut down cleanly with exit status 0.
//!
//! The same chaos drive runs in CI against three fixed seeds via
//! `python/tests/drive_frontend.py --chaos`; this test is the
//! in-process-toolchain version against the built binary
//! (`CARGO_BIN_EXE_osdp`).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use osdp::util::json::Json;

const TINY: &str = "gpt:3000,64,6,192,4";
const FAULTS: &str =
    "seed:1117,panic:60000,slow:40000,slow-ms:1,cache-io:150000,\
     sock-reset:40000";

fn spawn_serve(cache_dir: &std::path::Path) -> (Child, std::net::SocketAddr) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_osdp"))
        .args([
            "serve",
            "--listen",
            "127.0.0.1:0",
            "--workers",
            "2",
            "--cache-dir",
        ])
        .arg(cache_dir)
        .env("OSDP_FAULTS", FAULTS)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn osdp serve");
    // first stdout line announces the bound ephemeral port
    let mut line = String::new();
    BufReader::new(child.stdout.take().expect("piped stdout"))
        .read_line(&mut line)
        .expect("read the listening line");
    let doc = Json::parse(line.trim()).expect("listening line is JSON");
    assert_eq!(doc.get("kind").as_str(), Some("listening"), "{line:?}");
    let addr = doc
        .get("addr")
        .as_str()
        .expect("listening line carries the address")
        .parse()
        .expect("parse bound address");
    (child, addr)
}

/// One chaos-tolerant request: connect, send, read one line. `None` on
/// any transport failure (reset sockets and mid-response worker deaths
/// are exactly what the fault plan injects).
fn try_request(addr: std::net::SocketAddr, line: &str) -> Option<Json> {
    let stream = TcpStream::connect(addr).ok()?;
    stream.set_read_timeout(Some(Duration::from_secs(30))).ok()?;
    let mut writer = stream.try_clone().ok()?;
    let mut reader = BufReader::new(stream);
    writeln!(writer, "{line}").ok()?;
    writer.flush().ok()?;
    let mut resp = String::new();
    reader.read_line(&mut resp).ok()?;
    if !resp.ends_with('\n') {
        return None; // torn mid-line by an injected reset
    }
    Json::parse(resp.trim_end()).ok()
}

/// Retry a request until it survives the chaos (bounded by `deadline`).
fn request(addr: std::net::SocketAddr, line: &str,
           deadline: Instant) -> Json {
    loop {
        if let Some(doc) = try_request(addr, line) {
            return doc;
        }
        assert!(Instant::now() < deadline,
                "'{line}' never survived the fault plan");
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn check_invariants(stats: &Json) {
    let n = |k: &str| stats.get(k).as_f64().unwrap_or(-1.0);
    let t = |k: &str| stats.get("telemetry").get(k).as_f64().unwrap_or(-1.0);
    assert_eq!(
        n("hits") + n("misses"),
        t("queries") - t("rejected"),
        "hits + misses == queries − rejected must survive chaos: {stats:?}"
    );
    let lat = stats.get("telemetry").get("latency");
    let lane = |s: &str| {
        lat.get(s).get("count").as_f64().unwrap_or(-1.0)
    };
    assert_eq!(
        lane("batch") + lane("sweep") + lane("replan"),
        t("queries"),
        "every query is observed exactly once, in exactly one lane: \
         {stats:?}"
    );
}

/// Parse a Prometheus text page into `name{labels}` → value. Panics on
/// anything that is not a comment, a blank line, or `series value` —
/// which is the "exposition parses" invariant.
fn parse_prometheus(page: &str) -> std::collections::BTreeMap<String, f64> {
    let mut out = std::collections::BTreeMap::new();
    for line in page.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (series, value) =
            line.rsplit_once(' ').expect("metric lines are 'series value'");
        let v: f64 = value
            .parse()
            .unwrap_or_else(|_| panic!("unparseable value in '{line}'"));
        assert!(
            out.insert(series.to_string(), v).is_none(),
            "duplicate series '{series}'"
        );
    }
    out
}

/// The `metrics` verb must tell the same story as the `stats` verb:
/// every service counter and every latency-lane count equal, to the
/// unit. (Net counters like `requests` are excluded — serving the two
/// verbs itself moves them between the snapshots; the service-level
/// counters only move when a query runs, and the chaos drive is
/// sequential.)
fn check_metrics_match_stats(stats: &Json, page: &str) {
    let m = parse_prometheus(page);
    let metric = |k: &str| {
        *m.get(k).unwrap_or_else(|| panic!("metric '{k}' missing"))
    };
    for field in [
        "hits", "misses", "inserts", "evictions", "coalesced",
        "planner_runs", "warm_seeded", "persist_errors", "replans",
        "replan_repairs", "cache_write_retries", "remote_hits",
        "remote_errors", "breaker_open",
    ] {
        assert_eq!(
            metric(&format!("osdp_service_{field}_total")),
            stats.get(field).as_f64().unwrap_or(-1.0),
            "stats/metrics disagree on '{field}'"
        );
    }
    let t = stats.get("telemetry");
    for counter in ["queries", "rejected", "infeasible", "bad_requests"] {
        assert_eq!(
            metric(&format!("osdp_net_{counter}_total")),
            t.get(counter).as_f64().unwrap_or(-1.0),
            "stats/metrics disagree on net '{counter}'"
        );
    }
    for shape in ["batch", "sweep", "replan"] {
        assert_eq!(
            metric(&format!(
                "osdp_latency_seconds_count{{shape=\"{shape}\"}}"
            )),
            t.get("latency").get(shape).get("count").as_f64()
                .unwrap_or(-1.0),
            "stats/metrics disagree on the {shape} lane"
        );
    }
    assert_eq!(metric("osdp_cache_entries"),
               stats.get("cache_entries").as_f64().unwrap_or(-1.0));
    let breaker = stats.get("breaker").as_str().expect("breaker state");
    assert_eq!(
        metric(&format!("osdp_breaker_state{{state=\"{breaker}\"}}")),
        1.0,
        "the breaker gauge must be one-hot on the stats verb's state"
    );
}

/// Every trace the ring kept must be a closed tree: the request
/// finished, every span guard dropped, root span present. Chaos that
/// kills a request mid-flight drops its trace context entirely — it
/// never reaches the ring half-built.
fn check_traces_closed(traces: &Json) {
    assert_eq!(traces.get("kind").as_str(), Some("traces"));
    for t in traces.get("traces").as_arr().expect("trace summaries") {
        assert_eq!(
            t.get("complete").as_bool(),
            Some(true),
            "an incomplete trace escaped into the ring: {t:?}"
        );
    }
}

#[test]
fn chaos_serve_survives_restarts_workers_and_exits_cleanly() {
    let dir = std::env::temp_dir().join(format!(
        "osdp-chaos-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let (mut child, addr) = spawn_serve(&dir);
    let deadline = Instant::now() + Duration::from_secs(120);

    // distinct limits so misses (and thus persists, under cache-io
    // faults) keep happening; repeats so hits happen too
    let mut lines = Vec::new();
    for i in 0..12 {
        let mem = 2.0 + 0.5 * (i % 4) as f64;
        lines.push(format!(
            "query setting={TINY} mem={mem} batch={} threads=1",
            1 + i % 2
        ));
    }
    // replans ride along so the replan latency lane is exercised (and
    // its lane-sum invariant checked) under the same fault plan
    lines.push(format!(
        "replan setting={TINY} mem=2 batch=1 devices=8 threads=1 \
         new-devices=4"
    ));

    let mut restarts = 0.0;
    for round in 0.. {
        for line in &lines {
            // individual requests may die to injected faults — that is
            // the point; the server as a whole must keep answering
            let _ = try_request(addr, line);
        }
        let stats = request(addr, "stats", deadline);
        assert_eq!(stats.get("kind").as_str(), Some("stats"));
        check_invariants(&stats);
        // the observability surface holds under the same chaos: the
        // Prometheus page parses and agrees with the stats verb (the
        // drive is sequential, so nothing moves between the two), and
        // every trace in the ring is a closed tree
        let metrics = request(addr, "metrics", deadline);
        assert_eq!(metrics.get("kind").as_str(), Some("metrics"));
        check_metrics_match_stats(
            &stats,
            metrics.get("text").as_str().expect("exposition text"),
        );
        check_traces_closed(&request(addr, "trace", deadline));
        restarts = stats
            .get("telemetry")
            .get("worker_restarts")
            .as_f64()
            .unwrap_or(0.0);
        if restarts > 0.0 && round >= 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "no worker restart observed before the deadline \
             (injected panics are not reaching the pool): {stats:?}"
        );
    }
    assert!(restarts > 0.0);

    // the disk cache never corrupts: whatever survived the injected
    // write failures parses, and no temp file shadows it
    let cache = dir.join("plan_cache.json");
    if cache.exists() {
        let text = std::fs::read_to_string(&cache).unwrap();
        Json::parse(&text).expect("cache file stays valid JSON");
    }

    // graceful shutdown despite resets: keep asking until the ack
    // lands or the listener disappears (a torn ack still flips the
    // shutdown flag server-side)
    loop {
        match try_request(addr, "shutdown") {
            Some(ack) => {
                assert_eq!(ack.get("kind").as_str(), Some("shutdown"));
                break;
            }
            None => {
                if TcpStream::connect(addr).is_err() {
                    break; // already draining
                }
                assert!(Instant::now() < deadline,
                        "shutdown never acknowledged");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
    let status = loop {
        match child.try_wait().expect("poll child") {
            Some(status) => break status,
            None => {
                assert!(Instant::now() < deadline,
                        "serve did not exit after shutdown");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    };
    assert!(status.success(),
            "chaos serve must exit cleanly, got {status:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bad_fault_specs_refuse_loudly() {
    // a typo in OSDP_FAULTS must abort startup with exit 2, not
    // silently run without faults
    let out = Command::new(env!("CARGO_BIN_EXE_osdp"))
        .args(["query", "--setting", TINY, "--batch", "1"])
        .env("OSDP_FAULTS", "seed:1,panik:5")
        .output()
        .expect("run osdp query");
    assert_eq!(out.status.code(), Some(2),
               "bad fault grammar must exit 2: {out:?}");
}
