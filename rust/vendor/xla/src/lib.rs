//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate links the XLA C library, which the offline build cannot
//! provide, so this stub is API-compatible with the subset
//! `osdp::runtime` uses and fails at *runtime* with a clear message the
//! moment a PJRT client is requested. Everything downstream of the
//! runtime (the trainer, `osdp train`, the e2e tests) already skips
//! politely when AOT artifacts are absent, so the rest of the system —
//! planner, cost model, fabric, simulator — builds and tests without XLA.
//! Point the root `Cargo.toml`'s `xla` entry at the real bindings to
//! enable execution.

/// Error type matching the call sites' `{e:?}` formatting.
#[derive(Debug)]
pub struct XlaError(pub String);

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable() -> XlaError {
    XlaError(
        "the `xla` crate in this build is an offline stub — PJRT \
         execution is unavailable (see rust/vendor/xla/src/lib.rs)"
            .to_string(),
    )
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T>(&self, _data: &[T], _dims: &[usize],
                                      _device: Option<usize>)
                                      -> Result<PjRtBuffer> {
        Err(unavailable())
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<B>(&self, _args: &[B])
                        -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct Literal;

impl Literal {
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_stub() {
        let err = PjRtClient::cpu().map(|_| ()).unwrap_err();
        assert!(format!("{err:?}").contains("offline stub"));
    }
}
