//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment vendors no registry crates, so this in-repo stub
//! provides exactly the subset `osdp` uses: [`Result`], [`Error`], the
//! [`anyhow!`] and [`ensure!`] macros, and the [`Context`] extension
//! trait. Errors are a single formatted message with `:`-joined context —
//! enough for the runtime/train error paths, which only ever display them.
//! To use the real crate, point the root `Cargo.toml`'s `anyhow` entry at
//! crates.io instead of this path.

use std::fmt;

/// String-backed error value (the real crate's dynamic error + backtrace
/// machinery is not needed for display-only consumers).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to any `Result` whose error is debuggable.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T>;
}

impl<T, E: fmt::Debug> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{c}: {e:?}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e:?}", f()) })
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(anyhow!("bad {}", 7))
    }

    fn guarded(x: u32) -> Result<u32> {
        ensure!(x > 1, "x too small: {x}");
        Ok(x)
    }

    #[test]
    fn macros_and_context_chain() {
        let e = fails().context("opening").unwrap_err();
        assert_eq!(format!("{e}"), "opening: bad 7");
        let e2: Error = "io".parse::<u32>()
            .with_context(|| format!("parsing {}", "io"))
            .unwrap_err();
        assert!(format!("{e2:?}").starts_with("parsing io: "));
        assert!(guarded(0).is_err());
        assert_eq!(guarded(2).unwrap(), 2);
    }
}
