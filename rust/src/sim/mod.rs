//! Discrete-event iteration simulator: turns an execution plan into a
//! per-operator timeline over two device resources (a compute stream and a
//! communication stream), reproducing Figure 1's gantt chart and modeling
//! the comm/compute overlap that hides the operator-splitting overhead
//! (§3.3).
//!
//! All data-parallel ranks are symmetric under DP/ZDP (bulk-synchronous,
//! same op sequence, same collective participation), so one device's
//! timeline is the iteration time. The *fabric* (real byte-moving
//! collectives with logical clocks) cross-validates this model in
//! `rust/tests/sim_vs_fabric.rs`.

pub mod gantt;

pub use gantt::render_gantt;

use crate::cost::{Decision, Scope};
use crate::cost::time::scope_ring;
use crate::config::Cluster;
use crate::model::{ModelDesc, Operator};

/// Which stream an event occupies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// ZDP parameter all-gather before forward compute (rides the
    /// decision's scope ring: the full cluster for global scope, the
    /// intra-node group for node scope).
    FwdGather,
    ForwardCompute,
    /// ZDP parameter re-gather before backward (and the extra
    /// checkpointing-recompute gather when enabled).
    BwdGather,
    BackwardCompute,
    /// Gradient synchronization on the scope ring (reduce-scatter /
    /// all-reduce).
    GradSync,
    /// Node-scoped decisions only: the hierarchical cross-node all-reduce
    /// of the gradient shard after the intra-node reduce-scatter.
    GradSyncInter,
}

impl Phase {
    pub fn label(&self) -> &'static str {
        match self {
            Phase::FwdGather => "fwd-gather",
            Phase::ForwardCompute => "fwd",
            Phase::BwdGather => "bwd-gather",
            Phase::BackwardCompute => "bwd",
            Phase::GradSync => "grad-sync",
            Phase::GradSyncInter => "grad-sync-x",
        }
    }

    pub fn is_comm(&self) -> bool {
        matches!(
            self,
            Phase::FwdGather
                | Phase::BwdGather
                | Phase::GradSync
                | Phase::GradSyncInter
        )
    }
}

/// One scheduled interval on a stream.
#[derive(Debug, Clone)]
pub struct Event {
    pub op: String,
    pub phase: Phase,
    pub start: f64,
    pub end: f64,
    /// Payload bytes for comm events (0 for compute).
    pub bytes: f64,
}

impl Event {
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }
}

/// Simulated iteration: events plus totals.
#[derive(Debug, Clone)]
pub struct Timeline {
    pub events: Vec<Event>,
    pub iter_time: f64,
    pub comm_busy: f64,
    pub compute_busy: f64,
}

impl Timeline {
    /// Fraction of the iteration the compute stream is busy.
    pub fn compute_utilization(&self) -> f64 {
        self.compute_busy / self.iter_time.max(1e-30)
    }
}

// The comm formulas below deliberately re-derive the (α,β) model instead
// of calling `cost::time`: the simulator is one of three *independent*
// implementations of the same physics (analytic model, discrete-event
// sim, byte-moving fabric) whose agreement is the cross-check —
// `sim_matches_cost_model_sum` in `rust/tests/sim_vs_fabric.rs` (and the
// unit tests here) hold them together to 1e-9 relative.

/// Per-op slice of the (α,β) comm formula on the flat N-device ring: one
/// collective of `rounds` rounds over `bytes/g` per slice, times `g`
/// slices. Used for the DP share of an op (nothing sharded, so its
/// gradient all-reduce is scope-independent).
fn flat_comm_seconds(op: &Operator, d: Decision, cluster: &Cluster,
                     rounds: f64) -> f64 {
    if !op.shardable() || cluster.n_devices == 1 {
        return 0.0;
    }
    let (alpha, beta) = cluster.ring_link();
    let n = cluster.n_devices as f64;
    let g = d.slices() as f64;
    let bytes = op.param_bytes();
    rounds * (n - 1.0) * (g * alpha + bytes * beta / n)
}

/// The same formula on the decision's *scope* ring — what the ZDP share's
/// gathers and reduce-scatter ride: identical to [`flat_comm_seconds`] for
/// global scope, the intra-node `(α, β, devices_per_node)` ring for node
/// scope.
fn scoped_comm_seconds(op: &Operator, d: Decision, cluster: &Cluster,
                       rounds: f64) -> f64 {
    if !op.shardable() || cluster.n_devices == 1 {
        return 0.0;
    }
    let (alpha, beta, ring) = scope_ring(cluster, d.scope);
    if ring <= 1 {
        return 0.0;
    }
    let rf = ring as f64;
    let g = d.slices() as f64;
    let bytes = op.param_bytes();
    rounds * (rf - 1.0) * (g * alpha + bytes * beta / rf)
}

/// Whole-op hierarchical cross-node gradient term (node scope only): each
/// slice's 1/`devices_per_node` shard is all-reduced across the node ring
/// after the intra-node reduce-scatter (2 rounds on the inter link).
fn inter_sync_seconds(op: &Operator, d: Decision, cluster: &Cluster) -> f64 {
    if d.scope != Scope::Node || !op.shardable() {
        return 0.0;
    }
    let nodes = cluster.n_nodes();
    if nodes <= 1 || cluster.n_devices == 1 {
        return 0.0;
    }
    let group = cluster.node_group_size() as f64;
    let g = d.slices() as f64;
    let shard_bytes = op.param_bytes() / group;
    2.0 * (nodes as f64 - 1.0)
        * (g * cluster.alpha_inter
            + shard_bytes * cluster.beta_inter / nodes as f64)
}

/// Simulate one training iteration of `model` under per-op `decisions` at
/// per-device batch `b`. `overlap` allows the comm stream to run ahead
/// (prefetching gathers) as real FSDP implementations do; without it, every
/// event serializes (the paper's additive cost model).
pub fn simulate(model: &ModelDesc, decisions: &[Decision], cluster: &Cluster,
                b: usize, checkpointing: bool, overlap: bool) -> Timeline {
    assert_eq!(model.ops.len(), decisions.len());
    let bf = b as f64;
    let eff = crate::cost::time::batch_efficiency(b);
    let mut events = Vec::new();
    let mut comm_free = 0.0f64; // comm stream frontier
    let mut comp_free = 0.0f64; // compute stream frontier

    // helper: schedule on a stream, honoring dependency time `ready`
    let mut schedule = |events: &mut Vec<Event>, comm: bool, ready: f64,
                        dur: f64, op: &str, phase: Phase, bytes: f64|
     -> f64 {
        let stream = if comm { &mut comm_free } else { &mut comp_free };
        let start = if overlap {
            stream.max(ready)
        } else {
            // serial mode: both streams are one resource
            let s = comm_free.max(comp_free).max(ready);
            comm_free = s;
            comp_free = s;
            s
        };
        let end = start + dur;
        if comm {
            comm_free = end;
            if !overlap {
                comp_free = end;
            }
        } else {
            comp_free = end;
            if !overlap {
                comm_free = end;
            }
        }
        if dur > 0.0 {
            events.push(Event {
                op: op.to_string(),
                phase,
                start,
                end,
                bytes,
            });
        }
        end
    };

    // ---------- forward ----------
    // dependency: op i's forward compute needs its gather done
    let mut fwd_done = vec![0.0f64; model.ops.len()];
    let mut prev_fwd = 0.0f64;
    for (i, (op, d)) in model.ops.iter().zip(decisions).enumerate() {
        let gather = if d.zdp_slices > 0 {
            // forward share of the gathers: one all-gather round on the
            // decision's scope ring
            scoped_comm_seconds(op, *d, cluster, 1.0) * d.zdp_fraction()
        } else {
            0.0
        };
        // gathers have no data dependency (shards are resident): the comm
        // stream prefetches ahead of compute, as real FSDP does
        let g_end = schedule(&mut events, true, 0.0, gather, &op.name,
                             Phase::FwdGather, op.param_bytes());
        // forward compute = 1/3 of fwd+bwd flops
        let fwd_t = bf * op.flops_per_sample / 3.0 / (cluster.flops * eff);
        let ready = g_end.max(prev_fwd);
        let f_end = schedule(&mut events, false, ready, fwd_t, &op.name,
                             Phase::ForwardCompute, 0.0);
        fwd_done[i] = f_end;
        prev_fwd = f_end;
    }

    // ---------- backward (reverse op order) ----------
    let mut prev_bwd = prev_fwd;
    for (op, d) in model.ops.iter().zip(decisions).rev() {
        let regather_rounds = if checkpointing { 2.0 } else { 1.0 };
        let gather = if d.zdp_slices > 0 {
            scoped_comm_seconds(op, *d, cluster, regather_rounds)
                * d.zdp_fraction()
        } else {
            0.0
        };
        let g_end = schedule(&mut events, true, 0.0, gather, &op.name,
                             Phase::BwdGather, op.param_bytes());
        let mut bwd_t =
            bf * op.flops_per_sample * 2.0 / 3.0 / (cluster.flops * eff);
        if checkpointing
            && op.ckpt_act_bytes_per_sample < op.act_bytes_per_sample
        {
            // recompute forward before backward
            bwd_t += bf * op.flops_per_sample / 3.0 / (cluster.flops * eff);
        }
        let ready = g_end.max(prev_bwd);
        let b_end = schedule(&mut events, false, ready, bwd_t, &op.name,
                             Phase::BackwardCompute, 0.0);
        // gradient sync: DP slices pay 2 flat-ring rounds (RS+AG); ZDP
        // slices pay 1 on their scope ring (RS only — the AG half was
        // charged as the gathers above)
        let sync = if op.shardable() {
            let dp_part = flat_comm_seconds(op, *d, cluster, 2.0)
                * (1.0 - d.zdp_fraction());
            let zdp_part =
                scoped_comm_seconds(op, *d, cluster, 1.0) * d.zdp_fraction();
            dp_part + zdp_part
        } else {
            0.0
        };
        let s_end = schedule(&mut events, true, b_end, sync, &op.name,
                             Phase::GradSync, op.param_bytes());
        // node scope: the intra-node reduce-scatter leaves per-node
        // partial shards; same-local peers all-reduce them across nodes
        let inter = inter_sync_seconds(op, *d, cluster) * d.zdp_fraction();
        if inter > 0.0 {
            let group = cluster.node_group_size() as f64;
            schedule(&mut events, true, s_end, inter, &op.name,
                     Phase::GradSyncInter, op.param_bytes() / group);
        }
        prev_bwd = b_end;
    }

    let iter_time = comm_free.max(comp_free);
    let comm_busy: f64 =
        events.iter().filter(|e| e.phase.is_comm()).map(Event::duration).sum();
    let compute_busy: f64 = events
        .iter()
        .filter(|e| !e.phase.is_comm())
        .map(Event::duration)
        .sum();
    Timeline { events, iter_time, comm_busy, compute_busy }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Cluster;
    use crate::cost::Decision;
    use crate::model::{GptDims, build_gpt};

    fn setup() -> (ModelDesc, Cluster) {
        let m = build_gpt(&GptDims::uniform("t", 1000, 64, 2, 128, 4));
        (m, Cluster::rtx_titan(8, 8.0))
    }

    fn all(m: &ModelDesc, d: Decision) -> Vec<Decision> {
        vec![d; m.ops.len()]
    }

    #[test]
    fn zdp_timeline_slower_than_dp() {
        let (m, c) = setup();
        let dp = simulate(&m, &all(&m, Decision::DP), &c, 2, false, false);
        let zdp = simulate(&m, &all(&m, Decision::ZDP), &c, 2, false, false);
        assert!(zdp.iter_time > dp.iter_time);
        // ZDP has gather events; DP has none
        assert!(zdp.events.iter().any(|e| e.phase == Phase::FwdGather));
        assert!(!dp.events.iter().any(|e| e.phase == Phase::FwdGather));
    }

    #[test]
    fn serial_time_matches_additive_cost_model() {
        // Without overlap, the timeline must equal Σ comm + Σ compute.
        let (m, c) = setup();
        let tl = simulate(&m, &all(&m, Decision::ZDP), &c, 2, false, false);
        let want = tl.comm_busy + tl.compute_busy;
        assert!((tl.iter_time - want).abs() / want < 1e-9);
    }

    #[test]
    fn overlap_shortens_iteration() {
        let (m, c) = setup();
        let serial =
            simulate(&m, &all(&m, Decision::ZDP), &c, 4, false, false);
        let over = simulate(&m, &all(&m, Decision::ZDP), &c, 4, false, true);
        assert!(over.iter_time < serial.iter_time);
        // but never below either stream's busy time
        assert!(over.iter_time >= over.comm_busy.max(over.compute_busy) - 1e-12);
    }

    #[test]
    fn events_never_overlap_within_a_stream() {
        let (m, c) = setup();
        let tl = simulate(&m, &all(&m, Decision::ZDP), &c, 2, false, true);
        let mut comm: Vec<&Event> =
            tl.events.iter().filter(|e| e.phase.is_comm()).collect();
        comm.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        for w in comm.windows(2) {
            assert!(w[1].start >= w[0].end - 1e-12, "comm stream overlap");
        }
        let mut comp: Vec<&Event> =
            tl.events.iter().filter(|e| !e.phase.is_comm()).collect();
        comp.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        for w in comp.windows(2) {
            assert!(w[1].start >= w[0].end - 1e-12, "compute stream overlap");
        }
    }

    #[test]
    fn checkpointing_adds_bwd_gather_and_recompute() {
        let (m, c) = setup();
        let plain = simulate(&m, &all(&m, Decision::ZDP), &c, 2, false, false);
        let ckpt = simulate(&m, &all(&m, Decision::ZDP), &c, 2, true, false);
        let plain_bg: f64 = plain.events.iter()
            .filter(|e| e.phase == Phase::BwdGather)
            .map(Event::duration).sum();
        let ckpt_bg: f64 = ckpt.events.iter()
            .filter(|e| e.phase == Phase::BwdGather)
            .map(Event::duration).sum();
        assert!((ckpt_bg / plain_bg - 2.0).abs() < 1e-9,
                "ckpt doubles the backward gather");
        assert!(ckpt.compute_busy > plain.compute_busy, "recompute");
    }

    #[test]
    fn node_scope_timeline_matches_analytic_sum_and_wins_across_nodes() {
        // On the two-server topology the node-scoped timeline must (a)
        // charge exactly the analytic scoped comm model in serial mode,
        // (b) carry the hierarchical cross-node sync as explicit events,
        // and (c) beat the global-scope timeline.
        let m = build_gpt(&GptDims::uniform("t", 2000, 128, 2, 256, 4));
        let c = Cluster::two_server_a100(16.0);
        let node =
            simulate(&m, &all(&m, Decision::ZDP_NODE), &c, 2, false, false);
        let global =
            simulate(&m, &all(&m, Decision::ZDP), &c, 2, false, false);
        let expected: f64 = m
            .ops
            .iter()
            .map(|op| {
                crate::cost::op_comm_time(op, Decision::ZDP_NODE, &c, false)
            })
            .sum();
        assert!((node.comm_busy - expected).abs() / expected < 1e-9,
                "sim {} vs model {}", node.comm_busy, expected);
        assert!(node.events.iter().any(|e| e.phase == Phase::GradSyncInter),
                "hierarchical reduce must appear on the timeline");
        assert!(!global.events.iter()
                    .any(|e| e.phase == Phase::GradSyncInter),
                "global scope has no cross-node shard reduce");
        assert!(node.iter_time < global.iter_time,
                "node {} vs global {}", node.iter_time, global.iter_time);
        // the inter event carries the 1/devices_per_node shard
        let inter = node.events.iter()
            .find(|e| e.phase == Phase::GradSyncInter).unwrap();
        let op = m.ops.iter().find(|o| o.name == inter.op).unwrap();
        assert_eq!(inter.bytes, op.param_bytes() / 8.0);
    }

    #[test]
    fn splitting_overhead_small_when_bandwidth_bound() {
        // §3.3: for large operators the per-slice latency term is dwarfed
        // by the bandwidth term, so splitting barely moves iteration time —
        // while Figure 7 shows (and `cost::time` models) a real slowdown
        // for small-hidden operators where α dominates.
        let m = build_gpt(&GptDims::uniform("big", 1000, 512, 2, 4096, 8));
        let c = Cluster::rtx_titan(8, 8.0);
        let g1 = simulate(&m, &all(&m, Decision::zdp_at(1)), &c, 1, false,
                          true);
        let g8 = simulate(&m, &all(&m, Decision::zdp_at(8)), &c, 1, false,
                          true);
        assert!(g1.comm_busy > g1.compute_busy, "setup should be comm-bound");
        let slowdown = g8.iter_time / g1.iter_time;
        assert!(slowdown < 1.10, "split overhead visible: {slowdown}");

        // and the contrast: a small-hidden model slows down markedly
        let (small, c2) = setup();
        let s1 = simulate(&small, &all(&small, Decision::zdp_at(1)), &c2, 1,
                          false, true);
        let s8 = simulate(&small, &all(&small, Decision::zdp_at(8)), &c2, 1,
                          false, true);
        assert!(s8.iter_time / s1.iter_time > 1.5,
                "small ops should feel the per-slice latency");
    }
}
