//! Text gantt-chart rendering of a simulated timeline (Figure 1).

use super::{Event, Phase, Timeline};

/// Render the timeline as an ASCII gantt chart, one row per (op, phase),
/// `width` columns of resolution. Ops are shown in first-event order.
pub fn render_gantt(tl: &Timeline, width: usize) -> String {
    if tl.events.is_empty() {
        return "(empty timeline)\n".into();
    }
    let total = tl.iter_time.max(1e-30);
    let scale = width as f64 / total;
    let mut rows: Vec<(String, &Event)> = tl
        .events
        .iter()
        .map(|e| (format!("{:<14} {:<10}", trunc(&e.op, 14), e.phase.label()),
                  e))
        .collect();
    rows.sort_by(|a, b| a.1.start.partial_cmp(&b.1.start).unwrap());

    let mut out = String::new();
    out.push_str(&format!(
        "iteration {:.3} ms | comm busy {:.3} ms | compute busy {:.3} ms\n",
        tl.iter_time * 1e3,
        tl.comm_busy * 1e3,
        tl.compute_busy * 1e3
    ));
    for (label, e) in rows {
        let s = (e.start * scale).round() as usize;
        let w = ((e.end - e.start) * scale).round().max(1.0) as usize;
        let ch = match e.phase {
            Phase::FwdGather | Phase::BwdGather => '▒',
            Phase::GradSync | Phase::GradSyncInter => '█',
            _ => '■',
        };
        let mut bar = String::new();
        bar.push_str(&" ".repeat(s.min(width)));
        bar.push_str(&ch.to_string().repeat(w.min(width.saturating_sub(s))));
        out.push_str(&format!("{label} |{bar}\n"));
    }
    out
}

fn trunc(s: &str, n: usize) -> String {
    if s.len() <= n { s.to_string() } else { format!("{}…", &s[..n - 1]) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Cluster;
    use crate::cost::Decision;
    use crate::model::{GptDims, build_gpt};
    use crate::sim::simulate;

    #[test]
    fn renders_rows_for_each_event() {
        let m = build_gpt(&GptDims::uniform("t", 500, 32, 1, 64, 2));
        let c = Cluster::rtx_titan(4, 8.0);
        let decisions = vec![Decision::ZDP; m.ops.len()];
        let tl = simulate(&m, &decisions, &c, 1, false, false);
        let g = render_gantt(&tl, 60);
        assert_eq!(g.lines().count(), tl.events.len() + 1);
        assert!(g.contains("fwd-gather"));
        assert!(g.contains("grad-sync"));
    }

    #[test]
    fn empty_timeline_safe() {
        let tl = Timeline {
            events: vec![],
            iter_time: 0.0,
            comm_busy: 0.0,
            compute_busy: 0.0,
        };
        assert!(render_gantt(&tl, 40).contains("empty"));
    }
}
