//! Deterministic, seed-driven fault injection for the serve path.
//!
//! A fault plan is parsed once from the `OSDP_FAULTS` environment
//! variable and consulted at four hardened boundaries:
//!
//! - `panic` — the query dispatch panics before any accounting runs
//!   (models a worker crashing mid-search; the front-end pool must
//!   resurrect the thread),
//! - `slow`  — the dispatch sleeps `slow-ms` milliseconds first
//!   (models a pathological search hogging a worker),
//! - `cache-io` — `write_cache_file` fails with an I/O error
//!   (models a full or flaky disk; persistence must retry),
//! - `sock-reset` — the front-end writes a torn prefix of a response
//!   and slams the connection (models a mid-line TCP reset),
//! - `remote-slow` — a remote-tier operation stalls until its deadline
//!   budget is exhausted, then times out (models a slow or partitioned
//!   cache server; the client must never wait past its budget),
//! - `remote-io` — a remote-tier operation fails with an I/O error
//!   (models a dead or resetting cache server),
//! - `remote-garbage` — the payload fetched from the remote tier is
//!   replaced with garbage bytes (models a lying or corrupted cache
//!   server; the entry must quarantine, never change a plan).
//!
//! Grammar (comma-separated `key:value`, all values unsigned ints):
//!
//! ```text
//! OSDP_FAULTS=seed:7,panic:20000,slow:50000,slow-ms:40,cache-io:100000,sock-reset:30000,\
//!             remote-slow:50000,remote-io:100000,remote-garbage:30000
//! ```
//!
//! Rates are **parts per million** per call site invocation. Whether
//! invocation `n` of a site fires is a pure function of
//! `(seed, site, n)` — a splitmix64-style mix compared against the
//! rate — so the *number* of faults over N calls is reproducible for
//! a given seed regardless of thread interleaving. With `OSDP_FAULTS`
//! unset (or all rates zero) every hook is a branch-on-zero no-op.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// The hardened boundaries a fault plan can target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Site {
    /// Panic at query dispatch, before any telemetry accounting.
    SearchPanic,
    /// Sleep at query dispatch.
    SearchSlow,
    /// Fail a cache-file write with an I/O error.
    CacheIo,
    /// Tear a front-end response mid-line and drop the connection.
    SockReset,
    /// Stall a remote-tier operation past its deadline budget.
    RemoteSlow,
    /// Fail a remote-tier operation with an I/O error.
    RemoteIo,
    /// Corrupt the payload fetched from the remote tier.
    RemoteGarbage,
}

/// Number of distinct fault sites (per-site call counters).
pub const N_SITES: usize = 7;

/// A parsed `OSDP_FAULTS` specification. All rates in parts per
/// million per call; the default plan injects nothing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultPlan {
    pub seed: u64,
    pub panic_ppm: u64,
    pub slow_ppm: u64,
    pub slow_ms: u64,
    pub cache_io_ppm: u64,
    pub sock_reset_ppm: u64,
    pub remote_slow_ppm: u64,
    pub remote_io_ppm: u64,
    pub remote_garbage_ppm: u64,
}

impl FaultPlan {
    /// Parse the `OSDP_FAULTS` grammar. Unknown keys and malformed
    /// tokens are errors so a typo'd chaos run fails loudly instead
    /// of silently testing nothing.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for tok in spec.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let (key, value) = tok
                .split_once(':')
                .ok_or_else(|| format!("fault token `{tok}` is not key:value"))?;
            let n: u64 = value
                .trim()
                .parse()
                .map_err(|_| format!("fault value `{value}` is not an unsigned integer"))?;
            match key.trim() {
                "seed" => plan.seed = n,
                "panic" => plan.panic_ppm = n,
                "slow" => plan.slow_ppm = n,
                "slow-ms" => plan.slow_ms = n,
                "cache-io" => plan.cache_io_ppm = n,
                "sock-reset" => plan.sock_reset_ppm = n,
                "remote-slow" => plan.remote_slow_ppm = n,
                "remote-io" => plan.remote_io_ppm = n,
                "remote-garbage" => plan.remote_garbage_ppm = n,
                other => return Err(format!("unknown fault key `{other}`")),
            }
        }
        for rate in [
            plan.panic_ppm,
            plan.slow_ppm,
            plan.cache_io_ppm,
            plan.sock_reset_ppm,
            plan.remote_slow_ppm,
            plan.remote_io_ppm,
            plan.remote_garbage_ppm,
        ] {
            if rate > 1_000_000 {
                return Err(format!("fault rate {rate} exceeds 1000000 ppm"));
            }
        }
        Ok(plan)
    }

    /// True when any site can ever fire.
    pub fn enabled(&self) -> bool {
        self.panic_ppm
            + self.slow_ppm
            + self.cache_io_ppm
            + self.sock_reset_ppm
            + self.remote_slow_ppm
            + self.remote_io_ppm
            + self.remote_garbage_ppm
            > 0
    }

    fn rate_ppm(&self, site: Site) -> u64 {
        match site {
            Site::SearchPanic => self.panic_ppm,
            Site::SearchSlow => self.slow_ppm,
            Site::CacheIo => self.cache_io_ppm,
            Site::SockReset => self.sock_reset_ppm,
            Site::RemoteSlow => self.remote_slow_ppm,
            Site::RemoteIo => self.remote_io_ppm,
            Site::RemoteGarbage => self.remote_garbage_ppm,
        }
    }
}

/// A fault plan plus per-site call counters. The decision for call
/// `n` of a site depends only on `(seed, site, n)`, never on timing.
pub struct FaultState {
    plan: FaultPlan,
    calls: [AtomicU64; N_SITES],
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> FaultState {
        FaultState {
            plan,
            calls: [const { AtomicU64::new(0) }; N_SITES],
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Record one invocation of `site` and decide whether it faults.
    pub fn fires(&self, site: Site) -> bool {
        let rate = self.plan.rate_ppm(site);
        if rate == 0 {
            return false;
        }
        let n = self.calls[site as usize].fetch_add(1, Ordering::Relaxed);
        mix(self.plan.seed, site as u64, n) % 1_000_000 < rate
    }
}

/// splitmix64 finalizer over a combined (seed, site, call) word:
/// cheap, stateless, and well-distributed in the low bits.
fn mix(seed: u64, site: u64, n: u64) -> u64 {
    let mut z = seed
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(site.wrapping_mul(0xbf58_476d_1ce4_e5b9))
        .wrapping_add(n.wrapping_add(0x94d0_49bb_1331_11eb));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

static GLOBAL: OnceLock<FaultState> = OnceLock::new();

/// The process-wide fault state, parsed from `OSDP_FAULTS` on first
/// use. A malformed spec aborts: a chaos run that silently injects
/// nothing would pass CI while proving nothing.
pub fn global() -> &'static FaultState {
    GLOBAL.get_or_init(|| {
        let plan = match std::env::var("OSDP_FAULTS") {
            Ok(spec) => match FaultPlan::parse(&spec) {
                Ok(plan) => plan,
                Err(e) => {
                    eprintln!("osdp: bad OSDP_FAULTS spec: {e}");
                    std::process::exit(2);
                }
            },
            Err(_) => FaultPlan::default(),
        };
        FaultState::new(plan)
    })
}

/// Dispatch-boundary hook: maybe sleep, maybe panic. Called before
/// any telemetry or cache accounting so an injected crash leaves the
/// counters exactly as if the query had never arrived.
pub fn on_query_dispatch() {
    let state = global();
    if state.fires(Site::SearchSlow) {
        std::thread::sleep(std::time::Duration::from_millis(state.plan.slow_ms.max(1)));
    }
    if state.fires(Site::SearchPanic) {
        panic!("injected fault: search panicked");
    }
}

/// Cache-write hook: true when this write should fail.
pub fn cache_write_fails() -> bool {
    global().fires(Site::CacheIo)
}

/// Front-end response hook: true when this response should be torn
/// mid-line and the connection dropped.
pub fn sock_reset_fires() -> bool {
    global().fires(Site::SockReset)
}

/// Remote-tier hook: true when this remote operation should stall
/// past its deadline budget (the client sleeps at most its remaining
/// budget, then reports a timeout — exactly what a slow server costs).
pub fn remote_slow_fires() -> bool {
    global().fires(Site::RemoteSlow)
}

/// Remote-tier hook: true when this remote operation should fail with
/// an I/O error.
pub fn remote_io_fails() -> bool {
    global().fires(Site::RemoteIo)
}

/// Remote-tier hook: true when the payload fetched from the remote
/// tier should be replaced with garbage bytes.
pub fn remote_garbage_fires() -> bool {
    global().fires(Site::RemoteGarbage)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let plan = FaultPlan::parse(
            "seed:7,panic:20000,slow:50000,slow-ms:40,cache-io:100000,sock-reset:30000,\
             remote-slow:60000,remote-io:70000,remote-garbage:80000",
        )
        .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.panic_ppm, 20_000);
        assert_eq!(plan.slow_ppm, 50_000);
        assert_eq!(plan.slow_ms, 40);
        assert_eq!(plan.cache_io_ppm, 100_000);
        assert_eq!(plan.sock_reset_ppm, 30_000);
        assert_eq!(plan.remote_slow_ppm, 60_000);
        assert_eq!(plan.remote_io_ppm, 70_000);
        assert_eq!(plan.remote_garbage_ppm, 80_000);
        assert!(plan.enabled());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("seed").is_err());
        assert!(FaultPlan::parse("seed:x").is_err());
        assert!(FaultPlan::parse("warp:9").is_err());
        assert!(FaultPlan::parse("panic:2000000").is_err());
        assert!(FaultPlan::parse("remote-io:2000000").is_err());
    }

    #[test]
    fn empty_spec_is_disabled() {
        let plan = FaultPlan::parse("").unwrap();
        assert_eq!(plan, FaultPlan::default());
        assert!(!plan.enabled());
        assert!(!FaultState::new(plan).fires(Site::SearchPanic));
    }

    #[test]
    fn fire_count_is_a_function_of_seed_only() {
        let plan = FaultPlan::parse("seed:11,panic:100000").unwrap();
        let count = |state: &FaultState| {
            (0..10_000)
                .filter(|_| state.fires(Site::SearchPanic))
                .count()
        };
        let a = count(&FaultState::new(plan));
        let b = count(&FaultState::new(plan));
        assert_eq!(a, b, "same seed, same fault schedule");
        // ~10% rate over 10k draws: comfortably inside [500, 2000].
        assert!((500..2000).contains(&a), "rate wildly off: {a}");

        let other = FaultPlan::parse("seed:12,panic:100000").unwrap();
        let c = count(&FaultState::new(other));
        assert!(a != c || {
            // Equal counts are possible across seeds; the schedules
            // themselves must still differ somewhere.
            let s1 = FaultState::new(plan);
            let s2 = FaultState::new(other);
            (0..10_000).any(|_| s1.fires(Site::SearchPanic) != s2.fires(Site::SearchPanic))
        });
    }

    #[test]
    fn sites_draw_independent_schedules() {
        let plan = FaultPlan::parse("seed:3,panic:500000,sock-reset:500000").unwrap();
        let state = FaultState::new(plan);
        let panics: Vec<bool> = (0..256).map(|_| state.fires(Site::SearchPanic)).collect();
        let resets: Vec<bool> = (0..256).map(|_| state.fires(Site::SockReset)).collect();
        assert_ne!(panics, resets, "sites must not share one schedule");
    }

    #[test]
    fn zero_rate_site_never_counts_or_fires() {
        let plan = FaultPlan::parse("seed:5,panic:1000000").unwrap();
        let state = FaultState::new(plan);
        for _ in 0..100 {
            assert!(state.fires(Site::SearchPanic), "ppm=1000000 always fires");
            assert!(!state.fires(Site::CacheIo));
        }
    }
}
