//! Minimal JSON parser + writer (reads `artifacts/manifest.json`).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs are passed
//! through unvalidated. No external dependencies by necessity (offline
//! build, see util/mod.rs).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- typed accessors (None on type mismatch) -----

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 { Some(n as usize) } else { None }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access; `Json::Null` when missing or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// `arr[i]` access; `Json::Null` when out of range or not an array.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }
}

/// Parse failure with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos..self.pos + 4],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 codepoint
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.b.len()
                        && (self.b[self.pos] & 0xc0) == 0x80
                    {
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }
}

/// Serialize a `Json` value compactly (used for reports / gantt exports).
pub fn to_string(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(e, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, e)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_value(e, out);
            }
            out.push('}');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":{}}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("x"));
        assert_eq!(v.get("a").idx(0).as_usize(), Some(1));
        assert!(v.get("c").as_obj().unwrap().is_empty());
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null,true],"s":"he\"llo\n","n":-3}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&to_string(&v)).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = Json::parse(r#""héllo – ok""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo – ok"));
    }
}
