//! ASCII table rendering for paper-style result rows (Figures 5–9 output).

/// Column-aligned text table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str(c);
                if i + 1 < ncol {
                    for _ in 0..widths[i] - c.chars().count() + 2 {
                        out.push(' ');
                    }
                }
            }
            out.push('\n');
        };
        fmt_row(&self.header, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }

    /// CSV form (for plotting / EXPERIMENTS.md extraction).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.header.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["strategy", "throughput"]);
        t.row(vec!["DP", "123.4"]);
        t.row(vec!["OSDP", "201.9"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("strategy"));
        assert!(lines[2].starts_with("DP"));
        // all data rows align the second column
        let col = lines[0].find("throughput").unwrap();
        assert_eq!(lines[2].find("123.4").unwrap(), col);
        assert_eq!(lines[3].find("201.9").unwrap(), col);
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(vec!["k", "v"]);
        t.row(vec!["a,b", "c\"d"]);
        assert_eq!(t.to_csv(), "k,v\n\"a,b\",\"c\"\"d\"\n");
    }
}
