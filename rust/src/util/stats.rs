//! Summary statistics over measurement samples (criterion substitute core).

/// Mean / stddev / min / max / percentiles of a sample set.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample set");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
                / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
        }
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn rsd(&self) -> f64 {
        if self.mean == 0.0 { 0.0 } else { self.std / self.mean }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, q in [0,1].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (pos - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Exponential moving average (loss-curve smoothing in the trainer).
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0);
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile(&sorted, 0.5), 5.0);
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 1.0), 10.0);
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..32 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }
}
