//! Deterministic PRNG (splitmix64 core + xoshiro256** stream).
//!
//! Used by the synthetic-corpus generator, the property-test harness, and
//! the plan-space samplers. Seeded explicitly everywhere — reproducibility
//! is a requirement for EXPERIMENTS.md.

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm),
                  splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // rejection sampling to avoid modulo bias
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..8).map({
            let mut r = Rng::new(42);
            move |_| r.next_u64()
        }).collect();
        let b: Vec<u64> = (0..8).map({
            let mut r = Rng::new(42);
            move |_| r.next_u64()
        }).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map({
            let mut r = Rng::new(43);
            move |_| r.next_u64()
        }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| r.f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
