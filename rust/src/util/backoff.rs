//! Bounded, jittered exponential backoff — deterministic under a seed.
//!
//! Both retry loops in the tree (crash-safe cache persistence and the
//! remote-tier write path) need the same shape: a handful of attempts,
//! exponentially growing pauses, a hard cap on any single pause, and
//! jitter so a fleet of instances retrying the same dead dependency
//! does not synchronize into a thundering herd. The jitter stream is
//! drawn from the deterministic [`crate::util::rng::Rng`], seeded
//! explicitly, so fault-injection tests replay bit-identical retry
//! schedules: the *n*-th delay for a given `(seed, base, cap)` is a
//! pure function of those inputs and nothing else.
//!
//! The policy uses "equal jitter": the *k*-th delay is
//! `exp/2 + uniform[0, exp/2)` where `exp = min(base << k, cap)`.
//! Every delay therefore lands in `[exp/2, exp)` — bounded below (the
//! pause is never degenerate) and bounded above (never exceeds the
//! cap), while still decorrelating independent retriers.

use crate::util::rng::Rng;
use std::time::Duration;

/// A bounded retry schedule. `attempts` counts *total* tries, so
/// `attempts = 3` means one initial try plus up to two retries with
/// two pauses between them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BackoffPolicy {
    pub attempts: u32,
    pub base_ms: u64,
    pub cap_ms: u64,
    pub seed: u64,
}

impl BackoffPolicy {
    pub fn new(attempts: u32, base_ms: u64, cap_ms: u64, seed: u64) -> BackoffPolicy {
        BackoffPolicy {
            attempts: attempts.max(1),
            base_ms: base_ms.max(1),
            cap_ms: cap_ms.max(base_ms.max(1)),
            seed,
        }
    }

    /// The deterministic pause schedule: exactly `attempts - 1`
    /// durations, the pause taken after each failed non-final try.
    pub fn delays(&self) -> Vec<Duration> {
        let mut rng = Rng::new(self.seed);
        (0..self.attempts.saturating_sub(1))
            .map(|k| {
                let exp = self
                    .base_ms
                    .checked_shl(k)
                    .unwrap_or(self.cap_ms)
                    .min(self.cap_ms)
                    .max(1);
                let half = (exp / 2).max(1);
                Duration::from_millis(half + rng.below(half.max(1)))
            })
            .collect()
    }

    /// Run `op` up to `attempts` times. After each failed non-final
    /// try, `on_retry` observes the 0-based attempt index (so callers
    /// can count retries in their own telemetry) and the loop sleeps
    /// the corresponding jittered delay. The final error is returned
    /// unchanged; intermediate errors are discarded.
    pub fn retry<T, E>(
        &self,
        mut op: impl FnMut(u32) -> Result<T, E>,
        mut on_retry: impl FnMut(u32),
    ) -> Result<T, E> {
        let delays = self.delays();
        let mut attempt = 0;
        loop {
            match op(attempt) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    if attempt + 1 >= self.attempts {
                        return Err(e);
                    }
                    on_retry(attempt);
                    std::thread::sleep(delays[attempt as usize]);
                    attempt += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let p = BackoffPolicy::new(6, 1, 16, 42);
        assert_eq!(p.delays(), p.delays());
        let q = BackoffPolicy::new(6, 1, 16, 43);
        assert_ne!(p.delays(), q.delays(), "different seeds must decorrelate");
    }

    #[test]
    fn delays_are_bounded_by_cap_and_grow_from_base() {
        let p = BackoffPolicy::new(10, 2, 20, 7);
        let ds = p.delays();
        assert_eq!(ds.len(), 9);
        for (k, d) in ds.iter().enumerate() {
            let exp = (2u64 << k).min(20);
            let ms = d.as_millis() as u64;
            assert!(ms >= (exp / 2).max(1), "delay {k} below half-exp: {ms}");
            assert!(ms < exp.max(2), "delay {k} above exp: {ms}");
            assert!(ms <= 20, "delay {k} exceeds cap: {ms}");
        }
    }

    #[test]
    fn retry_stops_on_first_success() {
        let p = BackoffPolicy::new(5, 1, 4, 0);
        let mut calls = 0;
        let out: Result<u32, ()> = p.retry(
            |attempt| {
                calls += 1;
                if attempt >= 2 { Ok(attempt) } else { Err(()) }
            },
            |_| {},
        );
        assert_eq!(out, Ok(2));
        assert_eq!(calls, 3);
    }

    #[test]
    fn retry_exhausts_and_counts_retries() {
        let p = BackoffPolicy::new(3, 1, 2, 0);
        let mut retries = Vec::new();
        let out: Result<(), u32> = p.retry(|attempt| Err(attempt), |k| retries.push(k));
        assert_eq!(out, Err(2), "final attempt's error is returned");
        assert_eq!(retries, vec![0, 1], "one on_retry per non-final failure");
    }

    #[test]
    fn single_attempt_never_sleeps_or_retries() {
        let p = BackoffPolicy::new(1, 1, 1, 0);
        assert!(p.delays().is_empty());
        let mut retried = false;
        let out: Result<(), ()> = p.retry(|_| Err(()), |_| retried = true);
        assert_eq!(out, Err(()));
        assert!(!retried);
    }

    #[test]
    fn zero_attempts_clamps_to_one() {
        let p = BackoffPolicy::new(0, 0, 0, 0);
        assert_eq!(p.attempts, 1);
        let mut calls = 0;
        let _: Result<(), ()> = p.retry(
            |_| {
                calls += 1;
                Err(())
            },
            |_| {},
        );
        assert_eq!(calls, 1);
    }
}
