//! Small self-contained utilities.
//!
//! The build environment is offline with only the `xla` crate's vendored
//! dependency closure available, so the JSON reader, RNG, stats, table
//! printer, and property-testing helpers live here instead of coming from
//! serde / rand / criterion / proptest.

pub mod backoff;
pub mod faults;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod table;

/// Format a byte count human-readably (GiB/MiB/KiB).
pub fn fmt_bytes(b: f64) -> String {
    const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
    const MIB: f64 = 1024.0 * 1024.0;
    const KIB: f64 = 1024.0;
    if b >= GIB {
        format!("{:.2} GiB", b / GIB)
    } else if b >= MIB {
        format!("{:.2} MiB", b / MIB)
    } else if b >= KIB {
        format!("{:.2} KiB", b / KIB)
    } else {
        format!("{:.0} B", b)
    }
}

/// Format seconds with an adaptive unit (s/ms/µs).
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
        assert_eq!(fmt_bytes(3.5 * 1024.0 * 1024.0), "3.50 MiB");
        assert_eq!(fmt_bytes(8.0 * 1024.0 * 1024.0 * 1024.0), "8.00 GiB");
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.5), "2.500 s");
        assert_eq!(fmt_time(0.0125), "12.500 ms");
        assert_eq!(fmt_time(3e-6), "3.0 µs");
    }
}
