//! Tiny property-testing harness (proptest substitute).
//!
//! `check(seed, cases, gen, prop)` runs `prop` on `cases` generated inputs;
//! on failure it retries with a simple halving shrinker over the generator's
//! size budget and panics with the seed + the smallest failing case found,
//! so failures are reproducible (`Rng::new(seed)`).

use super::rng::Rng;
use std::fmt::Debug;

/// Run a property over `cases` random inputs.
///
/// `gen(rng, size)` draws a case at complexity `size` in `[1, 100]`;
/// `prop(case)` returns `Err(reason)` on violation.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Debug + Clone,
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case_idx in 0..cases {
        // ramp complexity up over the run, like proptest's sizing
        let size = 1 + (case_idx * 100) / cases.max(1);
        let case = gen(&mut rng, size);
        if let Err(reason) = prop(&case) {
            // shrink: re-generate at smaller sizes from a derived seed and
            // keep the smallest failure
            let mut smallest = (case.clone(), reason.clone(), size);
            let mut srng = Rng::new(seed ^ 0xdead_beef);
            let mut s = size;
            while s > 1 {
                s /= 2;
                for _ in 0..16 {
                    let c = gen(&mut srng, s);
                    if let Err(r) = prop(&c) {
                        smallest = (c, r, s);
                        break;
                    }
                }
            }
            panic!(
                "property failed (seed={seed}, case #{case_idx}, size={}):\n  \
                 case: {:?}\n  reason: {}",
                smallest.2, smallest.0, smallest.1
            );
        }
    }
}

/// Assert two f64 values are close (absolute + relative tolerance).
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = a.abs().max(b.abs()).max(1.0);
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol}, diff {})", (a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            1,
            50,
            |rng, size| rng.range(0, size),
            |&x| {
                count += 1;
                if x <= 100 { Ok(()) } else { Err("impossible".into()) }
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            2,
            50,
            |rng, _| rng.range(0, 1000),
            |&x| if x < 990 { Ok(()) } else { Err(format!("{x} too big")) },
        );
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6).is_ok());
        assert!(close(1e9, 1e9 * (1.0 + 1e-9), 1e-6).is_ok());
        assert!(close(1.0, 1.1, 1e-6).is_err());
    }
}
