//! Poison-recovering synchronization helpers.
//!
//! The serve path deliberately lets injected panics unwind worker
//! threads (the pool resurrects them), which means any mutex such a
//! thread held at the moment of the panic is poisoned. For the plain
//! data these locks guard — queue state, counters, cache maps — the
//! data is still structurally valid: every critical section either
//! completes its writes or panics before touching the guarded value.
//! Recovering the guard is therefore safe, and strictly better than
//! letting one dead thread wedge every subsequent `lock()` forever.

use std::sync::{Condvar, Mutex, MutexGuard};

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Block on `cv`, recovering the reacquired guard from poison.
pub fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recover_survives_a_panicked_holder() {
        let m = Arc::new(Mutex::new(41));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "the mutex really is poisoned");
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 42);
    }
}
