//! The Search Engine + Scheduler (paper §3.2, Algorithm 1).
//!
//! Given the Profiler's per-operator cost tables and the device memory
//! limit, the search engine picks a decision per operator minimizing the
//! iteration time `Σ T_i` subject to `peak_mem ≤ M_limit`; the Scheduler
//! sweeps batch sizes and keeps the candidate with the best throughput.
//!
//! Five planners share the problem definition (and, for the exact
//! searches, the bound machinery in the crate-private `bound` module):
//! * [`dfs`] — the paper's depth-first search with its two prunings
//!   (memory exceeded / incumbent time exceeded), strengthened with
//!   admissible suffix bounds and fast-completion (branch-and-bound).
//!   Exact.
//! * [`frontier`] — the sweep-optimized engine ([`Engine::Frontier`],
//!   the default): each class's count compositions are enumerated once
//!   per sweep into a batch-invariant dominance-pruned frontier, and
//!   every per-batch search merges those small Pareto sets under the
//!   same bounds. Bit-identical to [`dfs`].
//! * [`parallel`] — the same searches split at a configurable depth into
//!   subtree tasks over a `std::thread` worker pool, pruning against a
//!   shared atomic incumbent. Bit-identical to [`dfs`] for any thread
//!   count; ≥2x faster on paper-scale menus at 8 threads.
//! * [`exhaustive`] — brute-force enumeration (folded over monotone
//!   blocks, with a raw product-space variant); ground truth for tests.
//! * [`greedy`] — flip-the-best-ratio heuristic; ablation baseline, and
//!   the incumbent seed for the exact searches.
//!
//! Both exact engines plan over the **symmetry-folded** space by default:
//! operators whose pruned cost tables are byte-identical (runs of equal
//! transformer layers) collapse into `(class, multiplicity)` positions
//! whose branches assign counts per option, shrinking `Π |menu|^L` trees
//! to polynomial count-composition spaces with provably bit-identical
//! results (see `bound` for the argument, [`fold_report`] for the
//! numbers, and `--no-fold` / [`dfs::search_unfolded`] for the escape
//! hatch).
//!
//! The [`scheduler`]'s batch-size sweep runs on the same worker-pool
//! pattern, claiming batch sizes off an atomic counter until the memory
//! wall, and merges per-candidate [`DfsStats`] into a [`SweepStats`]
//! aggregate. The fold and every batch-independent suffix bound are built
//! once per sweep and shared across batch sizes.

mod bound;
pub mod dfs;
pub mod exhaustive;
pub mod frontier;
pub mod greedy;
pub mod parallel;
pub mod progress;
pub mod scheduler;

pub use dfs::{DfsStats, search as dfs_search,
              search_unfolded as dfs_search_unfolded,
              search_warm as dfs_search_warm};
pub use exhaustive::search as exhaustive_search;
pub use frontier::{FrontierStats, report as frontier_report,
                   search as frontier_search};
pub use greedy::{search as greedy_search,
                 search_from as greedy_search_from};
pub use parallel::{ParallelConfig, search as parallel_search,
                   search_seeded as parallel_search_seeded,
                   search_with_stats as parallel_search_with_stats,
                   search_traced as parallel_search_traced};
pub use progress::{Improvement, ImprovementSource, Recorder, SearchTrace};
pub use scheduler::{Candidate, Scheduler, SchedulerResult, SweepInfeasible,
                    SweepStats};

use crate::cost::{Decision, PlanCost, Profiler};

/// Which exact search engine to run. All three return the bit-identical
/// `(time, lex)` optimum (property-tested in `rust/tests/`); they differ
/// only in how much of the tree they must materialize, so the choice is a
/// pure performance knob with [`Engine::Frontier`] the default and the
/// branch-and-bound engines kept as ground truth (the CLI's
/// `--engine bb` / `--no-fold`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Engine {
    /// Per-class composition frontiers, built once per sweep and merged
    /// under the B&B bounds (see [`frontier`]).
    #[default]
    Frontier,
    /// Symmetry-folded branch-and-bound over count compositions
    /// (ground truth for the frontier engine).
    FoldedBb,
    /// Per-operator branch-and-bound over the raw product space
    /// (ground truth for the fold).
    UnfoldedBb,
}

impl Engine {
    /// Parse a CLI spelling (`--engine frontier|bb`).
    pub fn parse(s: &str) -> Option<Engine> {
        match s {
            "frontier" => Some(Engine::Frontier),
            "bb" => Some(Engine::FoldedBb),
            _ => None,
        }
    }

    /// Human label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            Engine::Frontier => "frontier",
            Engine::FoldedBb => "folded B&B",
            Engine::UnfoldedBb => "per-op B&B",
        }
    }
}

/// What the symmetry fold buys on a given profiler: how many operators
/// collapse into how many equivalence classes, and the search-space sizes
/// (as log10) with and without the fold. Reported by `osdp plan` and the
/// search benchmarks.
#[derive(Debug, Clone, PartialEq)]
pub struct FoldReport {
    /// Operators in the profiler.
    pub ops: usize,
    /// Interchangeability classes (equal pruned cost tables).
    pub classes: usize,
    /// Largest class multiplicity.
    pub max_multiplicity: usize,
    /// log10 of the per-operator plan space `Π |menu_i|`.
    pub log10_unfolded: f64,
    /// log10 of the folded space `Π C(m_k + o_k - 1, o_k - 1)` (count
    /// compositions per class).
    pub log10_folded: f64,
}

impl FoldReport {
    /// One-line human summary for CLI/bench reports.
    pub fn describe(&self) -> String {
        format!(
            "{} ops -> {} classes (max multiplicity {}); plan space \
             10^{:.1} -> 10^{:.1} folded",
            self.ops,
            self.classes,
            self.max_multiplicity,
            self.log10_unfolded,
            self.log10_folded,
        )
    }
}

/// Compute the [`FoldReport`] for a profiler.
pub fn fold_report(profiler: &Profiler) -> FoldReport {
    let classes = profiler.op_classes();
    let mut log10_folded = 0.0;
    let mut max_multiplicity = 0;
    for members in &classes {
        let m = members.len();
        let o = profiler.tables[members[0]].options.len();
        max_multiplicity = max_multiplicity.max(m);
        log10_folded += log10_binomial(m + o - 1, o - 1);
    }
    FoldReport {
        ops: profiler.n_ops(),
        classes: classes.len(),
        max_multiplicity,
        log10_unfolded: profiler.log10_plan_space(),
        log10_folded,
    }
}

/// `log10(C(n, k))` without overflow.
fn log10_binomial(n: usize, k: usize) -> f64 {
    let k = k.min(n - k);
    (1..=k)
        .map(|j| (((n - k + j) as f64) / j as f64).log10())
        .sum()
}

/// A fully-resolved execution plan: one decision per operator plus the
/// batch size it was evaluated at.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    /// Per-op index into the Profiler's Pareto menu.
    pub choice: Vec<usize>,
    /// Resolved decisions (same order as the profiler's tables).
    pub decisions: Vec<Decision>,
    /// Per-device batch size.
    pub batch: usize,
    pub cost: PlanCost,
}

impl ExecutionPlan {
    pub fn from_choice(profiler: &Profiler, choice: Vec<usize>, batch: usize)
                       -> ExecutionPlan {
        let cost = profiler.evaluate(&choice, batch);
        let decisions = profiler
            .tables
            .iter()
            .zip(&choice)
            .map(|(t, &c)| t.options[c].decision)
            .collect();
        ExecutionPlan { choice, decisions, batch, cost }
    }

    /// Cluster-wide samples/second.
    pub fn throughput(&self, n_devices: usize) -> f64 {
        self.cost.throughput(self.batch, n_devices)
    }

    /// Counts of (pure-DP, pure-ZDP, mixed) operators.
    pub fn mode_counts(&self) -> (usize, usize, usize) {
        let mut dp = 0;
        let mut zdp = 0;
        let mut mixed = 0;
        for d in &self.decisions {
            if d.is_pure_dp() {
                dp += 1;
            } else if d.is_pure_zdp() {
                zdp += 1;
            } else {
                mixed += 1;
            }
        }
        (dp, zdp, mixed)
    }

    /// Fraction of operators with slice granularity > 1 (Figure 8's
    /// "% of operators partitioned").
    pub fn split_fraction(&self) -> f64 {
        let split =
            self.decisions.iter().filter(|d| d.granularity > 1).count();
        split as f64 / self.decisions.len().max(1) as f64
    }

    /// Operators whose sharded slices live at node-local scope
    /// (MiCS/HSDP-style: sharded within a node, replicated across nodes).
    pub fn node_scoped_ops(&self) -> usize {
        self.decisions.iter().filter(|d| d.is_node_scoped()).count()
    }

    /// One-line human summary.
    pub fn describe(&self, profiler: &Profiler) -> String {
        let (dp, zdp, mixed) = self.mode_counts();
        let node = self.node_scoped_ops();
        let scopes = if node > 0 {
            format!(", {node} @node")
        } else {
            String::new()
        };
        format!(
            "b={} time={} peak={} [{} DP, {} ZDP, {} mixed{}, {:.0}% split] over {} ops",
            self.batch,
            crate::util::fmt_time(self.cost.time),
            crate::util::fmt_bytes(self.cost.peak_mem),
            dp,
            zdp,
            mixed,
            scopes,
            self.split_fraction() * 100.0,
            profiler.n_ops(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Cluster, SearchConfig};
    use crate::model::{GptDims, build_gpt};

    #[test]
    fn fold_report_shrinks_symmetric_spaces() {
        let m = build_gpt(&GptDims::uniform("t", 2000, 64, 8, 128, 4));
        let c = Cluster::rtx_titan(8, 8.0);
        let s = SearchConfig { granularities: vec![0, 2],
                               ..Default::default() };
        let p = Profiler::new(&m, &c, &s);
        let r = fold_report(&p);
        assert_eq!(r.ops, p.n_ops());
        assert!(r.classes < r.ops, "8 identical layers must fold");
        assert!(r.max_multiplicity >= 8);
        assert!(r.log10_folded < r.log10_unfolded,
                "folded space must be smaller: {} vs {}",
                r.log10_folded, r.log10_unfolded);
        assert!(r.describe().contains("classes"));
        // exact small case: C(3+2-1, 1) = 4 compositions
        assert!((log10_binomial(4, 1) - 4f64.log10()).abs() < 1e-12);
        assert!((log10_binomial(26, 2) - 325f64.log10()).abs() < 1e-9);
    }

    #[test]
    fn plan_mode_counts_and_split_fraction() {
        let m = build_gpt(&GptDims::uniform("t", 1000, 64, 2, 128, 4));
        let c = Cluster::rtx_titan(8, 8.0);
        let s = SearchConfig { granularities: vec![0, 4],
                               ..Default::default() };
        let p = Profiler::new(&m, &c, &s);
        let all_dp = p.index_of(|d| d.is_pure_dp());
        let plan = ExecutionPlan::from_choice(&p, all_dp, 2);
        let (dp, zdp, mixed) = plan.mode_counts();
        assert_eq!(dp, p.n_ops());
        assert_eq!(zdp + mixed, 0);
        assert_eq!(plan.split_fraction(), 0.0);
        assert_eq!(plan.node_scoped_ops(), 0);
        assert!(plan.throughput(8) > 0.0);
        assert!(plan.describe(&p).contains("DP"));
        assert!(!plan.describe(&p).contains("@node"));
    }

    #[test]
    fn describe_reports_node_scoped_ops() {
        let m = build_gpt(&GptDims::uniform("t", 1000, 64, 2, 128, 4));
        let c = Cluster::two_server_a100(16.0);
        let s = SearchConfig { granularities: vec![0],
                               ..Default::default() };
        let p = Profiler::new(&m, &c, &s);
        let choice = p.index_of(|d| d.is_node_scoped());
        let plan = ExecutionPlan::from_choice(&p, choice, 2);
        assert!(plan.node_scoped_ops() > 0);
        assert!(plan.describe(&p).contains("@node"));
    }
}
