//! The **composition-frontier** search engine: the third exact planner,
//! built for the Scheduler's batch sweep.
//!
//! The folded branch-and-bound ([`super::dfs`]) already plans over
//! `(class, multiplicity)` positions, but every per-batch search still
//! re-enumerates each class's count compositions from scratch inside
//! [`super::bound::Walker::descend_folded`]. This engine hoists that
//! enumeration out of the sweep entirely: each class's monotone option
//! blocks are enumerated **once per sweep** into a dominance-pruned
//! frontier of `(time_fixed_sum, states_sum, gather_max)` points, and
//! every per-batch search then merges those small frontiers under the
//! existing admissible suffix bounds. The per-batch work drops from
//! "walk the composition tree again" to "branch over precomputed Pareto
//! sets", while the scheduler recomputes only transients, base terms, and
//! the greedy seed per batch (see [`super::scheduler`]).
//!
//! # Why one frontier serves every batch size (batch invariance)
//!
//! A block `B` of class `k` contributes three quantities to the search:
//!
//! * `tf(B) = Σ_j time_fixed[B_j]` — batch-independent (menu times);
//! * `st(B) = Σ_j states[B_j]` — batch-independent (menu bytes);
//! * its transient, `max_j (gather[B_j] + b·w_k)` where `w_k` is the
//!   class's `workspace_per_sample` — the **only** batch-dependent term.
//!
//! Because `w_k` is class-constant (equal tables define the class — see
//! [`crate::cost::menu::table_key`]) and all quantities are exact
//! (grid-snapped times, whole-byte memory), the transient factors as
//! `gmax(B) + b·w_k` with `gmax(B) = max_j gather[B_j]`: it is a strictly
//! increasing function of `gmax(B)` alone, *for every batch size*. So if
//! block `A` satisfies
//!
//! ```text
//! tf(A) ≤ tf(B),  st(A) ≤ st(B),  gmax(A) ≤ gmax(B)
//! ```
//!
//! then swapping `B` for `A` in **any** plan, at **any** batch size and
//! memory limit, leaves the plan feasible (persistent sum and transient
//! max both weakly decrease) and no slower. `B` can therefore never be
//! part of the `(time, lex)`-optimal plan — *unless* it ties `A` exactly:
//! with `tf(A) == tf(B)` (an exact grid fact, not an epsilon), both plans
//! tie in time and the optimum is decided by the lexicographic
//! tie-break. Hence the pruning rule keeps exactness bit-for-bit:
//!
//! > drop `B` iff some `A` dominates it in all three coordinates **and**
//! > `A` precedes `B` in `(time_fixed_sum, lex-block)` order.
//!
//! If the dominator ties in time it must be lex-smaller, so the swapped
//! plan is lex-smaller too (class positions are contiguous in the visit
//! order, so replacing a class's block by a lex-smaller one makes the
//! whole ordered choice vector lex-smaller); if it is strictly faster the
//! tie-break never enters. Either way the `(time, lex)` optimum of the
//! folded space survives in the frontier space — proven as a property in
//! the unit tests below (`pruned_blocks_are_dominated_at_every_batch`)
//! and end-to-end in `rust/tests/frontier_planner.rs`.
//!
//! The all-zeros block (every member on option 0, the fastest) is
//! lex-least overall and time-minimal, so nothing can precede it: it is
//! always frontier point 0, which keeps the walker's fast-completion and
//! tie-pruning rules (`prefix + 0…0` reasoning) valid unchanged.
//!
//! # Exact arithmetic = bit-identical results
//!
//! Frontier aggregates are sums of grid-snapped times and whole-byte
//! memory, so `prefix + tf(B)` equals the folded walker's left-to-right
//! per-position accumulation bit-for-bit (exact sums are associative),
//! and `trans_max.max(gmax(B) + b·w_k)` equals the per-position transient
//! max. Every bound expression the shared [`Walker`] evaluates is
//! therefore the same f64, and the engine returns the bit-identical
//! `(time, lex)` optimum as the folded and per-operator engines.
//!
//! # Degradation, never wrongness
//!
//! A class whose composition count exceeds [`MAX_CLASS_COMPOSITIONS`] is
//! not enumerated; its frontier is marked too-wide and the walker falls
//! back to enumerating that class's monotone blocks in place (exactly
//! `descend_folded`'s loop). Exactness is unaffected — the frontier prune
//! is sound per class independently — only the one-time-build saving is
//! forgone for that class.

use super::bound::{FlatOpt, Prefold, Walker, composition_count,
                   next_monotone_block};
use super::dfs::{self, DfsStats};
use crate::cost::menu::MenuStats;
use crate::cost::{PlanCost, Profiler};

/// Composition-count ceiling for the one-time frontier build of a single
/// class. Classes wider than this (enormous menus at high multiplicity)
/// fall back to in-place block enumeration; everything the sweep targets
/// (deep uniform stacks with paper-scale menus) sits far below it.
pub const MAX_CLASS_COMPOSITIONS: usize = 1 << 18;

/// One frontier point: the batch-independent aggregates of a monotone
/// option block (its canonical count composition).
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct FrontierPoint {
    /// `Σ time_fixed` over the block (grid-exact).
    pub time_fixed: f64,
    /// `Σ states` over the block (whole bytes, exact).
    pub states: f64,
    /// `max gather` over the block; the block's transient at batch `b`
    /// is `gather_max + b·workspace_per_sample` (see module docs).
    pub gather_max: f64,
}

/// The kept points of one class, in `(time_fixed, lex-block)` order.
pub(crate) struct PointSet {
    pub agg: Vec<FrontierPoint>,
    /// Flattened option counts, stride `o`: point `p` assigns
    /// `counts[p*o + c]` members to option `c`.
    counts: Vec<u32>,
    o: usize,
}

impl PointSet {
    pub fn len(&self) -> usize {
        self.agg.len()
    }

    /// Materialize point `p`'s canonical monotone block into `out`
    /// (option `c` repeated `counts[c]` times, ascending).
    pub fn write_block(&self, p: usize, out: &mut [usize]) {
        let counts = &self.counts[p * self.o..(p + 1) * self.o];
        let mut j = 0;
        for (c, &n) in counts.iter().enumerate() {
            for slot in out[j..j + n as usize].iter_mut() {
                *slot = c;
            }
            j += n as usize;
        }
        debug_assert_eq!(j, out.len());
    }
}

/// One class's composition frontier.
pub(crate) struct ClassFrontier {
    /// Class multiplicity.
    pub m: usize,
    /// Menu size.
    pub o: usize,
    /// Total monotone blocks `C(m+o-1, o-1)` (saturating).
    pub compositions: usize,
    /// Dominance-pruned points, or `None` when the class is too wide to
    /// enumerate once ([`MAX_CLASS_COMPOSITIONS`]); the walker then
    /// enumerates this class's blocks in place, exactness unchanged.
    pub points: Option<PointSet>,
}

/// Per-class composition frontiers over a [`Prefold`]'s classes —
/// batch-independent by the module-docs argument, so the scheduler builds
/// one `Frontiers` per sweep and shares it across every batch size,
/// exactly like the `Prefold` itself.
pub(crate) struct Frontiers {
    pub classes: Vec<ClassFrontier>,
}

impl Frontiers {
    pub fn new(pre: &Prefold, profiler: &Profiler) -> Frontiers {
        let classes = (0..pre.n_classes())
            .map(|k| {
                let t = &profiler.tables[pre.order[pre.class_start[k]]];
                let tf: Vec<f64> =
                    t.options.iter().map(|o| o.time_fixed()).collect();
                let st: Vec<f64> =
                    t.options.iter().map(|o| o.states).collect();
                let g: Vec<f64> =
                    t.options.iter().map(|o| o.gather).collect();
                build_class(&tf, &st, &g, pre.multiplicity(k),
                            MAX_CLASS_COMPOSITIONS)
            })
            .collect();
        Frontiers { classes }
    }

    /// Aggregate + per-class build statistics (the per-class entries
    /// reuse [`MenuStats`]: `raw` = compositions, `kept` = points).
    pub fn stats(&self) -> FrontierStats {
        let mut s = FrontierStats::default();
        for c in &self.classes {
            s.classes += 1;
            s.compositions = s.compositions.saturating_add(c.compositions);
            let kept = match &c.points {
                Some(p) => {
                    s.points += p.len();
                    p.len()
                }
                None => {
                    s.too_wide += 1;
                    c.compositions
                }
            };
            s.per_class.push(MenuStats { raw: c.compositions, kept });
        }
        s
    }
}

/// What the one-time frontier build produced: how many compositions
/// collapsed into how many Pareto points, per class and in aggregate.
/// Reported by `osdp plan` (the frontier-size line) and recorded in
/// `BENCH_search.json`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FrontierStats {
    /// Equivalence classes in the fold.
    pub classes: usize,
    /// Count compositions across all classes (saturating).
    pub compositions: usize,
    /// Frontier points kept across the classes that were built.
    pub points: usize,
    /// Classes that exceeded [`MAX_CLASS_COMPOSITIONS`] and fall back to
    /// in-place block enumeration.
    pub too_wide: usize,
    /// Per-class reduction in fold-class order: `raw` = compositions,
    /// `kept` = frontier points (`kept == raw` for too-wide classes).
    pub per_class: Vec<MenuStats>,
}

impl FrontierStats {
    /// One-line human summary for CLI/bench reports.
    pub fn describe(&self) -> String {
        let suffix = if self.too_wide > 0 {
            format!(" ({} too wide to prebuild)", self.too_wide)
        } else {
            let agg =
                MenuStats { raw: self.compositions, kept: self.points };
            format!(" ({:.1}x fewer branches)", agg.reduction_factor())
        };
        format!(
            "{} compositions -> {} frontier points over {} classes{}",
            self.compositions, self.points, self.classes, suffix,
        )
    }
}

/// Build one class's frontier (or mark it too wide). `menu_*` are the
/// class menu's per-option `time_fixed`/`states`/`gather` in menu order;
/// `m` is the multiplicity.
fn build_class(menu_tf: &[f64], menu_st: &[f64], menu_g: &[f64], m: usize,
               cap: usize) -> ClassFrontier {
    let o = menu_tf.len();
    let compositions = composition_count(m, o);
    if compositions > cap {
        return ClassFrontier { m, o, compositions, points: None };
    }

    // Enumerate every monotone block once, in lex order, aggregating
    // left-to-right (exact sums, so the grouping cannot change a bit).
    let mut block = vec![0usize; m];
    let mut cand: Vec<FrontierPoint> = Vec::with_capacity(compositions);
    let mut cand_counts: Vec<u32> = Vec::with_capacity(compositions * o);
    let mut counts = vec![0u32; o];
    loop {
        let mut tf = 0.0;
        let mut st = 0.0;
        let mut g = 0.0f64;
        counts.fill(0);
        for &c in &block {
            tf += menu_tf[c];
            st += menu_st[c];
            g = g.max(menu_g[c]);
            counts[c] += 1;
        }
        cand.push(FrontierPoint { time_fixed: tf, states: st,
                                  gather_max: g });
        cand_counts.extend_from_slice(&counts);
        if !next_monotone_block(&mut block, o) {
            break;
        }
    }

    // (time, lex) processing order: stable sort by time keeps the lex
    // enumeration order on exact ties, so every point processed earlier
    // strictly precedes the current one in (time, lex) — which is exactly
    // the tie-break the pruning rule requires (module docs).
    let mut idx: Vec<usize> = (0..cand.len()).collect();
    idx.sort_by(|&a, &b| {
        cand[a].time_fixed.partial_cmp(&cand[b].time_fixed).unwrap()
    });

    // 2-D staircase over (states, gather_max): a point is pruned iff an
    // earlier-kept point weakly dominates it there (time dominance is
    // implied by the processing order).
    let mut stair: Vec<(f64, f64)> = Vec::new();
    let mut agg = Vec::new();
    let mut kept_counts = Vec::new();
    for &p in &idx {
        let pt = cand[p];
        if stair_dominates(&stair, pt.states, pt.gather_max) {
            continue;
        }
        stair_insert(&mut stair, pt.states, pt.gather_max);
        agg.push(pt);
        kept_counts.extend_from_slice(&cand_counts[p * o..(p + 1) * o]);
    }
    ClassFrontier {
        m,
        o,
        compositions,
        points: Some(PointSet { agg, counts: kept_counts, o }),
    }
}

/// Staircase invariant: entries sorted by `states` ascending with
/// `gather` strictly descending. Query: does any entry weakly dominate
/// `(st, g)`? The best candidate is the last entry with `states ≤ st`
/// (it has the minimum gather among them).
fn stair_dominates(stair: &[(f64, f64)], st: f64, g: f64) -> bool {
    match stair.partition_point(|e| e.0 <= st) {
        0 => false,
        i => stair[i - 1].1 <= g,
    }
}

/// Insert a non-dominated `(st, g)` and evict entries it dominates.
fn stair_insert(stair: &mut Vec<(f64, f64)>, st: f64, g: f64) {
    let i = stair.partition_point(|e| e.0 < st);
    let mut j = i;
    while j < stair.len() && stair[j].1 >= g {
        j += 1;
    }
    stair.splice(i..j, [(st, g)]);
}

// ---------------------------------------------------------------------
// The frontier descent: a third mode on the shared Walker, mirroring
// `descend_folded` with precomputed per-class branches.
// ---------------------------------------------------------------------

impl<'a> Walker<'a> {
    /// Search the frontier subtree rooted at class `class_depth`, with the
    /// first `class_start[class_depth]` positions fixed to `prefix` (their
    /// accumulated sums passed alongside, as in [`Walker::run_folded`]).
    pub fn run_frontier(&mut self, class_depth: usize, prefix: &[usize],
                        time_fixed: f64, states: f64, trans_max: f64) {
        self.prefix[..prefix.len()].copy_from_slice(prefix);
        self.descend_frontier(class_depth, time_fixed, states, trans_max);
        self.stats.complete = self.stats.nodes < self.budget;
    }

    /// Search the whole frontier space.
    pub fn run_root_frontier(&mut self) {
        self.run_frontier(0, &[], 0.0, 0.0, 0.0);
    }

    /// Frontier descent from class `k`: branches are the class's
    /// precomputed frontier points (every other composition is dominated
    /// at every batch size — see module docs), accumulated through the
    /// same exact arithmetic as [`Walker::descend_folded`], so all bound
    /// expressions and accepted totals are bit-identical. Too-wide
    /// classes fall back to in-place block enumeration.
    fn descend_frontier(&mut self, k: usize, time_fixed: f64, states: f64,
                        trans_max: f64) {
        if self.stats.nodes >= self.budget {
            return; // budget expired: keep the incumbent (anytime result)
        }
        self.stats.nodes += 1;
        let i = self.space.pre.class_start[k];
        if !self.open_subtree(i, time_fixed, states, trans_max) {
            return;
        }
        if i == self.space.n() {
            self.try_accept(self.space.base_time + time_fixed);
            return;
        }
        if self.try_fast_completion(i, time_fixed, states, trans_max) {
            return;
        }
        let fr: &'a Frontiers =
            self.frontier.expect("frontier descent without frontiers");
        let cls = &fr.classes[k];
        match &cls.points {
            Some(set) => {
                let bws = self.space.class_bws[k];
                for p in 0..set.len() {
                    let pt = set.agg[p];
                    set.write_block(p,
                                    &mut self.prefix[i..i + cls.m]);
                    self.descend_frontier(
                        k + 1,
                        time_fixed + pt.time_fixed,
                        states + pt.states,
                        trans_max.max(pt.gather_max + bws),
                    );
                    if self.stats.nodes >= self.budget {
                        break;
                    }
                }
            }
            None => {
                // Too wide to prebuild: enumerate this class's monotone
                // blocks in place (descend_folded's loop verbatim).
                let end = self.space.pre.class_start[k + 1];
                let o = self.space.flat[i].len();
                let mut block = std::mem::take(&mut self.blocks[k]);
                block.clear();
                block.resize(end - i, 0);
                loop {
                    let mut tf = time_fixed;
                    let mut st = states;
                    let mut tm = trans_max;
                    for (j, &c) in block.iter().enumerate() {
                        let opt: FlatOpt = self.space.flat[i + j][c];
                        tf += opt.time_fixed;
                        st += opt.states;
                        tm = tm.max(opt.transient);
                        self.prefix[i + j] = c;
                    }
                    self.descend_frontier(k + 1, tf, st, tm);
                    if self.stats.nodes >= self.budget
                        || !next_monotone_block(&mut block, o)
                    {
                        break;
                    }
                }
                self.blocks[k] = block;
            }
        }
    }
}

// ---------------------------------------------------------------------
// Public entry points
// ---------------------------------------------------------------------

/// Frontier-engine search with the default node budget: minimal `Σ T_i`
/// plan whose peak memory fits `mem_limit` at per-device batch `b`,
/// bit-identical to [`super::dfs::search`] (the folded branch-and-bound)
/// and to the per-operator engine. Returns `None` when nothing fits.
pub fn search(profiler: &Profiler, mem_limit: f64, b: usize)
              -> Option<(Vec<usize>, PlanCost, DfsStats)> {
    search_with_budget(profiler, mem_limit, b, dfs::DEFAULT_NODE_BUDGET)
}

/// [`search`] with an explicit node budget (`u64::MAX` = provably exact).
pub fn search_with_budget(profiler: &Profiler, mem_limit: f64, b: usize,
                          budget: u64)
                          -> Option<(Vec<usize>, PlanCost, DfsStats)> {
    let prefold = Prefold::new(profiler);
    let frontiers = Frontiers::new(&prefold, profiler);
    let (r, stats) =
        dfs::search_prefolded(profiler, &prefold, Some(&frontiers),
                              mem_limit, b, budget,
                              super::Engine::Frontier, None);
    r.map(|(choice, cost)| (choice, cost, stats))
}

/// Build the frontiers for a profiler and report their statistics (the
/// CLI's frontier-size line, the benches' point counts).
pub fn report(profiler: &Profiler) -> FrontierStats {
    let prefold = Prefold::new(profiler);
    Frontiers::new(&prefold, profiler).stats()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Cluster, SearchConfig};
    use crate::model::{GptDims, build_gpt};
    use crate::planner::bound::lex_less;

    /// A handcrafted menu with genuine 3-way trade-offs (times snapped to
    /// the grid, memory in whole bytes, like the Profiler emits).
    fn menu() -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let snap = crate::cost::time::snap_time;
        let tf = vec![snap(1e-3), snap(2e-3), snap(3e-3), snap(4e-3)];
        let st = vec![100.0, 60.0, 30.0, 10.0];
        let g = vec![0.0, 40.0, 20.0, 50.0];
        (tf, st, g)
    }

    fn blocks_of(m: usize, o: usize) -> Vec<Vec<usize>> {
        let mut b = vec![0usize; m];
        let mut all = vec![b.clone()];
        while next_monotone_block(&mut b, o) {
            all.push(b.clone());
        }
        all
    }

    fn aggregates(block: &[usize], tf: &[f64], st: &[f64], g: &[f64])
                  -> FrontierPoint {
        let mut p = FrontierPoint { time_fixed: 0.0, states: 0.0,
                                    gather_max: 0.0 };
        for &c in block {
            p.time_fixed += tf[c];
            p.states += st[c];
            p.gather_max = p.gather_max.max(g[c]);
        }
        p
    }

    #[test]
    fn frontier_points_are_sorted_mutually_undominated_and_lead_with_zero() {
        let (tf, st, g) = menu();
        let cf = build_class(&tf, &st, &g, 5, MAX_CLASS_COMPOSITIONS);
        let set = cf.points.as_ref().unwrap();
        assert_eq!(cf.compositions, composition_count(5, 4));
        assert!(set.len() <= cf.compositions);
        assert!(set.len() >= 1);
        // point 0 is the all-zeros (all-fastest, lex-least) block
        let mut b0 = vec![usize::MAX; 5];
        set.write_block(0, &mut b0);
        assert_eq!(b0, vec![0; 5]);
        // sorted by time; blocks monotone; mutually non-dominated under
        // the (time, lex) rule
        let mut blocks = Vec::new();
        for p in 0..set.len() {
            let mut b = vec![0usize; 5];
            set.write_block(p, &mut b);
            assert!(b.windows(2).all(|w| w[0] <= w[1]), "monotone {b:?}");
            let agg = aggregates(&b, &tf, &st, &g);
            assert_eq!(agg.time_fixed.to_bits(),
                       set.agg[p].time_fixed.to_bits());
            assert_eq!(agg.states.to_bits(), set.agg[p].states.to_bits());
            assert_eq!(agg.gather_max.to_bits(),
                       set.agg[p].gather_max.to_bits());
            blocks.push(b);
        }
        for w in set.agg.windows(2) {
            assert!(w[0].time_fixed <= w[1].time_fixed, "time-sorted");
        }
        for a in 0..set.len() {
            for b in 0..set.len() {
                if a == b {
                    continue;
                }
                let (pa, pb) = (set.agg[a], set.agg[b]);
                let dominates = pa.time_fixed <= pb.time_fixed
                    && pa.states <= pb.states
                    && pa.gather_max <= pb.gather_max
                    && (pa.time_fixed < pb.time_fixed
                        || lex_less(&blocks[a], &blocks[b]));
                assert!(!dominates,
                        "kept point {a} dominates kept point {b}");
            }
        }
    }

    /// The load-bearing batch-invariance property from the module docs:
    /// every pruned composition is dominated by a kept one — same or less
    /// time, states, and *transient* — at every batch size, with the
    /// dominator strictly earlier in (time, lex). So dropping it can
    /// never change the (time, lex) optimum of any per-batch search.
    #[test]
    fn pruned_blocks_are_dominated_at_every_batch() {
        let (tf, st, g) = menu();
        let workspace = 8.0; // class-constant bytes/sample, like a table's
        let m = 5;
        let cf = build_class(&tf, &st, &g, m, MAX_CLASS_COMPOSITIONS);
        let set = cf.points.as_ref().unwrap();
        let kept: Vec<Vec<usize>> = (0..set.len())
            .map(|p| {
                let mut b = vec![0usize; m];
                set.write_block(p, &mut b);
                b
            })
            .collect();
        let mut pruned = 0;
        for block in blocks_of(m, tf.len()) {
            if kept.contains(&block) {
                continue;
            }
            pruned += 1;
            let pb = aggregates(&block, &tf, &st, &g);
            // transient computed per position, NOT via the gmax algebra,
            // so this test independently checks the factorization claim
            for b in [1usize, 2, 3, 5, 8, 64] {
                let bws = b as f64 * workspace;
                let trans_b: f64 = block
                    .iter()
                    .map(|&c| g[c] + bws)
                    .fold(0.0, f64::max);
                let found = (0..set.len()).any(|p| {
                    let pa = set.agg[p];
                    let trans_a: f64 = kept[p]
                        .iter()
                        .map(|&c| g[c] + bws)
                        .fold(0.0, f64::max);
                    pa.time_fixed <= pb.time_fixed
                        && pa.states <= pb.states
                        && trans_a <= trans_b
                        && (pa.time_fixed < pb.time_fixed
                            || lex_less(&kept[p], &block))
                });
                assert!(found,
                        "pruned block {block:?} undominated at batch {b}");
            }
        }
        assert!(pruned > 0, "menu must actually exercise the pruning");
    }

    #[test]
    fn too_wide_classes_fall_back() {
        let (tf, st, g) = menu();
        // C(5+4-1, 3) = 56 compositions; a cap of 10 forces the fallback
        let cf = build_class(&tf, &st, &g, 5, 10);
        assert!(cf.points.is_none());
        assert_eq!(cf.compositions, 56);
        // and the stats mark it
        let fr = Frontiers { classes: vec![cf] };
        let s = fr.stats();
        assert_eq!(s.too_wide, 1);
        assert_eq!(s.per_class[0], MenuStats { raw: 56, kept: 56 });
        assert!(s.describe().contains("too wide"));
    }

    /// A forced too-wide class must leave the engine exact: overwrite one
    /// class's frontier with the fallback marker and compare against the
    /// folded engine across memory limits.
    #[test]
    fn fallback_classes_keep_the_engine_exact() {
        let m = build_gpt(&GptDims::uniform("t", 3000, 64, 4, 256, 4));
        let c = Cluster::rtx_titan(8, 8.0);
        let s = SearchConfig { granularities: vec![0, 2],
                               ..Default::default() };
        let p = Profiler::new(&m, &c, &s);
        let pre = Prefold::new(&p);
        let mut fr = Frontiers::new(&pre, &p);
        let widest = (0..fr.classes.len())
            .max_by_key(|&k| fr.classes[k].compositions)
            .unwrap();
        fr.classes[widest].points = None;
        let dp = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 2).peak_mem;
        for frac in [0.4, 0.7, 1.1] {
            let limit = dp * frac;
            let (with_fallback, _) = dfs::search_prefolded(
                &p, &pre, Some(&fr), limit, 2, u64::MAX,
                crate::planner::Engine::Frontier, None);
            let folded = dfs::search_with_budget(&p, limit, 2, u64::MAX);
            match (with_fallback, folded) {
                (None, None) => {}
                (Some((fc, fcost)), Some((gc, gcost, _))) => {
                    assert_eq!(fc, gc, "choice differs at frac {frac}");
                    assert_eq!(fcost.time.to_bits(), gcost.time.to_bits());
                }
                _ => panic!("feasibility disagreement at frac {frac}"),
            }
        }
    }

    #[test]
    fn frontier_search_matches_folded_on_a_small_model() {
        let m = build_gpt(&GptDims::uniform("t", 4000, 64, 6, 192, 4));
        let c = Cluster::rtx_titan(8, 8.0);
        let s = SearchConfig { granularities: vec![0, 2],
                               ..Default::default() };
        let p = Profiler::new(&m, &c, &s);
        let dp = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1).peak_mem;
        for frac in [0.35, 0.6, 0.9, 1.2] {
            let limit = dp * frac;
            let fr = search_with_budget(&p, limit, 1, u64::MAX);
            let fo = dfs::search_with_budget(&p, limit, 1, u64::MAX);
            match (fr, fo) {
                (None, None) => {}
                (Some((fc, fcost, fst)), Some((gc, gcost, gst))) => {
                    assert_eq!(fc, gc, "choice differs at frac {frac}");
                    assert_eq!(fcost.time.to_bits(), gcost.time.to_bits());
                    assert_eq!(fcost.peak_mem.to_bits(),
                               gcost.peak_mem.to_bits());
                    // the frontier never explores more than the fold
                    assert!(fst.nodes <= gst.nodes,
                            "frontier {} > folded {} nodes at frac {frac}",
                            fst.nodes, gst.nodes);
                }
                _ => panic!("feasibility disagreement at frac {frac}"),
            }
        }
    }

    #[test]
    fn report_counts_points() {
        let m = build_gpt(&GptDims::uniform("t", 3000, 64, 8, 128, 4));
        let c = Cluster::rtx_titan(8, 8.0);
        let s = SearchConfig { granularities: vec![0],
                               ..Default::default() };
        let p = Profiler::new(&m, &c, &s);
        let r = report(&p);
        assert_eq!(r.classes, p.op_classes().len());
        assert_eq!(r.per_class.len(), r.classes);
        assert!(r.points >= r.classes, "every class keeps >= 1 point");
        assert!(r.points <= r.compositions);
        assert_eq!(r.too_wide, 0);
        assert!(r.describe().contains("frontier points"));
    }
}
