//! The **composition-frontier** search engine: the third exact planner,
//! built for the Scheduler's batch sweep.
//!
//! The folded branch-and-bound ([`super::dfs`]) already plans over
//! `(class, multiplicity)` positions, but every per-batch search still
//! re-enumerates each class's count compositions from scratch inside
//! [`super::bound::Walker::descend_folded`]. This engine hoists that
//! enumeration out of the sweep entirely: each class's monotone option
//! blocks are enumerated **once per sweep** into a dominance-pruned
//! frontier of `(time_fixed_sum, states_sum, gather_max)` points, and
//! every per-batch search then merges those small frontiers under the
//! existing admissible suffix bounds. The per-batch work drops from
//! "walk the composition tree again" to "branch over precomputed Pareto
//! sets", while the scheduler recomputes only transients, base terms, and
//! the greedy seed per batch (see [`super::scheduler`]).
//!
//! # Why one frontier serves every batch size (batch invariance)
//!
//! A block `B` of class `k` contributes three quantities to the search:
//!
//! * `tf(B) = Σ_j time_fixed[B_j]` — batch-independent (menu times);
//! * `st(B) = Σ_j states[B_j]` — batch-independent (menu bytes);
//! * its transient, `max_j (gather[B_j] + b·w_k)` where `w_k` is the
//!   class's `workspace_per_sample` — the **only** batch-dependent term.
//!
//! Because `w_k` is class-constant (equal tables define the class — see
//! [`crate::cost::menu::table_key`]) and all quantities are exact
//! (grid-snapped times, whole-byte memory), the transient factors as
//! `gmax(B) + b·w_k` with `gmax(B) = max_j gather[B_j]`: it is a strictly
//! increasing function of `gmax(B)` alone, *for every batch size*. So if
//! block `A` satisfies
//!
//! ```text
//! tf(A) ≤ tf(B),  st(A) ≤ st(B),  gmax(A) ≤ gmax(B)
//! ```
//!
//! then swapping `B` for `A` in **any** plan, at **any** batch size and
//! memory limit, leaves the plan feasible (persistent sum and transient
//! max both weakly decrease) and no slower. `B` can therefore never be
//! part of the `(time, lex)`-optimal plan — *unless* it ties `A` exactly:
//! with `tf(A) == tf(B)` (an exact grid fact, not an epsilon), both plans
//! tie in time and the optimum is decided by the lexicographic
//! tie-break. Hence the pruning rule keeps exactness bit-for-bit:
//!
//! > drop `B` iff some `A` dominates it in all three coordinates **and**
//! > `A` precedes `B` in `(time_fixed_sum, lex-block)` order.
//!
//! If the dominator ties in time it must be lex-smaller, so the swapped
//! plan is lex-smaller too (class positions are contiguous in the visit
//! order, so replacing a class's block by a lex-smaller one makes the
//! whole ordered choice vector lex-smaller); if it is strictly faster the
//! tie-break never enters. Either way the `(time, lex)` optimum of the
//! folded space survives in the frontier space — proven as a property in
//! the unit tests below (`pruned_blocks_are_dominated_at_every_batch`)
//! and end-to-end in `rust/tests/frontier_planner.rs`.
//!
//! The all-zeros block (every member on option 0, the fastest) is
//! lex-least overall and time-minimal, so nothing can precede it: it is
//! always frontier point 0, which keeps the walker's fast-completion and
//! tie-pruning rules (`prefix + 0…0` reasoning) valid unchanged.
//!
//! # Exact arithmetic = bit-identical results
//!
//! Frontier aggregates are sums of grid-snapped times and whole-byte
//! memory, so `prefix + tf(B)` equals the folded walker's left-to-right
//! per-position accumulation bit-for-bit (exact sums are associative),
//! and `trans_max.max(gmax(B) + b·w_k)` equals the per-position transient
//! max. Every bound expression the shared [`Walker`] evaluates is
//! therefore the same f64, and the engine returns the bit-identical
//! `(time, lex)` optimum as the folded and per-operator engines.
//!
//! # The incremental Minkowski-sum build (no width ceiling)
//!
//! Enumerating all `C(m+o-1, o-1)` compositions at once is exponential in
//! the menu width `o`; it used to be capped at `2^18` per class, with
//! wider classes (wide menus × high multiplicity — precisely the
//! production shapes) falling back to in-place enumeration. Instead,
//! [`build_class`] now grows the frontier **level by level**: the
//! level-`l` candidate set is the Minkowski sum of the level-`l-1`
//! frontier with the `o` single-member option points
//! (`tf + tf[c]`, `st + st[c]`, `max(gmax, g[c])`), pruned by the same
//! `(time, lex-block)` staircase rule after every level. Work becomes
//! `O(m · |frontier| · o · log)` — independent of the composition count.
//!
//! Level-wise exactness: every level-`l` block is a level-`l-1` block
//! plus one member, and the pruning rule survives the extension `⊕ c`:
//!
//! * **dominance** is preserved because the aggregates are exact — grid
//!   times and whole bytes add without rounding, so
//!   `tf(A) ≤ tf(B) ⇒ tf(A)+tf[c] ≤ tf(B)+tf[c]` bit-for-bit (same for
//!   states; `gather_max` extends through `max`, which is monotone);
//! * **`(tf, lex)` precedence** is preserved because inserting the same
//!   option `c` into two sorted blocks keeps their lex order, and exact
//!   tf ties stay exact ties.
//!
//! So if `A` dominates-and-precedes `B` at level `l-1`, then `A ⊕ c`
//! dominates-and-precedes `B ⊕ c` at level `l`; with transitivity, every
//! composition pruned at any level stays covered by a kept one, and
//! conversely nothing the one-shot rule would keep can be lost. The
//! incremental kept set therefore **equals** the one-shot kept set,
//! point for point and in the same `(tf, lex)` order — asserted bit
//! for bit by `incremental_build_equals_one_shot_oracle` below and
//! mirrored in `python/mirror/frontier_mirror.py`. One subtlety: the
//! full sum (every kept point ⊕ every option) is required — extending
//! only monotonically (`c ≥` the block's last option) would be unsound,
//! because a pruned block's dominator may end in a larger option. The
//! sum can reach the same block from several parents; duplicates carry
//! identical bits and the weak staircase keeps exactly the first.
//!
//! Since the incremental build has no width ceiling, `too_wide` classes
//! no longer exist: every class prebuilds, the walker always branches
//! over frontier points, and [`FrontierStats::too_wide`] is structurally
//! zero (the field is retained, deprecated, for report compatibility).

use super::bound::{Prefold, Walker, composition_count};
use super::dfs::{self, DfsStats};
use crate::cost::menu::MenuStats;
use crate::cost::{PlanCost, Profiler};

/// One frontier point: the batch-independent aggregates of a monotone
/// option block (its canonical count composition).
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct FrontierPoint {
    /// `Σ time_fixed` over the block (grid-exact).
    pub time_fixed: f64,
    /// `Σ states` over the block (whole bytes, exact).
    pub states: f64,
    /// `max gather` over the block; the block's transient at batch `b`
    /// is `gather_max + b·workspace_per_sample` (see module docs).
    pub gather_max: f64,
}

/// The kept points of one class, in `(time_fixed, lex-block)` order.
pub(crate) struct PointSet {
    pub agg: Vec<FrontierPoint>,
    /// Flattened option counts, stride `o`: point `p` assigns
    /// `counts[p*o + c]` members to option `c`.
    counts: Vec<u32>,
    o: usize,
}

impl PointSet {
    pub fn len(&self) -> usize {
        self.agg.len()
    }

    /// Materialize point `p`'s canonical monotone block into `out`
    /// (option `c` repeated `counts[c]` times, ascending).
    pub fn write_block(&self, p: usize, out: &mut [usize]) {
        let counts = &self.counts[p * self.o..(p + 1) * self.o];
        let mut j = 0;
        for (c, &n) in counts.iter().enumerate() {
            for slot in out[j..j + n as usize].iter_mut() {
                *slot = c;
            }
            j += n as usize;
        }
        debug_assert_eq!(j, out.len());
    }
}

/// One class's composition frontier.
pub(crate) struct ClassFrontier {
    /// Class multiplicity.
    pub m: usize,
    /// Menu size.
    pub o: usize,
    /// Total monotone blocks `C(m+o-1, o-1)` (saturating) — reporting
    /// only; the incremental build never enumerates them.
    pub compositions: usize,
    /// Peak kept-frontier width across the build levels `0..=m` — the
    /// build's working-set high-water mark, surfaced by the strict bench
    /// so width regressions are visible.
    pub peak_width: usize,
    /// Dominance-pruned points in `(time_fixed, lex-block)` order.
    pub points: PointSet,
}

/// Per-class composition frontiers over a [`Prefold`]'s classes —
/// batch-independent by the module-docs argument, so the scheduler builds
/// one `Frontiers` per sweep and shares it across every batch size,
/// exactly like the `Prefold` itself.
pub(crate) struct Frontiers {
    pub classes: Vec<ClassFrontier>,
}

impl Frontiers {
    pub fn new(pre: &Prefold, profiler: &Profiler) -> Frontiers {
        let classes = (0..pre.n_classes())
            .map(|k| {
                let t = &profiler.tables[pre.order[pre.class_start[k]]];
                let tf: Vec<f64> =
                    t.options.iter().map(|o| o.time_fixed()).collect();
                let st: Vec<f64> =
                    t.options.iter().map(|o| o.states).collect();
                let g: Vec<f64> =
                    t.options.iter().map(|o| o.gather).collect();
                build_class(&tf, &st, &g, pre.multiplicity(k))
            })
            .collect();
        Frontiers { classes }
    }

    /// Aggregate + per-class build statistics (the per-class entries
    /// reuse [`MenuStats`]: `raw` = compositions, `kept` = points kept).
    /// `per_class` is preallocated once — thousand-class prefolds pay no
    /// reallocation churn.
    pub fn stats(&self) -> FrontierStats {
        let mut s = FrontierStats {
            per_class: Vec::with_capacity(self.classes.len()),
            ..FrontierStats::default()
        };
        for c in &self.classes {
            s.classes += 1;
            s.compositions = s.compositions.saturating_add(c.compositions);
            s.points += c.points.len();
            s.max_level_width = s.max_level_width.max(c.peak_width);
            s.per_class
                .push(MenuStats { raw: c.compositions, kept: c.points.len() });
        }
        s
    }
}

/// What the one-time frontier build produced: how many compositions
/// collapsed into how many Pareto points, per class and in aggregate.
/// Reported by `osdp plan` (the frontier-size line) and recorded in
/// `BENCH_search.json`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FrontierStats {
    /// Equivalence classes in the fold.
    pub classes: usize,
    /// Count compositions across all classes (saturating).
    pub compositions: usize,
    /// Frontier points kept across all classes.
    pub points: usize,
    /// Deprecated: always `0` since the incremental Minkowski-sum build
    /// removed the width ceiling — every class prebuilds. Retained (not
    /// `#[deprecated]`, our own reports still serialize it) so recorded
    /// `BENCH_search.json` trajectories keep their schema.
    pub too_wide: usize,
    /// Largest kept-frontier width any class reached at any build level
    /// (the incremental build's working-set high-water mark).
    pub max_level_width: usize,
    /// Per-class reduction in fold-class order: `raw` = compositions,
    /// `kept` = frontier points kept.
    pub per_class: Vec<MenuStats>,
}

impl FrontierStats {
    /// One-line human summary for CLI/bench reports. The reduction
    /// factor is always reported (it used to vanish behind the
    /// "too wide to prebuild" suffix; `too_wide` is structurally zero
    /// now, but stay defensive about stale deserialized stats).
    pub fn describe(&self) -> String {
        let agg = MenuStats { raw: self.compositions, kept: self.points };
        let mut out = format!(
            "{} compositions -> {} frontier points over {} classes \
             ({:.1}x fewer branches, peak level width {})",
            self.compositions,
            self.points,
            self.classes,
            agg.reduction_factor(),
            self.max_level_width,
        );
        if self.too_wide > 0 {
            out.push_str(&format!(" [{} too wide]", self.too_wide));
        }
        out
    }
}

/// Build one class's frontier by the incremental Minkowski-sum scheme
/// (module docs). `menu_*` are the class menu's per-option
/// `time_fixed`/`states`/`gather` in menu order; `m` is the multiplicity.
/// Work is `O(m · |frontier| · o)` candidates — no width ceiling.
fn build_class(menu_tf: &[f64], menu_st: &[f64], menu_g: &[f64], m: usize)
               -> ClassFrontier {
    let o = menu_tf.len();
    let compositions = composition_count(m, o);

    // Level 0: the empty block (all aggregates zero, all counts zero).
    let mut agg = vec![FrontierPoint { time_fixed: 0.0, states: 0.0,
                                       gather_max: 0.0 }];
    let mut counts = vec![0u32; o];
    let mut peak_width = 1;

    // Scratch buffers reused across levels.
    let mut cand: Vec<FrontierPoint> = Vec::new();
    let mut cand_counts: Vec<u32> = Vec::new();
    let mut idx: Vec<usize> = Vec::new();
    let mut stair: Vec<(f64, f64)> = Vec::new();
    for _level in 1..=m {
        // Minkowski sum: every kept point ⊕ every menu option. The FULL
        // sum is required for soundness — a pruned block's dominator may
        // end in a larger option, so monotone-only extension would lose
        // it (module docs). Exact sums make each candidate's aggregates
        // independent of the order its members were added, hence equal
        // to the one-shot block aggregates bit for bit.
        cand.clear();
        cand_counts.clear();
        cand.reserve(agg.len() * o);
        cand_counts.reserve(agg.len() * o * o);
        for (p, &base) in agg.iter().enumerate() {
            let pc = &counts[p * o..(p + 1) * o];
            for c in 0..o {
                cand.push(FrontierPoint {
                    time_fixed: base.time_fixed + menu_tf[c],
                    states: base.states + menu_st[c],
                    gather_max: base.gather_max.max(menu_g[c]),
                });
                let at = cand_counts.len();
                cand_counts.extend_from_slice(pc);
                cand_counts[at + c] += 1;
            }
        }

        // (time, lex-block) processing order. Unlike the one-shot
        // enumeration, candidates do not arrive in lex order (several
        // parents can reach the same block), so the lex tie-break is
        // explicit: count vectors compare DESCENDING — more members on
        // a smaller option is the lex-smaller block. Exact duplicates
        // compare equal; the weak staircase keeps only the first.
        idx.clear();
        idx.extend(0..cand.len());
        idx.sort_by(|&a, &b| {
            cand[a]
                .time_fixed
                .partial_cmp(&cand[b].time_fixed)
                .unwrap()
                .then_with(|| {
                    counts_lex_cmp(&cand_counts[a * o..(a + 1) * o],
                                   &cand_counts[b * o..(b + 1) * o])
                })
        });

        // 2-D staircase over (states, gather_max): a point is pruned iff
        // an earlier-kept point weakly dominates it there (time dominance
        // is implied by the processing order).
        stair.clear();
        let mut next_agg = Vec::with_capacity(agg.len() + o);
        let mut next_counts = Vec::with_capacity(counts.len() + o * o);
        for &p in &idx {
            let pt = cand[p];
            if stair_dominates(&stair, pt.states, pt.gather_max) {
                continue;
            }
            stair_insert(&mut stair, pt.states, pt.gather_max);
            next_agg.push(pt);
            next_counts
                .extend_from_slice(&cand_counts[p * o..(p + 1) * o]);
        }
        agg = next_agg;
        counts = next_counts;
        peak_width = peak_width.max(agg.len());
    }
    ClassFrontier { m, o, compositions, peak_width,
                    points: PointSet { agg, counts, o } }
}

/// Lexicographic order on canonical monotone blocks, compared through
/// their option-count vectors: at the first option where the counts
/// differ, the block with MORE members there is lex-smaller (its next
/// position carries the smaller option index).
fn counts_lex_cmp(a: &[u32], b: &[u32]) -> std::cmp::Ordering {
    for (x, y) in a.iter().zip(b) {
        if x != y {
            return y.cmp(x);
        }
    }
    std::cmp::Ordering::Equal
}

/// Staircase invariant: entries sorted by `states` ascending with
/// `gather` strictly descending. Query: does any entry weakly dominate
/// `(st, g)`? The best candidate is the last entry with `states ≤ st`
/// (it has the minimum gather among them).
fn stair_dominates(stair: &[(f64, f64)], st: f64, g: f64) -> bool {
    match stair.partition_point(|e| e.0 <= st) {
        0 => false,
        i => stair[i - 1].1 <= g,
    }
}

/// Insert a non-dominated `(st, g)` and evict entries it dominates.
fn stair_insert(stair: &mut Vec<(f64, f64)>, st: f64, g: f64) {
    let i = stair.partition_point(|e| e.0 < st);
    let mut j = i;
    while j < stair.len() && stair[j].1 >= g {
        j += 1;
    }
    stair.splice(i..j, [(st, g)]);
}

// ---------------------------------------------------------------------
// The frontier descent: a third mode on the shared Walker, mirroring
// `descend_folded` with precomputed per-class branches.
// ---------------------------------------------------------------------

impl<'a> Walker<'a> {
    /// Search the frontier subtree rooted at class `class_depth`, with the
    /// first `class_start[class_depth]` positions fixed to `prefix` (their
    /// accumulated sums passed alongside, as in [`Walker::run_folded`]).
    pub fn run_frontier(&mut self, class_depth: usize, prefix: &[usize],
                        time_fixed: f64, states: f64, trans_max: f64) {
        self.prefix[..prefix.len()].copy_from_slice(prefix);
        self.descend_frontier(class_depth, time_fixed, states, trans_max);
        self.stats.complete = self.stats.nodes < self.budget;
    }

    /// Search the whole frontier space.
    pub fn run_root_frontier(&mut self) {
        self.run_frontier(0, &[], 0.0, 0.0, 0.0);
    }

    /// Frontier descent from class `k`: branches are the class's
    /// precomputed frontier points (every other composition is dominated
    /// at every batch size — see module docs), accumulated through the
    /// same exact arithmetic as [`Walker::descend_folded`], so all bound
    /// expressions and accepted totals are bit-identical. Every class
    /// prebuilds (the incremental build has no width ceiling), so this
    /// is the only branch shape.
    fn descend_frontier(&mut self, k: usize, time_fixed: f64, states: f64,
                        trans_max: f64) {
        if self.stats.nodes >= self.budget {
            return; // budget expired: keep the incumbent (anytime result)
        }
        self.stats.nodes += 1;
        let i = self.space.pre.class_start[k];
        if !self.open_subtree(i, time_fixed, states, trans_max) {
            return;
        }
        if i == self.space.n() {
            self.try_accept(self.space.base_time + time_fixed);
            return;
        }
        if self.try_fast_completion(i, time_fixed, states, trans_max) {
            return;
        }
        let fr: &'a Frontiers =
            self.frontier.expect("frontier descent without frontiers");
        let cls = &fr.classes[k];
        let set = &cls.points;
        let bws = self.space.class_bws[k];
        for p in 0..set.len() {
            let pt = set.agg[p];
            set.write_block(p, &mut self.prefix[i..i + cls.m]);
            self.descend_frontier(
                k + 1,
                time_fixed + pt.time_fixed,
                states + pt.states,
                trans_max.max(pt.gather_max + bws),
            );
            if self.stats.nodes >= self.budget {
                break;
            }
        }
    }
}

// ---------------------------------------------------------------------
// Public entry points
// ---------------------------------------------------------------------

/// Frontier-engine search with the default node budget: minimal `Σ T_i`
/// plan whose peak memory fits `mem_limit` at per-device batch `b`,
/// bit-identical to [`super::dfs::search`] (the folded branch-and-bound)
/// and to the per-operator engine. Returns `None` when nothing fits.
pub fn search(profiler: &Profiler, mem_limit: f64, b: usize)
              -> Option<(Vec<usize>, PlanCost, DfsStats)> {
    search_with_budget(profiler, mem_limit, b, dfs::DEFAULT_NODE_BUDGET)
}

/// [`search`] with an explicit node budget (`u64::MAX` = provably exact).
pub fn search_with_budget(profiler: &Profiler, mem_limit: f64, b: usize,
                          budget: u64)
                          -> Option<(Vec<usize>, PlanCost, DfsStats)> {
    let prefold = Prefold::new(profiler);
    let frontiers = Frontiers::new(&prefold, profiler);
    let (r, stats) =
        dfs::search_prefolded(profiler, &prefold, Some(&frontiers),
                              mem_limit, b, budget,
                              super::Engine::Frontier, None);
    r.map(|(choice, cost)| (choice, cost, stats))
}

/// Build the frontiers for a profiler and report their statistics (the
/// CLI's frontier-size line, the benches' point counts).
pub fn report(profiler: &Profiler) -> FrontierStats {
    let prefold = Prefold::new(profiler);
    Frontiers::new(&prefold, profiler).stats()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Cluster, SearchConfig};
    use crate::model::{GptDims, build_gpt};
    use crate::planner::bound::{lex_less, next_monotone_block};

    /// A handcrafted menu with genuine 3-way trade-offs (times snapped to
    /// the grid, memory in whole bytes, like the Profiler emits).
    fn menu() -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let snap = crate::cost::time::snap_time;
        let tf = vec![snap(1e-3), snap(2e-3), snap(3e-3), snap(4e-3)];
        let st = vec![100.0, 60.0, 30.0, 10.0];
        let g = vec![0.0, 40.0, 20.0, 50.0];
        (tf, st, g)
    }

    /// The retired one-shot build (PR 3), kept verbatim as the oracle:
    /// enumerate every monotone block in lex order, stable-sort by time
    /// (ties keep lex order), staircase-prune. The incremental build
    /// must reproduce its kept set bit for bit, in the same order.
    fn build_class_oneshot(menu_tf: &[f64], menu_st: &[f64],
                           menu_g: &[f64], m: usize) -> PointSet {
        let o = menu_tf.len();
        let mut block = vec![0usize; m];
        let mut cand: Vec<FrontierPoint> = Vec::new();
        let mut cand_counts: Vec<u32> = Vec::new();
        let mut counts = vec![0u32; o];
        loop {
            let mut tf = 0.0;
            let mut st = 0.0;
            let mut g = 0.0f64;
            counts.fill(0);
            for &c in &block {
                tf += menu_tf[c];
                st += menu_st[c];
                g = g.max(menu_g[c]);
                counts[c] += 1;
            }
            cand.push(FrontierPoint { time_fixed: tf, states: st,
                                      gather_max: g });
            cand_counts.extend_from_slice(&counts);
            if !next_monotone_block(&mut block, o) {
                break;
            }
        }
        let mut idx: Vec<usize> = (0..cand.len()).collect();
        idx.sort_by(|&a, &b| {
            cand[a].time_fixed.partial_cmp(&cand[b].time_fixed).unwrap()
        });
        let mut stair: Vec<(f64, f64)> = Vec::new();
        let mut agg = Vec::new();
        let mut kept_counts = Vec::new();
        for &p in &idx {
            let pt = cand[p];
            if stair_dominates(&stair, pt.states, pt.gather_max) {
                continue;
            }
            stair_insert(&mut stair, pt.states, pt.gather_max);
            agg.push(pt);
            kept_counts
                .extend_from_slice(&cand_counts[p * o..(p + 1) * o]);
        }
        PointSet { agg, counts: kept_counts, o }
    }

    fn assert_sets_bit_identical(a: &PointSet, b: &PointSet, ctx: &str) {
        assert_eq!(a.len(), b.len(), "{ctx}: width mismatch");
        for p in 0..a.len() {
            assert_eq!(a.agg[p].time_fixed.to_bits(),
                       b.agg[p].time_fixed.to_bits(), "{ctx}: tf[{p}]");
            assert_eq!(a.agg[p].states.to_bits(),
                       b.agg[p].states.to_bits(), "{ctx}: st[{p}]");
            assert_eq!(a.agg[p].gather_max.to_bits(),
                       b.agg[p].gather_max.to_bits(), "{ctx}: g[{p}]");
        }
        assert_eq!(a.counts, b.counts, "{ctx}: blocks differ");
    }

    fn blocks_of(m: usize, o: usize) -> Vec<Vec<usize>> {
        let mut b = vec![0usize; m];
        let mut all = vec![b.clone()];
        while next_monotone_block(&mut b, o) {
            all.push(b.clone());
        }
        all
    }

    fn aggregates(block: &[usize], tf: &[f64], st: &[f64], g: &[f64])
                  -> FrontierPoint {
        let mut p = FrontierPoint { time_fixed: 0.0, states: 0.0,
                                    gather_max: 0.0 };
        for &c in block {
            p.time_fixed += tf[c];
            p.states += st[c];
            p.gather_max = p.gather_max.max(g[c]);
        }
        p
    }

    #[test]
    fn frontier_points_are_sorted_mutually_undominated_and_lead_with_zero() {
        let (tf, st, g) = menu();
        let cf = build_class(&tf, &st, &g, 5);
        let set = &cf.points;
        assert_eq!(cf.compositions, composition_count(5, 4));
        assert!(cf.peak_width >= set.len());
        assert!(set.len() <= cf.compositions);
        assert!(set.len() >= 1);
        // point 0 is the all-zeros (all-fastest, lex-least) block
        let mut b0 = vec![usize::MAX; 5];
        set.write_block(0, &mut b0);
        assert_eq!(b0, vec![0; 5]);
        // sorted by time; blocks monotone; mutually non-dominated under
        // the (time, lex) rule
        let mut blocks = Vec::new();
        for p in 0..set.len() {
            let mut b = vec![0usize; 5];
            set.write_block(p, &mut b);
            assert!(b.windows(2).all(|w| w[0] <= w[1]), "monotone {b:?}");
            let agg = aggregates(&b, &tf, &st, &g);
            assert_eq!(agg.time_fixed.to_bits(),
                       set.agg[p].time_fixed.to_bits());
            assert_eq!(agg.states.to_bits(), set.agg[p].states.to_bits());
            assert_eq!(agg.gather_max.to_bits(),
                       set.agg[p].gather_max.to_bits());
            blocks.push(b);
        }
        for w in set.agg.windows(2) {
            assert!(w[0].time_fixed <= w[1].time_fixed, "time-sorted");
        }
        for a in 0..set.len() {
            for b in 0..set.len() {
                if a == b {
                    continue;
                }
                let (pa, pb) = (set.agg[a], set.agg[b]);
                let dominates = pa.time_fixed <= pb.time_fixed
                    && pa.states <= pb.states
                    && pa.gather_max <= pb.gather_max
                    && (pa.time_fixed < pb.time_fixed
                        || lex_less(&blocks[a], &blocks[b]));
                assert!(!dominates,
                        "kept point {a} dominates kept point {b}");
            }
        }
    }

    /// The load-bearing batch-invariance property from the module docs,
    /// now exercised against the **incremental** build: every pruned
    /// composition is dominated by a kept one — same or less time,
    /// states, and *transient* — at every batch in `1..=64`, with the
    /// dominator strictly earlier in (time, lex). So per-level pruning
    /// can never change the (time, lex) optimum of any per-batch search.
    #[test]
    fn pruned_blocks_are_dominated_at_every_batch() {
        let (tf, st, g) = menu();
        let workspace = 8.0; // class-constant bytes/sample, like a table's
        let m = 5;
        let cf = build_class(&tf, &st, &g, m);
        let set = &cf.points;
        let kept: Vec<Vec<usize>> = (0..set.len())
            .map(|p| {
                let mut b = vec![0usize; m];
                set.write_block(p, &mut b);
                b
            })
            .collect();
        let mut pruned = 0;
        for block in blocks_of(m, tf.len()) {
            if kept.contains(&block) {
                continue;
            }
            pruned += 1;
            let pb = aggregates(&block, &tf, &st, &g);
            // transient computed per position, NOT via the gmax algebra,
            // so this test independently checks the factorization claim
            for b in 1usize..=64 {
                let bws = b as f64 * workspace;
                let trans_b: f64 = block
                    .iter()
                    .map(|&c| g[c] + bws)
                    .fold(0.0, f64::max);
                let found = (0..set.len()).any(|p| {
                    let pa = set.agg[p];
                    let trans_a: f64 = kept[p]
                        .iter()
                        .map(|&c| g[c] + bws)
                        .fold(0.0, f64::max);
                    pa.time_fixed <= pb.time_fixed
                        && pa.states <= pb.states
                        && trans_a <= trans_b
                        && (pa.time_fixed < pb.time_fixed
                            || lex_less(&kept[p], &block))
                });
                assert!(found,
                        "pruned block {block:?} undominated at batch {b}");
            }
        }
        assert!(pruned > 0, "menu must actually exercise the pruning");
    }

    /// The strong exactness statement from the module docs: the
    /// incremental kept set EQUALS the one-shot kept set — same points,
    /// same (tf, lex) order, same bits — across multiplicities.
    #[test]
    fn incremental_build_equals_one_shot_oracle() {
        let (tf, st, g) = menu();
        for m in [0usize, 1, 2, 3, 5, 8, 13, 24, 40] {
            let inc = build_class(&tf, &st, &g, m);
            let one = build_class_oneshot(&tf, &st, &g, m);
            assert_sets_bit_identical(&inc.points, &one, &format!("m={m}"));
        }
        // and on a 2-option paper-style menu (the 24L sweep shape)
        let snap = crate::cost::time::snap_time;
        let (tf2, st2, g2) =
            (vec![snap(1e-3), snap(3.5e-3)], vec![4000.0, 500.0],
             vec![0.0, 3500.0]);
        for m in [1usize, 7, 24, 96] {
            let inc = build_class(&tf2, &st2, &g2, m);
            let one = build_class_oneshot(&tf2, &st2, &g2, m);
            assert_sets_bit_identical(&inc.points, &one,
                                      &format!("o=2 m={m}"));
        }
    }

    /// A class above the old `2^18` one-shot ceiling prebuilds — no
    /// fallback exists any more — and still matches the oracle bit for
    /// bit (the oracle has no ceiling in test builds, only cost).
    #[test]
    fn above_old_ceiling_class_prebuilds_and_matches_oracle() {
        let (tf, st, g) = menu();
        let m = 120; // C(123, 3) = 302_621 > 2^18 = 262_144
        let cf = build_class(&tf, &st, &g, m);
        assert!(cf.compositions > 1 << 18,
                "fixture must exceed the old ceiling: {}", cf.compositions);
        assert!(cf.points.len() >= 1);
        assert!(cf.peak_width < 4096,
                "frontier width stays tiny: {}", cf.peak_width);
        let one = build_class_oneshot(&tf, &st, &g, m);
        assert_sets_bit_identical(&cf.points, &one, "m=120");
        // stats: every class reports its real kept count, none too wide
        let fr = Frontiers { classes: vec![cf] };
        let s = fr.stats();
        assert_eq!(s.too_wide, 0);
        assert_eq!(s.per_class[0],
                   MenuStats { raw: 302_621, kept: s.points });
        assert!(s.max_level_width >= s.points);
        assert!(s.describe().contains("fewer branches"),
                "reduction factor must always be reported: {}",
                s.describe());
    }

    /// `describe` keeps reporting the reduction factor even on stale
    /// deserialized stats that claim too-wide classes (satellite fix).
    #[test]
    fn describe_reports_reduction_even_with_stale_too_wide() {
        let s = FrontierStats { classes: 3, compositions: 1000,
                                points: 50, too_wide: 1,
                                max_level_width: 40,
                                per_class: Vec::new() };
        let d = s.describe();
        assert!(d.contains("fewer branches"), "{d}");
        assert!(d.contains("[1 too wide]"), "{d}");
    }

    #[test]
    fn frontier_search_matches_folded_on_a_small_model() {
        let m = build_gpt(&GptDims::uniform("t", 4000, 64, 6, 192, 4));
        let c = Cluster::rtx_titan(8, 8.0);
        let s = SearchConfig { granularities: vec![0, 2],
                               ..Default::default() };
        let p = Profiler::new(&m, &c, &s);
        let dp = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1).peak_mem;
        for frac in [0.35, 0.6, 0.9, 1.2] {
            let limit = dp * frac;
            let fr = search_with_budget(&p, limit, 1, u64::MAX);
            let fo = dfs::search_with_budget(&p, limit, 1, u64::MAX);
            match (fr, fo) {
                (None, None) => {}
                (Some((fc, fcost, fst)), Some((gc, gcost, gst))) => {
                    assert_eq!(fc, gc, "choice differs at frac {frac}");
                    assert_eq!(fcost.time.to_bits(), gcost.time.to_bits());
                    assert_eq!(fcost.peak_mem.to_bits(),
                               gcost.peak_mem.to_bits());
                    // the frontier never explores more than the fold
                    assert!(fst.nodes <= gst.nodes,
                            "frontier {} > folded {} nodes at frac {frac}",
                            fst.nodes, gst.nodes);
                }
                _ => panic!("feasibility disagreement at frac {frac}"),
            }
        }
    }

    #[test]
    fn report_counts_points() {
        let m = build_gpt(&GptDims::uniform("t", 3000, 64, 8, 128, 4));
        let c = Cluster::rtx_titan(8, 8.0);
        let s = SearchConfig { granularities: vec![0],
                               ..Default::default() };
        let p = Profiler::new(&m, &c, &s);
        let r = report(&p);
        assert_eq!(r.classes, p.op_classes().len());
        assert_eq!(r.per_class.len(), r.classes);
        assert!(r.points >= r.classes, "every class keeps >= 1 point");
        assert!(r.points <= r.compositions);
        assert_eq!(r.too_wide, 0);
        assert!(r.describe().contains("frontier points"));
    }
}
