//! Shared branch-and-bound machinery for the serial DFS ([`super::dfs`]),
//! the parallel planner ([`super::parallel`]), and the frontier engine
//! ([`super::frontier`], which adds a third descent mode to the same
//! [`Walker`]), including the **symmetry fold**: planning over operator
//! equivalence classes instead of individual operators.
//!
//! * [`Prefold`] — the batch-independent precomputation pass, built once
//!   per profiler and reused across every batch size of a sweep: the
//!   class partition (operators whose pruned [`crate::cost::OpCostTable`]s
//!   are byte-for-byte equal, via [`crate::cost::menu::table_key`]), the
//!   class-contiguous visit order, and the batch-independent suffix
//!   bounds.
//! * [`SearchSpace`] — the per-(memory limit, batch) view over a
//!   `Prefold`: flattened option menus with the batch's transients,
//!   transient suffix bounds, decision-independent base terms, and the
//!   greedy incumbent seed.
//! * [`Walker`] — one depth-first worker over a (possibly proper) subtree
//!   of the space, carrying its local incumbent and [`DfsStats`]. It has
//!   two descent modes over the *same* incumbent machinery: the classic
//!   per-operator descent, and the folded descent whose positions are
//!   `(class, multiplicity)` and whose branches assign counts per option.
//! * [`SharedBound`] — the global incumbent *time* shared across workers
//!   as an `AtomicU64` holding the f64 bit pattern (for non-negative
//!   floats the IEEE-754 bit pattern is monotone in the numeric value, so
//!   `fetch_min` over bits is `fetch_min` over seconds).
//!
//! # The symmetry fold
//!
//! GPT-style stacks are dominated by runs of identical layers whose cost
//! tables are equal, so the per-operator tree has `Π |menu|^L` leaves
//! while the *distinct-cost* plan space only has one point per count
//! vector: what matters is **how many** members of a class take each
//! option, never **which** members. The folded descent therefore branches
//! over count compositions — equivalently, over the monotone
//! (non-decreasing) option blocks that canonically represent them — and
//! a class with multiplicity `m` and menu size `o` contributes
//! `C(m+o-1, o-1)` branches (polynomial in `m`) instead of `o^m`.
//!
//! The fold is *exact*, and bit-identical to the unfolded engine, by
//! construction:
//!
//! 1. **Interchangeability is bitwise.** The Profiler snaps menu times to
//!    the power-of-two [`crate::cost::time::TIME_GRID`] and memory to
//!    whole bytes, so every sum the search forms is computed without f64
//!    rounding. Permuting the decisions of same-class operators changes
//!    no accumulated time, no state sum, and no transient max — not even
//!    in the last bit.
//! 2. **The visit order is class-contiguous.** Classes are laid out as
//!    contiguous runs (members of a class have equal menus, hence equal
//!    sort keys, so this only reorders within equal-key runs of the
//!    largest-parameter-mass-first order). A folded prefix of classes is
//!    therefore also a plain positional prefix, and the folded walker
//!    accumulates each block's options left-to-right through the same
//!    per-position arithmetic as the unfolded walker descending the same
//!    positions.
//! 3. **The canonical unfold is the lex-least representative.** Within a
//!    class, sorting the assigned options ascending over its positions is
//!    the lexicographically least member of the permutation orbit, and
//!    the orbit's members all tie exactly (point 1) — so the
//!    `(time, lex)`-minimum of the full space is always a monotone
//!    assignment, which is exactly the set of leaves the folded descent
//!    enumerates (in the same lex order the unfolded descent would meet
//!    them).
//!
//! # Exactness and determinism
//!
//! The walker optimizes the *lexicographic* objective
//! `(Σ T_i, choice-vector in visit order)`: among all minimum-time feasible
//! plans it returns the one whose choice vector is lexicographically
//! smallest in the search order. Three rules make that exact and — crucial
//! for the parallel planner — independent of worker timing:
//!
//! 1. Time pruning against the *shared* bound is strict (`lb > bound`), so
//!    another worker's equal-time incumbent can never hide a tied plan that
//!    this worker's subtree must still report.
//! 2. Time pruning against the *local* incumbent closes ties only when the
//!    lexicographically least completion of the prefix (`prefix + 0…0`;
//!    option 0 is the fastest entry of every menu) cannot beat the local
//!    incumbent's choice — so the tie-break never explodes the tree the
//!    way a fully strict bound would on symmetric (equal-layer) models.
//! 3. Leaf/fast-completion acceptance compares against the local incumbent
//!    only. The shared bound accelerates pruning of strictly worse
//!    subtrees; it never participates in a tie decision.
//!
//! Consequently every walker returns the exact `(time, lex)`-minimum of
//! {greedy seed} ∪ {feasible leaves of its subtree}, whatever the other
//! workers did, and the merge over subtrees is deterministic. The only
//! caveat is the node budget: when it expires (`DfsStats::complete ==
//! false`) the result is anytime-best-so-far and the visit order — hence
//! the result — may depend on shared-bound timing.

use super::dfs::DfsStats;
use crate::cost::Profiler;
use std::sync::atomic::{AtomicU64, Ordering};

/// One option's costs, flattened into search order with the transient
/// (gather + b·workspace) precomputed — the DFS inner loop touches only
/// this contiguous structure (perf pass: EXPERIMENTS.md §Perf).
#[derive(Clone, Copy)]
pub(crate) struct FlatOpt {
    pub time_fixed: f64,
    pub states: f64,
    pub transient: f64,
}

/// Batch-independent precomputation: the class partition, the
/// class-contiguous visit order, and every suffix bound that does not
/// depend on the batch size. Built once per profiler; the scheduler's
/// batch sweep shares one `Prefold` across all its workers and batch
/// sizes instead of rebuilding the fold for every `b`.
pub(crate) struct Prefold {
    /// Op evaluation order (largest params first, then regrouped so each
    /// equivalence class is a contiguous run), as profiler indices.
    pub order: Vec<usize>,
    /// Class boundaries over `order`: class `k` occupies positions
    /// `class_start[k]..class_start[k+1]`; `class_start[n_classes] == n`.
    pub class_start: Vec<usize>,
    /// Per ordered position `i`: min over options of `time_fixed` summed
    /// over positions `>= i` (batch-independent).
    pub suffix_min_time: Vec<f64>,
    /// Per ordered position `i`: min over options of `states` summed over
    /// positions `>= i` (batch-independent).
    pub suffix_min_states: Vec<f64>,
    /// Fast-completion (option 0 = fastest) states suffix sums.
    pub suffix_opt0_states: Vec<f64>,
}

impl Prefold {
    pub fn new(profiler: &Profiler) -> Prefold {
        let n = profiler.n_ops();

        // Visit ops with the largest parameter mass first: their decisions
        // move the most memory/time, so bounds tighten early. The sort is
        // stable (ties keep profiler order), so the order is
        // deterministic.
        let mut base: Vec<usize> = (0..n).collect();
        base.sort_by(|&x, &y| {
            let sx = profiler.tables[x].fastest().states;
            let sy = profiler.tables[y].fastest().states;
            sy.partial_cmp(&sx).unwrap()
        });

        // Regroup so every equivalence class is contiguous, keyed on the
        // canonical table key. Same-class ops have identical menus —
        // identical sort keys — so members only move within equal-key
        // runs: the "heaviest first" shape of the order is preserved, and
        // a folded class prefix is also a positional prefix.
        let class_id = profiler.class_ids();
        let n_classes = class_id.iter().copied().max().map_or(0, |m| m + 1);
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); n_classes];
        for &op in &base {
            members[class_id[op]].push(op);
        }
        let mut order = Vec::with_capacity(n);
        let mut class_start = Vec::with_capacity(n_classes + 1);
        let mut placed = vec![false; n_classes];
        for &op in &base {
            let c = class_id[op];
            if !placed[c] {
                placed[c] = true;
                class_start.push(order.len());
                order.extend_from_slice(&members[c]);
            }
        }
        class_start.push(n);
        debug_assert_eq!(order.len(), n);

        let mut suffix_min_time = vec![0.0; n + 1];
        let mut suffix_min_states = vec![0.0; n + 1];
        let mut suffix_opt0_states = vec![0.0; n + 1];
        for i in (0..n).rev() {
            let t = &profiler.tables[order[i]];
            suffix_min_time[i] = suffix_min_time[i + 1] + t.min_time_fixed();
            suffix_min_states[i] = suffix_min_states[i + 1] + t.min_states;
            suffix_opt0_states[i] =
                suffix_opt0_states[i + 1] + t.fastest().states;
        }

        Prefold {
            order,
            class_start,
            suffix_min_time,
            suffix_min_states,
            suffix_opt0_states,
        }
    }

    pub fn n(&self) -> usize {
        self.order.len()
    }

    pub fn n_classes(&self) -> usize {
        self.class_start.len() - 1
    }

    /// Members of class `k` (count of positions it occupies).
    pub fn multiplicity(&self, k: usize) -> usize {
        self.class_start[k + 1] - self.class_start[k]
    }

    /// Map a search-order choice vector back to profiler order.
    pub fn unpermute(&self, ordered: &[usize]) -> Vec<usize> {
        let mut choice = vec![0usize; ordered.len()];
        for (pos, &op_idx) in self.order.iter().enumerate() {
            choice[op_idx] = ordered[pos];
        }
        choice
    }
}

/// The decision-independent search-arithmetic time term, shared by every
/// engine **and** the exhaustive ground truth — snapped to the grid so
/// `base + grid time_fixed sums` are exact under any accumulation order
/// (see [`crate::cost::time::TIME_GRID`]). Their bit-for-bit agreement is
/// load-bearing for the `(total, lex)` tie-break, so there is exactly one
/// copy of this expression.
pub(crate) fn base_time(profiler: &Profiler, b: usize) -> f64 {
    let bf = b as f64;
    let eff = crate::cost::time::batch_efficiency(b);
    let compute: f64 = profiler.tables.iter().map(|t| bf * t.gamma).sum();
    crate::cost::time::snap_time(compute / eff)
}

/// The per-(memory limit, batch) search problem over a [`Prefold`]:
/// everything descend needs, none of it mutable. Shared by reference
/// across workers.
pub(crate) struct SearchSpace<'p> {
    pub pre: &'p Prefold,
    /// Per ordered position: the option menu, flattened with this batch's
    /// transients.
    pub flat: Vec<Vec<FlatOpt>>,
    /// Per class: this batch's `b · workspace_per_sample` (class-constant
    /// because equal tables define the class). A composition's transient
    /// is `gather_max + class_bws[k]` — the frontier engine's per-batch
    /// term (see `super::frontier`).
    pub class_bws: Vec<f64>,
    pub mem_limit: f64,
    /// Max over remaining ops of their minimum transient (admissible lower
    /// bound on the final transient max).
    pub suffix_min_trans: Vec<f64>,
    /// Fast-completion transient suffix max.
    pub suffix_opt0_trans: Vec<f64>,
    // decision-independent totals
    pub base_time: f64,
    pub base_act: f64,
    /// Greedy incumbent: (time, choice in *search order*). Feasible seed
    /// for every walker; `None` when even the memory-minimal plan fails.
    pub seed: Option<(f64, Vec<usize>)>,
}

impl<'p> SearchSpace<'p> {
    /// The per-batch pass: transients, base terms, and the greedy seed.
    /// Everything else comes from the shared `Prefold`.
    pub fn for_batch(pre: &'p Prefold, profiler: &Profiler, mem_limit: f64,
                     b: usize) -> SearchSpace<'p> {
        let n = pre.n();
        let bf = b as f64;

        // Seed the incumbent with the greedy plan: a feasible solution
        // before descent makes the time-pruning bound bite from node one
        // and gives the budget-expired case a quality floor.
        let seed = super::greedy::search(profiler, mem_limit, b);

        let mut suffix_min_trans = vec![0.0f64; n + 1];
        let mut suffix_opt0_trans = vec![0.0f64; n + 1];
        for i in (0..n).rev() {
            let t = &profiler.tables[pre.order[i]];
            let bws = bf * t.workspace_per_sample;
            suffix_min_trans[i] =
                suffix_min_trans[i + 1].max(t.min_gather + bws);
            suffix_opt0_trans[i] =
                suffix_opt0_trans[i + 1].max(t.fastest().gather + bws);
        }
        let base_time = base_time(profiler, b);
        let base_act: f64 =
            profiler.tables.iter().map(|t| bf * t.act_per_sample).sum();

        let class_bws: Vec<f64> = (0..pre.n_classes())
            .map(|k| {
                let op = pre.order[pre.class_start[k]];
                bf * profiler.tables[op].workspace_per_sample
            })
            .collect();

        let flat: Vec<Vec<FlatOpt>> = pre
            .order
            .iter()
            .map(|&op| {
                profiler.tables[op]
                    .options
                    .iter()
                    .map(|o| FlatOpt {
                        time_fixed: o.time_fixed(),
                        states: o.states,
                        transient: o.gather
                            + bf * profiler.tables[op].workspace_per_sample,
                    })
                    .collect()
            })
            .collect();

        let seed = seed.map(|(choice, _cost)| {
            // Permute the greedy choice into search order and price it in
            // *search arithmetic* (base_time + the same grid-exact
            // time_fixed sum a descent accumulates) — NOT evaluate()'s
            // time, whose unsnapped compute term differs from base_time by
            // up to half a grid step. Pricing the seed like any other leaf
            // keeps time ties against the incumbent exact, so the strict
            // `lb > best_time` prune can never hide a plan that ties (or
            // marginally beats) the greedy seed.
            let ordered: Vec<usize> =
                pre.order.iter().map(|&op| choice[op]).collect();
            let mut time_fixed = 0.0;
            for (i, &c) in ordered.iter().enumerate() {
                time_fixed += flat[i][c].time_fixed;
            }
            (base_time + time_fixed, ordered)
        });

        SearchSpace {
            pre,
            flat,
            class_bws,
            mem_limit,
            suffix_min_trans,
            suffix_opt0_trans,
            base_time,
            base_act,
            seed,
        }
    }

    pub fn n(&self) -> usize {
        self.pre.n()
    }

    /// Map a search-order choice vector back to profiler order.
    pub fn unpermute(&self, ordered: &[usize]) -> Vec<usize> {
        self.pre.unpermute(ordered)
    }

    /// Offer a full profiler-order choice vector — the plan service's
    /// **warm start** from a cached neighbor query — as an additional
    /// incumbent seed. Installed only when it is memory-feasible at this
    /// batch and `(time, lex)`-better than the greedy seed, and priced in
    /// the same search arithmetic as any leaf (`base_time` + the grid
    /// `time_fixed` sum in visit order), exactly like the greedy seed —
    /// so exact ties against the incumbent survive the strict `lb` prune
    /// and the search result stays **bit-identical** to a cold search:
    /// the incumbent only tightens bounds (see `service::warm` for the
    /// argument, `rust/tests/plan_service.rs` for the property tests).
    ///
    /// Returns true when the seed was feasible (whether or not it beat
    /// the greedy seed; either way it cannot loosen anything). Rejects —
    /// rather than panics on — length or menu-index mismatches, so a
    /// stale cache entry can never poison a search.
    pub fn offer_warm(&mut self, choice: &[usize]) -> bool {
        if choice.len() != self.n() {
            return false;
        }
        let mut time_fixed = 0.0;
        let mut states = 0.0;
        let mut trans_max = 0.0f64;
        let mut ordered = Vec::with_capacity(self.n());
        for (i, &op) in self.pre.order.iter().enumerate() {
            let c = choice[op];
            let Some(opt) = self.flat[i].get(c) else { return false };
            time_fixed += opt.time_fixed;
            states += opt.states;
            trans_max = trans_max.max(opt.transient);
            ordered.push(c);
        }
        if states + self.base_act + trans_max > self.mem_limit {
            return false;
        }
        let total = self.base_time + time_fixed;
        let better = match &self.seed {
            None => true,
            Some((t, c)) => {
                total < *t || (total == *t && lex_less(&ordered, c))
            }
        };
        if better {
            self.seed = Some((total, ordered));
        }
        true
    }
}

/// `a` strictly precedes `b` lexicographically. Both vectors are full
/// search-order choice vectors of equal length.
pub(crate) fn lex_less(a: &[usize], b: &[usize]) -> bool {
    for (x, y) in a.iter().zip(b) {
        if x != y {
            return x < y;
        }
    }
    false
}

/// Advance `block` to the next monotone non-decreasing option block over a
/// menu of size `o`, in lexicographic order (`[0,0,…,0]` first). Returns
/// false when exhausted. These blocks are exactly the canonical
/// representatives of the count compositions: one per multiset of options.
pub(crate) fn next_monotone_block(block: &mut [usize], o: usize) -> bool {
    for p in (0..block.len()).rev() {
        if block[p] + 1 < o {
            let v = block[p] + 1;
            for slot in block[p..].iter_mut() {
                *slot = v;
            }
            return true;
        }
    }
    false
}

/// Number of monotone blocks (count compositions) of length `m` over `o`
/// options: `C(m+o-1, o-1)`, saturating at `usize::MAX`.
pub(crate) fn composition_count(m: usize, o: usize) -> usize {
    if o == 0 {
        return if m == 0 { 1 } else { 0 };
    }
    // multiplicative binomial with early saturation
    let mut num: u128 = 1;
    let k = (o - 1).min(m);
    for j in 1..=k as u128 {
        num = num.saturating_mul((m + o - 1) as u128 - k as u128 + j);
        num /= j; // exact: C(n, j) is an integer at every step
        if num > usize::MAX as u128 {
            return usize::MAX;
        }
    }
    num as usize
}

/// Global incumbent time shared across workers: f64 bits in an atomic,
/// monotonically decreasing under `fetch_min` (valid because iteration
/// times are non-negative, where the IEEE bit pattern orders like the
/// value).
pub(crate) struct SharedBound(AtomicU64);

impl SharedBound {
    pub fn new(time: f64) -> SharedBound {
        SharedBound(AtomicU64::new(time.to_bits()))
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    pub fn publish(&self, time: f64) {
        self.0.fetch_min(time.to_bits(), Ordering::Relaxed);
    }
}

/// One depth-first worker over a subtree of the space. Local incumbent
/// starts at the greedy seed; the optional [`SharedBound`] tightens time
/// pruning across workers without ever deciding a tie. The same incumbent
/// machinery serves the per-operator, the folded, and the frontier
/// descent (the last lives in `super::frontier`).
pub(crate) struct Walker<'a> {
    pub(crate) space: &'a SearchSpace<'a>,
    shared: Option<&'a SharedBound>,
    /// Per-class composition frontiers; required by the frontier descent
    /// only (`None` for the per-operator and folded engines).
    pub(crate) frontier: Option<&'a super::frontier::Frontiers>,
    /// Local incumbent time (search arithmetic for plans found here; the
    /// greedy seed's evaluated time before any improvement).
    pub best_time: f64,
    /// Local incumbent choice in search order.
    pub best_choice: Option<Vec<usize>>,
    pub stats: DfsStats,
    pub(crate) budget: u64,
    pub(crate) prefix: Vec<usize>,
    /// Convergence-timeline log (`progress::Recorder`), off unless a
    /// traced entry point armed it. Write-only from the search's point
    /// of view: `try_accept` appends on an accepted improvement and
    /// nothing reads it back, so arming cannot change any decision
    /// (pinned traced == untraced in `planner_properties.rs`).
    pub(crate) recorder: super::progress::Recorder,
    /// Per-class monotone-block scratch, preallocated so the folded
    /// descent's hot loop never touches the heap (taken/restored around
    /// the recursion with `mem::take`). Only `descend_folded` uses it:
    /// the frontier descent branches over prebuilt points — since the
    /// incremental Minkowski-sum build, every class prebuilds and the
    /// frontier walker has no in-place enumeration branch at all.
    pub(crate) blocks: Vec<Vec<usize>>,
}

impl<'a> Walker<'a> {
    pub fn new(space: &'a SearchSpace<'a>,
               frontier: Option<&'a super::frontier::Frontiers>,
               shared: Option<&'a SharedBound>, budget: u64) -> Walker<'a> {
        let (best_time, best_choice) = match &space.seed {
            Some((t, c)) => (*t, Some(c.clone())),
            None => (f64::INFINITY, None),
        };
        let blocks = (0..space.pre.n_classes())
            .map(|k| Vec::with_capacity(space.pre.multiplicity(k)))
            .collect();
        Walker {
            space,
            shared,
            frontier,
            best_time,
            best_choice,
            stats: DfsStats::default(),
            budget,
            prefix: vec![0usize; space.n()],
            recorder: super::progress::Recorder::off(),
            blocks,
        }
    }

    /// Search the per-operator subtree rooted at `prefix[..depth]` given
    /// the prefix's accumulated time/states/transient (left-to-right, so
    /// the arithmetic is bit-identical to a serial descent through the
    /// same prefix).
    pub fn run(&mut self, depth: usize, prefix: &[usize], time_fixed: f64,
               states: f64, trans_max: f64) {
        self.prefix[..depth].copy_from_slice(prefix);
        self.descend(depth, time_fixed, states, trans_max);
        self.stats.complete = self.stats.nodes < self.budget;
    }

    /// Search the whole per-operator space.
    pub fn run_root(&mut self) {
        self.run(0, &[], 0.0, 0.0, 0.0);
    }

    /// Search the folded subtree rooted at class `class_depth`, with the
    /// first `class_start[class_depth]` positions fixed to `prefix` (their
    /// accumulated sums passed alongside, as in [`Walker::run`]).
    pub fn run_folded(&mut self, class_depth: usize, prefix: &[usize],
                      time_fixed: f64, states: f64, trans_max: f64) {
        self.prefix[..prefix.len()].copy_from_slice(prefix);
        self.descend_folded(class_depth, time_fixed, states, trans_max);
        self.stats.complete = self.stats.nodes < self.budget;
    }

    /// Search the whole folded space.
    pub fn run_root_folded(&mut self) {
        self.run_folded(0, &[], 0.0, 0.0, 0.0);
    }

    /// Bound checks shared by both descents at ordered position `i`:
    /// returns false when the subtree is pruned. The expressions — and so
    /// the f64 bits — are identical whichever descent evaluates them.
    #[inline]
    pub(crate) fn open_subtree(&mut self, i: usize, time_fixed: f64,
                               states: f64, trans_max: f64) -> bool {
        let sp = self.space;
        // ---- time pruning (paper's incumbent rule + admissible suffix
        // bound). Strictly worse than any incumbent is dead; tied with the
        // *local* incumbent is dead unless the lex-least completion of this
        // prefix would still win the tie-break. Ties against the shared
        // bound are explored: the merge tie-breaks deterministically.
        let lb = sp.base_time + time_fixed + sp.pre.suffix_min_time[i];
        let shared_bound =
            self.shared.map(|s| s.get()).unwrap_or(f64::INFINITY);
        if lb > self.best_time.min(shared_bound)
            || (lb == self.best_time && !self.prefix_zero_beats_best(i))
        {
            self.stats.pruned_time += 1;
            return false;
        }
        // ---- memory pruning (paper's limit rule + admissible suffix
        // bound); decision-independent, hence deterministic.
        let min_possible_peak = states
            + sp.pre.suffix_min_states[i]
            + sp.base_act
            + trans_max.max(sp.suffix_min_trans[i]);
        if min_possible_peak > sp.mem_limit {
            self.stats.pruned_mem += 1;
            return false;
        }
        true
    }

    /// Fast completion at position `i`: the all-fastest suffix is both
    /// time-minimal and lex-minimal among completions of this prefix; if
    /// it fits, it is the subtree's `(time, lex)` optimum and the subtree
    /// closes. Returns true when it fired (subtree done).
    #[inline]
    pub(crate) fn try_fast_completion(&mut self, i: usize, time_fixed: f64,
                                      states: f64, trans_max: f64) -> bool {
        let sp = self.space;
        let opt0_peak = states
            + sp.pre.suffix_opt0_states[i]
            + sp.base_act
            + trans_max.max(sp.suffix_opt0_trans[i]);
        if opt0_peak > sp.mem_limit {
            return false;
        }
        for slot in self.prefix[i..].iter_mut() {
            *slot = 0;
        }
        let total = sp.base_time + time_fixed + sp.pre.suffix_min_time[i];
        if self.try_accept(total) {
            self.stats.fast_completions += 1;
        }
        true
    }

    /// Per-operator descent from ordered position `i`.
    fn descend(&mut self, i: usize, time_fixed: f64, states: f64,
               trans_max: f64) {
        if self.stats.nodes >= self.budget {
            return; // budget expired: keep the incumbent (anytime result)
        }
        self.stats.nodes += 1;
        if !self.open_subtree(i, time_fixed, states, trans_max) {
            return;
        }
        if i == self.space.n() {
            // feasibility is exact here (the suffix terms above are zero)
            self.try_accept(self.space.base_time + time_fixed);
            return;
        }
        if self.try_fast_completion(i, time_fixed, states, trans_max) {
            return;
        }
        let sp = self.space;
        for (c, opt) in sp.flat[i].iter().enumerate() {
            self.prefix[i] = c;
            self.descend(i + 1, time_fixed + opt.time_fixed,
                         states + opt.states, trans_max.max(opt.transient));
        }
    }

    /// Folded descent from class `k`. One node per count composition
    /// instead of one per per-op branch: the subtree for class `k`
    /// enumerates its monotone option blocks in lex order (exactly the
    /// order the per-operator descent meets their canonical
    /// representatives), accumulating each block's costs through the same
    /// per-position left-to-right arithmetic — so accepted totals and all
    /// bound expressions are bit-identical to the unfolded engine's.
    fn descend_folded(&mut self, k: usize, time_fixed: f64, states: f64,
                      trans_max: f64) {
        if self.stats.nodes >= self.budget {
            return; // budget expired: keep the incumbent (anytime result)
        }
        self.stats.nodes += 1;
        let i = self.space.pre.class_start[k];
        if !self.open_subtree(i, time_fixed, states, trans_max) {
            return;
        }
        if i == self.space.n() {
            self.try_accept(self.space.base_time + time_fixed);
            return;
        }
        if self.try_fast_completion(i, time_fixed, states, trans_max) {
            return;
        }
        let end = self.space.pre.class_start[k + 1];
        let o = self.space.flat[i].len();
        let mut block = std::mem::take(&mut self.blocks[k]);
        block.clear();
        block.resize(end - i, 0);
        loop {
            let mut tf = time_fixed;
            let mut st = states;
            let mut tm = trans_max;
            for (j, &c) in block.iter().enumerate() {
                let opt = self.space.flat[i + j][c];
                tf += opt.time_fixed;
                st += opt.states;
                tm = tm.max(opt.transient);
                self.prefix[i + j] = c;
            }
            self.descend_folded(k + 1, tf, st, tm);
            // once the budget expires, stop enumerating compositions too —
            // a wide class can hold billions of them
            if self.stats.nodes >= self.budget
                || !next_monotone_block(&mut block, o)
            {
                break;
            }
        }
        self.blocks[k] = block;
    }

    /// Would `prefix[..i]` completed with all zeros beat the local
    /// incumbent's choice lexicographically? (Trivially yes when there is
    /// no local incumbent.)
    fn prefix_zero_beats_best(&self, i: usize) -> bool {
        let Some(best) = &self.best_choice else { return true };
        for j in 0..i {
            if self.prefix[j] != best[j] {
                return self.prefix[j] < best[j];
            }
        }
        best[i..].iter().any(|&c| c > 0)
    }

    /// Offer `self.prefix` at time `total` to the local incumbent; publish
    /// to the shared bound on improvement. Returns true when accepted.
    pub(crate) fn try_accept(&mut self, total: f64) -> bool {
        let better = total < self.best_time
            || (total == self.best_time
                && match &self.best_choice {
                    None => true,
                    Some(b) => lex_less(&self.prefix, b),
                });
        if better {
            self.best_time = total;
            self.best_choice = Some(self.prefix.clone());
            self.recorder.record(self.stats.nodes, total.to_bits(),
                                 super::progress::ImprovementSource::Descent);
            if let Some(s) = self.shared {
                s.publish(total);
            }
        }
        better
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Cluster, SearchConfig};
    use crate::model::{GptDims, build_gpt};

    #[test]
    fn monotone_blocks_enumerate_all_compositions_in_lex_order() {
        let (m, o) = (3usize, 3usize);
        let mut block = vec![0usize; m];
        let mut seen = vec![block.clone()];
        while next_monotone_block(&mut block, o) {
            seen.push(block.clone());
        }
        assert_eq!(seen.len(), composition_count(m, o)); // C(5,2) = 10
        for w in seen.windows(2) {
            assert!(lex_less(&w[0], &w[1]), "{:?} !< {:?}", w[0], w[1]);
        }
        for b in &seen {
            assert!(b.windows(2).all(|w| w[0] <= w[1]), "monotone {b:?}");
        }
    }

    #[test]
    fn composition_counts() {
        assert_eq!(composition_count(1, 4), 4);
        assert_eq!(composition_count(24, 1), 1);
        assert_eq!(composition_count(24, 2), 25);
        assert_eq!(composition_count(2, 3), 6);
        assert_eq!(composition_count(0, 3), 1);
    }

    #[test]
    fn prefold_order_is_class_contiguous_and_heavy_first() {
        let m = build_gpt(&GptDims::uniform("t", 3000, 64, 6, 128, 4));
        let c = Cluster::rtx_titan(8, 8.0);
        let s = SearchConfig { granularities: vec![0, 2],
                               ..Default::default() };
        let p = crate::cost::Profiler::new(&m, &c, &s);
        let pre = Prefold::new(&p);
        assert_eq!(pre.n(), p.n_ops());
        assert_eq!(*pre.class_start.last().unwrap(), p.n_ops());
        assert_eq!(pre.n_classes(), p.op_classes().len());
        let ids = p.class_ids();
        let mult_total: usize =
            (0..pre.n_classes()).map(|k| pre.multiplicity(k)).sum();
        assert_eq!(mult_total, p.n_ops());
        // contiguity: each class run holds exactly one class id
        for k in 0..pre.n_classes() {
            let run = &pre.order[pre.class_start[k]..pre.class_start[k + 1]];
            assert!(run.iter().all(|&op| ids[op] == ids[run[0]]));
        }
        // heaviest-first is preserved across class boundaries: the first
        // member of each class is non-increasing in fastest-option states
        let firsts: Vec<f64> = (0..pre.n_classes())
            .map(|k| p.tables[pre.order[pre.class_start[k]]].fastest().states)
            .collect();
        for w in firsts.windows(2) {
            assert!(w[0] >= w[1], "class order not heavy-first: {w:?}");
        }
    }
}
