//! Shared branch-and-bound machinery for the serial DFS ([`super::dfs`])
//! and the parallel planner ([`super::parallel`]).
//!
//! The two planners explore the same tree with the same bounds; this module
//! owns the pieces they share so they cannot drift apart:
//!
//! * [`SearchSpace`] — the precomputation pass: operator visit order
//!   (largest parameter mass first), flattened per-position option menus,
//!   admissible suffix bounds, decision-independent base terms, and the
//!   greedy incumbent seed.
//! * [`Walker`] — one depth-first worker over a (possibly proper) subtree
//!   of the space, carrying its local incumbent and [`DfsStats`].
//! * [`SharedBound`] — the global incumbent *time* shared across workers as
//!   an `AtomicU64` holding the f64 bit pattern (for non-negative floats
//!   the IEEE-754 bit pattern is monotone in the numeric value, so
//!   `fetch_min` over bits is `fetch_min` over seconds).
//!
//! # Exactness and determinism
//!
//! The walker optimizes the *lexicographic* objective
//! `(Σ T_i, choice-vector in visit order)`: among all minimum-time feasible
//! plans it returns the one whose choice vector is lexicographically
//! smallest in the search order. Three rules make that exact and — crucial
//! for the parallel planner — independent of worker timing:
//!
//! 1. Time pruning against the *shared* bound is strict (`lb > bound`), so
//!    another worker's equal-time incumbent can never hide a tied plan that
//!    this worker's subtree must still report.
//! 2. Time pruning against the *local* incumbent closes ties only when the
//!    lexicographically least completion of the prefix (`prefix + 0…0`;
//!    option 0 is the fastest entry of every menu) cannot beat the local
//!    incumbent's choice — so the tie-break never explodes the tree the
//!    way a fully strict bound would on symmetric (equal-layer) models.
//! 3. Leaf/fast-completion acceptance compares against the local incumbent
//!    only. The shared bound accelerates pruning of strictly worse
//!    subtrees; it never participates in a tie decision.
//!
//! Consequently every walker returns the exact `(time, lex)`-minimum of
//! {greedy seed} ∪ {feasible leaves of its subtree}, whatever the other
//! workers did, and the merge over subtrees is deterministic. The only
//! caveat is the node budget: when it expires (`DfsStats::complete ==
//! false`) the result is anytime-best-so-far and the visit order — hence
//! the result — may depend on shared-bound timing.

use super::dfs::DfsStats;
use crate::cost::Profiler;
use std::sync::atomic::{AtomicU64, Ordering};

/// One option's costs, flattened into search order with the transient
/// (gather + b·workspace) precomputed — the DFS inner loop touches only
/// this contiguous structure (perf pass: EXPERIMENTS.md §Perf).
#[derive(Clone, Copy)]
pub(crate) struct FlatOpt {
    pub time_fixed: f64,
    pub states: f64,
    pub transient: f64,
}

/// The precomputed search problem: everything descend needs, none of it
/// mutable. Built once per (profiler, memory limit, batch) triple and
/// shared by reference across workers.
pub(crate) struct SearchSpace {
    /// op evaluation order (largest params first), as profiler indices
    pub order: Vec<usize>,
    /// per ordered position: the option menu, flattened
    pub flat: Vec<Vec<FlatOpt>>,
    pub mem_limit: f64,
    // per ordered position i: min over options of time_fixed / states for
    // ops at positions >= i
    pub suffix_min_time: Vec<f64>,
    pub suffix_min_states: Vec<f64>,
    /// max over remaining ops of their minimum transient (admissible lower
    /// bound on the final transient max)
    pub suffix_min_trans: Vec<f64>,
    // fast-completion (option 0 = fastest) suffix sums
    pub suffix_opt0_states: Vec<f64>,
    pub suffix_opt0_trans: Vec<f64>,
    // decision-independent totals
    pub base_time: f64,
    pub base_act: f64,
    /// Greedy incumbent: (time, choice in *search order*). Feasible seed
    /// for every walker; `None` when even the memory-minimal plan fails.
    pub seed: Option<(f64, Vec<usize>)>,
}

impl SearchSpace {
    pub fn new(profiler: &Profiler, mem_limit: f64, b: usize) -> SearchSpace {
        let n = profiler.n_ops();
        let bf = b as f64;

        // Seed the incumbent with the greedy plan: a feasible solution
        // before descent makes the time-pruning bound bite from node one
        // and gives the budget-expired case a quality floor.
        let seed = super::greedy::search(profiler, mem_limit, b);

        // Visit ops with the largest parameter mass first: their decisions
        // move the most memory/time, so bounds tighten early. The sort is
        // stable (ties keep profiler order), so the order — and with it the
        // planner's canonical tie-break — is deterministic.
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&x, &y| {
            let sx = profiler.tables[x].fastest().states;
            let sy = profiler.tables[y].fastest().states;
            sy.partial_cmp(&sx).unwrap()
        });

        let mut suffix_min_time = vec![0.0; n + 1];
        let mut suffix_min_states = vec![0.0; n + 1];
        let mut suffix_min_trans = vec![0.0f64; n + 1];
        let mut suffix_opt0_states = vec![0.0; n + 1];
        let mut suffix_opt0_trans = vec![0.0f64; n + 1];
        for i in (0..n).rev() {
            let t = &profiler.tables[order[i]];
            let min_time = t.min_time_fixed();
            let min_states = t.min_states();
            let min_trans = t
                .options
                .iter()
                .map(|o| o.gather)
                .fold(f64::INFINITY, f64::min)
                + bf * t.workspace_per_sample;
            suffix_min_time[i] = suffix_min_time[i + 1] + min_time;
            suffix_min_states[i] = suffix_min_states[i + 1] + min_states;
            suffix_min_trans[i] = suffix_min_trans[i + 1].max(min_trans);
            suffix_opt0_states[i] =
                suffix_opt0_states[i + 1] + t.fastest().states;
            suffix_opt0_trans[i] = suffix_opt0_trans[i + 1]
                .max(t.fastest().gather + bf * t.workspace_per_sample);
        }
        let eff = crate::cost::time::batch_efficiency(b);
        let base_time: f64 =
            profiler.tables.iter().map(|t| bf * t.gamma / eff).sum();
        let base_act: f64 =
            profiler.tables.iter().map(|t| bf * t.act_per_sample).sum();

        let seed = seed.map(|(choice, cost)| {
            // permute the greedy choice into search order
            let ordered: Vec<usize> =
                order.iter().map(|&op| choice[op]).collect();
            (cost.time, ordered)
        });

        let flat = order
            .iter()
            .map(|&op| {
                profiler.tables[op]
                    .options
                    .iter()
                    .map(|o| FlatOpt {
                        time_fixed: o.time_fixed(),
                        states: o.states,
                        transient: o.gather
                            + bf * profiler.tables[op].workspace_per_sample,
                    })
                    .collect()
            })
            .collect();

        SearchSpace {
            order,
            flat,
            mem_limit,
            suffix_min_time,
            suffix_min_states,
            suffix_min_trans,
            suffix_opt0_states,
            suffix_opt0_trans,
            base_time,
            base_act,
            seed,
        }
    }

    pub fn n(&self) -> usize {
        self.order.len()
    }

    /// Map a search-order choice vector back to profiler order.
    pub fn unpermute(&self, ordered: &[usize]) -> Vec<usize> {
        let mut choice = vec![0usize; ordered.len()];
        for (pos, &op_idx) in self.order.iter().enumerate() {
            choice[op_idx] = ordered[pos];
        }
        choice
    }
}

/// `a` strictly precedes `b` lexicographically. Both vectors are full
/// search-order choice vectors of equal length.
pub(crate) fn lex_less(a: &[usize], b: &[usize]) -> bool {
    for (x, y) in a.iter().zip(b) {
        if x != y {
            return x < y;
        }
    }
    false
}

/// Global incumbent time shared across workers: f64 bits in an atomic,
/// monotonically decreasing under `fetch_min` (valid because iteration
/// times are non-negative, where the IEEE bit pattern orders like the
/// value).
pub(crate) struct SharedBound(AtomicU64);

impl SharedBound {
    pub fn new(time: f64) -> SharedBound {
        SharedBound(AtomicU64::new(time.to_bits()))
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    pub fn publish(&self, time: f64) {
        self.0.fetch_min(time.to_bits(), Ordering::Relaxed);
    }
}

/// One depth-first worker over a subtree of the space. Local incumbent
/// starts at the greedy seed; the optional [`SharedBound`] tightens time
/// pruning across workers without ever deciding a tie.
pub(crate) struct Walker<'a> {
    space: &'a SearchSpace,
    shared: Option<&'a SharedBound>,
    /// Local incumbent time (search arithmetic for plans found here; the
    /// greedy seed's evaluated time before any improvement).
    pub best_time: f64,
    /// Local incumbent choice in search order.
    pub best_choice: Option<Vec<usize>>,
    pub stats: DfsStats,
    budget: u64,
    prefix: Vec<usize>,
}

impl<'a> Walker<'a> {
    pub fn new(space: &'a SearchSpace, shared: Option<&'a SharedBound>,
               budget: u64) -> Walker<'a> {
        let (best_time, best_choice) = match &space.seed {
            Some((t, c)) => (*t, Some(c.clone())),
            None => (f64::INFINITY, None),
        };
        Walker {
            space,
            shared,
            best_time,
            best_choice,
            stats: DfsStats::default(),
            budget,
            prefix: vec![0usize; space.n()],
        }
    }

    /// Search the subtree rooted at `prefix[..depth]` given the prefix's
    /// accumulated time/states/transient (left-to-right, so the arithmetic
    /// is bit-identical to a serial descent through the same prefix).
    pub fn run(&mut self, depth: usize, prefix: &[usize], time_fixed: f64,
               states: f64, trans_max: f64) {
        self.prefix[..depth].copy_from_slice(prefix);
        self.descend(depth, time_fixed, states, trans_max);
        self.stats.complete = self.stats.nodes < self.budget;
    }

    /// Search the whole space (the serial planner's entry point).
    pub fn run_root(&mut self) {
        self.run(0, &[], 0.0, 0.0, 0.0);
    }

    fn descend(&mut self, i: usize, time_fixed: f64, states: f64,
               trans_max: f64) {
        if self.stats.nodes >= self.budget {
            return; // budget expired: keep the incumbent (anytime result)
        }
        self.stats.nodes += 1;
        let sp = self.space;
        let n = sp.order.len();

        // ---- time pruning (paper's incumbent rule + admissible suffix
        // bound). Strictly worse than any incumbent is dead; tied with the
        // *local* incumbent is dead unless the lex-least completion of this
        // prefix would still win the tie-break. Ties against the shared
        // bound are explored: the merge tie-breaks deterministically.
        let lb = sp.base_time + time_fixed + sp.suffix_min_time[i];
        let shared_bound =
            self.shared.map(|s| s.get()).unwrap_or(f64::INFINITY);
        if lb > self.best_time.min(shared_bound)
            || (lb == self.best_time && !self.prefix_zero_beats_best(i))
        {
            self.stats.pruned_time += 1;
            return;
        }
        // ---- memory pruning (paper's limit rule + admissible suffix
        // bound); decision-independent, hence deterministic.
        let min_possible_peak = states
            + sp.suffix_min_states[i]
            + sp.base_act
            + trans_max.max(sp.suffix_min_trans[i]);
        if min_possible_peak > sp.mem_limit {
            self.stats.pruned_mem += 1;
            return;
        }

        if i == n {
            // feasibility is exact here (the suffix terms above are zero)
            self.try_accept(sp.base_time + time_fixed);
            return;
        }

        // ---- fast completion: the all-fastest suffix is both time-minimal
        // and lex-minimal among completions of this prefix; if it fits, it
        // is the subtree's (time, lex) optimum and the subtree closes.
        let opt0_peak = states
            + sp.suffix_opt0_states[i]
            + sp.base_act
            + trans_max.max(sp.suffix_opt0_trans[i]);
        if opt0_peak <= sp.mem_limit {
            for slot in self.prefix[i..].iter_mut() {
                *slot = 0;
            }
            let total = sp.base_time + time_fixed + sp.suffix_min_time[i];
            if self.try_accept(total) {
                self.stats.fast_completions += 1;
            }
            return;
        }

        for c in 0..sp.flat[i].len() {
            let opt = sp.flat[i][c];
            self.prefix[i] = c;
            self.descend(i + 1, time_fixed + opt.time_fixed,
                         states + opt.states, trans_max.max(opt.transient));
        }
    }

    /// Would `prefix[..i]` completed with all zeros beat the local
    /// incumbent's choice lexicographically? (Trivially yes when there is
    /// no local incumbent.)
    fn prefix_zero_beats_best(&self, i: usize) -> bool {
        let Some(best) = &self.best_choice else { return true };
        for j in 0..i {
            if self.prefix[j] != best[j] {
                return self.prefix[j] < best[j];
            }
        }
        best[i..].iter().any(|&c| c > 0)
    }

    /// Offer `self.prefix` at time `total` to the local incumbent; publish
    /// to the shared bound on improvement. Returns true when accepted.
    fn try_accept(&mut self, total: f64) -> bool {
        let better = total < self.best_time
            || (total == self.best_time
                && match &self.best_choice {
                    None => true,
                    Some(b) => lex_less(&self.prefix, b),
                });
        if better {
            self.best_time = total;
            self.best_choice = Some(self.prefix.clone());
            if let Some(s) = self.shared {
                s.publish(total);
            }
        }
        better
    }
}
