//! Search convergence timelines: every incumbent improvement, keyed by
//! visited-node count.
//!
//! The engines already funnel every incumbent change through one site —
//! `bound::Walker::try_accept` — so observing convergence costs exactly
//! one branch on the (rare) accept path and nothing anywhere else. Each
//! accepted improvement is logged as `(nodes_visited, time_bits,
//! source)`: node counts, not timestamps, are the x-axis, so two runs of
//! the same deterministic search produce **bit-identical** timelines and
//! warm-start value is directly visible (the seed event's time vs the
//! first descent improvement) instead of inferred from aggregate node
//! counts.
//!
//! ## Determinism envelope
//!
//! * serial searches (one [`bound::Walker`]) — bit-identical timelines
//!   at any thread count, because there is only one walker. This covers
//!   every per-batch search inside a sweep (the scheduler's workers
//!   parallelize over batch sizes, each batch is one serial walker).
//! * parallel batch searches — per-task timelines are concatenated in
//!   **task order** (not completion order) with cumulative node offsets
//!   and filtered to the strictly-improving `time_bits` subsequence.
//!   The surviving *plan* is bit-identical at any thread count (the
//!   engines' core property), and the timeline is bit-reproducible at
//!   `threads = 1`; at higher thread counts the shared incumbent makes
//!   per-task node counts timing-dependent, so the timeline is faithful
//!   but not reproducible bit-for-bit. Pinned in
//!   `rust/tests/planner_properties.rs`.
//!
//! ## Inertness
//!
//! Recording **observes and never branches**: a [`Recorder`] is either
//! armed (it pushes events) or off (it does nothing), and nothing in the
//! search reads it back. Compiling with `--features no_trace` turns
//! [`Recorder::armed`] into [`Recorder::off`], so the uninstrumented
//! cost is a single never-taken branch per accepted incumbent.

/// Where an incumbent came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ImprovementSource {
    /// The greedy heuristic's seed, installed before the descent.
    Greedy,
    /// A warm-start seed (repaired neighbor / replan projection) that
    /// beat the greedy seed.
    Warm,
    /// Found by the branch-and-bound descent itself.
    Descent,
}

impl ImprovementSource {
    /// Wire/JSON label.
    pub fn label(&self) -> &'static str {
        match self {
            ImprovementSource::Greedy => "greedy",
            ImprovementSource::Warm => "warm",
            ImprovementSource::Descent => "descent",
        }
    }
}

/// One accepted incumbent improvement.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Improvement {
    /// Nodes visited when the incumbent was accepted (0 for seeds).
    pub nodes: u64,
    /// `f64::to_bits` of the incumbent's search-arithmetic total time.
    /// Bits, not the float, so timelines compare exactly and serialize
    /// losslessly (as hex strings — u64 exceeds f64-exact JSON range).
    pub time_bits: u64,
    /// Where the incumbent came from.
    pub source: ImprovementSource,
}

/// An append-only improvement log handed to a [`bound::Walker`]. Off by
/// default (no allocation, nothing recorded); the traced entry points
/// arm it. The search never reads it — see the module docs for the
/// inertness argument.
#[derive(Debug, Default)]
pub struct Recorder {
    events: Option<Vec<Improvement>>,
}

impl Recorder {
    /// A disabled recorder: `record` is a no-op.
    pub fn off() -> Recorder {
        Recorder { events: None }
    }

    /// An armed recorder (disabled under `--features no_trace`).
    pub fn armed() -> Recorder {
        #[cfg(feature = "no_trace")]
        {
            Recorder::off()
        }
        #[cfg(not(feature = "no_trace"))]
        {
            Recorder { events: Some(Vec::new()) }
        }
    }

    /// Log one accepted improvement (no-op when off).
    #[inline]
    pub fn record(&mut self, nodes: u64, time_bits: u64,
                  source: ImprovementSource) {
        if let Some(v) = &mut self.events {
            v.push(Improvement { nodes, time_bits, source });
        }
    }

    /// Drain the log (empty when off).
    pub fn take(&mut self) -> Vec<Improvement> {
        self.events.take().unwrap_or_default()
    }
}

/// What a traced search observed, returned out-of-band next to the
/// (bit-identical) plan: phase wall-times, the convergence timeline,
/// and the frontier-build shape. Purely an observation — nothing in
/// the search reads it.
#[derive(Debug, Clone, Default)]
pub struct SearchTrace {
    /// Seconds spent building the prefold + class frontiers.
    pub build_s: f64,
    /// Seconds spent in the descent (task enumeration + walkers).
    pub descent_s: f64,
    /// The convergence timeline (see module docs for determinism).
    pub timeline: Vec<Improvement>,
    /// Frontier-build shape (classes, points, per-class level widths),
    /// when the frontier engine ran.
    pub frontier: Option<super::FrontierStats>,
}

/// Merge per-task timelines from a parallel search into one query-level
/// timeline: `seed` first (at `nodes = 0`), then each task's events in
/// task order with node counts offset by the cumulative visited-node
/// total of every earlier task, filtered to the strictly-improving
/// `time_bits` subsequence (equal-time lex improvements from later
/// tasks are dropped — the merged x-axis must be monotone).
pub fn merge_task_timelines(
    seed: Option<Improvement>,
    tasks: &[(u64, Vec<Improvement>)],
) -> Vec<Improvement> {
    let mut out: Vec<Improvement> = Vec::new();
    let mut best_bits: Option<u64> = None;
    let mut push = |e: Improvement, out: &mut Vec<Improvement>| {
        let improves = match best_bits {
            None => true,
            Some(b) => f64::from_bits(e.time_bits) < f64::from_bits(b),
        };
        if improves {
            best_bits = Some(e.time_bits);
            out.push(e);
        }
    };
    if let Some(s) = seed {
        push(s, &mut out);
    }
    let mut offset = 0u64;
    for (task_nodes, events) in tasks {
        for e in events {
            push(Improvement { nodes: e.nodes.saturating_add(offset), ..*e },
                 &mut out);
        }
        offset = offset.saturating_add(*task_nodes);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(nodes: u64, t: f64, source: ImprovementSource) -> Improvement {
        Improvement { nodes, time_bits: t.to_bits(), source }
    }

    #[test]
    fn recorder_off_is_silent_and_armed_logs() {
        let mut off = Recorder::off();
        off.record(3, 1.0f64.to_bits(), ImprovementSource::Descent);
        assert!(off.take().is_empty());
        let mut on = Recorder::armed();
        on.record(3, 1.0f64.to_bits(), ImprovementSource::Descent);
        #[cfg(not(feature = "no_trace"))]
        assert_eq!(on.take().len(), 1);
    }

    #[test]
    fn merge_offsets_by_task_nodes_and_keeps_strict_improvements() {
        let seed = Some(ev(0, 10.0, ImprovementSource::Warm));
        let tasks = vec![
            // task 0: improves at local node 5, then an equal-time lex
            // improvement at node 7 (dropped by the merge)
            (100, vec![ev(5, 8.0, ImprovementSource::Descent),
                       ev(7, 8.0, ImprovementSource::Descent)]),
            // task 1: a stale "improvement" vs its own local seed that
            // does not beat the global best (dropped), then a real one
            (50, vec![ev(2, 9.0, ImprovementSource::Descent),
                      ev(40, 6.0, ImprovementSource::Descent)]),
        ];
        let merged = merge_task_timelines(seed, &tasks);
        assert_eq!(merged, vec![ev(0, 10.0, ImprovementSource::Warm),
                                ev(5, 8.0, ImprovementSource::Descent),
                                ev(140, 6.0, ImprovementSource::Descent)]);
        // monotone in nodes, strictly improving in time
        for w in merged.windows(2) {
            assert!(w[0].nodes <= w[1].nodes);
            assert!(f64::from_bits(w[1].time_bits)
                    < f64::from_bits(w[0].time_bits));
        }
    }

    #[test]
    fn merge_with_no_seed_and_empty_tasks_is_empty() {
        assert!(merge_task_timelines(None, &[]).is_empty());
        assert!(merge_task_timelines(None, &[(10, vec![])]).is_empty());
    }
}
