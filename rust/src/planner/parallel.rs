//! Parallel branch-and-bound planner: the search tree split at a
//! configurable depth into independent subtree tasks executed across
//! `std::thread` workers, all pruning against one shared incumbent (an
//! `AtomicU64` carrying the best time's f64 bits — see `bound`).
//!
//! Exactness and determinism are inherited from the shared `bound` module:
//! every worker reports the exact `(time, lex)`-minimum of its subtree and
//! the merge is a deterministic fold in task order, so the result is
//! bit-identical to [`super::dfs::search`] for any thread count whenever
//! the node budget does not expire — property-tested against
//! [`super::exhaustive`] in `rust/tests/parallel_planner.rs` and against
//! the unfolded engine in `rust/tests/folded_planner.rs`.
//!
//! By default the split works on the **frontier** space ([`Engine`]):
//! subtree tasks are every combination of the first `split_depth`
//! equivalence classes' *frontier points* (see `super::frontier`) — or
//! their count compositions for the folded engine, or the first
//! `split_depth` operators' raw menus for the per-op engine. On symmetric
//! models that keeps the task list proportional to the distinct-plan
//! space. Tasks are capped at [`MAX_TASKS`] by shrinking the depth, then
//! drained by workers over an atomic task counter (cheap work stealing:
//! whichever worker is free takes the next prefix).

use super::Engine;
use super::bound::{Prefold, SearchSpace, SharedBound, Walker,
                   composition_count, lex_less, next_monotone_block};
use super::dfs::{DEFAULT_NODE_BUDGET, DfsStats};
use super::frontier::Frontiers;
use super::progress;
use crate::cost::{PlanCost, Profiler};
use std::sync::Mutex;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Default tree-split depth: combinations of the first 3 positions'
/// branch menus (class compositions when folding, operator menus
/// otherwise) give a few hundred tasks on paper-scale menus — enough to
/// load-balance 8–64 workers without per-task overhead mattering.
pub const DEFAULT_SPLIT_DEPTH: usize = 3;

/// Hard cap on subtree tasks; the split depth shrinks until the task count
/// (product of the first `depth` branch counts) fits. Keeps per-task
/// overhead (one incumbent clone + one claim) under ~1% of any real
/// search.
pub const MAX_TASKS: usize = 4096;

/// Floor on the per-task node budget so a huge task count cannot starve
/// individual subtrees into returning only the greedy seed.
const MIN_TASK_BUDGET: u64 = 16_384;

/// Worker-pool settings for [`search`] (and the `--threads` /
/// `--split-depth` / `--engine` / `--no-fold` CLI flags).
#[derive(Debug, Clone)]
pub struct ParallelConfig {
    /// Worker threads (clamped to at least 1).
    pub threads: usize,
    /// Depth at which the search tree splits into tasks (0 = one task,
    /// i.e. serial search on a worker thread). Counts classes for the
    /// frontier and folded engines, operators for the per-op engine.
    pub split_depth: usize,
    /// Global node budget. The split depth shrinks until every task gets
    /// at least `MIN_TASK_BUDGET` nodes from it, so the aggregate stays
    /// within the cap; exactness holds iff the merged stats report
    /// `complete`.
    pub node_budget: u64,
    /// Which exact engine runs in every worker. Identical results for
    /// all of them; [`Engine::Frontier`] is the default and splits the
    /// tree over the first classes' frontier points.
    pub engine: Engine,
}

impl Default for ParallelConfig {
    fn default() -> Self {
        ParallelConfig {
            threads: default_threads(),
            split_depth: DEFAULT_SPLIT_DEPTH,
            node_budget: DEFAULT_NODE_BUDGET,
            engine: Engine::Frontier,
        }
    }
}

/// Hardware parallelism (1 when it cannot be determined).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One subtree task: a fixed choice for a positional prefix of the
/// ordered operators (the first `depth` operators, or every member of the
/// first `depth` classes when folding) plus its accumulated partial sums
/// (folded left-to-right, so task arithmetic is bit-identical to a serial
/// descent).
struct Task {
    prefix: Vec<usize>,
    time_fixed: f64,
    states: f64,
    trans_max: f64,
}

/// Parallel branch-and-bound: minimal `Σ T_i` plan whose peak memory fits
/// `mem_limit` at per-device batch `b`, bit-identical to
/// [`super::dfs::search`] (ties resolve to the lexicographically least
/// choice in visit order). Returns `None` when nothing fits.
pub fn search(profiler: &Profiler, mem_limit: f64, b: usize,
              cfg: &ParallelConfig)
              -> Option<(Vec<usize>, PlanCost, DfsStats)> {
    search_seeded(profiler, mem_limit, b, cfg, None)
}

/// [`search`] with an optional warm-start seed (a full profiler-order
/// choice vector, installed as the initial incumbent — and the shared
/// bound's starting value — when feasible). The seed only tightens
/// pruning, so the result is bit-identical to the unseeded search at any
/// thread count; see `crate::planner::dfs::search_warm`.
pub fn search_seeded(profiler: &Profiler, mem_limit: f64, b: usize,
                     cfg: &ParallelConfig, warm: Option<&[usize]>)
                     -> Option<(Vec<usize>, PlanCost, DfsStats)> {
    let (r, stats) = search_with_stats(profiler, mem_limit, b, cfg, warm);
    r.map(|(choice, cost)| (choice, cost, stats))
}

/// [`search_seeded`], but the merged [`DfsStats`] come back even when no
/// plan exists — `stats.complete` is then the certificate that
/// infeasibility was *proven* (every subtree searched to completion)
/// rather than the node budget expiring first. The plan service caches
/// "nothing fits" only under that certificate.
pub fn search_with_stats(profiler: &Profiler, mem_limit: f64, b: usize,
                         cfg: &ParallelConfig, warm: Option<&[usize]>)
                         -> (Option<(Vec<usize>, PlanCost)>, DfsStats) {
    search_traced(profiler, mem_limit, b, cfg, warm, None)
}

/// [`search_with_stats`] with an optional search-trace observation:
/// build vs descent wall-seconds, the frontier-build shape, and the
/// convergence timeline (per-task walker logs concatenated in task
/// order with cumulative node offsets — see
/// [`progress::merge_task_timelines`] for the determinism envelope).
/// Tracing is inert: recorders are write-only, nothing in the search
/// reads them, and the returned plan + stats are bit-identical to the
/// untraced call at any thread count (pinned in
/// `planner_properties.rs`).
pub fn search_traced(profiler: &Profiler, mem_limit: f64, b: usize,
                     cfg: &ParallelConfig, warm: Option<&[usize]>,
                     trace: Option<&mut progress::SearchTrace>)
                     -> (Option<(Vec<usize>, PlanCost)>, DfsStats) {
    let traced = trace.is_some();
    let build_started = traced.then(std::time::Instant::now);
    let prefold = Prefold::new(profiler);
    let frontiers = match cfg.engine {
        Engine::Frontier => Some(Frontiers::new(&prefold, profiler)),
        _ => None,
    };
    let mut space = SearchSpace::for_batch(&prefold, profiler, mem_limit, b);
    // observation only: remember the greedy seed so the timeline can
    // label whether the warm offer displaced it
    let greedy_seed = if traced { space.seed.clone() } else { None };
    if let Some(w) = warm {
        // Same warm-seed repair as the serial engine (see
        // `super::dfs::search_prefolded`): greedy-downgrade the
        // neighbor plan until it fits, then offer it as the incumbent.
        if let Some((repaired, _)) =
            super::greedy::search_from(profiler, mem_limit, b, w)
        {
            space.offer_warm(&repaired);
        }
    }
    let space = space;

    // Shrink the split depth until (a) the task count is bounded and
    // (b) dividing the node budget across tasks leaves each at least the
    // per-task floor — so the budget stays a real global cap instead of
    // being silently multiplied by the task count. Every frontier class
    // prebuilds (the incremental build has no width ceiling), so the
    // frontier split region is the whole class sequence.
    let max_depth = match cfg.engine {
        Engine::UnfoldedBb => space.n(),
        Engine::FoldedBb | Engine::Frontier => prefold.n_classes(),
    };
    let mut depth = cfg.split_depth.min(max_depth);
    while depth > 0 && {
        let tasks =
            task_count(&space, frontiers.as_ref(), depth, cfg.engine) as u64;
        tasks > MAX_TASKS as u64
            || cfg.node_budget / tasks < MIN_TASK_BUDGET
    } {
        depth -= 1;
    }
    let tasks = match cfg.engine {
        Engine::Frontier => {
            enumerate_tasks_frontier(&space, frontiers.as_ref().unwrap(),
                                     depth)
        }
        Engine::FoldedBb => enumerate_tasks_folded(&space, depth),
        Engine::UnfoldedBb => enumerate_tasks(&space, depth),
    };
    let budget = per_task_budget(cfg.node_budget, tasks.len());
    let build_s = build_started.map_or(0.0, |t| t.elapsed().as_secs_f64());
    let descent_started = traced.then(std::time::Instant::now);

    let shared = SharedBound::new(
        space.seed.as_ref().map(|(t, _)| *t).unwrap_or(f64::INFINITY),
    );
    let threads = cfg.threads.max(1).min(tasks.len().max(1));
    let next = AtomicUsize::new(0);
    type Slot = (f64, Option<Vec<usize>>, DfsStats, Vec<progress::Improvement>);
    let results: Mutex<Vec<Option<Slot>>> =
        Mutex::new((0..tasks.len()).map(|_| None).collect());

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                loop {
                    let idx = next.fetch_add(1, Ordering::Relaxed);
                    if idx >= tasks.len() {
                        break;
                    }
                    let t = &tasks[idx];
                    let mut w = Walker::new(&space, frontiers.as_ref(),
                                            Some(&shared), budget);
                    if traced {
                        w.recorder = progress::Recorder::armed();
                    }
                    match cfg.engine {
                        Engine::Frontier => {
                            w.run_frontier(depth, &t.prefix, t.time_fixed,
                                           t.states, t.trans_max);
                        }
                        Engine::FoldedBb => {
                            w.run_folded(depth, &t.prefix, t.time_fixed,
                                         t.states, t.trans_max);
                        }
                        Engine::UnfoldedBb => {
                            w.run(depth, &t.prefix, t.time_fixed, t.states,
                                  t.trans_max);
                        }
                    }
                    let events = w.recorder.take();
                    results.lock().unwrap()[idx] =
                        Some((w.best_time, w.best_choice, w.stats, events));
                }
            });
        }
    });

    // Deterministic merge in task order: every walker's result is exact
    // for its subtree (see bound.rs), so the fold below does not depend on
    // which worker ran which task, or when.
    let mut agg = DfsStats { complete: true, ..DfsStats::default() };
    let mut best: Option<(f64, Vec<usize>)> = space.seed.clone();
    let mut task_timelines: Vec<(u64, Vec<progress::Improvement>)> =
        Vec::new();
    for slot in results.into_inner().unwrap() {
        let (time, choice, stats, events) = slot.expect("worker pool drained");
        agg.absorb(&stats);
        if traced {
            task_timelines.push((stats.nodes, events));
        }
        let Some(choice) = choice else { continue };
        let improves = match &best {
            None => true,
            Some((bt, bc)) => {
                time < *bt || (time == *bt && lex_less(&choice, bc))
            }
        };
        if improves {
            best = Some((time, choice));
        }
    }

    if let Some(t) = trace {
        let seed = space.seed.as_ref().map(|(st, _)| progress::Improvement {
            nodes: 0,
            time_bits: st.to_bits(),
            source: if space.seed == greedy_seed {
                progress::ImprovementSource::Greedy
            } else {
                progress::ImprovementSource::Warm
            },
        });
        t.build_s = build_s;
        t.descent_s =
            descent_started.map_or(0.0, |s| s.elapsed().as_secs_f64());
        t.timeline = progress::merge_task_timelines(seed, &task_timelines);
        t.frontier = frontiers.as_ref().map(|f| f.stats());
    }

    let result = best.map(|(_, choice_ordered)| {
        let choice = space.unpermute(&choice_ordered);
        let cost = profiler.evaluate(&choice, b);
        (choice, cost)
    });
    (result, agg)
}

/// Branch-count product of the first `depth` split positions, saturating.
fn task_count(space: &SearchSpace, frontiers: Option<&Frontiers>,
              depth: usize, engine: Engine) -> usize {
    match engine {
        Engine::Frontier => {
            let fr = frontiers.expect("frontier engine without frontiers");
            (0..depth).fold(1usize, |acc, k| {
                acc.saturating_mul(fr.classes[k].points.len())
            })
        }
        Engine::FoldedBb => (0..depth).fold(1usize, |acc, k| {
            let i = space.pre.class_start[k];
            acc.saturating_mul(composition_count(
                space.pre.multiplicity(k),
                space.flat[i].len(),
            ))
        }),
        Engine::UnfoldedBb => space.flat[..depth]
            .iter()
            .fold(1usize, |acc, menu| acc.saturating_mul(menu.len())),
    }
}

/// All per-operator prefixes of length `depth` in lexicographic order,
/// with their left-to-right partial sums.
fn enumerate_tasks(space: &SearchSpace, depth: usize) -> Vec<Task> {
    let mut tasks = Vec::with_capacity(task_count(space, None, depth,
                                                  Engine::UnfoldedBb));
    let mut idx = vec![0usize; depth];
    loop {
        tasks.push(make_task(space, &idx));
        // odometer, rightmost digit fastest = lexicographic order
        let mut pos = depth;
        loop {
            if pos == 0 {
                return tasks;
            }
            pos -= 1;
            idx[pos] += 1;
            if idx[pos] < space.flat[pos].len() {
                break;
            }
            idx[pos] = 0;
        }
    }
}

/// All folded prefixes over the first `class_depth` classes — one task
/// per combination of count compositions, each materialized as its
/// canonical monotone position prefix — in lexicographic order, with
/// their left-to-right partial sums.
fn enumerate_tasks_folded(space: &SearchSpace, class_depth: usize)
                          -> Vec<Task> {
    let pre = space.pre;
    let len = pre.class_start[class_depth];
    let mut tasks = Vec::with_capacity(task_count(space, None, class_depth,
                                                  Engine::FoldedBb));
    let mut prefix = vec![0usize; len];
    loop {
        tasks.push(make_task(space, &prefix));
        // odometer over classes, rightmost class fastest; each class
        // steps through its monotone blocks in lex order
        let mut k = class_depth;
        loop {
            if k == 0 {
                return tasks;
            }
            k -= 1;
            let (s, e) = (pre.class_start[k], pre.class_start[k + 1]);
            let o = space.flat[s].len();
            if next_monotone_block(&mut prefix[s..e], o) {
                break;
            }
            for slot in prefix[s..e].iter_mut() {
                *slot = 0;
            }
        }
    }
}

/// All frontier prefixes over the first `class_depth` classes — one task
/// per combination of frontier points, each materialized as its canonical
/// monotone position prefix — in point order, with their left-to-right
/// partial sums. Every class has prebuilt points, so any depth up to
/// `n_classes` is a valid split region.
fn enumerate_tasks_frontier(space: &SearchSpace, fr: &Frontiers,
                            class_depth: usize) -> Vec<Task> {
    let pre = space.pre;
    let len = pre.class_start[class_depth];
    let mut tasks = Vec::with_capacity(task_count(
        space,
        Some(fr),
        class_depth,
        Engine::Frontier,
    ));
    let mut pidx = vec![0usize; class_depth];
    let mut prefix = vec![0usize; len];
    loop {
        for k in 0..class_depth {
            let (s, e) = (pre.class_start[k], pre.class_start[k + 1]);
            fr.classes[k].points.write_block(pidx[k], &mut prefix[s..e]);
        }
        tasks.push(make_task(space, &prefix));
        // odometer over classes, rightmost class fastest; each class
        // steps through its frontier points in (time, lex) order
        let mut k = class_depth;
        loop {
            if k == 0 {
                return tasks;
            }
            k -= 1;
            pidx[k] += 1;
            if pidx[k] < fr.classes[k].points.len() {
                break;
            }
            pidx[k] = 0;
        }
    }
}

/// Accumulate a positional prefix's sums left-to-right (bit-identical to
/// a serial descent through the same positions).
fn make_task(space: &SearchSpace, prefix: &[usize]) -> Task {
    let mut time_fixed = 0.0;
    let mut states = 0.0;
    let mut trans_max = 0.0f64;
    for (i, &c) in prefix.iter().enumerate() {
        let o = space.flat[i][c];
        time_fixed += o.time_fixed;
        states += o.states;
        trans_max = trans_max.max(o.transient);
    }
    Task { prefix: prefix.to_vec(), time_fixed, states, trans_max }
}

/// Slice the global budget across tasks. The floor keeps tiny slices
/// useful; the final `min` keeps the aggregate within the configured cap
/// even when the floor would otherwise exceed a very small budget.
fn per_task_budget(total: u64, tasks: usize) -> u64 {
    if total == u64::MAX {
        return u64::MAX;
    }
    (total / tasks.max(1) as u64).max(MIN_TASK_BUDGET).min(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Cluster, SearchConfig};
    use crate::cost::Profiler;
    use crate::planner::dfs;

    fn profiler(hidden: usize, layers: usize, grans: Vec<usize>) -> Profiler {
        let m = crate::model::build_gpt(&crate::model::GptDims::uniform(
            "t", 5000, 128, layers, hidden, 4,
        ));
        let c = Cluster::rtx_titan(8, 8.0);
        let s = SearchConfig { granularities: grans, ..Default::default() };
        Profiler::new(&m, &c, &s)
    }

    fn cfg(threads: usize, split_depth: usize) -> ParallelConfig {
        ParallelConfig {
            threads,
            split_depth,
            node_budget: u64::MAX,
            engine: Engine::Frontier,
        }
    }

    #[test]
    fn unlimited_memory_yields_all_dp() {
        let p = profiler(256, 2, vec![0]);
        let (choice, cost, stats) =
            search(&p, 1e18, 4, &cfg(4, 2)).unwrap();
        assert_eq!(choice, p.index_of(|d| d.is_pure_dp()));
        assert!(cost.time > 0.0);
        assert!(stats.complete);
    }

    #[test]
    fn infeasible_matches_serial() {
        let p = profiler(256, 2, vec![0]);
        assert!(search(&p, 1.0, 1, &cfg(4, 2)).is_none());
    }

    #[test]
    fn matches_serial_bitwise_across_limits_and_split_depths() {
        let p = profiler(512, 3, vec![0, 2]);
        let dp = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1);
        for frac in [0.45, 0.6, 0.8, 1.1] {
            let limit = dp.peak_mem * frac;
            let serial = dfs::search_with_budget(&p, limit, 1, u64::MAX);
            for d in [0, 1, 2, 5] {
                for engine in [Engine::Frontier, Engine::FoldedBb,
                               Engine::UnfoldedBb]
                {
                    let mut c = cfg(4, d);
                    c.engine = engine;
                    let par = search(&p, limit, 1, &c);
                    match (&serial, &par) {
                        (None, None) => {}
                        (Some((sc, scost, sst)), Some((pc, pcost, pst))) => {
                            assert!(sst.complete && pst.complete);
                            assert_eq!(
                                sc, pc,
                                "frac {frac} depth {d} engine {engine:?}"
                            );
                            assert_eq!(scost.time.to_bits(),
                                       pcost.time.to_bits());
                            assert_eq!(scost.peak_mem.to_bits(),
                                       pcost.peak_mem.to_bits());
                        }
                        _ => panic!(
                            "feasibility disagreement at \
                             {frac}/{d}/{engine:?}"
                        ),
                    }
                }
            }
        }
    }

    #[test]
    fn split_depth_exceeding_positions_is_clamped() {
        let p = profiler(128, 1, vec![0]);
        let n = p.n_ops();
        for engine in [Engine::Frontier, Engine::FoldedBb,
                       Engine::UnfoldedBb]
        {
            let mut c = cfg(2, n + 10);
            c.engine = engine;
            let (choice, _, _) = search(&p, 1e18, 1, &c).unwrap();
            assert_eq!(choice.len(), n);
        }
    }

    #[test]
    fn more_threads_than_tasks_is_fine() {
        let p = profiler(128, 1, vec![0]);
        assert!(search(&p, 1e18, 1, &cfg(64, 1)).is_some());
    }
}
