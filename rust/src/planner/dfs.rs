//! Depth-first search over per-operator decisions — the paper's Algorithm 1
//! inner loop ("Traverse execution plans via Depth First Search") with its
//! two prunings:
//!
//! 1. *memory pruning*: "if the current memory usage exceeds memory limit";
//! 2. *time pruning*: "or the current time cost exceeds the best plan so
//!    far, we will prune the searching immediately".
//!
//! We strengthen both with admissible suffix bounds (the minimum possible
//! time / memory any completion of the prefix can reach) and a
//! fast-completion rule (if the time-optimal completion of the suffix is
//! memory-feasible, take it — no descent needed). Both preserve exactness:
//! the result equals brute-force enumeration (proven against
//! [`super::exhaustive`] in tests).
//!
//! The bound precomputation and the descend loop live in the crate-private
//! `bound` module, shared verbatim with [`super::parallel`] — this serial
//! entry point is a single [`bound::Walker`] over the whole tree, so serial
//! and parallel results are bit-identical whenever the node budget does not
//! expire (see `rust/tests/parallel_planner.rs`).
//!
//! By default the walker runs on the **symmetry-folded** space: operators
//! with byte-identical cost tables are planned as one `(class,
//! multiplicity)` position whose branches assign counts per option — exact
//! and bit-identical to the per-operator descent (see `bound`), but with
//! `C(m+o-1, o-1)` branches per class instead of `o^m`. The unfolded
//! engine remains available ([`search_unfolded`], the CLI's `--no-fold`)
//! as ground truth and for measuring the fold's node reduction.

use super::Engine;
use super::bound::{Prefold, SearchSpace, Walker};
use super::frontier::Frontiers;
use super::progress;
use crate::cost::{PlanCost, Profiler};

/// Search diagnostics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DfsStats {
    /// Tree nodes expanded.
    pub nodes: u64,
    /// Branches cut by the memory bound.
    pub pruned_mem: u64,
    /// Branches cut by the incumbent-time bound.
    pub pruned_time: u64,
    /// Subtrees closed by fast completion.
    pub fast_completions: u64,
    /// True when the search ran to completion (result is provably optimal);
    /// false when the node budget expired first (result is the best plan
    /// found so far, never worse than the greedy seed).
    pub complete: bool,
}

impl DfsStats {
    /// Fold another worker's counters into this one (`complete` is the
    /// conjunction: an aggregate is exact only if every part was).
    pub fn absorb(&mut self, other: &DfsStats) {
        self.nodes += other.nodes;
        self.pruned_mem += other.pruned_mem;
        self.pruned_time += other.pruned_time;
        self.fast_completions += other.fast_completions;
        self.complete &= other.complete;
    }
}

/// Node budget for one search. The paper reports 9–307 s per search; the
/// budget keeps the batch-size sweep bounded on the biggest zoo models
/// while leaving small/medium instances provably exact (see tests vs
/// [`super::exhaustive`]). Anytime behavior: the greedy seed guarantees a
/// feasible incumbent before descent begins.
pub const DEFAULT_NODE_BUDGET: u64 = 2_000_000;

/// Search with the default node budget (see [`DEFAULT_NODE_BUDGET`]) on
/// the symmetry-folded space: minimal `Σ T_i` plan whose peak memory fits
/// `mem_limit` at per-device batch `b`. Returns `None` when nothing fits.
/// Ties in time resolve to the lexicographically least choice vector in
/// the planner's visit order (canonical, so serial and parallel runs
/// agree — and so folded and unfolded runs agree bit-for-bit).
pub fn search(profiler: &Profiler, mem_limit: f64, b: usize)
              -> Option<(Vec<usize>, PlanCost, DfsStats)> {
    search_with_budget(profiler, mem_limit, b, DEFAULT_NODE_BUDGET)
}

/// [`search`] with an explicit node budget (`u64::MAX` = provably exact).
pub fn search_with_budget(profiler: &Profiler, mem_limit: f64, b: usize,
                          budget: u64)
                          -> Option<(Vec<usize>, PlanCost, DfsStats)> {
    let prefold = Prefold::new(profiler);
    let (r, stats) = search_prefolded(profiler, &prefold, None, mem_limit,
                                      b, budget, Engine::FoldedBb, None);
    r.map(|(choice, cost)| (choice, cost, stats))
}

/// The per-operator (unfolded) engine: identical results, exponentially
/// more nodes on symmetric models. Ground truth for the fold's exactness
/// tests and the baseline for its node-reduction benchmarks.
pub fn search_unfolded(profiler: &Profiler, mem_limit: f64, b: usize,
                       budget: u64)
                       -> Option<(Vec<usize>, PlanCost, DfsStats)> {
    let prefold = Prefold::new(profiler);
    let (r, stats) = search_prefolded(profiler, &prefold, None, mem_limit,
                                      b, budget, Engine::UnfoldedBb, None);
    r.map(|(choice, cost)| (choice, cost, stats))
}

/// Search with an optional **warm-start seed**: a full profiler-order
/// choice vector (typically a cached neighbor query's plan, see
/// `crate::service::warm`) installed as the initial incumbent when it is
/// feasible at this `(mem_limit, b)`. The seed only tightens the
/// incumbent bound, so the result is provably bit-identical to the
/// unseeded search for every engine — it just visits fewer nodes
/// (property-tested in `rust/tests/plan_service.rs`). An infeasible or
/// malformed seed is ignored.
pub fn search_warm(profiler: &Profiler, mem_limit: f64, b: usize,
                   budget: u64, engine: Engine, warm: Option<&[usize]>)
                   -> Option<(Vec<usize>, PlanCost, DfsStats)> {
    let prefold = Prefold::new(profiler);
    let frontiers = match engine {
        Engine::Frontier => Some(Frontiers::new(&prefold, profiler)),
        _ => None,
    };
    let (r, stats) = search_prefolded(profiler, &prefold, frontiers.as_ref(),
                                      mem_limit, b, budget, engine, warm);
    r.map(|(choice, cost)| (choice, cost, stats))
}

/// Search over a prebuilt [`Prefold`] (and, for [`Engine::Frontier`],
/// prebuilt [`Frontiers`]) — the scheduler's batch sweep builds the fold,
/// the batch-independent suffix bounds, and the class frontiers once and
/// calls this per batch size, recomputing only the transient and base
/// terms (and the greedy seed). `warm` optionally installs a feasible
/// profiler-order choice as the initial incumbent (see [`search_warm`]).
///
/// Stats come back even when no plan exists: `stats.complete` is the
/// *certificate* that infeasibility was proven rather than the node
/// budget expiring first — the plan service refuses to cache an
/// un-proven "nothing fits".
#[allow(clippy::too_many_arguments)] // crate-internal plumbing entry
pub(crate) fn search_prefolded(profiler: &Profiler, prefold: &Prefold,
                               frontiers: Option<&Frontiers>, mem_limit: f64,
                               b: usize, budget: u64, engine: Engine,
                               warm: Option<&[usize]>)
                               -> (Option<(Vec<usize>, PlanCost)>, DfsStats) {
    search_prefolded_traced(profiler, prefold, frontiers, mem_limit, b,
                            budget, engine, warm, None)
}

/// [`search_prefolded`] with an optional convergence-timeline
/// observation ([`progress::SearchTrace::timeline`] only — the caller
/// owns the build/descent phase clocks). Tracing is inert: the recorder
/// is write-only from the walker's point of view, so the returned plan
/// and stats are bit-identical to the untraced call (pinned in
/// `planner_properties.rs`).
#[allow(clippy::too_many_arguments)] // crate-internal plumbing entry
pub(crate) fn search_prefolded_traced(
    profiler: &Profiler, prefold: &Prefold, frontiers: Option<&Frontiers>,
    mem_limit: f64, b: usize, budget: u64, engine: Engine,
    warm: Option<&[usize]>, trace: Option<&mut progress::SearchTrace>)
    -> (Option<(Vec<usize>, PlanCost)>, DfsStats) {
    let mut space = SearchSpace::for_batch(prefold, profiler, mem_limit, b);
    // observation only: remember the greedy seed so the timeline can
    // label whether the warm offer displaced it
    let greedy_seed = if trace.is_some() { space.seed.clone() } else { None };
    if let Some(w) = warm {
        // Repair the seed first (greedy downgrades from the neighbor
        // plan until it fits this batch/limit): a neighbor that no
        // longer fits verbatim is usually one move from a strong
        // incumbent. The repaired plan is still just a feasible full
        // assignment, so exactness is untouched (service::warm).
        if let Some((repaired, _)) =
            super::greedy::search_from(profiler, mem_limit, b, w)
        {
            space.offer_warm(&repaired);
        }
    }
    let space = space;
    let mut walker = Walker::new(&space, frontiers, None, budget);
    if trace.is_some() {
        walker.recorder = progress::Recorder::armed();
    }
    match engine {
        Engine::Frontier => walker.run_root_frontier(),
        Engine::FoldedBb => walker.run_root_folded(),
        Engine::UnfoldedBb => walker.run_root(),
    }

    if let Some(t) = trace {
        let seed = space.seed.as_ref().map(|(st, _)| progress::Improvement {
            nodes: 0,
            time_bits: st.to_bits(),
            source: if space.seed == greedy_seed {
                progress::ImprovementSource::Greedy
            } else {
                progress::ImprovementSource::Warm
            },
        });
        t.timeline = progress::merge_task_timelines(
            seed, &[(walker.stats.nodes, walker.recorder.take())]);
    }

    let result = walker.best_choice.map(|choice_ordered| {
        let choice = space.unpermute(&choice_ordered);
        let cost = profiler.evaluate(&choice, b);
        (choice, cost)
    });
    (result, walker.stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Cluster, GIB, SearchConfig};
    use crate::cost::Profiler;
    use crate::model::{GptDims, build_gpt};

    fn profiler(hidden: usize, layers: usize, grans: Vec<usize>)
                -> (Profiler, Cluster) {
        let m = build_gpt(&GptDims::uniform("t", 5000, 128, layers, hidden, 4));
        let c = Cluster::rtx_titan(8, 8.0);
        let s = SearchConfig { granularities: grans, ..Default::default() };
        (Profiler::new(&m, &c, &s), c)
    }

    #[test]
    fn unlimited_memory_yields_all_dp() {
        let (p, _) = profiler(256, 2, vec![0]);
        let (choice, cost, stats) = search(&p, 1e18, 4).unwrap();
        let all_dp = p.index_of(|d| d.is_pure_dp());
        assert_eq!(choice, all_dp);
        assert!(cost.time > 0.0);
        // greedy seed is already optimal; the root closes immediately
        assert!(stats.nodes <= 2, "nodes={}", stats.nodes);
        assert!(stats.complete);
    }

    #[test]
    fn infeasible_when_even_zdp_oom() {
        let (p, _) = profiler(256, 2, vec![0]);
        assert!(search(&p, 1.0, 1).is_none());
    }

    #[test]
    fn tight_memory_forces_sharding() {
        let (p, _) = profiler(512, 4, vec![0]);
        // all-DP memory
        let dp = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1);
        let zdp = p.evaluate(&p.index_of(|d| d.is_pure_zdp()), 1);
        let limit = (dp.peak_mem + zdp.peak_mem) / 2.0;
        let (choice, cost, _) = search(&p, limit, 1).unwrap();
        assert!(cost.peak_mem <= limit);
        // must shard something but not everything
        let plan =
            crate::planner::ExecutionPlan::from_choice(&p, choice, 1);
        let (dp_ops, zdp_ops, mixed) = plan.mode_counts();
        assert!(zdp_ops + mixed > 0, "must shard: {dp_ops} dp");
        assert!(dp_ops > 0, "should keep small ops in DP");
        // faster than all-ZDP, slower than all-DP
        assert!(cost.time <= zdp.time + 1e-12);
        assert!(cost.time >= dp.time - 1e-12);
    }

    #[test]
    fn monotone_in_memory_limit() {
        let (p, _) = profiler(384, 3, vec![0, 4]);
        let dp = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 2);
        let mut last_time = f64::INFINITY;
        for frac in [0.4, 0.6, 0.8, 1.0, 1.2] {
            if let Some((_, cost, _)) = search(&p, dp.peak_mem * frac, 2) {
                assert!(cost.time <= last_time + 1e-12,
                        "more memory must not slow the plan");
                last_time = cost.time;
            }
        }
        assert!(last_time.is_finite());
    }

    #[test]
    fn splitting_enables_otherwise_infeasible_fits() {
        // Choose a limit below what unsplit ZDP can reach: the gather
        // transient of the biggest op is the floor; splitting divides it.
        let (p0, _) = profiler(2048, 2, vec![0]);
        let zdp = p0.evaluate(&p0.index_of(|d| d.is_pure_zdp()), 1);
        // limit slightly under the unsplit ZDP peak
        let limit = zdp.peak_mem * 0.96;
        assert!(search(&p0, limit, 1).is_none(),
                "unsplit should be infeasible at this limit");
        let (p1, _) = profiler(2048, 2, vec![0, 8]);
        let hit = search(&p1, limit, 1);
        assert!(hit.is_some(), "splitting must unlock the fit");
        let (_, cost, _) = hit.unwrap();
        assert!(cost.peak_mem <= limit);
    }

    #[test]
    fn stats_count_pruning() {
        let (p, _) = profiler(512, 4, vec![0, 2, 4]);
        let dp = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1);
        let (_, _, stats) = search(&p, dp.peak_mem * 0.5, 1).unwrap();
        assert!(stats.nodes > 0);
        assert!(stats.pruned_mem + stats.pruned_time + stats.fast_completions
                > 0);
    }

    #[test]
    fn respects_8gib_style_limits_on_big_models() {
        // A zoo-sized model: the budgeted search must terminate promptly,
        // fit the limit, and never be worse than its greedy seed.
        let m = build_gpt(&GptDims::uniform("nd", 50257, 1024, 48, 1024, 16));
        let c = Cluster::rtx_titan(8, 8.0);
        let s = SearchConfig { granularities: vec![0, 4],
                               ..Default::default() };
        let p = Profiler::new(&m, &c, &s);
        let t0 = std::time::Instant::now();
        let got = search_with_budget(&p, 8.0 * GIB, 1, 200_000);
        assert!(t0.elapsed().as_secs() < 60, "search too slow");
        let (_, cost, _) = got.expect("8 GiB must be feasible for 48L/1024H");
        assert!(cost.peak_mem <= 8.0 * GIB);
        let (_, gcost) =
            crate::planner::greedy::search(&p, 8.0 * GIB, 1).unwrap();
        assert!(cost.time <= gcost.time + 1e-12);
    }
}
