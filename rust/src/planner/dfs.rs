//! Depth-first search over per-operator decisions — the paper's Algorithm 1
//! inner loop ("Traverse execution plans via Depth First Search") with its
//! two prunings:
//!
//! 1. *memory pruning*: "if the current memory usage exceeds memory limit";
//! 2. *time pruning*: "or the current time cost exceeds the best plan so
//!    far, we will prune the searching immediately".
//!
//! We strengthen both with admissible suffix bounds (the minimum possible
//! time / memory any completion of the prefix can reach) and a
//! fast-completion rule (if the time-optimal completion of the suffix is
//! memory-feasible, take it — no descent needed). Both preserve exactness:
//! the result equals brute-force enumeration (proven against
//! [`super::exhaustive`] in tests).

use crate::cost::{PlanCost, Profiler};

/// Search diagnostics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DfsStats {
    /// Tree nodes expanded.
    pub nodes: u64,
    /// Branches cut by the memory bound.
    pub pruned_mem: u64,
    /// Branches cut by the incumbent-time bound.
    pub pruned_time: u64,
    /// Subtrees closed by fast completion.
    pub fast_completions: u64,
    /// True when the search ran to completion (result is provably optimal);
    /// false when the node budget expired first (result is the best plan
    /// found so far, never worse than the greedy seed).
    pub complete: bool,
}

/// Node budget for one search. The paper reports 9–307 s per search; the
/// budget keeps the batch-size sweep bounded on the biggest zoo models
/// while leaving small/medium instances provably exact (see tests vs
/// [`super::exhaustive`]). Anytime behavior: the greedy seed guarantees a
/// feasible incumbent before descent begins.
pub const DEFAULT_NODE_BUDGET: u64 = 2_000_000;

/// One option's costs, flattened into search order with the transient
/// (gather + b·workspace) precomputed — the DFS inner loop touches only
/// this contiguous structure (perf pass: EXPERIMENTS.md §Perf).
#[derive(Clone, Copy)]
struct FlatOpt {
    time_fixed: f64,
    states: f64,
    transient: f64,
}

struct Ctx<'a> {
    #[allow(dead_code)] // kept for debugging/extension hooks
    profiler: &'a Profiler,
    /// op evaluation order (largest params first), as profiler indices
    order: Vec<usize>,
    /// per ordered position: the option menu, flattened
    flat: Vec<Vec<FlatOpt>>,
    mem_limit: f64,
    #[allow(dead_code)]
    b: f64,
    // per ordered position i: min over options of time_fixed / states /
    // transient for ops at positions >= i
    suffix_min_time: Vec<f64>,
    suffix_min_states: Vec<f64>,
    /// max over remaining ops of their minimum transient (admissible lower
    /// bound on the final transient max)
    suffix_min_trans: Vec<f64>,
    // fast-completion (option 0 = fastest) suffix sums
    suffix_opt0_states: Vec<f64>,
    suffix_opt0_trans: Vec<f64>,
    // decision-independent totals
    base_time: f64,
    base_act: f64,
    // incumbent
    best_time: f64,
    best_choice: Option<Vec<usize>>,
    stats: DfsStats,
    budget: u64,
}

/// Search with the default node budget (see [`DEFAULT_NODE_BUDGET`]):
/// minimal `Σ T_i` plan whose peak memory fits `mem_limit` at per-device
/// batch `b`. Returns `None` when nothing fits.
pub fn search(profiler: &Profiler, mem_limit: f64, b: usize)
              -> Option<(Vec<usize>, PlanCost, DfsStats)> {
    search_with_budget(profiler, mem_limit, b, DEFAULT_NODE_BUDGET)
}

/// [`search`] with an explicit node budget (`u64::MAX` = provably exact).
pub fn search_with_budget(profiler: &Profiler, mem_limit: f64, b: usize,
                          budget: u64)
                          -> Option<(Vec<usize>, PlanCost, DfsStats)> {
    let n = profiler.n_ops();
    let bf = b as f64;

    // Seed the incumbent with the greedy plan: a feasible solution before
    // descent makes the time-pruning bound bite from node one and gives the
    // budget-expired case a quality floor.
    let seed = super::greedy::search(profiler, mem_limit, b);

    // Visit ops with the largest parameter mass first: their decisions move
    // the most memory/time, so bounds tighten early.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&x, &y| {
        let sx = profiler.tables[x].fastest().states;
        let sy = profiler.tables[y].fastest().states;
        sy.partial_cmp(&sx).unwrap()
    });

    let mut suffix_min_time = vec![0.0; n + 1];
    let mut suffix_min_states = vec![0.0; n + 1];
    let mut suffix_min_trans = vec![0.0f64; n + 1];
    let mut suffix_opt0_states = vec![0.0; n + 1];
    let mut suffix_opt0_trans = vec![0.0f64; n + 1];
    for i in (0..n).rev() {
        let t = &profiler.tables[order[i]];
        let min_time = t.min_time_fixed();
        let min_states = t.min_states();
        let min_trans = t
            .options
            .iter()
            .map(|o| o.gather)
            .fold(f64::INFINITY, f64::min)
            + bf * t.workspace_per_sample;
        suffix_min_time[i] = suffix_min_time[i + 1] + min_time;
        suffix_min_states[i] = suffix_min_states[i + 1] + min_states;
        suffix_min_trans[i] = suffix_min_trans[i + 1].max(min_trans);
        suffix_opt0_states[i] =
            suffix_opt0_states[i + 1] + t.fastest().states;
        suffix_opt0_trans[i] = suffix_opt0_trans[i + 1]
            .max(t.fastest().gather + bf * t.workspace_per_sample);
    }
    let eff = crate::cost::time::batch_efficiency(b);
    let base_time: f64 =
        profiler.tables.iter().map(|t| bf * t.gamma / eff).sum();
    let base_act: f64 =
        profiler.tables.iter().map(|t| bf * t.act_per_sample).sum();

    let (seed_time, seed_choice_ordered) = match &seed {
        Some((choice, cost)) => {
            // permute the greedy choice into search order
            let ordered: Vec<usize> =
                order.iter().map(|&op| choice[op]).collect();
            (cost.time, Some(ordered))
        }
        None => (f64::INFINITY, None),
    };

    let mut ctx = Ctx {
        profiler,
        order,
        flat: Vec::new(),
        mem_limit,
        b: bf,
        suffix_min_time,
        suffix_min_states,
        suffix_min_trans,
        suffix_opt0_states,
        suffix_opt0_trans,
        base_time,
        base_act,
        best_time: seed_time,
        best_choice: seed_choice_ordered,
        stats: DfsStats::default(),
        budget,
    };

    ctx.flat = ctx
        .order
        .iter()
        .map(|&op| {
            profiler.tables[op]
                .options
                .iter()
                .map(|o| FlatOpt {
                    time_fixed: o.time_fixed(),
                    states: o.states,
                    transient: o.gather
                        + bf * profiler.tables[op].workspace_per_sample,
                })
                .collect()
        })
        .collect();

    let mut prefix = vec![0usize; n];
    descend(&mut ctx, 0, 0.0, 0.0, 0.0, &mut prefix);
    ctx.stats.complete = ctx.stats.nodes < ctx.budget;

    let choice_ordered = ctx.best_choice?;
    // un-permute to profiler order
    let mut choice = vec![0usize; n];
    for (pos, &op_idx) in ctx.order.iter().enumerate() {
        choice[op_idx] = choice_ordered[pos];
    }
    let cost = profiler.evaluate(&choice, b);
    Some((choice, cost, ctx.stats))
}

fn descend(ctx: &mut Ctx, i: usize, time_fixed: f64, states: f64,
           trans_max: f64, prefix: &mut Vec<usize>) {
    if ctx.stats.nodes >= ctx.budget {
        return; // budget expired: keep the incumbent (anytime result)
    }
    ctx.stats.nodes += 1;
    let n = ctx.order.len();

    // ---- time pruning (paper's incumbent rule + admissible suffix bound)
    if ctx.base_time + time_fixed + ctx.suffix_min_time[i] >= ctx.best_time {
        ctx.stats.pruned_time += 1;
        return;
    }
    // ---- memory pruning (paper's limit rule + admissible suffix bound)
    let min_possible_peak = states
        + ctx.suffix_min_states[i]
        + ctx.base_act
        + trans_max.max(ctx.suffix_min_trans[i]);
    if min_possible_peak > ctx.mem_limit {
        ctx.stats.pruned_mem += 1;
        return;
    }

    if i == n {
        let total = ctx.base_time + time_fixed;
        // bounds above guarantee feasibility and improvement
        ctx.best_time = total;
        ctx.best_choice = Some(prefix.clone());
        return;
    }

    // ---- fast completion: the all-fastest suffix is time-minimal; if it
    // fits, no other completion of this prefix can beat it.
    let opt0_peak = states
        + ctx.suffix_opt0_states[i]
        + ctx.base_act
        + trans_max.max(ctx.suffix_opt0_trans[i]);
    if opt0_peak <= ctx.mem_limit {
        let total = ctx.base_time + time_fixed + ctx.suffix_min_time_opt0(i);
        if total < ctx.best_time {
            ctx.stats.fast_completions += 1;
            for pos in i..n {
                prefix[pos] = 0;
            }
            ctx.best_time = total;
            ctx.best_choice = Some(prefix.clone());
        }
        return;
    }

    let n_opts = ctx.flat[i].len();
    for c in 0..n_opts {
        let opt = ctx.flat[i][c];
        let trans = trans_max.max(opt.transient);
        prefix[i] = c;
        descend(ctx, i + 1, time_fixed + opt.time_fixed,
                states + opt.states, trans, prefix);
    }
}

impl<'a> Ctx<'a> {
    /// Suffix time of the all-fastest completion. Option 0 is the fastest
    /// in every menu, so this equals the admissible bound.
    fn suffix_min_time_opt0(&self, i: usize) -> f64 {
        self.suffix_min_time[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Cluster, GIB, SearchConfig};
    use crate::cost::Profiler;
    use crate::model::{GptDims, build_gpt};

    fn profiler(hidden: usize, layers: usize, grans: Vec<usize>)
                -> (Profiler, Cluster) {
        let m = build_gpt(&GptDims::uniform("t", 5000, 128, layers, hidden, 4));
        let c = Cluster::rtx_titan(8, 8.0);
        let s = SearchConfig { granularities: grans, ..Default::default() };
        (Profiler::new(&m, &c, &s), c)
    }

    #[test]
    fn unlimited_memory_yields_all_dp() {
        let (p, _) = profiler(256, 2, vec![0]);
        let (choice, cost, stats) = search(&p, 1e18, 4).unwrap();
        let all_dp = p.index_of(|d| d.is_pure_dp());
        assert_eq!(choice, all_dp);
        assert!(cost.time > 0.0);
        // greedy seed is already optimal; the root closes immediately
        assert!(stats.nodes <= 2, "nodes={}", stats.nodes);
        assert!(stats.complete);
    }

    #[test]
    fn infeasible_when_even_zdp_oom() {
        let (p, _) = profiler(256, 2, vec![0]);
        assert!(search(&p, 1.0, 1).is_none());
    }

    #[test]
    fn tight_memory_forces_sharding() {
        let (p, _) = profiler(512, 4, vec![0]);
        // all-DP memory
        let dp = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1);
        let zdp = p.evaluate(&p.index_of(|d| d.is_pure_zdp()), 1);
        let limit = (dp.peak_mem + zdp.peak_mem) / 2.0;
        let (choice, cost, _) = search(&p, limit, 1).unwrap();
        assert!(cost.peak_mem <= limit);
        // must shard something but not everything
        let plan =
            crate::planner::ExecutionPlan::from_choice(&p, choice, 1);
        let (dp_ops, zdp_ops, mixed) = plan.mode_counts();
        assert!(zdp_ops + mixed > 0, "must shard: {dp_ops} dp");
        assert!(dp_ops > 0, "should keep small ops in DP");
        // faster than all-ZDP, slower than all-DP
        assert!(cost.time <= zdp.time + 1e-12);
        assert!(cost.time >= dp.time - 1e-12);
    }

    #[test]
    fn monotone_in_memory_limit() {
        let (p, _) = profiler(384, 3, vec![0, 4]);
        let dp = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 2);
        let mut last_time = f64::INFINITY;
        for frac in [0.4, 0.6, 0.8, 1.0, 1.2] {
            if let Some((_, cost, _)) = search(&p, dp.peak_mem * frac, 2) {
                assert!(cost.time <= last_time + 1e-12,
                        "more memory must not slow the plan");
                last_time = cost.time;
            }
        }
        assert!(last_time.is_finite());
    }

    #[test]
    fn splitting_enables_otherwise_infeasible_fits() {
        // Choose a limit below what unsplit ZDP can reach: the gather
        // transient of the biggest op is the floor; splitting divides it.
        let (p0, _) = profiler(2048, 2, vec![0]);
        let zdp = p0.evaluate(&p0.index_of(|d| d.is_pure_zdp()), 1);
        // limit slightly under the unsplit ZDP peak
        let limit = zdp.peak_mem * 0.96;
        assert!(search(&p0, limit, 1).is_none(),
                "unsplit should be infeasible at this limit");
        let (p1, _) = profiler(2048, 2, vec![0, 8]);
        let hit = search(&p1, limit, 1);
        assert!(hit.is_some(), "splitting must unlock the fit");
        let (_, cost, _) = hit.unwrap();
        assert!(cost.peak_mem <= limit);
    }

    #[test]
    fn stats_count_pruning() {
        let (p, _) = profiler(512, 4, vec![0, 2, 4]);
        let dp = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1);
        let (_, _, stats) = search(&p, dp.peak_mem * 0.5, 1).unwrap();
        assert!(stats.nodes > 0);
        assert!(stats.pruned_mem + stats.pruned_time + stats.fast_completions
                > 0);
    }

    #[test]
    fn respects_8gib_style_limits_on_big_models() {
        // A zoo-sized model: the budgeted search must terminate promptly,
        // fit the limit, and never be worse than its greedy seed.
        let m = build_gpt(&GptDims::uniform("nd", 50257, 1024, 48, 1024, 16));
        let c = Cluster::rtx_titan(8, 8.0);
        let s = SearchConfig { granularities: vec![0, 4],
                               ..Default::default() };
        let p = Profiler::new(&m, &c, &s);
        let t0 = std::time::Instant::now();
        let got = search_with_budget(&p, 8.0 * GIB, 1, 200_000);
        assert!(t0.elapsed().as_secs() < 60, "search too slow");
        let (_, cost, _) = got.expect("8 GiB must be feasible for 48L/1024H");
        assert!(cost.peak_mem <= 8.0 * GIB);
        let (_, gcost) =
            crate::planner::greedy::search(&p, 8.0 * GIB, 1).unwrap();
        assert!(cost.time <= gcost.time + 1e-12);
    }
}
