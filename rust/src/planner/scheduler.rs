//! The Scheduler (Algorithm 1's outer loop): "iteratively collects the
//! output plan and throughput from Search Engine as candidates, and
//! increases the training batch size ... until the minimum possible overall
//! memory cost exceeds device memory limit", then returns the candidate
//! with the highest estimated system throughput — which is *not* always the
//! largest batch (§3.2's closing observation), because a smaller batch can
//! afford more DP-mode operators.

use super::dfs;
use super::ExecutionPlan;
use crate::cost::Profiler;

/// One batch size's best plan.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub plan: ExecutionPlan,
    /// Cluster-wide samples/second.
    pub throughput: f64,
    pub search_nodes: u64,
}

/// Scheduler outcome: every candidate plus the winner index.
#[derive(Debug, Clone)]
pub struct SchedulerResult {
    pub candidates: Vec<Candidate>,
    pub best: usize,
    /// Total search-engine nodes across the batch sweep.
    pub total_nodes: u64,
    pub elapsed: std::time::Duration,
}

impl SchedulerResult {
    pub fn best_plan(&self) -> &ExecutionPlan {
        &self.candidates[self.best].plan
    }

    pub fn best_throughput(&self) -> f64 {
        self.candidates[self.best].throughput
    }
}

/// Batch-size sweep driver.
pub struct Scheduler<'a> {
    pub profiler: &'a Profiler,
    pub mem_limit: f64,
    pub max_batch: usize,
}

impl<'a> Scheduler<'a> {
    pub fn new(profiler: &'a Profiler, mem_limit: f64,
               max_batch: usize) -> Self {
        Scheduler { profiler, mem_limit, max_batch }
    }

    /// Run Algorithm 1. Returns `None` when no batch size fits at all.
    pub fn run(&self) -> Option<SchedulerResult> {
        let start = std::time::Instant::now();
        let n_dev = self.profiler.cluster.n_devices;
        let mut candidates = Vec::new();
        let mut total_nodes = 0;
        for b in 1..=self.max_batch {
            match dfs::search(self.profiler, self.mem_limit, b) {
                None => break, // smallest-memory plan no longer fits
                Some((choice, _cost, stats)) => {
                    let plan =
                        ExecutionPlan::from_choice(self.profiler, choice, b);
                    let throughput = plan.throughput(n_dev);
                    total_nodes += stats.nodes;
                    candidates.push(Candidate {
                        plan,
                        throughput,
                        search_nodes: stats.nodes,
                    });
                }
            }
        }
        if candidates.is_empty() {
            return None;
        }
        let best = candidates
            .iter()
            .enumerate()
            .max_by(|a, b| {
                a.1.throughput.partial_cmp(&b.1.throughput).unwrap()
            })
            .map(|(i, _)| i)
            .unwrap();
        Some(SchedulerResult {
            candidates,
            best,
            total_nodes,
            elapsed: start.elapsed(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Cluster, SearchConfig};
    use crate::cost::Profiler;
    use crate::model::{GptDims, build_gpt};

    fn profiler(n_dev: usize) -> Profiler {
        let m = build_gpt(&GptDims::uniform("t", 5000, 128, 2, 256, 4));
        let c = Cluster::rtx_titan(n_dev, 8.0);
        let s = SearchConfig { granularities: vec![0],
                               ..Default::default() };
        Profiler::new(&m, &c, &s)
    }

    #[test]
    fn sweep_stops_at_memory_wall() {
        let p = profiler(8);
        // pick a limit that fits only a handful of batch sizes
        let zdp1 = p.evaluate(&p.index_of(|d| d.is_pure_zdp()), 1);
        let limit = zdp1.peak_mem * 2.0;
        let res = Scheduler::new(&p, limit, 1024).run().unwrap();
        let n = res.candidates.len();
        assert!(n >= 1);
        assert!(n < 1024, "must hit the wall, got {n}");
        // batch sizes are exactly 1..=n
        for (i, c) in res.candidates.iter().enumerate() {
            assert_eq!(c.plan.batch, i + 1);
            assert!(c.plan.cost.peak_mem <= limit);
        }
    }

    #[test]
    fn none_when_nothing_fits() {
        let p = profiler(8);
        assert!(Scheduler::new(&p, 1.0, 16).run().is_none());
    }

    #[test]
    fn best_candidate_maximizes_throughput() {
        let p = profiler(8);
        let dp1 = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1);
        let res = Scheduler::new(&p, dp1.peak_mem * 4.0, 64).run().unwrap();
        let best_tp = res.best_throughput();
        for c in &res.candidates {
            assert!(c.throughput <= best_tp + 1e-12);
        }
    }

    #[test]
    fn larger_memory_never_hurts_throughput() {
        let p = profiler(8);
        let base = p.evaluate(&p.index_of(|d| d.is_pure_zdp()), 1).peak_mem;
        let mut last = 0.0;
        for mult in [1.5, 2.5, 4.0, 8.0] {
            if let Some(res) = Scheduler::new(&p, base * mult, 64).run() {
                let tp = res.best_throughput();
                assert!(tp >= last - 1e-9,
                        "throughput regressed with more memory");
                last = tp;
            }
        }
        assert!(last > 0.0);
    }

    #[test]
    fn throughput_counts_all_devices() {
        let p4 = profiler(4);
        let res = Scheduler::new(&p4, 1e18, 4).run().unwrap();
        let c = &res.candidates[0];
        let per_dev = c.plan.batch as f64 / c.plan.cost.time;
        assert!((c.throughput - per_dev * 4.0).abs() < 1e-9);
    }
}
