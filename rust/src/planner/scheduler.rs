//! The Scheduler (Algorithm 1's outer loop): "iteratively collects the
//! output plan and throughput from Search Engine as candidates, and
//! increases the training batch size ... until the minimum possible overall
//! memory cost exceeds device memory limit", then returns the candidate
//! with the highest estimated system throughput — which is *not* always the
//! largest batch (§3.2's closing observation), because a smaller batch can
//! afford more DP-mode operators.
//!
//! The sweep runs on a worker pool: batch sizes are claimed off an atomic
//! counter and searched concurrently, with an atomic "memory wall" (the
//! lowest batch size known infeasible) stopping the pool. Per-candidate
//! [`DfsStats`] are merged into a [`SweepStats`] aggregate. Because each
//! per-batch search is the deterministic serial engine and feasibility is
//! monotone in `b` under the §3.1 cost model (every memory term is
//! non-decreasing in the batch), the candidate set — and hence the result —
//! is identical for any thread count.
//!
//! The sweep is **incremental** over the symmetry fold: the class
//! partition, visit order, and every batch-independent suffix bound (the
//! menus' `time_fixed`/`states` terms) live in one shared
//! [`super::bound::Prefold`] built before the pool starts; each per-batch
//! search only recomputes the transient and `base_*` terms (and its greedy
//! seed) instead of rebuilding the whole space for every `b`. Under the
//! default [`Engine::Frontier`] the per-class composition frontiers are
//! likewise built **once per sweep** and shared read-only across every
//! batch size — they are batch-invariant by construction (see
//! [`super::frontier`]) — so the per-batch search work collapses to a
//! merge over precomputed Pareto sets.

use super::bound::Prefold;
use super::dfs::{self, DfsStats};
use super::frontier::{FrontierStats, Frontiers};
use super::progress;
use super::{Engine, ExecutionPlan};
use crate::cost::{PlanCost, Profiler};
use std::sync::Mutex;
use std::sync::atomic::{AtomicUsize, Ordering};

/// One batch size's best plan.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub plan: ExecutionPlan,
    /// Cluster-wide samples/second.
    pub throughput: f64,
    /// Full search diagnostics for this batch size (`stats.nodes` is the
    /// per-candidate search-engine node count).
    pub stats: DfsStats,
}

/// Aggregate search diagnostics across the batch sweep (the merge of every
/// kept candidate's [`DfsStats`]).
#[derive(Debug, Clone, Default)]
pub struct SweepStats {
    /// Batch sizes that produced a feasible plan.
    pub searches: usize,
    pub nodes: u64,
    pub pruned_mem: u64,
    pub pruned_time: u64,
    pub fast_completions: u64,
    /// True iff every kept search ran to completion (all results provably
    /// optimal for their batch size).
    pub complete: bool,
}

impl SweepStats {
    fn absorb(&mut self, s: &DfsStats) {
        self.searches += 1;
        self.nodes += s.nodes;
        self.pruned_mem += s.pruned_mem;
        self.pruned_time += s.pruned_time;
        self.fast_completions += s.fast_completions;
        self.complete &= s.complete;
    }

    /// One-line human summary for CLI/bench reports.
    pub fn describe(&self) -> String {
        format!(
            "{} searches, {} nodes ({} mem-pruned, {} time-pruned, {} fast){}",
            self.searches,
            self.nodes,
            self.pruned_mem,
            self.pruned_time,
            self.fast_completions,
            if self.complete { "" } else { " [budget expired]" },
        )
    }
}

/// Scheduler outcome: every candidate plus the winner index.
#[derive(Debug, Clone)]
pub struct SchedulerResult {
    pub candidates: Vec<Candidate>,
    pub best: usize,
    /// Total search-engine nodes across the batch sweep.
    pub total_nodes: u64,
    pub elapsed: std::time::Duration,
    /// Aggregate per-candidate diagnostics.
    pub stats: SweepStats,
    /// The one-time frontier build's statistics (composition/point counts
    /// per class); `None` for the branch-and-bound engines.
    pub frontier: Option<FrontierStats>,
    /// True when the sweep's stopping point is *proven*: either every
    /// batch up to `max_batch` was feasible (no wall), or the search at
    /// the first infeasible batch ran to completion. False means that
    /// failing search's node budget expired first — "nothing fits at
    /// b = n+1" is then the engine's verdict but not a certificate (the
    /// plan service refuses to cache the wall in that case).
    pub wall_complete: bool,
}

impl SchedulerResult {
    pub fn best_plan(&self) -> &ExecutionPlan {
        &self.candidates[self.best].plan
    }

    pub fn best_throughput(&self) -> f64 {
        self.candidates[self.best].throughput
    }
}

/// Algorithm 1's "nothing fits": even `b = 1` has no feasible plan
/// under the memory limit. The structured verdict carries the failing
/// search's own diagnostics, so callers read the completeness
/// certificate directly instead of re-running a `b = 1` probe to
/// establish it (the plan service caches the wall only when
/// [`SweepInfeasible::complete`] holds — a budget expiry is a verdict,
/// not a proof).
#[derive(Debug, Clone, Default)]
pub struct SweepInfeasible {
    /// The `b = 1` search's diagnostics (zeroed and not-complete in the
    /// degenerate `max_batch = 0` sweep, which searches nothing).
    pub stats: DfsStats,
}

impl SweepInfeasible {
    /// True iff the failing search ran to completion: infeasibility is
    /// proven, not an artifact of the node budget.
    pub fn complete(&self) -> bool {
        self.stats.complete
    }
}

/// Batch-size sweep driver.
pub struct Scheduler<'a> {
    pub profiler: &'a Profiler,
    pub mem_limit: f64,
    pub max_batch: usize,
    /// Worker threads for the sweep (1 = serial). Defaults to the
    /// hardware parallelism; the result is thread-count-invariant.
    pub threads: usize,
    /// Which exact engine every per-batch search runs
    /// ([`Engine::Frontier`] by default; identical results for all).
    pub engine: Engine,
    /// Optional warm-start seed (profiler-order choice vector, typically
    /// a cached neighbor query's plan handed down by the plan service):
    /// re-priced per batch size and installed as the initial incumbent
    /// wherever it is feasible. Only tightens pruning — the sweep result
    /// is bit-identical with or without it (see
    /// `crate::planner::dfs::search_warm`).
    pub warm: Option<Vec<usize>>,
    /// Per-batch node budget ([`dfs::DEFAULT_NODE_BUDGET`] by default).
    /// A search that exhausts it returns its best-so-far with
    /// `complete == false`; deep ladders (1000-layer stacks whose wide
    /// classes keep ~3m frontier points) raise it to keep the sweep's
    /// completeness certificate.
    pub node_budget: u64,
}

impl<'a> Scheduler<'a> {
    pub fn new(profiler: &'a Profiler, mem_limit: f64,
               max_batch: usize) -> Self {
        Scheduler {
            profiler,
            mem_limit,
            max_batch,
            threads: super::parallel::default_threads(),
            engine: Engine::Frontier,
            warm: None,
            node_budget: dfs::DEFAULT_NODE_BUDGET,
        }
    }

    /// Raise (or shrink) the per-batch node budget. Budgets never change
    /// a completed search's result — only whether `complete` certifies
    /// it — so any value is safe; deep-ladder benches raise it.
    pub fn with_budget(mut self, node_budget: u64) -> Self {
        self.node_budget = node_budget.max(1);
        self
    }

    /// Override the sweep's worker count (the CLI's `--threads`).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Pick the search engine (the CLI's `--engine`).
    pub fn with_engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    /// Install a warm-start seed for every per-batch search (the plan
    /// service's cached-neighbor incumbent). Bit-identical results,
    /// fewer nodes.
    pub fn with_warm(mut self, warm: Vec<usize>) -> Self {
        self.warm = Some(warm);
        self
    }

    /// Toggle the symmetry fold (the CLI's `--no-fold` escape hatch):
    /// `true` is the folded branch-and-bound, `false` the per-operator
    /// engine. Use [`Scheduler::with_engine`] for the frontier default.
    pub fn with_fold(mut self, fold: bool) -> Self {
        self.engine =
            if fold { Engine::FoldedBb } else { Engine::UnfoldedBb };
        self
    }

    /// Run Algorithm 1. `Err` when no batch size fits at all, carrying
    /// the `b = 1` search's diagnostics (its completeness certificate
    /// in particular).
    pub fn run(&self) -> Result<SchedulerResult, SweepInfeasible> {
        self.run_traced(None)
    }

    /// [`Scheduler::run`] with an optional search-trace observation:
    /// build vs descent wall-seconds, the frontier-build shape, and the
    /// *winning candidate's* convergence timeline. Each per-batch search
    /// is one serial walker, so sweep timelines are bit-reproducible at
    /// any thread count; tracing is inert and the result is bit-identical
    /// to the untraced run.
    pub fn run_traced(&self, trace: Option<&mut progress::SearchTrace>)
                      -> Result<SchedulerResult, SweepInfeasible> {
        let start = std::time::Instant::now();
        let traced = trace.is_some();
        let n_dev = self.profiler.cluster.n_devices;

        // Fold + batch-independent suffix structures — and, for the
        // frontier engine, the per-class composition frontiers — built
        // once, shared read-only by every worker and batch size.
        let prefold = Prefold::new(self.profiler);
        let frontiers = match self.engine {
            Engine::Frontier => {
                Some(Frontiers::new(&prefold, self.profiler))
            }
            _ => None,
        };

        let build_s = start.elapsed().as_secs_f64();
        let threads = self.threads.max(1).min(self.max_batch.max(1));
        let next = AtomicUsize::new(1);
        // lowest batch size known to be infeasible (the "memory wall")
        let wall = AtomicUsize::new(usize::MAX);
        type Row =
            (usize, Vec<usize>, PlanCost, DfsStats, Vec<progress::Improvement>);
        let found: Mutex<Vec<Row>> = Mutex::new(Vec::new());
        // per failed batch: that search's full diagnostics (its
        // `complete` flag is the proven-vs-budget-expired distinction)
        let failed: Mutex<Vec<(usize, DfsStats)>> = Mutex::new(Vec::new());

        // Known bounded overshoot: a worker already searching some b when
        // another worker lowers the wall below it runs that search to
        // completion and the row is discarded by the contiguous-prefix
        // filter — at most threads-1 wasted searches per sweep (infeasible
        // instances die fast on the memory bound). Cancelling mid-search
        // would thread a token through the walker for little gain.
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| {
                    loop {
                        let b = next.fetch_add(1, Ordering::Relaxed);
                        // claims increase monotonically: past the wall (or
                        // the cap) this worker can never see feasible work
                        if b > self.max_batch
                            || b >= wall.load(Ordering::Relaxed)
                        {
                            break;
                        }
                        let mut tl = if traced {
                            Some(progress::SearchTrace::default())
                        } else {
                            None
                        };
                        match dfs::search_prefolded_traced(
                            self.profiler,
                            &prefold,
                            frontiers.as_ref(),
                            self.mem_limit,
                            b,
                            self.node_budget,
                            self.engine,
                            self.warm.as_deref(),
                            tl.as_mut(),
                        ) {
                            (None, stats) => {
                                failed.lock().unwrap().push((b, stats));
                                wall.fetch_min(b, Ordering::Relaxed);
                                break;
                            }
                            (Some((choice, cost)), stats) => {
                                let timeline =
                                    tl.map(|t| t.timeline).unwrap_or_default();
                                found.lock().unwrap().push(
                                    (b, choice, cost, stats, timeline));
                            }
                        }
                    }
                });
            }
        });

        let mut rows = found.into_inner().unwrap();
        rows.sort_by_key(|r| r.0);
        // Keep only the contiguous feasible prefix starting at b=1 — the
        // serial sweep's stop-at-first-failure semantics, kept explicit so
        // even a non-monotone cost model could not change the result.
        let mut candidates = Vec::new();
        let mut timelines: Vec<Vec<progress::Improvement>> = Vec::new();
        let mut stats = SweepStats { complete: true, ..Default::default() };
        for (i, (b, choice, _cost, st, tl)) in rows.into_iter().enumerate() {
            if b != i + 1 {
                break;
            }
            let plan = ExecutionPlan::from_choice(self.profiler, choice, b);
            let throughput = plan.throughput(n_dev);
            stats.absorb(&st);
            candidates.push(Candidate { plan, throughput, stats: st });
            timelines.push(tl);
        }
        let failed = failed.into_inner().unwrap();
        if candidates.is_empty() {
            // the b = 1 search's diagnostics *are* the verdict; the
            // degenerate max_batch = 0 sweep searched nothing and gets
            // the default (not-complete) stats
            let stats = failed
                .iter()
                .find(|(b, _)| *b == 1)
                .map(|(_, st)| st.clone())
                .unwrap_or_default();
            return Err(SweepInfeasible { stats });
        }
        // The first gap is b = n+1; when it is below the cap some worker
        // searched exactly that batch and recorded its completeness (a
        // worker skips a batch only when it is at or past the recorded
        // wall, which is itself such a failure).
        let n = candidates.len();
        let wall_complete = n >= self.max_batch
            || failed
                .iter()
                .find(|(b, _)| *b == n + 1)
                .map(|(_, st)| st.complete)
                .unwrap_or(false);
        let best = pick_best(&candidates);
        let frontier_stats = frontiers.map(|f| f.stats());
        if let Some(t) = trace {
            t.build_s = build_s;
            t.descent_s = start.elapsed().as_secs_f64() - build_s;
            t.timeline = timelines.swap_remove(best);
            t.frontier = frontier_stats.clone();
        }
        Ok(SchedulerResult {
            best,
            total_nodes: stats.nodes,
            elapsed: start.elapsed(),
            stats,
            candidates,
            frontier: frontier_stats,
            wall_complete,
        })
    }
}

/// Winner of the sweep: highest throughput; exact ties go to the
/// *smallest* batch (explicitly — `max_by` would keep the last maximum,
/// i.e. the largest batch, a tie-break by iteration accident). Smaller
/// batches reach the same throughput with less memory headroom and lower
/// latency, so they are the canonical pick. `candidates` is sorted by
/// batch ascending and non-empty.
fn pick_best(candidates: &[Candidate]) -> usize {
    let mut best = 0;
    for (i, c) in candidates.iter().enumerate().skip(1) {
        if c.throughput > candidates[best].throughput {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Cluster, SearchConfig};
    use crate::cost::Profiler;
    use crate::model::{GptDims, build_gpt};

    fn profiler(n_dev: usize) -> Profiler {
        let m = build_gpt(&GptDims::uniform("t", 5000, 128, 2, 256, 4));
        let c = Cluster::rtx_titan(n_dev, 8.0);
        let s = SearchConfig { granularities: vec![0],
                               ..Default::default() };
        Profiler::new(&m, &c, &s)
    }

    #[test]
    fn sweep_stops_at_memory_wall() {
        let p = profiler(8);
        // pick a limit that fits only a handful of batch sizes
        let zdp1 = p.evaluate(&p.index_of(|d| d.is_pure_zdp()), 1);
        let limit = zdp1.peak_mem * 2.0;
        let res = Scheduler::new(&p, limit, 1024).run().unwrap();
        let n = res.candidates.len();
        assert!(n >= 1);
        assert!(n < 1024, "must hit the wall, got {n}");
        assert!(res.wall_complete,
                "this tiny instance's wall search must run to completion");
        // batch sizes are exactly 1..=n
        for (i, c) in res.candidates.iter().enumerate() {
            assert_eq!(c.plan.batch, i + 1);
            assert!(c.plan.cost.peak_mem <= limit);
        }
    }

    #[test]
    fn structured_infeasible_when_nothing_fits() {
        let p = profiler(8);
        let err = Scheduler::new(&p, 1.0, 16).run().unwrap_err();
        // this tiny instance dies on the memory bound long before the
        // node budget: the verdict must be a *certificate*
        assert!(err.complete(), "b=1 failure must be proven: {err:?}");
        assert!(err.stats.nodes > 0, "the b=1 search really ran");
        // the degenerate cap-zero sweep searches nothing and says so
        let err = Scheduler::new(&p, 1.0, 0).run().unwrap_err();
        assert!(!err.complete());
        assert_eq!(err.stats.nodes, 0);
    }

    #[test]
    fn node_budget_only_changes_the_certificate() {
        let p = profiler(8);
        let dp1 = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1);
        let limit = dp1.peak_mem * 3.0;
        let base = Scheduler::new(&p, limit, 8).run().unwrap();
        // a raised budget is invisible on an instance the default
        // budget already completes
        let high = Scheduler::new(&p, limit, 8)
            .with_budget(u64::MAX)
            .run()
            .unwrap();
        assert_eq!(base.candidates.len(), high.candidates.len());
        for (a, b) in base.candidates.iter().zip(&high.candidates) {
            assert_eq!(a.plan.choice, b.plan.choice);
            assert_eq!(a.plan.cost.time.to_bits(),
                       b.plan.cost.time.to_bits());
        }
        // a starved budget may cost candidates or certificates, but a
        // batch it *does* complete must carry the identical plan
        match Scheduler::new(&p, limit, 8).with_budget(1).run() {
            Err(err) => assert!(!err.complete(),
                                "starved b=1 must not certify"),
            Ok(res) => {
                for (a, b) in res.candidates.iter().zip(&base.candidates) {
                    if a.stats.complete {
                        assert_eq!(a.plan.choice, b.plan.choice);
                    }
                }
            }
        }
    }

    #[test]
    fn best_candidate_maximizes_throughput() {
        let p = profiler(8);
        let dp1 = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1);
        let res = Scheduler::new(&p, dp1.peak_mem * 4.0, 64).run().unwrap();
        let best_tp = res.best_throughput();
        for c in &res.candidates {
            assert!(c.throughput <= best_tp + 1e-12);
        }
    }

    #[test]
    fn larger_memory_never_hurts_throughput() {
        let p = profiler(8);
        let base = p.evaluate(&p.index_of(|d| d.is_pure_zdp()), 1).peak_mem;
        let mut last = 0.0;
        for mult in [1.5, 2.5, 4.0, 8.0] {
            if let Ok(res) = Scheduler::new(&p, base * mult, 64).run() {
                let tp = res.best_throughput();
                assert!(tp >= last - 1e-9,
                        "throughput regressed with more memory");
                last = tp;
            }
        }
        assert!(last > 0.0);
    }

    #[test]
    fn throughput_counts_all_devices() {
        let p4 = profiler(4);
        let res = Scheduler::new(&p4, 1e18, 4).run().unwrap();
        let c = &res.candidates[0];
        let per_dev = c.plan.batch as f64 / c.plan.cost.time;
        assert!((c.throughput - per_dev * 4.0).abs() < 1e-9);
    }

    #[test]
    fn throughput_ties_resolve_to_smallest_batch() {
        let p = profiler(8);
        let mk = |batch: usize, throughput: f64| {
            let choice = p.index_of(|d| d.is_pure_dp());
            Candidate {
                plan: ExecutionPlan::from_choice(&p, choice, batch),
                throughput,
                stats: DfsStats::default(),
            }
        };
        let cands = vec![mk(1, 5.0), mk(2, 9.0), mk(3, 9.0), mk(4, 7.0)];
        assert_eq!(pick_best(&cands), 1, "tie must keep the smaller batch");
        assert_eq!(pick_best(&cands[..1]), 0);
    }

    #[test]
    fn folded_and_unfolded_sweeps_agree() {
        let p = profiler(8);
        let dp1 = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1);
        let limit = dp1.peak_mem * 3.0;
        let folded =
            Scheduler::new(&p, limit, 24).with_fold(true).run().unwrap();
        let plain =
            Scheduler::new(&p, limit, 24).with_fold(false).run().unwrap();
        assert_eq!(folded.best, plain.best);
        assert_eq!(folded.candidates.len(), plain.candidates.len());
        for (a, b) in folded.candidates.iter().zip(&plain.candidates) {
            assert_eq!(a.plan.choice, b.plan.choice);
            assert_eq!(a.plan.cost.time.to_bits(), b.plan.cost.time.to_bits());
        }
    }

    #[test]
    fn frontier_sweep_matches_bb_sweeps_and_explores_no_more() {
        let p = profiler(8);
        let dp1 = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1);
        let limit = dp1.peak_mem * 3.0;
        let fr = Scheduler::new(&p, limit, 24).run().unwrap();
        let stats = fr.frontier.as_ref().expect("default engine is frontier");
        assert!(stats.points > 0);
        assert_eq!(stats.per_class.len(), stats.classes);
        // structural since the incremental build: no class is ever too
        // wide to prebuild, and the build tracks its widest level
        assert_eq!(stats.too_wide, 0, "every class prebuilds");
        assert!(stats.max_level_width >= 1);
        assert!(stats.per_class.iter().all(|c| c.kept <= c.raw));
        let folded = Scheduler::new(&p, limit, 24)
            .with_engine(Engine::FoldedBb)
            .run()
            .unwrap();
        assert!(folded.frontier.is_none());
        assert_eq!(fr.best, folded.best);
        assert_eq!(fr.candidates.len(), folded.candidates.len());
        for (a, b) in fr.candidates.iter().zip(&folded.candidates) {
            assert_eq!(a.plan.choice, b.plan.choice);
            assert_eq!(a.plan.cost.time.to_bits(),
                       b.plan.cost.time.to_bits());
            assert!(a.stats.nodes <= b.stats.nodes,
                    "frontier explored more nodes than the fold at b={}",
                    a.plan.batch);
        }
    }

    #[test]
    fn parallel_sweep_matches_serial_sweep() {
        let p = profiler(8);
        let dp1 = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1);
        let limit = dp1.peak_mem * 3.0;
        let serial =
            Scheduler::new(&p, limit, 32).with_threads(1).run().unwrap();
        let par =
            Scheduler::new(&p, limit, 32).with_threads(8).run().unwrap();
        assert_eq!(serial.candidates.len(), par.candidates.len());
        assert_eq!(serial.best, par.best);
        assert_eq!(serial.total_nodes, par.total_nodes);
        for (a, b) in serial.candidates.iter().zip(&par.candidates) {
            assert_eq!(a.plan.choice, b.plan.choice);
            assert_eq!(a.plan.cost.time.to_bits(),
                       b.plan.cost.time.to_bits());
            assert_eq!(a.stats, b.stats);
        }
        assert!(par.stats.complete);
        assert_eq!(par.stats.searches, par.candidates.len());
    }
}
