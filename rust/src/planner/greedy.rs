//! Greedy planner (ablation baseline): start from the all-fastest plan and
//! repeatedly apply the memory-per-time-cheapest decision downgrade until
//! the plan fits. Near-optimal here because both the time penalty and the
//! memory saving of sharding an operator scale with its parameter bytes —
//! but not exact (see tests for a constructed gap), which is why the paper
//! (and we) search.

use crate::cost::{PlanCost, Profiler};

/// Greedy descent. Returns `None` when even the memory-minimal plan
/// violates the limit.
pub fn search(profiler: &Profiler, mem_limit: f64, b: usize)
              -> Option<(Vec<usize>, PlanCost)> {
    // option 0 = fastest per op
    search_from(profiler, mem_limit, b, &vec![0usize; profiler.n_ops()])
}

/// Greedy descent from an arbitrary start plan — the plan service's
/// **warm-start repair**: a cached neighbor plan that no longer fits at
/// this `(mem_limit, b)` is downgraded along the same
/// best-memory-per-time moves until it does, which keeps it a useful
/// incumbent instead of discarding it (a plan one batch away is usually
/// one or two downgrades from optimal). Starting from the all-fastest
/// plan is exactly [`search`]. Malformed starts (wrong length,
/// out-of-menu indices — e.g. a stale cache entry) and unrepairable
/// starts return `None`; since moves only advance menu indices, the
/// loop terminates in at most `Σ |menu|` steps.
pub fn search_from(profiler: &Profiler, mem_limit: f64, b: usize,
                   start: &[usize]) -> Option<(Vec<usize>, PlanCost)> {
    let n = profiler.n_ops();
    if start.len() != n
        || start
            .iter()
            .zip(&profiler.tables)
            .any(|(&c, t)| c >= t.options.len())
    {
        return None;
    }
    let mut choice = start.to_vec();
    let mut cost = profiler.evaluate(&choice, b);
    while cost.peak_mem > mem_limit {
        // candidate moves: advance any op to any later (smaller) option;
        // pick the best Δmem/Δtime ratio (Δmem>0 by Pareto ordering)
        let mut best: Option<(usize, usize, f64)> = None;
        for i in 0..n {
            let t = &profiler.tables[i];
            let cur = &t.options[choice[i]];
            for c in choice[i] + 1..t.options.len() {
                let cand = &t.options[c];
                let dmem = (cur.states - cand.states)
                    + (cur.gather - cand.gather).max(0.0);
                let dtime = cand.time_fixed() - cur.time_fixed();
                if dmem <= 0.0 {
                    continue;
                }
                let ratio = dmem / dtime.max(1e-15);
                if best.map(|(_, _, r)| ratio > r).unwrap_or(true) {
                    best = Some((i, c, ratio));
                }
            }
        }
        let (i, c, _) = best?; // no downgrades left -> infeasible
        choice[i] = c;
        cost = profiler.evaluate(&choice, b);
    }
    Some((choice, cost))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Cluster, SearchConfig};
    use crate::cost::Profiler;
    use crate::model::{GptDims, build_gpt};
    use crate::planner::dfs;

    fn profiler() -> Profiler {
        let m = build_gpt(&GptDims::uniform("t", 5000, 128, 2, 256, 4));
        let c = Cluster::rtx_titan(8, 8.0);
        let s = SearchConfig { granularities: vec![0, 4],
                               ..Default::default() };
        Profiler::new(&m, &c, &s)
    }

    #[test]
    fn feasible_and_never_better_than_dfs() {
        let p = profiler();
        let dp = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 2);
        for frac in [0.5, 0.7, 0.9] {
            let limit = dp.peak_mem * frac;
            let g = search(&p, limit, 2);
            let d = dfs::search(&p, limit, 2);
            match (g, d) {
                (Some((_, gc)), Some((_, dc, _))) => {
                    assert!(gc.peak_mem <= limit);
                    assert!(
                        gc.time >= dc.time - 1e-12,
                        "greedy {} cannot beat exact {}",
                        gc.time,
                        dc.time
                    );
                    // and shouldn't be wildly off on this well-behaved family
                    assert!(gc.time <= dc.time * 1.25);
                }
                (None, None) => {}
                other => panic!("feasibility disagreement {other:?}"),
            }
        }
    }

    #[test]
    fn unlimited_memory_returns_all_fastest() {
        let p = profiler();
        let (choice, _) = search(&p, 1e18, 1).unwrap();
        assert!(choice.iter().all(|&c| c == 0));
    }

    #[test]
    fn infeasible_detected() {
        let p = profiler();
        assert!(search(&p, 1.0, 1).is_none());
    }

    #[test]
    fn search_from_repairs_or_rejects() {
        let p = profiler();
        let dp = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 2);
        // starting from all-fastest is exactly the classic greedy
        let a = search(&p, dp.peak_mem * 0.6, 2).unwrap();
        let b = search_from(&p, dp.peak_mem * 0.6, 2,
                            &vec![0; p.n_ops()])
            .unwrap();
        assert_eq!(a.0, b.0);
        // a feasible start passes through untouched...
        let (repaired, cost) =
            search_from(&p, dp.peak_mem * 0.6, 2, &a.0).unwrap();
        assert!(cost.peak_mem <= dp.peak_mem * 0.6);
        assert_eq!(repaired, a.0, "feasible start needs no repair");
        // ...while a start that no longer fits a tighter limit is
        // downgraded until it does
        let tight = search_from(&p, dp.peak_mem * 0.45, 2, &a.0);
        if let Some((_, c)) = tight {
            assert!(c.peak_mem <= dp.peak_mem * 0.45);
        }
        // malformed starts are rejected, not panicked on
        assert!(search_from(&p, 1e18, 2, &vec![0; p.n_ops() + 1])
            .is_none());
        assert!(search_from(&p, 1e18, 2,
                            &vec![usize::MAX; p.n_ops()])
            .is_none());
        // unrepairable: nothing fits one byte
        assert!(search_from(&p, 1.0, 1, &vec![0; p.n_ops()]).is_none());
    }
}
