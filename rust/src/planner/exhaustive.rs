//! Brute-force plan enumeration: ground truth the DFS is validated against
//! (only viable for small operator counts; tests keep `Π|menu| ≤ ~1e6`).

use crate::cost::{PlanCost, Profiler};

/// Enumerate every decision combination; return the feasible minimum-time
/// plan, or `None` if nothing fits.
pub fn search(profiler: &Profiler, mem_limit: f64, b: usize)
              -> Option<(Vec<usize>, PlanCost)> {
    let n = profiler.n_ops();
    let mut choice = vec![0usize; n];
    let mut best: Option<(Vec<usize>, PlanCost)> = None;
    loop {
        let cost = profiler.evaluate(&choice, b);
        if cost.peak_mem <= mem_limit {
            let better = match &best {
                None => true,
                Some((_, c)) => cost.time < c.time,
            };
            if better {
                best = Some((choice.clone(), cost));
            }
        }
        // odometer increment
        let mut i = 0;
        loop {
            if i == n {
                return best;
            }
            choice[i] += 1;
            if choice[i] < profiler.tables[i].options.len() {
                break;
            }
            choice[i] = 0;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Cluster, SearchConfig};
    use crate::cost::Profiler;
    use crate::model::{GptDims, build_gpt};
    use crate::planner::dfs;
    use crate::util::rng::Rng;

    /// The core exactness guarantee: DFS == brute force on every feasible
    /// instance we can afford to enumerate.
    #[test]
    fn dfs_matches_exhaustive_across_limits() {
        let m = build_gpt(&GptDims::uniform("t", 2000, 64, 1, 96, 4));
        let c = Cluster::rtx_titan(4, 8.0);
        let s = SearchConfig { granularities: vec![0], ..Default::default() };
        let p = Profiler::new(&m, &c, &s);
        let dp_mem = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 2).peak_mem;
        let zdp_mem = p.evaluate(&p.index_of(|d| d.is_pure_zdp()), 2).peak_mem;
        for frac in [0.95, 0.99, 1.02, 1.1, 1.5] {
            let limit = zdp_mem + (dp_mem - zdp_mem) * frac / 1.5;
            let brute = search(&p, limit, 2);
            let smart = dfs::search(&p, limit, 2);
            match (brute, smart) {
                (None, None) => {}
                (Some((_, bc)), Some((_, sc, _))) => {
                    assert!(
                        (bc.time - sc.time).abs() < 1e-12,
                        "limit {limit}: brute {} vs dfs {}",
                        bc.time,
                        sc.time
                    );
                    assert!(sc.peak_mem <= limit);
                }
                (b, s) => panic!(
                    "feasibility disagreement at {limit}: brute={:?} dfs={:?}",
                    b.map(|x| x.1),
                    s.map(|x| x.1)
                ),
            }
        }
    }

    /// Property: random small instances with splitting menus.
    #[test]
    fn dfs_matches_exhaustive_random_instances() {
        let mut rng = Rng::new(0xD15C);
        for trial in 0..8 {
            let hidden = 32 * rng.range(1, 4);
            let m = build_gpt(&GptDims::uniform("t", 500, 32, 1, hidden, 2));
            let c = Cluster::rtx_titan(rng.range(2, 8), 8.0);
            let s = SearchConfig {
                granularities: vec![0, 2],
                ..Default::default()
            };
            let p = Profiler::new(&m, &c, &s);
            let b = rng.range(1, 4);
            let dp_mem =
                p.evaluate(&p.index_of(|d| d.is_pure_dp()), b).peak_mem;
            let limit = dp_mem * (0.3 + rng.f64() * 0.9);
            let brute = search(&p, limit, b);
            let smart = dfs::search(&p, limit, b);
            match (brute, smart) {
                (None, None) => {}
                (Some((_, bc)), Some((_, sc, _))) => assert!(
                    (bc.time - sc.time).abs() <= 1e-12 * bc.time.max(1.0),
                    "trial {trial}: brute {} dfs {}",
                    bc.time,
                    sc.time
                ),
                (b, s) => panic!(
                    "trial {trial}: disagreement brute={:?} dfs={:?}",
                    b.map(|x| x.1),
                    s.map(|x| x.1)
                ),
            }
        }
    }
}
