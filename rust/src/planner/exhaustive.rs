//! Brute-force plan enumeration: ground truth the exact engines are
//! validated against.
//!
//! Two enumerators, both optimizing the *same* canonical objective as the
//! branch-and-bound engines — `(total, lex)` where `total` is the search
//! arithmetic `base_time + Σ time_fixed` (grid-exact, see
//! `cost::time::TIME_GRID`) and `lex` is over the planner's visit order —
//! so ground-truth comparisons can assert **full choice-vector
//! equality**, not just time:
//!
//! * [`search`] — folded over the symmetry classes: enumerates each
//!   class's monotone option blocks (the canonical representatives of its
//!   count compositions) instead of the raw per-operator product, so
//!   exhaustive anchors scale to deeper stacks. Exact by the same
//!   argument as the folded engine (`planner::bound`): permuting
//!   same-class decisions changes no cost bit, and the `(total, lex)`
//!   optimum is always a monotone assignment.
//! * [`search_unfolded`] — the raw product space, for instances whose
//!   menus were *not* built with the interchangeability invariants (and
//!   as ground truth for the fold of this very enumerator).
//!
//! Ties are compared on the search-arithmetic total rather than
//! `evaluate()`'s time because the latter adds an unsnapped compute term
//! that can round two distinct `time_fixed` sums into the same f64 —
//! exactly the collapse the grid exists to avoid. (The previous
//! implementation instead kept the first minimum in odometer order with
//! index 0 varying fastest — i.e. *reverse*-lex in profiler order — which
//! made tie instances incomparable against the engines' canonical
//! `(time, lex)` choice.)

use super::bound::{Prefold, base_time, lex_less, next_monotone_block};
use crate::cost::{PlanCost, Profiler};

/// Offer one feasible plan to the incumbent under the canonical
/// `(total, lex-in-visit-order)` objective.
fn consider(profiler: &Profiler, pre: &Prefold, base: f64, mem_limit: f64,
            b: usize, ordered: &[usize],
            best: &mut Option<(f64, Vec<usize>, Vec<usize>)>) {
    let mut time_fixed = 0.0;
    for (pos, &c) in ordered.iter().enumerate() {
        time_fixed += profiler.tables[pre.order[pos]].options[c].time_fixed();
    }
    let total = base + time_fixed;
    let choice = pre.unpermute(ordered);
    if profiler.evaluate(&choice, b).peak_mem > mem_limit {
        return;
    }
    let better = match best {
        None => true,
        Some((bt, bo, _)) => {
            total < *bt || (total == *bt && lex_less(ordered, bo))
        }
    };
    if better {
        *best = Some((total, ordered.to_vec(), choice));
    }
}

fn finish(profiler: &Profiler, b: usize,
          best: Option<(f64, Vec<usize>, Vec<usize>)>)
          -> Option<(Vec<usize>, PlanCost)> {
    best.map(|(_, _, choice)| {
        let cost = profiler.evaluate(&choice, b);
        (choice, cost)
    })
}

/// Enumerate every *distinct-cost* decision combination — one monotone
/// option block per class and count composition — and return the feasible
/// `(total, lex)`-minimum plan, or `None` if nothing fits. Matches the
/// exact engines bit-for-bit, choice vector included.
pub fn search(profiler: &Profiler, mem_limit: f64, b: usize)
              -> Option<(Vec<usize>, PlanCost)> {
    let pre = Prefold::new(profiler);
    let n = pre.n();
    let n_classes = pre.n_classes();
    let base = base_time(profiler, b);
    let mut ordered = vec![0usize; n];
    let mut best: Option<(f64, Vec<usize>, Vec<usize>)> = None;
    loop {
        consider(profiler, &pre, base, mem_limit, b, &ordered, &mut best);
        // odometer over classes, rightmost fastest; each class steps
        // through its monotone blocks in lex order
        let mut k = n_classes;
        loop {
            if k == 0 {
                return finish(profiler, b, best);
            }
            k -= 1;
            let (s, e) = (pre.class_start[k], pre.class_start[k + 1]);
            let o = profiler.tables[pre.order[s]].options.len();
            if next_monotone_block(&mut ordered[s..e], o) {
                break;
            }
            for slot in ordered[s..e].iter_mut() {
                *slot = 0;
            }
        }
    }
}

/// Enumerate the raw per-operator product space under the same
/// `(total, lex)` objective. Exponentially larger than [`search`] on
/// symmetric models (tests keep `Π|menu| ≤ ~1e6`); ground truth for the
/// folded enumerator itself.
pub fn search_unfolded(profiler: &Profiler, mem_limit: f64, b: usize)
                       -> Option<(Vec<usize>, PlanCost)> {
    let pre = Prefold::new(profiler);
    let n = profiler.n_ops();
    let base = base_time(profiler, b);
    let mut choice = vec![0usize; n];
    let mut ordered = vec![0usize; n];
    let mut best: Option<(f64, Vec<usize>, Vec<usize>)> = None;
    loop {
        for (pos, &op) in pre.order.iter().enumerate() {
            ordered[pos] = choice[op];
        }
        consider(profiler, &pre, base, mem_limit, b, &ordered, &mut best);
        // odometer increment (profiler order; enumeration order is
        // irrelevant because the comparison above is explicit)
        let mut i = 0;
        loop {
            if i == n {
                return finish(profiler, b, best);
            }
            choice[i] += 1;
            if choice[i] < profiler.tables[i].options.len() {
                break;
            }
            choice[i] = 0;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Cluster, SearchConfig};
    use crate::cost::Profiler;
    use crate::model::{GptDims, build_gpt};
    use crate::planner::dfs;
    use crate::util::rng::Rng;

    /// The core exactness guarantee: DFS == brute force on every feasible
    /// instance we can afford to enumerate — including the full choice
    /// vector, now that both optimize the same `(total, lex)` objective.
    #[test]
    fn dfs_matches_exhaustive_across_limits() {
        let m = build_gpt(&GptDims::uniform("t", 2000, 64, 1, 96, 4));
        let c = Cluster::rtx_titan(4, 8.0);
        let s = SearchConfig { granularities: vec![0], ..Default::default() };
        let p = Profiler::new(&m, &c, &s);
        let dp_mem = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 2).peak_mem;
        let zdp_mem = p.evaluate(&p.index_of(|d| d.is_pure_zdp()), 2).peak_mem;
        for frac in [0.95, 0.99, 1.02, 1.1, 1.5] {
            let limit = zdp_mem + (dp_mem - zdp_mem) * frac / 1.5;
            let brute = search(&p, limit, 2);
            let smart = dfs::search(&p, limit, 2);
            match (brute, smart) {
                (None, None) => {}
                (Some((bchoice, bc)), Some((schoice, sc, _))) => {
                    assert_eq!(bchoice, schoice, "limit {limit}");
                    assert_eq!(bc.time.to_bits(), sc.time.to_bits());
                    assert!(sc.peak_mem <= limit);
                }
                (b, s) => panic!(
                    "feasibility disagreement at {limit}: brute={:?} dfs={:?}",
                    b.map(|x| x.1),
                    s.map(|x| x.1)
                ),
            }
        }
    }

    /// Property: random small instances with splitting menus.
    #[test]
    fn dfs_matches_exhaustive_random_instances() {
        let mut rng = Rng::new(0xD15C);
        for trial in 0..8 {
            let hidden = 32 * rng.range(1, 4);
            let m = build_gpt(&GptDims::uniform("t", 500, 32, 1, hidden, 2));
            let c = Cluster::rtx_titan(rng.range(2, 8), 8.0);
            let s = SearchConfig {
                granularities: vec![0, 2],
                ..Default::default()
            };
            let p = Profiler::new(&m, &c, &s);
            let b = rng.range(1, 4);
            let dp_mem =
                p.evaluate(&p.index_of(|d| d.is_pure_dp()), b).peak_mem;
            let limit = dp_mem * (0.3 + rng.f64() * 0.9);
            let brute = search(&p, limit, b);
            let smart = dfs::search(&p, limit, b);
            match (brute, smart) {
                (None, None) => {}
                (Some((bchoice, bc)), Some((schoice, sc, _))) => {
                    assert_eq!(bchoice, schoice, "trial {trial}");
                    assert_eq!(bc.time.to_bits(), sc.time.to_bits(),
                               "trial {trial}");
                }
                (b, s) => panic!(
                    "trial {trial}: disagreement brute={:?} dfs={:?}",
                    b.map(|x| x.1),
                    s.map(|x| x.1)
                ),
            }
        }
    }

    /// The fold of the enumerator itself is exact: folded and raw-product
    /// enumeration return the identical choice vector (not just time) on
    /// symmetric models, where ties across interchangeable operators are
    /// the norm.
    #[test]
    fn folded_enumeration_matches_raw_product() {
        let m = build_gpt(&GptDims::uniform("t", 800, 32, 2, 64, 2));
        let c = Cluster::rtx_titan(4, 8.0);
        let s = SearchConfig { granularities: vec![0],
                               ..Default::default() };
        let p = Profiler::new(&m, &c, &s);
        assert!(p.log10_plan_space() < 6.0, "keep the product affordable");
        let dp_mem = p.evaluate(&p.index_of(|d| d.is_pure_dp()), 1).peak_mem;
        let mut feasible = 0;
        for frac in [0.3, 0.55, 0.8, 1.1] {
            let limit = dp_mem * frac;
            let folded = search(&p, limit, 1);
            let raw = search_unfolded(&p, limit, 1);
            match (folded, raw) {
                (None, None) => {}
                (Some((fc, fcost)), Some((rc, rcost))) => {
                    assert_eq!(fc, rc, "frac {frac}");
                    assert_eq!(fcost.time.to_bits(), rcost.time.to_bits());
                    assert_eq!(fcost.peak_mem.to_bits(),
                               rcost.peak_mem.to_bits());
                    feasible += 1;
                }
                _ => panic!("feasibility disagreement at frac {frac}"),
            }
        }
        assert!(feasible > 0, "sweep must exercise feasible limits");
    }
}
