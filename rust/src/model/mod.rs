//! Model description: the operator graph OSDP plans over.
//!
//! The paper's search space is *per operator*: each operator `i` carries a
//! parameter size `S_i` (bytes communicated by sharding collectives), the
//! three memory factors `M_model / M_act / M_extra` (§3.1), and a
//! per-sample compute cost used to derive `γ_i`.  `gpt.rs` builds this
//! inventory for GPT-like Transformers; `zoo.rs` instantiates the paper's
//! N&D / W&S / I&C families (Table 1).

pub mod gpt;
pub mod zoo;

pub use gpt::{GptDims, build_gpt};
pub use zoo::{Family, ZooEntry, zoo};

/// Bytes per fp32 element.
pub const F32: f64 = 4.0;

/// Model states per parameter under mixed Adam training: fp32 param + grad
/// + two Adam moments (the paper's "model parameters and optimizer states").
pub const STATE_BYTES_PER_PARAM: f64 = 16.0;

/// Operator category — drives the sizing formulas and lets the planner /
/// reports group results.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    Embedding,
    LayerNorm,
    /// A dense matmul `in_dim -> out_dim`; the paper's splitting target.
    MatMul,
    /// Parameter-free attention context (softmax(QKᵀ)V).
    Attention,
    /// LM head projection to vocabulary.
    Head,
}

impl OpKind {
    pub fn short(&self) -> &'static str {
        match self {
            OpKind::Embedding => "emb",
            OpKind::LayerNorm => "ln",
            OpKind::MatMul => "mm",
            OpKind::Attention => "attn",
            OpKind::Head => "head",
        }
    }
}

/// One operator in the computation graph (one decision variable `p_i`).
#[derive(Debug, Clone)]
pub struct Operator {
    /// Human-readable name, e.g. `l12.mlp_up`.
    pub name: String,
    pub kind: OpKind,
    /// Which layer this op belongs to (None for embed/head) — used by the
    /// pipeline-parallel baseline to form stages.
    pub layer: Option<usize>,
    /// Trainable parameter count.
    pub params: f64,
    /// Activation bytes *per sample* stored for backward (`b · M_act`).
    pub act_bytes_per_sample: f64,
    /// Activation bytes per sample that remain resident when checkpointing
    /// is on (segment boundaries only; interior activations are recomputed).
    pub ckpt_act_bytes_per_sample: f64,
    /// Mode-independent workspace bytes (`M_extra`).
    pub extra_bytes: f64,
    /// Forward+backward FLOPs per sample (≈ 3× forward for matmuls); the
    /// profiler converts this to `γ_i` via the device FLOP rate.
    pub flops_per_sample: f64,
    /// For MatMul ops: (in_dim, out_dim) — operator splitting slices
    /// `out_dim`-side weight rows (Figure 4).
    pub matmul_dims: Option<(usize, usize)>,
}

impl Operator {
    /// Parameter bytes = the `S_i` in the paper's comm formulas.
    pub fn param_bytes(&self) -> f64 {
        self.params * F32
    }

    /// Full model-state bytes (params + grads + Adam moments).
    pub fn state_bytes(&self) -> f64 {
        self.params * STATE_BYTES_PER_PARAM
    }

    /// Whether sharding this op moves any bytes (LN/attention are free).
    pub fn shardable(&self) -> bool {
        self.params > 0.0
    }
}

/// A full model: an ordered operator list plus descriptive metadata.
#[derive(Debug, Clone)]
pub struct ModelDesc {
    pub name: String,
    pub ops: Vec<Operator>,
    /// Sequence length the sizing assumed.
    pub seq: usize,
    /// Layer count (transformer blocks).
    pub layers: usize,
    /// Representative hidden size (max over layers for I&C).
    pub hidden: usize,
}

impl ModelDesc {
    pub fn param_count(&self) -> f64 {
        self.ops.iter().map(|o| o.params).sum()
    }

    pub fn state_bytes(&self) -> f64 {
        self.ops.iter().map(|o| o.state_bytes()).sum()
    }

    pub fn act_bytes_per_sample(&self) -> f64 {
        self.ops.iter().map(|o| o.act_bytes_per_sample).sum()
    }

    pub fn flops_per_sample(&self) -> f64 {
        self.ops.iter().map(|o| o.flops_per_sample).sum()
    }

    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    /// Fuse fine-grained ops into the paper's ~2-ops-per-layer granularity
    /// (attention block + MLP block, embed, head) so Table 1's "Operator
    /// Num" column reproduces. Planning on the fused graph is coarser but
    /// cheaper; both granularities are supported everywhere.
    pub fn fuse_paper_granularity(&self) -> ModelDesc {
        let mut fused: Vec<Operator> = Vec::new();
        for op in &self.ops {
            let target = match (op.layer, op.kind) {
                (None, _) => None, // embed / head stay as-is
                (Some(l), k) => {
                    let block = match k {
                        OpKind::Attention => "attn",
                        OpKind::MatMul | OpKind::LayerNorm => {
                            if op.name.contains("mlp") || op.name.contains("ln2")
                            {
                                "mlp"
                            } else {
                                "attn"
                            }
                        }
                        _ => "attn",
                    };
                    Some((l, block))
                }
            };
            match target {
                None => fused.push(op.clone()),
                Some((l, block)) => {
                    let name = format!("l{l}.{block}");
                    if let Some(f) = fused.iter_mut().find(|f| f.name == name) {
                        f.params += op.params;
                        f.act_bytes_per_sample += op.act_bytes_per_sample;
                        f.ckpt_act_bytes_per_sample +=
                            op.ckpt_act_bytes_per_sample;
                        f.extra_bytes = f.extra_bytes.max(op.extra_bytes);
                        f.flops_per_sample += op.flops_per_sample;
                        // keep the largest matmul as the splitting target
                        if let Some(d) = op.matmul_dims {
                            let keep = match f.matmul_dims {
                                Some((a, b)) => a * b < d.0 * d.1,
                                None => true,
                            };
                            if keep {
                                f.matmul_dims = Some(d);
                            }
                        }
                    } else {
                        let mut f = op.clone();
                        f.name = name;
                        f.kind = if block == "mlp" {
                            OpKind::MatMul
                        } else {
                            OpKind::Attention
                        };
                        fused.push(f);
                    }
                }
            }
        }
        // Fold the final LayerNorm into the head op so the coarse count is
        // exactly 2·layers + 2 (embed + blocks + head), matching Table 1.
        if let Some(lnf_pos) = fused.iter().position(|o| o.name == "lnf") {
            let lnf = fused.remove(lnf_pos);
            if let Some(head) = fused.iter_mut().find(|o| o.kind == OpKind::Head)
            {
                head.params += lnf.params;
                head.act_bytes_per_sample += lnf.act_bytes_per_sample;
                head.ckpt_act_bytes_per_sample +=
                    lnf.ckpt_act_bytes_per_sample;
                head.flops_per_sample += lnf.flops_per_sample;
            } else {
                fused.insert(lnf_pos, lnf);
            }
        }
        ModelDesc { name: format!("{}(fused)", self.name), ops: fused, ..self.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> ModelDesc {
        build_gpt(&GptDims {
            name: "toy".into(),
            vocab: 1000,
            seq: 64,
            layers: 2,
            hidden_per_layer: vec![32, 32],
            heads: 2,
            tied_head: false,
        })
    }

    #[test]
    fn fused_has_two_ops_per_layer_plus_two() {
        let m = toy().fuse_paper_granularity();
        assert_eq!(m.n_ops(), 2 * 2 + 2);
    }

    #[test]
    fn fusing_preserves_totals() {
        let m = toy();
        let f = m.fuse_paper_granularity();
        assert!((m.param_count() - f.param_count()).abs() < 1e-6);
        assert!(
            (m.act_bytes_per_sample() - f.act_bytes_per_sample()).abs() < 1e-6
        );
        assert!((m.flops_per_sample() - f.flops_per_sample()).abs() < 1.0);
    }

    #[test]
    fn state_bytes_is_16x_params() {
        let m = toy();
        assert_eq!(m.state_bytes(), m.param_count() * 16.0);
    }
}
