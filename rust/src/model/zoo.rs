//! The paper's model zoo (Table 1): narrow & deep (N&D), wide & shallow
//! (W&S), and inconsistent & consecutive (I&C) GPT variants.
//!
//! | Model | Layer Num | Operator Num | Hidden Size | Param. Num |
//! |-------|-----------|--------------|-------------|------------|
//! | N&D   | 48-96     | 98-194       | 1024-1536   | 1.3-2.9B   |
//! | W&S   | 2-4       | 6-10         | 6144-12288  | 1.7-4B     |
//! | I&C   | 24-96     | 50-194       | 1024-4096   | 0.9-2.3B   |
//!
//! "Operator Num" counts the paper's coarse granularity (2 ops/layer + 2 =
//! `ModelDesc::fuse_paper_granularity`).

use super::gpt::{GptDims, build_gpt};
use super::ModelDesc;

/// Paper model family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    /// Narrow & deep (GPT-2 / BERT / T5-like).
    NarrowDeep,
    /// Wide & shallow (GPT-3-like layers that barely fit one device).
    WideShallow,
    /// Inconsistent & consecutive (Swin-like mixed hidden sizes).
    InconsistentConsecutive,
}

impl Family {
    pub fn label(&self) -> &'static str {
        match self {
            Family::NarrowDeep => "N&D",
            Family::WideShallow => "W&S",
            Family::InconsistentConsecutive => "I&C",
        }
    }
}

/// One zoo configuration (one x-axis setting in Figures 5/6/8/9).
#[derive(Debug, Clone)]
pub struct ZooEntry {
    pub family: Family,
    /// Figure x-label, e.g. "48L/1024H".
    pub setting: String,
    pub model: ModelDesc,
}

const VOCAB: usize = 50257;
const SEQ: usize = 256;
const HEADS: usize = 16;

fn nd(layers: usize, hidden: usize) -> ZooEntry {
    let dims = GptDims::uniform(
        &format!("N&D-{layers}L-{hidden}H"), VOCAB, SEQ, layers, hidden, HEADS);
    ZooEntry {
        family: Family::NarrowDeep,
        setting: format!("{layers}L/{hidden}H"),
        model: build_gpt(&dims),
    }
}

fn ws(layers: usize, hidden: usize) -> ZooEntry {
    let dims = GptDims::uniform(
        &format!("W&S-{layers}L-{hidden}H"), VOCAB, SEQ, layers, hidden, HEADS);
    ZooEntry {
        family: Family::WideShallow,
        setting: format!("{layers}L/{hidden}H"),
        model: build_gpt(&dims),
    }
}

fn ic(layers: usize, hiddens: &[usize]) -> ZooEntry {
    // Swin-style: consecutive stages of equal depth with growing hidden.
    let stages = hiddens.len();
    let per = layers / stages;
    let mut hidden_per_layer = Vec::with_capacity(layers);
    for (i, &h) in hiddens.iter().enumerate() {
        let count = if i + 1 == stages { layers - per * (stages - 1) } else { per };
        hidden_per_layer.extend(std::iter::repeat(h).take(count));
    }
    let hmax = *hiddens.iter().max().unwrap();
    let dims = GptDims {
        name: format!("I&C-{layers}L-{hmax}H"),
        vocab: VOCAB,
        seq: SEQ,
        layers,
        hidden_per_layer,
        heads: HEADS,
        tied_head: false,
    };
    ZooEntry {
        family: Family::InconsistentConsecutive,
        setting: format!("{layers}L/{}-{}H", hiddens[0], hmax),
        model: build_gpt(&dims),
    }
}

/// The full evaluation zoo: four settings per family, matching Table 1's
/// ranges (layer counts, coarse operator counts, hidden sizes, parameter
/// counts).
pub fn zoo() -> Vec<ZooEntry> {
    vec![
        // N&D: 48-96 layers, hidden 1024-1536, 1.3-2.9B params
        nd(48, 1024),
        nd(96, 1024),
        nd(48, 1536),
        nd(96, 1536),
        // W&S: 2-4 layers, hidden 6144-12288, 1.7-4B params
        ws(4, 6144),
        ws(2, 12288),
        ws(3, 8192),
        ws(4, 8192),
        // I&C: 24-96 layers, hidden 1024-4096, 0.9-2.3B params
        ic(24, &[1024, 2048, 3072, 4096]),
        ic(48, &[1024, 1536, 2048]),
        ic(64, &[1024, 1536, 2048]),
        ic(96, &[1024, 1536]),
    ]
}

/// Entries of one family, in declaration order.
pub fn family_entries(f: Family) -> Vec<ZooEntry> {
    zoo().into_iter().filter(|e| e.family == f).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_layer_ranges() {
        for e in zoo() {
            let l = e.model.layers;
            match e.family {
                Family::NarrowDeep => assert!((48..=96).contains(&l)),
                Family::WideShallow => assert!((2..=4).contains(&l)),
                Family::InconsistentConsecutive => {
                    assert!((24..=96).contains(&l))
                }
            }
        }
    }

    #[test]
    fn table1_operator_counts() {
        // Paper granularity: 2·layers + 2 → N&D 98-194, W&S 6-10, I&C 50-194.
        for e in zoo() {
            let n = e.model.fuse_paper_granularity().n_ops();
            match e.family {
                Family::NarrowDeep => assert!((98..=194).contains(&n), "{n}"),
                Family::WideShallow => assert!((6..=10).contains(&n), "{n}"),
                Family::InconsistentConsecutive => {
                    // stage_proj ops add a few beyond 2/layer+2
                    assert!((50..=200).contains(&n), "{n}")
                }
            }
        }
    }

    #[test]
    fn table1_param_ranges() {
        for e in zoo() {
            let b = e.model.param_count() / 1e9;
            match e.family {
                // widened slightly: the paper reports 1.3-2.9B over its own
                // (unpublished) exact settings; ours span 0.7-2.9B
                Family::NarrowDeep => assert!((0.6..=3.0).contains(&b), "{b}"),
                Family::WideShallow => assert!((1.5..=4.9).contains(&b), "{b}"),
                Family::InconsistentConsecutive => {
                    assert!((0.8..=2.6).contains(&b), "{b}")
                }
            }
        }
    }

    #[test]
    fn ic_models_mix_hidden_sizes() {
        for e in family_entries(Family::InconsistentConsecutive) {
            let has_stage_proj =
                e.model.ops.iter().any(|o| o.name.contains("stage_proj"));
            assert!(has_stage_proj, "{} has uniform hidden", e.model.name);
        }
    }

    #[test]
    fn zoo_has_four_settings_per_family() {
        assert_eq!(family_entries(Family::NarrowDeep).len(), 4);
        assert_eq!(family_entries(Family::WideShallow).len(), 4);
        assert_eq!(family_entries(Family::InconsistentConsecutive).len(), 4);
    }
}
