//! GPT operator-graph builder: the paper's minGPT-style inventory with the
//! §3.1 memory factors computed "according to the definition of operators
//! (types and shapes)".
//!
//! Per-layer hidden sizes may differ (the I&C family); layer `l` reads
//! hidden `h_in[l]` and writes `h_out[l] = h_in[l+1]` through a projection
//! when sizes change (Swin-style stage transitions).

use super::{F32, ModelDesc, OpKind, Operator};

/// Shape description consumed by [`build_gpt`].
#[derive(Debug, Clone)]
pub struct GptDims {
    pub name: String,
    pub vocab: usize,
    pub seq: usize,
    pub layers: usize,
    /// Hidden size per layer; uniform models repeat one value.
    pub hidden_per_layer: Vec<usize>,
    pub heads: usize,
    /// Tied LM head shares the embedding matrix (no extra params).
    pub tied_head: bool,
}

impl GptDims {
    pub fn uniform(name: &str, vocab: usize, seq: usize, layers: usize,
                   hidden: usize, heads: usize) -> GptDims {
        GptDims {
            name: name.into(),
            vocab,
            seq,
            layers,
            hidden_per_layer: vec![hidden; layers],
            heads,
            tied_head: false,
        }
    }
}

fn matmul_op(name: String, layer: Option<usize>, seq: usize, in_dim: usize,
             out_dim: usize, bias: bool) -> Operator {
    let s = seq as f64;
    let (i, o) = (in_dim as f64, out_dim as f64);
    Operator {
        name,
        kind: OpKind::MatMul,
        layer,
        params: i * o + if bias { o } else { 0.0 },
        // store the output for backward
        act_bytes_per_sample: s * o * F32,
        // interior activation: recomputed from the segment boundary
        ckpt_act_bytes_per_sample: 0.0,
        // fwd 2·s·i·o, bwd ≈ 2× fwd (dX and dW products)
        flops_per_sample: 6.0 * s * i * o,
        extra_bytes: 0.0,
        matmul_dims: Some((in_dim, out_dim)),
    }
}

fn layernorm_op(name: String, layer: Option<usize>, seq: usize,
                hidden: usize) -> Operator {
    let s = seq as f64;
    let h = hidden as f64;
    Operator {
        name,
        kind: OpKind::LayerNorm,
        layer,
        params: 2.0 * h,
        act_bytes_per_sample: s * h * F32,
        // ln1 is the checkpoint segment boundary (the block input is what
        // gets stored); set after construction in build_gpt
        ckpt_act_bytes_per_sample: 0.0,
        flops_per_sample: 16.0 * s * h,
        extra_bytes: 0.0,
        matmul_dims: None,
    }
}

fn attention_op(name: String, layer: usize, seq: usize, hidden: usize,
                heads: usize) -> Operator {
    let s = seq as f64;
    let h = hidden as f64;
    let nh = heads as f64;
    Operator {
        name,
        kind: OpKind::Attention,
        layer: Some(layer),
        params: 0.0,
        // attention probabilities (nh·s·s) + context output (s·h)
        act_bytes_per_sample: (nh * s * s + s * h) * F32,
        ckpt_act_bytes_per_sample: 0.0,
        // QKᵀ and PV fwd (4·s²·h) + ~2× backward
        flops_per_sample: 12.0 * s * s * h,
        // transient full-score stripe before softmax normalization
        extra_bytes: nh * s * s * F32,
        matmul_dims: None,
    }
}

/// Build the fine-grained (≈8 ops/layer) GPT operator graph.
pub fn build_gpt(dims: &GptDims) -> ModelDesc {
    assert_eq!(
        dims.hidden_per_layer.len(),
        dims.layers,
        "hidden_per_layer must have one entry per layer"
    );
    assert!(dims.layers > 0);
    let seq = dims.seq;
    let s = seq as f64;
    let mut ops = Vec::new();

    // Embedding: token + positional tables.
    let h0 = dims.hidden_per_layer[0];
    ops.push(Operator {
        name: "embed".into(),
        kind: OpKind::Embedding,
        layer: None,
        params: (dims.vocab * h0 + seq * h0) as f64,
        act_bytes_per_sample: s * h0 as f64 * F32,
        ckpt_act_bytes_per_sample: s * h0 as f64 * F32,
        flops_per_sample: 2.0 * s * h0 as f64,
        extra_bytes: 0.0,
        matmul_dims: None,
    });

    for l in 0..dims.layers {
        let h = dims.hidden_per_layer[l];
        let mut ln1 = layernorm_op(format!("l{l}.ln1"), Some(l), seq, h);
        // checkpointing keeps one boundary activation per block (its input)
        ln1.ckpt_act_bytes_per_sample = s * h as f64 * F32;
        ops.push(ln1);
        ops.push(matmul_op(format!("l{l}.qkv"), Some(l), seq, h, 3 * h, true));
        ops.push(attention_op(format!("l{l}.attn"), l, seq, h, dims.heads));
        ops.push(matmul_op(format!("l{l}.proj"), Some(l), seq, h, h, true));
        ops.push(layernorm_op(format!("l{l}.ln2"), Some(l), seq, h));
        ops.push(matmul_op(format!("l{l}.mlp_up"), Some(l), seq, h, 4 * h, true));
        ops.push(matmul_op(format!("l{l}.mlp_down"), Some(l), seq, 4 * h, h, true));
        // stage transition when the next layer widens/narrows (I&C models)
        if l + 1 < dims.layers {
            let h_next = dims.hidden_per_layer[l + 1];
            if h_next != h {
                ops.push(matmul_op(
                    format!("l{l}.stage_proj"),
                    Some(l),
                    seq,
                    h,
                    h_next,
                    false,
                ));
            }
        }
    }

    let h_last = *dims.hidden_per_layer.last().unwrap();
    let mut lnf = layernorm_op("lnf".into(), None, seq, h_last);
    lnf.ckpt_act_bytes_per_sample = lnf.act_bytes_per_sample;
    ops.push(lnf);
    ops.push(Operator {
        name: "head".into(),
        kind: OpKind::Head,
        layer: None,
        params: if dims.tied_head { 0.0 } else { (h_last * dims.vocab) as f64 },
        act_bytes_per_sample: s * dims.vocab as f64 * F32,
        ckpt_act_bytes_per_sample: s * dims.vocab as f64 * F32,
        flops_per_sample: 6.0 * s * h_last as f64 * dims.vocab as f64,
        extra_bytes: 0.0,
        matmul_dims: Some((h_last, dims.vocab)),
    });

    ModelDesc {
        name: dims.name.clone(),
        ops,
        seq,
        layers: dims.layers,
        hidden: dims.hidden_per_layer.iter().copied().max().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpt2_small_param_count() {
        // GPT-2 small: 12L, h=768, vocab 50257, seq 1024 ≈ 163M untied
        // (124M tied): 12·12h² = 85M, embed 39.4M, head 38.6M.
        let d = GptDims::uniform("gpt2s", 50257, 1024, 12, 768, 12);
        let m = build_gpt(&d);
        let p = m.param_count();
        assert!((p - 163e6).abs() / 163e6 < 0.02, "params={p}");
    }

    #[test]
    fn tied_head_has_no_params() {
        let mut d = GptDims::uniform("t", 1000, 64, 2, 64, 2);
        d.tied_head = true;
        let m = build_gpt(&d);
        let head = m.ops.iter().find(|o| o.kind == OpKind::Head).unwrap();
        assert_eq!(head.params, 0.0);
    }

    #[test]
    fn stage_transition_inserts_projection() {
        let d = GptDims {
            name: "ic".into(),
            vocab: 1000,
            seq: 64,
            layers: 4,
            hidden_per_layer: vec![64, 64, 128, 128],
            heads: 4,
            tied_head: false,
        };
        let m = build_gpt(&d);
        let projs: Vec<_> =
            m.ops.iter().filter(|o| o.name.contains("stage_proj")).collect();
        assert_eq!(projs.len(), 1);
        assert_eq!(projs[0].matmul_dims, Some((64, 128)));
        assert_eq!(m.hidden, 128);
    }

    #[test]
    fn per_layer_op_inventory() {
        let m = build_gpt(&GptDims::uniform("x", 512, 32, 3, 32, 2));
        // embed + 3·7 + lnf + head
        assert_eq!(m.n_ops(), 2 + 3 * 7 + 1);
        // attention ops carry no params but nonzero activations
        for o in &m.ops {
            if o.kind == OpKind::Attention {
                assert_eq!(o.params, 0.0);
                assert!(o.act_bytes_per_sample > 0.0);
                assert!(!o.shardable());
            }
        }
    }

    #[test]
    fn flops_dominated_by_matmuls() {
        let m = build_gpt(&GptDims::uniform("x", 512, 128, 4, 256, 4));
        let mm: f64 = m.ops.iter()
            .filter(|o| matches!(o.kind, OpKind::MatMul | OpKind::Head))
            .map(|o| o.flops_per_sample).sum();
        assert!(mm / m.flops_per_sample() > 0.8);
    }
}
