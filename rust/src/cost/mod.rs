//! The paper's §3.1 cost model: per-operator memory `M_i(p_i, b)` and time
//! `T_i(p_i, b)` under the (α, β, γ) communication/computation model, plus
//! the Profiler that precomputes per-op cost tables for the search engine.

pub mod memory;
pub mod menu;
pub mod profiler;
pub mod time;

pub use memory::{MemoryCost, op_memory};
pub use menu::{MenuStats, pareto_filter};
pub use profiler::{DecisionCost, OpCostTable, PlanCost, Profiler};
pub use time::{comm_rounds, op_comm_time, op_compute_time};

/// Where in the device hierarchy an operator's ZDP slices shard their
/// model states. The paper's formulation implicitly uses [`Scope::Global`]
/// (ZeRO over the whole cluster); [`Scope::Node`] is the MiCS/HSDP-style
/// hybrid: states sharded over the intra-node group and replicated across
/// nodes, so the parameter gathers ride the fast intra-node link and only
/// the gradient reduce crosses nodes — a second Pareto point the planner
/// can trade against the global scope's smaller state footprint.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Scope {
    /// Shard over all `N` devices (the paper's ZDP; collectives pay the
    /// cluster's bottleneck ring link).
    #[default]
    Global,
    /// Shard over the `devices_per_node` intra-node group, replicated
    /// across nodes: gathers stay on the intra link, gradients pay one
    /// hierarchical cross-node reduce of the 1/`devices_per_node` shard.
    Node,
}

impl Scope {
    /// Number of devices the sharded states spread over.
    pub fn group_size(&self, cluster: &crate::config::Cluster) -> usize {
        match self {
            Scope::Global => cluster.n_devices,
            Scope::Node => cluster.node_group_size(),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Scope::Global => "global",
            Scope::Node => "node",
        }
    }
}

/// Per-operator parallel mode decision. The paper's base space is
/// `{DP, ZDP}`; operator splitting (§3.3) enlarges it to per-slice choices
/// (an op split into `granularity` slices can hold `zdp_slices` of them in
/// ZDP mode and the rest in DP mode), and the sharding [`Scope`] adds the
/// hierarchy dimension: *where* the ZDP slices' states are sharded.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Decision {
    /// Slice granularity `g` (0 = no splitting; the paper's figures use 0
    /// for "off", treated identically to 1 slice).
    pub granularity: usize,
    /// Number of slices trained in ZDP mode (sharded states);
    /// `0 ≤ zdp_slices ≤ max(granularity, 1)`.
    pub zdp_slices: usize,
    /// Device group the ZDP slices shard over (irrelevant — and kept
    /// [`Scope::Global`] — when `zdp_slices == 0`: pure DP shards nothing).
    pub scope: Scope,
}

impl Decision {
    /// Plain DP (no sharding, no splitting).
    pub const DP: Decision =
        Decision { granularity: 0, zdp_slices: 0, scope: Scope::Global };
    /// Plain ZDP (fully sharded over the whole cluster, no splitting).
    pub const ZDP: Decision =
        Decision { granularity: 0, zdp_slices: 1, scope: Scope::Global };
    /// Node-scoped ZDP (fully sharded within each node, replicated across
    /// nodes — MiCS/HSDP-style, no splitting).
    pub const ZDP_NODE: Decision =
        Decision { granularity: 0, zdp_slices: 1, scope: Scope::Node };

    /// Effective slice count (granularity 0 behaves as a single slice).
    pub fn slices(&self) -> usize {
        self.granularity.max(1)
    }

    /// Fraction of the operator's states that are sharded.
    pub fn zdp_fraction(&self) -> f64 {
        self.zdp_slices as f64 / self.slices() as f64
    }

    pub fn is_pure_dp(&self) -> bool {
        self.zdp_slices == 0
    }

    pub fn is_pure_zdp(&self) -> bool {
        self.zdp_slices == self.slices()
    }

    /// Fully-ZDP decision at a given granularity (global scope).
    pub fn zdp_at(granularity: usize) -> Decision {
        Decision {
            granularity,
            zdp_slices: granularity.max(1),
            scope: Scope::Global,
        }
    }

    /// Fully-DP decision at a given granularity.
    pub fn dp_at(granularity: usize) -> Decision {
        Decision { granularity, zdp_slices: 0, scope: Scope::Global }
    }

    /// The same decision with its sharding scope replaced.
    pub fn with_scope(self, scope: Scope) -> Decision {
        Decision { scope, ..self }
    }

    /// Project this decision onto another cluster's device hierarchy
    /// (the elastic-replan primitive: an old plan's decisions become
    /// projection targets on the new hardware). Two degradations:
    /// pure DP shards nothing, so its scope canonicalizes to
    /// [`Scope::Global`]; and a node-scoped decision on a cluster with
    /// no multi-node structure has lost its group — it degrades to the
    /// global scope, which on a single node is the same device set.
    pub fn project(&self, cluster: &crate::config::Cluster) -> Decision {
        if self.zdp_slices == 0 || !cluster.crosses_nodes() {
            self.with_scope(Scope::Global)
        } else {
            *self
        }
    }

    /// Whether any state is sharded over the intra-node group only.
    pub fn is_node_scoped(&self) -> bool {
        self.scope == Scope::Node && self.zdp_slices > 0
    }

    /// Plan-label grammar: `DP`, `ZDP`, `ZDP/g4`, `MIX1:3/g4`, with an
    /// `@node` suffix when the sharded slices are node-scoped (e.g.
    /// `ZDP@node`, `MIX1:3/g4@node`).
    pub fn label(&self) -> String {
        let base = match (self.is_pure_dp(), self.is_pure_zdp()) {
            (true, _) if self.granularity <= 1 => "DP".to_string(),
            (_, true) if self.granularity <= 1 => "ZDP".to_string(),
            (true, _) => format!("DP/g{}", self.granularity),
            (_, true) => format!("ZDP/g{}", self.granularity),
            _ => format!("MIX{}:{}/g{}", self.zdp_slices,
                         self.slices() - self.zdp_slices, self.granularity),
        };
        if self.is_node_scoped() {
            format!("{base}@node")
        } else {
            base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_fractions() {
        assert_eq!(Decision::DP.zdp_fraction(), 0.0);
        assert_eq!(Decision::ZDP.zdp_fraction(), 1.0);
        let mixed = Decision { granularity: 4, zdp_slices: 1,
                               scope: Scope::Global };
        assert_eq!(mixed.zdp_fraction(), 0.25);
        assert!(!mixed.is_pure_dp() && !mixed.is_pure_zdp());
    }

    #[test]
    fn labels() {
        assert_eq!(Decision::DP.label(), "DP");
        assert_eq!(Decision::ZDP.label(), "ZDP");
        assert_eq!(Decision::zdp_at(4).label(), "ZDP/g4");
        assert_eq!(
            Decision { granularity: 4, zdp_slices: 1, scope: Scope::Global }
                .label(),
            "MIX1:3/g4"
        );
        assert_eq!(Decision::ZDP_NODE.label(), "ZDP@node");
        assert_eq!(Decision::zdp_at(4).with_scope(Scope::Node).label(),
                   "ZDP/g4@node");
        assert_eq!(
            Decision { granularity: 4, zdp_slices: 1, scope: Scope::Node }
                .label(),
            "MIX1:3/g4@node"
        );
        // pure DP shards nothing: the scope never shows in its label
        assert_eq!(Decision::DP.with_scope(Scope::Node).label(), "DP");
        assert!(!Decision::DP.with_scope(Scope::Node).is_node_scoped());
    }

    #[test]
    fn projection_degrades_scope_with_the_hierarchy() {
        let two_node = crate::config::Cluster::two_server_a100(16.0);
        let one_node = crate::config::Cluster::rtx_titan(8, 8.0);
        // node scope survives where nodes exist, degrades where not
        assert_eq!(Decision::ZDP_NODE.project(&two_node),
                   Decision::ZDP_NODE);
        assert_eq!(Decision::ZDP_NODE.project(&one_node), Decision::ZDP);
        // global decisions project to themselves everywhere
        assert_eq!(Decision::zdp_at(4).project(&one_node),
                   Decision::zdp_at(4));
        // pure DP canonicalizes its (meaningless) scope
        assert_eq!(Decision::DP.with_scope(Scope::Node).project(&two_node),
                   Decision::DP);
    }

    #[test]
    fn scope_group_sizes() {
        let c = crate::config::Cluster::two_server_a100(16.0);
        assert_eq!(Scope::Global.group_size(&c), 16);
        assert_eq!(Scope::Node.group_size(&c), 8);
        let single = crate::config::Cluster::rtx_titan(8, 8.0);
        assert_eq!(Scope::Node.group_size(&single), 8);
        assert_eq!(Scope::default(), Scope::Global);
    }
}
