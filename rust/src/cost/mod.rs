//! The paper's §3.1 cost model: per-operator memory `M_i(p_i, b)` and time
//! `T_i(p_i, b)` under the (α, β, γ) communication/computation model, plus
//! the Profiler that precomputes per-op cost tables for the search engine.

pub mod memory;
pub mod menu;
pub mod profiler;
pub mod time;

pub use memory::{MemoryCost, op_memory};
pub use menu::{MenuStats, pareto_filter};
pub use profiler::{DecisionCost, OpCostTable, PlanCost, Profiler};
pub use time::{comm_rounds, op_comm_time, op_compute_time};

/// Per-operator parallel mode decision. The paper's base space is
/// `{DP, ZDP}`; operator splitting (§3.3) enlarges it to per-slice choices:
/// an op split into `granularity` slices can hold `zdp_slices` of them in
/// ZDP mode and the rest in DP mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Decision {
    /// Slice granularity `g` (0 = no splitting; the paper's figures use 0
    /// for "off", treated identically to 1 slice).
    pub granularity: usize,
    /// Number of slices trained in ZDP mode (sharded states);
    /// `0 ≤ zdp_slices ≤ max(granularity, 1)`.
    pub zdp_slices: usize,
}

impl Decision {
    /// Plain DP (no sharding, no splitting).
    pub const DP: Decision = Decision { granularity: 0, zdp_slices: 0 };
    /// Plain ZDP (fully sharded, no splitting).
    pub const ZDP: Decision = Decision { granularity: 0, zdp_slices: 1 };

    /// Effective slice count (granularity 0 behaves as a single slice).
    pub fn slices(&self) -> usize {
        self.granularity.max(1)
    }

    /// Fraction of the operator's states that are sharded.
    pub fn zdp_fraction(&self) -> f64 {
        self.zdp_slices as f64 / self.slices() as f64
    }

    pub fn is_pure_dp(&self) -> bool {
        self.zdp_slices == 0
    }

    pub fn is_pure_zdp(&self) -> bool {
        self.zdp_slices == self.slices()
    }

    /// Fully-ZDP decision at a given granularity.
    pub fn zdp_at(granularity: usize) -> Decision {
        Decision { granularity, zdp_slices: granularity.max(1) }
    }

    /// Fully-DP decision at a given granularity.
    pub fn dp_at(granularity: usize) -> Decision {
        Decision { granularity, zdp_slices: 0 }
    }

    pub fn label(&self) -> String {
        match (self.is_pure_dp(), self.is_pure_zdp()) {
            (true, _) if self.granularity <= 1 => "DP".into(),
            (_, true) if self.granularity <= 1 => "ZDP".into(),
            (true, _) => format!("DP/g{}", self.granularity),
            (_, true) => format!("ZDP/g{}", self.granularity),
            _ => format!("MIX{}:{}/g{}", self.zdp_slices,
                         self.slices() - self.zdp_slices, self.granularity),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_fractions() {
        assert_eq!(Decision::DP.zdp_fraction(), 0.0);
        assert_eq!(Decision::ZDP.zdp_fraction(), 1.0);
        let mixed = Decision { granularity: 4, zdp_slices: 1 };
        assert_eq!(mixed.zdp_fraction(), 0.25);
        assert!(!mixed.is_pure_dp() && !mixed.is_pure_zdp());
    }

    #[test]
    fn labels() {
        assert_eq!(Decision::DP.label(), "DP");
        assert_eq!(Decision::ZDP.label(), "ZDP");
        assert_eq!(Decision::zdp_at(4).label(), "ZDP/g4");
        assert_eq!(
            Decision { granularity: 4, zdp_slices: 1 }.label(),
            "MIX1:3/g4"
        );
    }
}
