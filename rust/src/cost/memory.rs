//! Memory cost model (paper Eq. in §3.1):
//!
//! ```text
//! M_i(p_i, b) = M_model(/N for the ZDP share) + b·M_act + M_extra
//! ```
//!
//! extended with the two effects the paper layers on top:
//!
//! * **Operator splitting** (§3.3): the transient gather of a ZDP slice
//!   materializes only `param_bytes/g` at a time ("amortizes the memory
//!   from size(MatMul) to size(MatMul)/slice_granularity").
//! * **Checkpointing** (§2.3/4.3): only segment-boundary activations stay
//!   resident; interior activations are recomputed.
//!
//! Memory is split into a *persistent* part (additive across ops) and a
//! *transient* part (peaks one op at a time); the device peak is
//! `Σ persistent + max transient`, which the search engine tracks
//! incrementally.
//!
//! The sharding [`Scope`] sets the divisor of the ZDP share: states spread
//! over the whole cluster (`/N`, the paper's formula) or over the
//! intra-node group only (`/devices_per_node`, replicated across nodes) —
//! less memory relief, but the collectives stay on the fast link (see
//! `cost::time`).

use super::Decision;
use crate::config::Cluster;
use crate::model::Operator;

/// Per-operator memory breakdown on one device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryCost {
    /// Model states resident for the whole iteration (params+grads+Adam;
    /// the ZDP share is divided by N).
    pub states: f64,
    /// Activations resident until backward (scales with batch).
    pub activations: f64,
    /// Mode-independent workspace that exists only while the op runs.
    pub workspace: f64,
    /// ZDP re-gather transient: unsharded fp32 params (+ full-size gradient
    /// before reduce-scatter in backward), divided by the slice granularity.
    pub gather: f64,
}

impl MemoryCost {
    /// Bytes that add up across operators.
    pub fn persistent(&self) -> f64 {
        self.states + self.activations
    }

    /// Bytes that exist only while this operator executes.
    pub fn transient(&self) -> f64 {
        self.workspace + self.gather
    }

    /// Stand-alone total (the paper's additive `M_i`).
    pub fn total(&self) -> f64 {
        self.persistent() + self.transient()
    }
}

/// Memory cost of operator `op` under decision `d` with per-device batch
/// size `b` on `cluster`.
pub fn op_memory(op: &Operator, d: Decision, b: usize, cluster: &Cluster,
                 checkpointing: bool) -> MemoryCost {
    debug_assert!(cluster.n_devices >= 1);
    debug_assert!(d.zdp_slices <= d.slices());
    let zdp_frac = d.zdp_fraction();
    let dp_frac = 1.0 - zdp_frac;
    // ZDP shards states over the scope's device group (the whole cluster
    // for the paper's global ZDP, one node's worth for node scope); DP
    // replicates them.
    let group = d.scope.group_size(cluster) as f64;
    let states = op.state_bytes() * (dp_frac + zdp_frac / group);

    let act_per_sample = if checkpointing {
        op.ckpt_act_bytes_per_sample
    } else {
        op.act_bytes_per_sample
    };
    let activations = b as f64 * act_per_sample;

    // Attention-score style workspaces scale with batch.
    let workspace = b as f64 * op.extra_bytes;

    // The gather transient exists only if some slice is sharded: one slice
    // of fp32 params in forward, and (param + grad) slices in backward
    // before the reduce-scatter — 2× param_bytes / g at peak.
    let gather = if d.zdp_slices > 0 {
        2.0 * op.param_bytes() / d.slices() as f64
    } else {
        0.0
    };

    MemoryCost { states, activations, workspace, gather }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::Scope;
    use crate::model::{GptDims, build_gpt};

    fn mm_op() -> Operator {
        let m = build_gpt(&GptDims::uniform("t", 1000, 64, 1, 512, 4));
        m.ops.iter().find(|o| o.name == "l0.mlp_up").unwrap().clone()
    }

    fn c8() -> Cluster {
        Cluster::rtx_titan(8, 8.0)
    }

    #[test]
    fn zdp_shards_states_to_one_nth() {
        let op = mm_op();
        let dp = op_memory(&op, Decision::DP, 1, &c8(), false);
        let zdp = op_memory(&op, Decision::ZDP, 1, &c8(), false);
        assert!((zdp.states - dp.states / 8.0).abs() < 1e-6);
        // activations are mode-independent
        assert_eq!(zdp.activations, dp.activations);
    }

    #[test]
    fn node_scope_shards_states_by_group_size() {
        // two_server_a100: N=16 but 8 devices/node — node scope divides by
        // 8 (replicated across the two nodes), global by 16.
        let op = mm_op();
        let c = Cluster::two_server_a100(16.0);
        let dp = op_memory(&op, Decision::DP, 1, &c, false);
        let global = op_memory(&op, Decision::ZDP, 1, &c, false);
        let node = op_memory(&op, Decision::ZDP_NODE, 1, &c, false);
        assert!((global.states - dp.states / 16.0).abs() < 1e-6);
        assert!((node.states - dp.states / 8.0).abs() < 1e-6);
        // the gather transient materializes the full slice either way
        assert_eq!(global.gather, node.gather);
        // single node: both scopes shard identically
        let single = c8();
        let g1 = op_memory(&op, Decision::ZDP, 1, &single, false);
        let n1 = op_memory(&op, Decision::ZDP_NODE, 1, &single, false);
        assert_eq!(g1.states.to_bits(), n1.states.to_bits());
    }

    #[test]
    fn dp_has_no_gather_transient() {
        let op = mm_op();
        assert_eq!(op_memory(&op, Decision::DP, 4, &c8(), false).gather, 0.0);
        assert!(op_memory(&op, Decision::ZDP, 4, &c8(), false).gather > 0.0);
    }

    #[test]
    fn splitting_divides_gather_peak() {
        // Paper Fig 7: up to ~50% peak reduction at g=2, monotone in g.
        let op = mm_op();
        let peaks: Vec<f64> = [0usize, 2, 4, 8, 16]
            .iter()
            .map(|&g| {
                op_memory(&op, Decision::zdp_at(g), 1, &c8(), false).gather
            })
            .collect();
        assert!((peaks[1] - peaks[0] / 2.0).abs() < 1e-6, "g=2 halves");
        for w in peaks.windows(2) {
            assert!(w[1] < w[0]);
        }
    }

    #[test]
    fn mixed_slices_interpolate_states() {
        let op = mm_op();
        let c = c8();
        let dp = op_memory(&op, Decision::DP, 1, &c, false).states;
        let zdp = op_memory(&op, Decision::ZDP, 1, &c, false).states;
        let half = op_memory(
            &op,
            Decision { granularity: 4, zdp_slices: 2, scope: Scope::Global },
            1,
            &c,
            false,
        )
        .states;
        assert!((half - (dp + zdp) / 2.0).abs() < 1e-6);
    }

    #[test]
    fn activations_scale_with_batch() {
        let op = mm_op();
        let m1 = op_memory(&op, Decision::DP, 1, &c8(), false).activations;
        let m8 = op_memory(&op, Decision::DP, 8, &c8(), false).activations;
        assert!((m8 - 8.0 * m1).abs() < 1e-6);
    }

    #[test]
    fn checkpointing_frees_interior_activations() {
        let op = mm_op(); // interior matmul: ckpt residency 0
        let off = op_memory(&op, Decision::DP, 4, &c8(), false).activations;
        let on = op_memory(&op, Decision::DP, 4, &c8(), true).activations;
        assert!(off > 0.0);
        assert_eq!(on, 0.0);
    }

    #[test]
    fn full_model_dp_memory_matches_closed_form() {
        let m = build_gpt(&GptDims::uniform("t", 1000, 64, 2, 128, 4));
        let b = 4;
        let c = c8();
        let total: f64 = m
            .ops
            .iter()
            .map(|o| op_memory(o, Decision::DP, b, &c, false).persistent())
            .sum::<f64>();
        let expect = m.state_bytes() + b as f64 * m.act_bytes_per_sample();
        assert!((total - expect).abs() / expect < 1e-9);
    }
}
