//! Memory cost model (paper Eq. in §3.1):
//!
//! ```text
//! M_i(p_i, b) = M_model(/N for the ZDP share) + b·M_act + M_extra
//! ```
//!
//! extended with the two effects the paper layers on top:
//!
//! * **Operator splitting** (§3.3): the transient gather of a ZDP slice
//!   materializes only `param_bytes/g` at a time ("amortizes the memory
//!   from size(MatMul) to size(MatMul)/slice_granularity").
//! * **Checkpointing** (§2.3/4.3): only segment-boundary activations stay
//!   resident; interior activations are recomputed.
//!
//! Memory is split into a *persistent* part (additive across ops) and a
//! *transient* part (peaks one op at a time); the device peak is
//! `Σ persistent + max transient`, which the search engine tracks
//! incrementally.

use super::Decision;
use crate::model::Operator;

/// Per-operator memory breakdown on one device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryCost {
    /// Model states resident for the whole iteration (params+grads+Adam;
    /// the ZDP share is divided by N).
    pub states: f64,
    /// Activations resident until backward (scales with batch).
    pub activations: f64,
    /// Mode-independent workspace that exists only while the op runs.
    pub workspace: f64,
    /// ZDP re-gather transient: unsharded fp32 params (+ full-size gradient
    /// before reduce-scatter in backward), divided by the slice granularity.
    pub gather: f64,
}

impl MemoryCost {
    /// Bytes that add up across operators.
    pub fn persistent(&self) -> f64 {
        self.states + self.activations
    }

    /// Bytes that exist only while this operator executes.
    pub fn transient(&self) -> f64 {
        self.workspace + self.gather
    }

    /// Stand-alone total (the paper's additive `M_i`).
    pub fn total(&self) -> f64 {
        self.persistent() + self.transient()
    }
}

/// Memory cost of operator `op` under decision `d` with per-device batch
/// size `b` on an `n`-way cluster.
pub fn op_memory(op: &Operator, d: Decision, b: usize, n: usize,
                 checkpointing: bool) -> MemoryCost {
    debug_assert!(n >= 1);
    debug_assert!(d.zdp_slices <= d.slices());
    let zdp_frac = d.zdp_fraction();
    let dp_frac = 1.0 - zdp_frac;
    // ZDP shards states 1/N; DP replicates them.
    let states = op.state_bytes() * (dp_frac + zdp_frac / n as f64);

    let act_per_sample = if checkpointing {
        op.ckpt_act_bytes_per_sample
    } else {
        op.act_bytes_per_sample
    };
    let activations = b as f64 * act_per_sample;

    // Attention-score style workspaces scale with batch.
    let workspace = b as f64 * op.extra_bytes;

    // The gather transient exists only if some slice is sharded: one slice
    // of fp32 params in forward, and (param + grad) slices in backward
    // before the reduce-scatter — 2× param_bytes / g at peak.
    let gather = if d.zdp_slices > 0 {
        2.0 * op.param_bytes() / d.slices() as f64
    } else {
        0.0
    };

    MemoryCost { states, activations, workspace, gather }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GptDims, build_gpt};

    fn mm_op() -> Operator {
        let m = build_gpt(&GptDims::uniform("t", 1000, 64, 1, 512, 4));
        m.ops.iter().find(|o| o.name == "l0.mlp_up").unwrap().clone()
    }

    #[test]
    fn zdp_shards_states_to_one_nth() {
        let op = mm_op();
        let dp = op_memory(&op, Decision::DP, 1, 8, false);
        let zdp = op_memory(&op, Decision::ZDP, 1, 8, false);
        assert!((zdp.states - dp.states / 8.0).abs() < 1e-6);
        // activations are mode-independent
        assert_eq!(zdp.activations, dp.activations);
    }

    #[test]
    fn dp_has_no_gather_transient() {
        let op = mm_op();
        assert_eq!(op_memory(&op, Decision::DP, 4, 8, false).gather, 0.0);
        assert!(op_memory(&op, Decision::ZDP, 4, 8, false).gather > 0.0);
    }

    #[test]
    fn splitting_divides_gather_peak() {
        // Paper Fig 7: up to ~50% peak reduction at g=2, monotone in g.
        let op = mm_op();
        let peaks: Vec<f64> = [0usize, 2, 4, 8, 16]
            .iter()
            .map(|&g| op_memory(&op, Decision::zdp_at(g), 1, 8, false).gather)
            .collect();
        assert!((peaks[1] - peaks[0] / 2.0).abs() < 1e-6, "g=2 halves");
        for w in peaks.windows(2) {
            assert!(w[1] < w[0]);
        }
    }

    #[test]
    fn mixed_slices_interpolate_states() {
        let op = mm_op();
        let n = 8;
        let dp = op_memory(&op, Decision::DP, 1, n, false).states;
        let zdp = op_memory(&op, Decision::ZDP, 1, n, false).states;
        let half = op_memory(
            &op,
            Decision { granularity: 4, zdp_slices: 2 },
            1,
            n,
            false,
        )
        .states;
        assert!((half - (dp + zdp) / 2.0).abs() < 1e-6);
    }

    #[test]
    fn activations_scale_with_batch() {
        let op = mm_op();
        let m1 = op_memory(&op, Decision::DP, 1, 8, false).activations;
        let m8 = op_memory(&op, Decision::DP, 8, 8, false).activations;
        assert!((m8 - 8.0 * m1).abs() < 1e-6);
    }

    #[test]
    fn checkpointing_frees_interior_activations() {
        let op = mm_op(); // interior matmul: ckpt residency 0
        let off = op_memory(&op, Decision::DP, 4, 8, false).activations;
        let on = op_memory(&op, Decision::DP, 4, 8, true).activations;
        assert!(off > 0.0);
        assert_eq!(on, 0.0);
    }

    #[test]
    fn full_model_dp_memory_matches_closed_form() {
        let m = build_gpt(&GptDims::uniform("t", 1000, 64, 2, 128, 4));
        let b = 4;
        let total: f64 = m
            .ops
            .iter()
            .map(|o| op_memory(o, Decision::DP, b, 8, false).persistent())
            .sum::<f64>();
        let expect = m.state_bytes() + b as f64 * m.act_bytes_per_sample();
        assert!((total - expect).abs() / expect < 1e-9);
    }
}
