//! Time cost model (paper §3.1, the (α, β, γ) / Hockney model):
//!
//! ```text
//! T_i(p_i, b) = k·(N−1)·(α + S_i·β/N) + b·γ_i
//! k = 2 for DP (reduce-scatter + all-gather of gradients)
//! k = 3 for ZDP (two parameter all-gathers + one gradient reduce-scatter)
//! k = 4 for ZDP under checkpointing (one extra gather for recomputation)
//! ```
//!
//! Operator splitting turns one collective of `S_i` bytes into `g`
//! collectives of `S_i/g` bytes: the bandwidth term is unchanged while the
//! latency term grows to `g·α` — exactly the small-op slowdown Figure 7
//! shows. Mixed per-slice decisions charge each slice its own `k`.
//!
//! The sharding [`Scope`] picks which ring the ZDP rounds ride:
//!
//! * [`Scope::Global`] — all `k` rounds on the N-device ring at the
//!   cluster's bottleneck `(α, β)` (`Cluster::ring_link`), the paper's
//!   formula verbatim.
//! * [`Scope::Node`] — all `k` rounds on the `devices_per_node`-device
//!   intra-node ring at `(α_intra, β_intra)`, plus one hierarchical
//!   cross-node reduce of the 1/`devices_per_node` gradient shard
//!   ([`inter_node_grad_time`]) so the gradient is still averaged over all
//!   N data-parallel replicas. DP slices are scope-independent: nothing is
//!   sharded, so their gradient all-reduce keeps the paper's flat-ring
//!   charge.

use super::{Decision, Scope};
use crate::config::Cluster;
use crate::model::Operator;

/// Per-slice launch overhead of operator splitting (the sequential
/// slice-and-sum bookkeeping; §3.3 argues it is hidden by overlap when
/// communication dominates, so it only surfaces on compute-bound ops).
pub const SPLIT_LAUNCH_OVERHEAD: f64 = 5e-6;

/// Grid every per-decision time is snapped to when the Profiler builds its
/// cost tables: 2⁻³⁰ s ≈ 0.93 ns, far below anything the (α, β, γ) model
/// can resolve.
///
/// The point is not precision but *exactness*: sums of multiples of a
/// power-of-two grid are computed without rounding by f64 (any total below
/// 2²³ s stays within 53 significand bits of the grid), so plan times are
/// identical no matter the order operators are visited in. That makes time
/// ties exact rather than ULP-dependent, which the symmetry-folded planner
/// relies on: permuting the decisions of interchangeable operators must
/// not change a plan's time by even one bit (see `planner::bound`).
pub const TIME_GRID: f64 = 1.0 / (1u64 << 30) as f64;

/// Snap a non-negative time to the nearest [`TIME_GRID`] multiple. Exact
/// for every physically plausible input: `t · 2³⁰` fits f64's integer
/// range for `t` up to days, `round` is exact, and scaling by a power of
/// two never rounds.
pub fn snap_time(t: f64) -> f64 {
    (t * (1u64 << 30) as f64).round() * TIME_GRID
}

/// Device compute efficiency at per-device batch `b`: small batches
/// under-utilize wide execution units (GEMM tiles, pipelines), so effective
/// FLOP/s saturate with batch. This simple `b/(b+2)` curve (33% at b=1,
/// 80% at b=8, →100%) models the effect uniformly for *every* strategy —
/// it is the physical mechanism behind the paper's observation that memory
/// savings convert to throughput via larger batches.
pub fn batch_efficiency(b: usize) -> f64 {
    let bf = b as f64;
    bf / (bf + 2.0)
}

/// Collective rounds `k` for one slice.
pub fn comm_rounds(zdp: bool, checkpointing: bool) -> f64 {
    match (zdp, checkpointing) {
        (false, _) => 2.0,        // grad all-reduce = RS + AG
        (true, false) => 3.0,     // + param re-gather (fwd, bwd share)
        (true, true) => 4.0,      // + recompute-phase gather (§4.3/Fig 9)
    }
}

/// The `(α, β, ring size)` a scope's collectives run over: the bottleneck
/// link of the whole N-device ring for [`Scope::Global`], the intra-node
/// link over the `devices_per_node`-device subgroup for [`Scope::Node`].
pub fn scope_ring(cluster: &Cluster, scope: Scope) -> (f64, f64, usize) {
    match scope {
        Scope::Global => {
            let (alpha, beta) = cluster.ring_link();
            (alpha, beta, cluster.n_devices)
        }
        Scope::Node => (
            cluster.alpha_intra,
            cluster.beta_intra,
            cluster.node_group_size(),
        ),
    }
}

/// Hierarchical cross-node gradient term of one node-scoped ZDP slice of
/// `slice_bytes`: after the intra-node reduce-scatter each device holds a
/// `slice_bytes / devices_per_node` shard summed only within its node;
/// same-local-rank peers all-reduce it across the `n_nodes` ring (2 rounds
/// on the inter-node link). Zero on single-node clusters, where node scope
/// degenerates to global.
pub fn inter_node_grad_time(slice_bytes: f64, cluster: &Cluster) -> f64 {
    let nodes = cluster.n_nodes();
    if nodes <= 1 {
        return 0.0;
    }
    let group = cluster.node_group_size() as f64;
    let shard = slice_bytes / group;
    2.0 * (nodes as f64 - 1.0)
        * (cluster.alpha_inter + shard * cluster.beta_inter / nodes as f64)
}

/// Communication seconds for operator `op` under decision `d`.
pub fn op_comm_time(op: &Operator, d: Decision, cluster: &Cluster,
                    checkpointing: bool) -> f64 {
    if !op.shardable() {
        return 0.0;
    }
    if cluster.n_devices == 1 {
        return 0.0; // single device: no collectives at all
    }
    let g = d.slices() as f64;
    let slice_bytes = op.param_bytes() / g;
    let zdp = d.zdp_slices as f64;
    let dp = g - zdp;
    // DP slices: nothing sharded, gradient all-reduce on the flat N-ring
    // (scope-independent).
    let n = cluster.n_devices as f64;
    let (alpha, beta) = cluster.ring_link();
    let per_dp_slice = (n - 1.0)
        * comm_rounds(false, checkpointing)
        * (alpha + slice_bytes * beta / n);
    // ZDP slices: every gather/reduce-scatter round rides the scope's
    // ring; node scope adds the hierarchical cross-node shard reduce.
    let (sa, sb, ring) = scope_ring(cluster, d.scope);
    let rf = ring as f64;
    let mut per_zdp_slice = (rf - 1.0)
        * comm_rounds(true, checkpointing)
        * (sa + slice_bytes * sb / rf);
    if d.scope == Scope::Node {
        per_zdp_slice += inter_node_grad_time(slice_bytes, cluster);
    }
    dp * per_dp_slice + zdp * per_zdp_slice
}

/// Computation seconds for operator `op` at per-device batch `b`:
/// `b·γ_i` with `γ_i = flops_per_sample / device_flops`, plus the
/// checkpointing recompute (one extra forward ≈ ×4/3) and the slice launch
/// overhead.
pub fn op_compute_time(op: &Operator, d: Decision, cluster: &Cluster, b: usize,
                       checkpointing: bool) -> f64 {
    let mut flops = b as f64 * op.flops_per_sample;
    if checkpointing && op.ckpt_act_bytes_per_sample < op.act_bytes_per_sample
    {
        // recomputed segment: forward again before backward (fwd ≈ 1/3 of
        // the fwd+bwd total) — the paper's "roughly 30% additional
        // computation cost"
        flops *= 4.0 / 3.0;
    }
    let launch = (d.slices() - 1) as f64 * SPLIT_LAUNCH_OVERHEAD;
    flops / (cluster.flops * batch_efficiency(b)) + launch
}

/// Total per-iteration seconds of one operator.
pub fn op_time(op: &Operator, d: Decision, cluster: &Cluster, b: usize,
               checkpointing: bool) -> f64 {
    op_comm_time(op, d, cluster, checkpointing)
        + op_compute_time(op, d, cluster, b, checkpointing)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GptDims, build_gpt};

    fn setup() -> (Operator, Cluster) {
        let m = build_gpt(&GptDims::uniform("t", 1000, 64, 1, 512, 4));
        let op = m.ops.iter().find(|o| o.name == "l0.mlp_up").unwrap().clone();
        (op, Cluster::rtx_titan(8, 8.0))
    }

    #[test]
    fn snapped_times_sum_exactly_in_any_order() {
        // The property the folded planner relies on: snapped values are
        // grid multiples, and grid-multiple sums never round — so the sum
        // is bit-identical under permutation.
        let vals: Vec<f64> =
            [1.7e-3, 3.1e-5, 0.25, 9.9e-7, 1.0 / 3.0, 42.0e-3]
                .iter()
                .map(|&t| snap_time(t))
                .collect();
        let fwd: f64 = vals.iter().sum();
        let rev: f64 = vals.iter().rev().sum();
        assert_eq!(fwd.to_bits(), rev.to_bits());
        for v in &vals {
            assert_eq!(snap_time(*v).to_bits(), v.to_bits(), "idempotent");
            assert_eq!((v / TIME_GRID).fract(), 0.0, "grid multiple");
        }
        // snapping moves a value by at most half a grid step
        assert!((snap_time(1.0 / 3.0) - 1.0 / 3.0).abs() <= TIME_GRID);
    }

    #[test]
    fn zdp_comm_is_1_5x_dp() {
        // The paper's headline overhead: ZeRO costs 1.5× vanilla DP comm.
        let (op, c) = setup();
        let dp = op_comm_time(&op, Decision::DP, &c, false);
        let zdp = op_comm_time(&op, Decision::ZDP, &c, false);
        assert!((zdp / dp - 1.5).abs() < 1e-9, "ratio {}", zdp / dp);
    }

    #[test]
    fn ckpt_adds_one_round_to_zdp_only() {
        let (op, c) = setup();
        let dp = op_comm_time(&op, Decision::DP, &c, false);
        let dp_ck = op_comm_time(&op, Decision::DP, &c, true);
        assert_eq!(dp, dp_ck);
        let zdp = op_comm_time(&op, Decision::ZDP, &c, false);
        let zdp_ck = op_comm_time(&op, Decision::ZDP, &c, true);
        assert!((zdp_ck / zdp - 4.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn splitting_grows_latency_term_only() {
        let (op, c) = setup();
        let t1 = op_comm_time(&op, Decision::zdp_at(1), &c, false);
        let t8 = op_comm_time(&op, Decision::zdp_at(8), &c, false);
        let n = c.n_devices as f64;
        let extra_latency = 3.0 * (n - 1.0) * c.alpha_intra * 7.0;
        assert!((t8 - t1 - extra_latency).abs() < 1e-9);
    }

    #[test]
    fn single_device_pays_no_comm() {
        let (op, _) = setup();
        let c1 = Cluster::rtx_titan(1, 8.0);
        assert_eq!(op_comm_time(&op, Decision::ZDP, &c1, false), 0.0);
    }

    #[test]
    fn unshardable_ops_are_comm_free() {
        let m = build_gpt(&GptDims::uniform("t", 1000, 64, 1, 512, 4));
        let attn = m.ops.iter().find(|o| o.name == "l0.attn").unwrap();
        let c = Cluster::rtx_titan(8, 8.0);
        assert_eq!(op_comm_time(attn, Decision::ZDP, &c, false), 0.0);
    }

    #[test]
    fn compute_scales_with_batch_and_efficiency() {
        let (op, c) = setup();
        let t1 = op_compute_time(&op, Decision::DP, &c, 1, false);
        let t4 = op_compute_time(&op, Decision::DP, &c, 4, false);
        // 4x the work at eff(4)/eff(1) = (4/6)/(1/3) = 2x the rate
        let expect = 4.0 * t1 * (batch_efficiency(1) / batch_efficiency(4));
        assert!((t4 - expect).abs() < 1e-12 * expect.max(1.0));
        // per-sample time improves with batch
        assert!(t4 / 4.0 < t1);
    }

    #[test]
    fn ckpt_recompute_only_for_interior_ops() {
        let (op, c) = setup(); // interior matmul: recomputed
        let t = op_compute_time(&op, Decision::DP, &c, 2, false);
        let tc = op_compute_time(&op, Decision::DP, &c, 2, true);
        assert!((tc / t - 4.0 / 3.0).abs() < 1e-9);

        let m = build_gpt(&GptDims::uniform("t", 1000, 64, 1, 512, 4));
        let emb = m.ops.iter().find(|o| o.name == "embed").unwrap();
        let te = op_compute_time(emb, Decision::DP, &c, 2, false);
        let tec = op_compute_time(emb, Decision::DP, &c, 2, true);
        assert_eq!(te, tec); // boundary op is not recomputed
    }

    #[test]
    fn mixed_slices_interpolate_comm() {
        let (op, c) = setup();
        let g = 4;
        let all_dp = op_comm_time(&op, Decision::dp_at(g), &c, false);
        let all_zdp = op_comm_time(&op, Decision::zdp_at(g), &c, false);
        let half = op_comm_time(
            &op,
            Decision { granularity: g, zdp_slices: 2, scope: Scope::Global },
            &c,
            false,
        );
        assert!((half - (all_dp + all_zdp) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn inter_node_link_dominates_two_server() {
        let (op, _) = setup();
        let c16 = Cluster::two_server_a100(16.0);
        let c8 = Cluster { n_devices: 8, devices_per_node: 8, ..c16.clone() };
        let t16 = op_comm_time(&op, Decision::DP, &c16, false);
        let t8 = op_comm_time(&op, Decision::DP, &c8, false);
        // crossing nodes switches β from NVLink to 12.5 GB/s: much slower
        assert!(t16 > 5.0 * t8, "t16={t16} t8={t8}");
    }

    #[test]
    fn node_scope_matches_closed_form_on_two_server() {
        let (op, _) = setup();
        let c = Cluster::two_server_a100(16.0);
        let t = op_comm_time(&op, Decision::ZDP_NODE, &c, false);
        let s = op.param_bytes();
        // 3 rounds on the 8-device intra ring + the hierarchical reduce of
        // the S/8 shard across the 2-node ring (2 rounds)
        let intra = 3.0 * 7.0 * (c.alpha_intra + s * c.beta_intra / 8.0);
        let inter =
            2.0 * 1.0 * (c.alpha_inter + (s / 8.0) * c.beta_inter / 2.0);
        assert!((t - (intra + inter)).abs() < 1e-12 * (intra + inter),
                "{t} vs {}", intra + inter);
    }

    #[test]
    fn node_scope_beats_global_zdp_across_slow_inter_link() {
        // The whole point of the scope dimension: on the Figure-6 topology
        // the node-scoped gathers ride NVLink instead of pricing every
        // round at the 12.5 GB/s bottleneck.
        let (op, _) = setup();
        let c = Cluster::two_server_a100(16.0);
        let global = op_comm_time(&op, Decision::ZDP, &c, false);
        let node = op_comm_time(&op, Decision::ZDP_NODE, &c, false);
        assert!(node < global / 4.0, "node {node} vs global {global}");
    }

    #[test]
    fn node_scope_degenerates_to_global_on_single_node() {
        // One node: the intra ring spans all devices and there is no
        // cross-node term, so both scopes price identically.
        let (op, c) = setup(); // rtx_titan: devices_per_node == n_devices
        let global = op_comm_time(&op, Decision::ZDP, &c, false);
        let node = op_comm_time(&op, Decision::ZDP_NODE, &c, false);
        assert_eq!(global.to_bits(), node.to_bits());
        assert_eq!(inter_node_grad_time(1e9, &c), 0.0);
    }

    #[test]
    fn scope_ring_picks_links() {
        let c = Cluster::two_server_a100(16.0);
        assert_eq!(scope_ring(&c, Scope::Global),
                   (c.alpha_inter, c.beta_inter, 16));
        assert_eq!(scope_ring(&c, Scope::Node),
                   (c.alpha_intra, c.beta_intra, 8));
        let single = Cluster::rtx_titan(4, 8.0);
        assert_eq!(scope_ring(&single, Scope::Node),
                   (single.alpha_intra, single.beta_intra, 4));
    }
}
