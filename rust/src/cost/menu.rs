//! Menu preprocessing: the Profiler's last pass before the search engine
//! sees an operator's decisions.
//!
//! Every candidate decision is a point in (time_fixed, states, gather)
//! space. A decision that is no better than another on *every* axis can
//! never appear in an optimal plan — any plan using it stays feasible and
//! gets no slower by swapping in the dominating decision — so the menu
//! handed to search is the Pareto frontier. On paper-scale granularity
//! sets this typically removes more than half of the raw candidates,
//! shrinking the DFS branching factor multiplicatively per operator
//! (optimality is unit-tested here and property-tested against raw-menu
//! exhaustive search in `rust/tests/parallel_planner.rs`).
//!
//! The filtered menu is sorted by ascending `time_fixed` with exact ties
//! deduplicated; option 0 being the fastest entry is an invariant both the
//! suffix bounds and the fast-completion rule of the search rely on.

use super::profiler::{DecisionCost, OpCostTable};

/// Before/after size of one dominance-filtering pass. Used at both
/// levels of the planner's Pareto machinery: per *operator* (raw
/// candidate decisions → menu entries, this module's filter) and per
/// *equivalence class* (count compositions → composition-frontier points,
/// `planner::frontier` — same relation, one level up, where each "raw
/// candidate" is a whole monotone option block).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MenuStats {
    /// Candidate entries before dominance filtering.
    pub raw: usize,
    /// Pareto-frontier entries handed to the search engine.
    pub kept: usize,
}

impl MenuStats {
    pub fn removed(&self) -> usize {
        self.raw - self.kept
    }

    /// `raw / kept` shrink factor (1.0 = nothing removed) — the
    /// branching-factor reduction the filter bought.
    pub fn reduction_factor(&self) -> f64 {
        self.raw as f64 / self.kept.max(1) as f64
    }

    /// Fold another pass's counts into a running total.
    pub fn absorb(&mut self, other: &MenuStats) {
        self.raw = self.raw.saturating_add(other.raw);
        self.kept = self.kept.saturating_add(other.kept);
    }
}

/// Canonical equality key of one operator's pruned cost table: the exact
/// bit patterns of every quantity the search engine (and `evaluate`) reads.
/// Two operators with equal keys are *interchangeable* — swapping their
/// decisions changes neither any plan's time nor its peak memory — which is
/// what lets the planner fold them into one multiplicity class
/// (`planner::bound`). Deliberately excludes names and `Decision` labels:
/// they do not enter any cost.
///
/// `Ord`/`Hash` are derived over the bit encoding so the key can index
/// maps and give classes a canonical order.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TableKey(Vec<u64>);

impl TableKey {
    /// The raw bit encoding, in field order — the plan service feeds
    /// these words into its canonical query fingerprint
    /// (`service::key`), so cache identity inherits exactly this module's
    /// "search-relevant fields only" discipline.
    pub fn bits(&self) -> &[u64] {
        &self.0
    }
}

/// Build the [`TableKey`] for a table. Menus are already sorted
/// fastest-first with exact ties deduplicated, so equal menus produce
/// equal encodings positionally.
pub fn table_key(t: &OpCostTable) -> TableKey {
    let mut bits = Vec::with_capacity(3 * t.options.len() + 3);
    bits.push(t.act_per_sample.to_bits());
    bits.push(t.workspace_per_sample.to_bits());
    bits.push(t.gamma.to_bits());
    for o in &t.options {
        bits.push(o.time_fixed().to_bits());
        bits.push(o.states.to_bits());
        bits.push(o.gather.to_bits());
    }
    TableKey(bits)
}

/// Drop every strictly dominated decision, dedupe exact ties, and sort the
/// survivors fastest-first. Exact: the optimum over the filtered menu
/// equals the optimum over `raw` for every memory limit and batch size.
pub fn pareto_filter(raw: Vec<DecisionCost>) -> (Vec<DecisionCost>, MenuStats) {
    let n_raw = raw.len();
    let mut keep: Vec<DecisionCost> = Vec::new();
    for o in &raw {
        if raw.iter().any(|p| p != o && p.dominates(o) && !o.dominates(p)) {
            continue;
        }
        // also dedupe exact ties
        if keep.iter().any(|k| {
            k.time_fixed() == o.time_fixed()
                && k.states == o.states
                && k.gather == o.gather
        }) {
            continue;
        }
        keep.push(*o);
    }
    let stats = MenuStats { raw: n_raw, kept: keep.len() };
    (sort_fastest_first(keep), stats)
}

/// The unfiltered menu under the same ordering invariant — ground truth
/// for "dominance never removes the optimum" tests.
pub fn sorted_unfiltered(raw: Vec<DecisionCost>)
                         -> (Vec<DecisionCost>, MenuStats) {
    let n = raw.len();
    (sort_fastest_first(raw), MenuStats { raw: n, kept: n })
}

fn sort_fastest_first(mut options: Vec<DecisionCost>) -> Vec<DecisionCost> {
    options.sort_by(|a, b| {
        a.time_fixed().partial_cmp(&b.time_fixed()).unwrap()
    });
    options
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Cluster, SearchConfig};
    use crate::cost::Profiler;
    use crate::model::{GptDims, build_gpt};
    use crate::planner::exhaustive_search;

    fn cost(time: f64, states: f64, gather: f64) -> DecisionCost {
        DecisionCost {
            decision: crate::cost::Decision::DP,
            comm: time,
            launch: 0.0,
            states,
            gather,
        }
    }

    #[test]
    fn dominated_entries_are_removed_and_frontier_kept() {
        let (menu, stats) = pareto_filter(vec![
            cost(1.0, 10.0, 0.0), // fastest, biggest
            cost(2.0, 5.0, 0.0),  // frontier
            cost(3.0, 7.0, 0.0),  // dominated by (2.0, 5.0)
            cost(4.0, 1.0, 0.0),  // smallest
        ]);
        assert_eq!(stats, MenuStats { raw: 4, kept: 3 });
        assert_eq!(stats.removed(), 1);
        assert!((stats.reduction_factor() - 4.0 / 3.0).abs() < 1e-12);
        assert!(menu.iter().all(|o| o.comm != 3.0));
        // sorted fastest-first
        for w in menu.windows(2) {
            assert!(w[0].time_fixed() <= w[1].time_fixed());
        }
    }

    #[test]
    fn exact_ties_dedupe_but_incomparable_points_survive() {
        let (menu, stats) = pareto_filter(vec![
            cost(1.0, 4.0, 0.0),
            cost(1.0, 4.0, 0.0), // exact duplicate
            cost(2.0, 2.0, 9.0), // trades states for gather: incomparable
            cost(3.0, 3.0, 1.0),
        ]);
        assert_eq!(stats.kept, 3);
        assert_eq!(menu.len(), 3);
    }

    #[test]
    fn table_key_separates_search_relevant_differences_only() {
        let mk = |options: Vec<DecisionCost>, act: f64, gamma: f64| {
            crate::cost::OpCostTable::new("x".into(), options, act, 0.0,
                                          gamma)
        };
        let a = mk(vec![cost(1.0, 4.0, 0.0), cost(2.0, 2.0, 0.0)], 8.0, 1e-3);
        // same costs, different name — equal keys
        let mut b = mk(vec![cost(1.0, 4.0, 0.0), cost(2.0, 2.0, 0.0)], 8.0,
                       1e-3);
        b.name = "y".into();
        assert_eq!(table_key(&a), table_key(&b));
        // any search-relevant field difference splits the key
        let c = mk(vec![cost(1.0, 4.0, 0.0), cost(2.0, 2.5, 0.0)], 8.0, 1e-3);
        let d = mk(vec![cost(1.0, 4.0, 0.0), cost(2.0, 2.0, 0.0)], 9.0, 1e-3);
        let e = mk(vec![cost(1.0, 4.0, 0.0), cost(2.0, 2.0, 0.0)], 8.0, 2e-3);
        assert_ne!(table_key(&a), table_key(&c));
        assert_ne!(table_key(&a), table_key(&d));
        assert_ne!(table_key(&a), table_key(&e));
    }

    /// The load-bearing property: filtering the menus never changes the
    /// optimal plan's cost, at any memory limit (here swept from
    /// infeasible-ish to unconstrained).
    #[test]
    fn dominance_never_removes_the_optimal_plan() {
        let m = build_gpt(&GptDims::uniform("t", 800, 32, 1, 64, 2));
        let c = Cluster::rtx_titan(4, 8.0);
        let s = SearchConfig { granularities: vec![0, 2],
                               ..Default::default() };
        let pruned = Profiler::new(&m, &c, &s);
        let raw = Profiler::with_pruning(&m, &c, &s, false);
        assert!(raw.log10_plan_space() < 6.5, "keep brute force affordable");
        assert!(pruned.menu_reduction().removed() > 0,
                "test must actually exercise the filter");
        let dp_mem =
            raw.evaluate(&raw.index_of(|d| d.is_pure_dp()), 1).peak_mem;
        for frac in [0.3, 0.5, 0.8, 1.1] {
            let limit = dp_mem * frac;
            let a = exhaustive_search(&raw, limit, 1);
            let b = exhaustive_search(&pruned, limit, 1);
            match (a, b) {
                (None, None) => {}
                (Some((_, ca)), Some((_, cb))) => {
                    assert!(
                        (ca.time - cb.time).abs()
                            <= 1e-12 * ca.time.max(1.0),
                        "frac {frac}: raw {} vs pruned {}",
                        ca.time,
                        cb.time
                    );
                }
                (a, b) => panic!(
                    "feasibility changed by pruning at {frac}: raw={} \
                     pruned={}",
                    a.is_some(),
                    b.is_some()
                ),
            }
        }
    }
}
