//! The Profiler (Figure 2): turns a model description + device information
//! into per-operator cost tables the Search Engine evaluates millions of
//! times, and prunes each operator's decision menu to its Pareto frontier
//! (the [`super::menu`] preprocessing pass; per-menu reductions are kept
//! in [`Profiler::menu_stats`]).
//!
//! Every quantity is split into decision-independent per-sample terms
//! (activations, workspace, γ_i) and per-decision terms (comm seconds,
//! launch overhead, resident states, gather transient), so evaluating a
//! full plan is a handful of fused multiply-adds per operator.

use super::memory::op_memory;
use super::menu::{self, MenuStats, TableKey};
use super::time::{batch_efficiency, op_comm_time, snap_time,
                  SPLIT_LAUNCH_OVERHEAD};
use super::{Decision, Scope};
use crate::config::{Cluster, SearchConfig};
use crate::model::ModelDesc;

/// Cost of one candidate decision for one operator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecisionCost {
    pub decision: Decision,
    /// Communication seconds per iteration (batch-independent).
    pub comm: f64,
    /// Slice launch overhead seconds (batch-independent).
    pub launch: f64,
    /// Resident model-state bytes on one device.
    pub states: f64,
    /// Transient gather bytes while this op executes.
    pub gather: f64,
}

impl DecisionCost {
    /// Batch-independent time contribution.
    pub fn time_fixed(&self) -> f64 {
        self.comm + self.launch
    }

    /// `self` is at least as good as `other` on every axis (the dominance
    /// relation of the [`super::menu`] preprocessing pass).
    pub fn dominates(&self, other: &DecisionCost) -> bool {
        self.time_fixed() <= other.time_fixed()
            && self.states <= other.states
            && self.gather <= other.gather
    }
}

/// Precomputed cost table for one operator.
#[derive(Debug, Clone)]
pub struct OpCostTable {
    pub name: String,
    /// Pareto-optimal decisions, sorted by ascending `time_fixed` (the
    /// first entry is the fastest = most DP-ish, the last the smallest).
    pub options: Vec<DecisionCost>,
    /// Activation bytes per sample (resident; respects checkpointing).
    pub act_per_sample: f64,
    /// Workspace bytes per sample (transient).
    pub workspace_per_sample: f64,
    /// γ_i: compute seconds per sample (includes ckpt recompute factor).
    pub gamma: f64,
    /// Cached `min(states)` over the menu — the search engine's suffix
    /// bounds read this once per op instead of re-folding over `options`.
    pub min_states: f64,
    /// Cached `min(gather)` over the menu (the batch-independent part of
    /// the minimum transient; add `b · workspace_per_sample` per batch).
    pub min_gather: f64,
}

impl OpCostTable {
    /// Build a table, caching the per-menu minima the search bounds read.
    pub fn new(name: String, options: Vec<DecisionCost>, act_per_sample: f64,
               workspace_per_sample: f64, gamma: f64) -> OpCostTable {
        assert!(!options.is_empty(), "empty menu for {name}");
        let min_states =
            options.iter().map(|o| o.states).fold(f64::INFINITY, f64::min);
        let min_gather =
            options.iter().map(|o| o.gather).fold(f64::INFINITY, f64::min);
        OpCostTable {
            name,
            options,
            act_per_sample,
            workspace_per_sample,
            gamma,
            min_states,
            min_gather,
        }
    }

    pub fn fastest(&self) -> &DecisionCost {
        &self.options[0]
    }

    pub fn min_time_fixed(&self) -> f64 {
        self.fastest().time_fixed()
    }

    /// Menu index whose decision is nearest to `d` — exact when the
    /// menu still offers it (distance zero is only achievable by
    /// equality), else the deterministic nearest by a lexicographic
    /// rank of (scope mismatch, |ZDP-fraction gap| as bits,
    /// granularity gap, slice gap, index). The elastic-replan
    /// projection maps each old-plan decision through this to seed
    /// the new cluster's search; any choice is merely a seed, so
    /// "nearest" only needs to be deterministic, not clever.
    pub fn closest_option(&self, d: &Decision) -> usize {
        self.options
            .iter()
            .enumerate()
            .min_by_key(|(i, o)| {
                let od = &o.decision;
                (
                    od.scope != d.scope,
                    (od.zdp_fraction() - d.zdp_fraction()).abs().to_bits(),
                    od.granularity.abs_diff(d.granularity),
                    od.zdp_slices.abs_diff(d.zdp_slices),
                    *i,
                )
            })
            .map(|(i, _)| i)
            .expect("menus are never empty")
    }
}

/// Evaluated cost of a full execution plan at a batch size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanCost {
    /// Per-iteration wall time `Σ T_i` (seconds).
    pub time: f64,
    /// Per-device peak memory: `Σ persistent + max transient` (bytes).
    pub peak_mem: f64,
}

impl PlanCost {
    /// The paper's objective: averaged per-sample time `T(p,b)/b`
    /// (minimizing it maximizes throughput).
    pub fn per_sample_time(&self, b: usize) -> f64 {
        self.time / b as f64
    }

    /// Cluster-wide samples/second at per-device batch `b`.
    pub fn throughput(&self, b: usize, n_devices: usize) -> f64 {
        (b * n_devices) as f64 / self.time
    }
}

/// The Profiler: per-op cost tables for a (model, cluster, search) triple.
#[derive(Debug, Clone)]
pub struct Profiler {
    pub cluster: Cluster,
    pub checkpointing: bool,
    pub tables: Vec<OpCostTable>,
    /// Per-operator menu sizes before/after dominance filtering (same
    /// order as `tables`).
    pub menu_stats: Vec<MenuStats>,
}

impl Profiler {
    pub fn new(model: &ModelDesc, cluster: &Cluster,
               search: &SearchConfig) -> Profiler {
        Profiler::with_pruning(model, cluster, search, true)
    }

    /// [`Profiler::new`] with the menu dominance filter optionally
    /// disabled — ground truth for "pruning never removes the optimum"
    /// tests; production callers always prune.
    pub fn with_pruning(model: &ModelDesc, cluster: &Cluster,
                        search: &SearchConfig, prune: bool) -> Profiler {
        let model_owned;
        let model = if search.paper_granularity {
            model_owned = model.fuse_paper_granularity();
            &model_owned
        } else {
            model
        };
        let ck = search.checkpointing;
        // Sharding scopes on offer: the global (paper) scope always; the
        // node-local (MiCS/HSDP-style) scope only when the cluster actually
        // crosses a node boundary — on a single node both scopes price
        // identically, so enumerating Node would only duplicate menu
        // entries for the dominance filter to drop. Each op's menu grows by
        // at most 2× (every zdp_slices > 0 candidate forks per scope).
        let scopes: &[Scope] =
            if cluster.crosses_nodes() && search.hybrid_scopes {
                &[Scope::Global, Scope::Node]
            } else {
                &[Scope::Global]
            };
        let (tables, menu_stats): (Vec<_>, Vec<_>) = model
            .ops
            .iter()
            .map(|op| {
                // Build the candidate menu.
                let mut cands: Vec<Decision> = Vec::new();
                if !op.shardable() {
                    cands.push(Decision::DP);
                } else {
                    for &g in &search.granularities {
                        // Splitting applies to matmul-bearing ops and to
                        // embeddings (vocab-dim slicing follows the same
                        // Figure-4 slice/process/sum semantics: each slice
                        // holds a vocab range, lookups hit one slice, the
                        // partial results sum). LayerNorms are too small
                        // to be worth slicing.
                        let splittable = op.matmul_dims.is_some()
                            || op.kind == crate::model::OpKind::Embedding;
                        if g > 1 && !splittable {
                            continue;
                        }
                        let slices = g.max(1);
                        for z in 0..=slices {
                            for &scope in scopes {
                                // scope only governs where sharded states
                                // live; pure DP has none to place
                                if z == 0 && scope != Scope::Global {
                                    continue;
                                }
                                cands.push(Decision { granularity: g,
                                                      zdp_slices: z,
                                                      scope });
                            }
                        }
                    }
                    if cands.is_empty() {
                        cands.push(Decision::DP);
                        for &scope in scopes {
                            cands.push(Decision::ZDP.with_scope(scope));
                        }
                    }
                }
                // Times snap to the 2⁻³⁰ s grid and memory to whole bytes:
                // both are far below model resolution, and they make every
                // sum the search engine forms *exact* in f64 — so plan
                // costs are independent of operator visit order, which the
                // symmetry-folded planner's tie-breaking requires (see
                // `cost::time::TIME_GRID` and `planner::bound`).
                let raw: Vec<DecisionCost> = cands
                    .into_iter()
                    .map(|d| {
                        let mem = op_memory(op, d, 1, cluster, ck);
                        DecisionCost {
                            decision: d,
                            comm: snap_time(op_comm_time(op, d, cluster, ck)),
                            launch: snap_time(
                                (d.slices() - 1) as f64
                                    * SPLIT_LAUNCH_OVERHEAD,
                            ),
                            states: mem.states.ceil(),
                            gather: mem.gather.ceil(),
                        }
                    })
                    .collect();
                // Menu preprocessing: drop every dominated decision (or,
                // for ground-truth profilers, keep the raw menu under the
                // same fastest-first ordering invariant).
                let (options, mstats) = if prune {
                    menu::pareto_filter(raw)
                } else {
                    menu::sorted_unfiltered(raw)
                };

                // raw γ_i (seconds per sample at 100% efficiency);
                // evaluate() divides by batch_efficiency(b)
                let mut flops = op.flops_per_sample;
                if ck && op.ckpt_act_bytes_per_sample < op.act_bytes_per_sample
                {
                    flops *= 4.0 / 3.0; // recompute
                }
                let gamma = flops / cluster.flops;
                let mem1 = op_memory(op, Decision::DP, 1, cluster, ck);
                let table = OpCostTable::new(
                    op.name.clone(),
                    options,
                    mem1.activations.ceil(),
                    mem1.workspace.ceil(),
                    gamma,
                );
                (table, mstats)
            })
            .unzip();
        Profiler {
            cluster: cluster.clone(),
            checkpointing: ck,
            tables,
            menu_stats,
        }
    }

    pub fn n_ops(&self) -> usize {
        self.tables.len()
    }

    /// Aggregate menu reduction across all operators: how many raw
    /// candidate decisions the dominance pass saw and how many survived.
    pub fn menu_reduction(&self) -> MenuStats {
        let mut total = MenuStats::default();
        for s in &self.menu_stats {
            total.absorb(s);
        }
        total
    }

    /// Total decision-space size (product of menu sizes), as a log10.
    pub fn log10_plan_space(&self) -> f64 {
        self.tables.iter().map(|t| (t.options.len() as f64).log10()).sum()
    }

    /// Partition the operators into interchangeability classes: groups
    /// whose pruned cost tables are byte-for-byte equal (menus *and*
    /// per-sample act/workspace/γ — see [`menu::table_key`]). On the
    /// GPT-style stacks the paper targets this collapses runs of identical
    /// layers into one class per op shape, which is what the planner's
    /// symmetry fold searches over.
    ///
    /// Classes are returned in order of first appearance; members keep
    /// profiler order, so the partition is deterministic.
    pub fn op_classes(&self) -> Vec<Vec<usize>> {
        let mut classes: Vec<(TableKey, Vec<usize>)> = Vec::new();
        for (i, t) in self.tables.iter().enumerate() {
            let key = menu::table_key(t);
            match classes.iter_mut().find(|(k, _)| *k == key) {
                Some((_, members)) => members.push(i),
                None => classes.push((key, vec![i])),
            }
        }
        classes.into_iter().map(|(_, m)| m).collect()
    }

    /// Per-operator class index (same class numbering as
    /// [`Profiler::op_classes`]).
    pub fn class_ids(&self) -> Vec<usize> {
        let classes = self.op_classes();
        let mut ids = vec![0usize; self.n_ops()];
        for (c, members) in classes.iter().enumerate() {
            for &op in members {
                ids[op] = c;
            }
        }
        ids
    }

    /// Evaluate a plan given per-op option indices.
    ///
    /// The decision-dependent time (a sum of grid-snapped `time_fixed`
    /// terms) and the decision-independent compute time are accumulated
    /// separately, so the result is bit-identical under any permutation of
    /// interchangeable operators' decisions — the invariant the folded
    /// planner's canonical unfold relies on.
    pub fn evaluate(&self, choice: &[usize], b: usize) -> PlanCost {
        assert_eq!(choice.len(), self.tables.len());
        let bf = b as f64;
        let eff = batch_efficiency(b);
        let mut time_fixed = 0.0;
        let mut compute = 0.0;
        let mut persistent = 0.0;
        let mut transient_max: f64 = 0.0;
        for (t, &c) in self.tables.iter().zip(choice) {
            let opt = &t.options[c];
            time_fixed += opt.time_fixed();
            compute += bf * t.gamma;
            persistent += opt.states + bf * t.act_per_sample;
            transient_max = transient_max
                .max(opt.gather + bf * t.workspace_per_sample);
        }
        PlanCost {
            time: time_fixed + compute / eff,
            peak_mem: persistent + transient_max,
        }
    }

    /// Per-op option index of the first menu entry whose decision matches
    /// `pred` (a decision-predicate lookup, e.g. "the pure-DP option" or
    /// "the pure-ZDP option"); falls back to option 0 — the fastest entry —
    /// for any op whose menu has no match.
    pub fn index_of(&self, pred: impl Fn(&Decision) -> bool) -> Vec<usize> {
        self.tables
            .iter()
            .map(|t| {
                t.options
                    .iter()
                    .position(|o| pred(&o.decision))
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GptDims, build_gpt};

    fn profiler(granularities: Vec<usize>) -> Profiler {
        let m = build_gpt(&GptDims::uniform("t", 1000, 64, 2, 256, 4));
        let c = Cluster::rtx_titan(8, 8.0);
        let s = SearchConfig { granularities, ..Default::default() };
        Profiler::new(&m, &c, &s)
    }

    #[test]
    fn menu_has_dp_and_zdp_extremes() {
        let p = profiler(vec![0]);
        for t in &p.tables {
            assert!(!t.options.is_empty());
            // fastest option is pure DP (comm 2 rounds)
            assert!(t.fastest().decision.is_pure_dp());
        }
    }

    #[test]
    fn pareto_drops_dominated() {
        // With granularities {0, 4}: DP@g4 is dominated by DP@g0 (same
        // states/gather, more latency) — must be pruned.
        let p = profiler(vec![0, 4]);
        for t in &p.tables {
            for o in &t.options {
                if o.decision.is_pure_dp() {
                    assert!(o.decision.granularity <= 1,
                            "dominated DP@g4 kept in {}", t.name);
                }
            }
        }
        // the per-menu bookkeeping matches the tables
        assert_eq!(p.menu_stats.len(), p.n_ops());
        for (t, s) in p.tables.iter().zip(&p.menu_stats) {
            assert_eq!(t.options.len(), s.kept);
            assert!(s.kept <= s.raw);
        }
        assert!(p.menu_reduction().removed() > 0,
                "the {{0,4}} menus must contain dominated entries");
    }

    #[test]
    fn closest_option_is_exact_then_deterministic_nearest() {
        let p = profiler(vec![0, 4]);
        for t in &p.tables {
            // every decision the menu offers maps back to itself
            for (i, o) in t.options.iter().enumerate() {
                assert_eq!(t.closest_option(&o.decision), i,
                           "exact match must win in {}", t.name);
            }
            // a decision the menu cannot offer (finer than any
            // granularity present) lands on the fraction-nearest one:
            // 7/8 sharded is closer to ZDP (1.0) than to 3/4
            let alien = Decision { granularity: 8, zdp_slices: 7,
                                   scope: Scope::Global };
            let near = &t.options[t.closest_option(&alien)].decision;
            let gap = (near.zdp_fraction() - alien.zdp_fraction()).abs();
            for o in &t.options {
                assert!(gap <= (o.decision.zdp_fraction()
                                - alien.zdp_fraction()).abs() + 1e-12);
            }
        }
    }

    #[test]
    fn evaluate_all_dp_matches_components() {
        let p = profiler(vec![0]);
        let dp = p.index_of(|d| d.is_pure_dp());
        let cost = p.evaluate(&dp, 2);
        assert!(cost.time > 0.0);
        assert!(cost.peak_mem > 0.0);
        // doubling batch increases both time and memory
        let cost4 = p.evaluate(&dp, 4);
        assert!(cost4.time > cost.time);
        assert!(cost4.peak_mem > cost.peak_mem);
    }

    #[test]
    fn zdp_plan_smaller_but_slower() {
        let p = profiler(vec![0]);
        let dp = p.index_of(|d| d.is_pure_dp());
        let zdp = p.index_of(|d| d.is_pure_zdp());
        let cd = p.evaluate(&dp, 1);
        let cz = p.evaluate(&zdp, 1);
        assert!(cz.time > cd.time, "ZDP must pay more comm");
        assert!(cz.peak_mem < cd.peak_mem, "ZDP must save memory");
    }

    #[test]
    fn throughput_and_per_sample_agree() {
        let cost = PlanCost { time: 2.0, peak_mem: 0.0 };
        assert_eq!(cost.per_sample_time(4), 0.5);
        assert_eq!(cost.throughput(4, 8), 16.0);
    }

    #[test]
    fn plan_space_grows_with_granularities() {
        let small = profiler(vec![0]).log10_plan_space();
        let big = profiler(vec![0, 2, 4, 8]).log10_plan_space();
        assert!(big > small);
    }

    fn two_server_profiler(hybrid_scopes: bool) -> Profiler {
        let m = build_gpt(&GptDims::uniform("t", 1000, 64, 2, 256, 4));
        let c = Cluster::two_server_a100(16.0);
        let s = SearchConfig { granularities: vec![0], hybrid_scopes,
                               ..Default::default() };
        Profiler::new(&m, &c, &s)
    }

    #[test]
    fn node_scope_candidates_only_on_multi_node_clusters() {
        // Single node: no node-scoped entries even with the knob on.
        let single = profiler(vec![0]);
        for t in &single.tables {
            assert!(t.options.iter().all(|o| !o.decision.is_node_scoped()),
                    "{}: node scope on a single-node cluster", t.name);
        }
        // Two servers: every shardable op's menu keeps a node-scoped entry
        // (incomparable with global ZDP: faster, more states) and the menu
        // grows by at most 2x per op.
        let scoped = two_server_profiler(true);
        let plain = two_server_profiler(false);
        assert_eq!(scoped.n_ops(), plain.n_ops());
        let mut any_node = false;
        for (ts, tp) in scoped.tables.iter().zip(&plain.tables) {
            assert!(ts.options.len() <= 2 * tp.options.len(),
                    "{}: menu more than doubled", ts.name);
            let node =
                ts.options.iter().any(|o| o.decision.is_node_scoped());
            any_node |= node;
            // scope-free menus never contain node-scoped entries
            assert!(tp.options.iter().all(|o| !o.decision.is_node_scoped()));
        }
        assert!(any_node, "two-server menus must offer node scope");
    }

    #[test]
    fn node_scope_is_a_distinct_pareto_point() {
        // On the two-server cluster node-ZDP must survive the dominance
        // filter alongside global ZDP: strictly faster, strictly more
        // states.
        let p = two_server_profiler(true);
        let c = Cluster::two_server_a100(16.0);
        let t = p.tables.iter().find(|t| t.name.contains("mlp_up")).unwrap();
        let global = t.options.iter()
            .find(|o| o.decision.is_pure_zdp() && !o.decision.is_node_scoped())
            .expect("global ZDP kept");
        let node = t.options.iter()
            .find(|o| o.decision.is_pure_zdp() && o.decision.is_node_scoped())
            .expect("node ZDP kept");
        assert!(node.time_fixed() < global.time_fixed());
        assert!(node.states > global.states);
        assert!(c.crosses_nodes());
    }

    #[test]
    fn menu_costs_are_grid_quantized() {
        let p = profiler(vec![0, 4]);
        for t in &p.tables {
            assert_eq!(t.act_per_sample.fract(), 0.0, "{}", t.name);
            assert_eq!(t.workspace_per_sample.fract(), 0.0);
            for o in &t.options {
                assert_eq!((o.comm / crate::cost::time::TIME_GRID).fract(),
                           0.0);
                assert_eq!((o.launch / crate::cost::time::TIME_GRID).fract(),
                           0.0);
                assert_eq!(o.states.fract(), 0.0, "whole bytes");
                assert_eq!(o.gather.fract(), 0.0, "whole bytes");
            }
        }
    }

    #[test]
    fn cached_menu_minima_match_folds() {
        let p = profiler(vec![0, 4]);
        for t in &p.tables {
            let ms =
                t.options.iter().map(|o| o.states).fold(f64::INFINITY,
                                                        f64::min);
            let mg =
                t.options.iter().map(|o| o.gather).fold(f64::INFINITY,
                                                        f64::min);
            assert_eq!(t.min_states.to_bits(), ms.to_bits());
            assert_eq!(t.min_gather.to_bits(), mg.to_bits());
        }
    }

    #[test]
    fn identical_layers_share_a_class() {
        // 2 identical fine-grained layers: each per-layer op shape folds
        // into one class of multiplicity 2 (+ lnf joining the ln class
        // when checkpointing is off), embed and head stay singletons.
        let p = profiler(vec![0]);
        let classes = p.op_classes();
        let total: usize = classes.iter().map(|c| c.len()).sum();
        assert_eq!(total, p.n_ops());
        assert!(classes.len() < p.n_ops(), "identical layers must fold");
        let max_mult = classes.iter().map(|c| c.len()).max().unwrap();
        assert!(max_mult >= 2);
        // the id view agrees with the partition
        let ids = p.class_ids();
        for (c, members) in classes.iter().enumerate() {
            for &op in members {
                assert_eq!(ids[op], c);
            }
        }
        // interchangeability is real: swapping two same-class members'
        // decisions changes neither time nor peak memory
        let big = classes.iter().find(|c| c.len() >= 2).unwrap();
        let (a, b) = (big[0], big[1]);
        let mut choice = p.index_of(|d| d.is_pure_dp());
        choice[a] = p.tables[a].options.len() - 1;
        let cost = p.evaluate(&choice, 2);
        let mut swapped = choice.clone();
        swapped.swap(a, b);
        let cost2 = p.evaluate(&swapped, 2);
        assert_eq!(cost.time.to_bits(), cost2.time.to_bits());
        assert_eq!(cost.peak_mem.to_bits(), cost2.peak_mem.to_bits());
    }
}
