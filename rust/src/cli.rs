//! Hand-rolled CLI argument parsing (offline build: no clap). Flags are
//! `--key value` or `--flag`; positional args are collected in order.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, positionals, flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(cmd) = it.peek() {
            if !cmd.starts_with('-') {
                out.command = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                out.flags.insert(key.to_string(), value);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| {
                panic!("--{key} expects an integer, got '{v}'")
            }))
            .unwrap_or(default)
    }

    /// Optional integer flag: `None` when absent, so callers can
    /// distinguish "use the computed default" (e.g. `--threads` defaulting
    /// to the hardware parallelism) from an explicit value.
    pub fn usize_opt(&self, key: &str) -> Option<usize> {
        self.get(key).map(|v| {
            v.parse().unwrap_or_else(|_| {
                panic!("--{key} expects an integer, got '{v}'")
            })
        })
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| {
                panic!("--{key} expects a number, got '{v}'")
            }))
            .unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated usize list.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse().unwrap_or_else(|_| {
                    panic!("--{key}: bad integer '{s}'")
                }))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_flags_positionals() {
        let a = parse("plan nd48 --devices 8 --mem 8.5 --ckpt --g 0,4,8");
        assert_eq!(a.command, "plan");
        assert_eq!(a.positional, vec!["nd48"]);
        assert_eq!(a.usize_or("devices", 1), 8);
        assert_eq!(a.f64_or("mem", 0.0), 8.5);
        assert!(a.flag("ckpt"));
        assert!(!a.flag("missing"));
        assert_eq!(a.usize_list_or("g", &[0]), vec![0, 4, 8]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("zoo");
        assert_eq!(a.usize_or("devices", 8), 8);
        assert_eq!(a.get_or("model", "tiny"), "tiny");
    }

    #[test]
    fn optional_integers_distinguish_absent() {
        let a = parse("plan --threads 8 --split-depth 2");
        assert_eq!(a.usize_opt("threads"), Some(8));
        assert_eq!(a.usize_opt("split-depth"), Some(2));
        assert_eq!(a.usize_opt("batch"), None);
    }

    #[test]
    fn bare_flags_before_values() {
        let a = parse("train --verbose --steps 10");
        assert!(a.flag("verbose"));
        assert_eq!(a.usize_or("steps", 0), 10);
    }
}
