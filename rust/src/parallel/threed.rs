//! 3D parallelism (DeepSpeed-style DP × TP × PP composition) and 3D+OSDP
//! (the paper's hybrid: OSDP replaces the DP dimension).
//!
//! For every factorization `dp·tp·pp = N` the estimator composes the three
//! axes the way the individual baselines do:
//!
//! * **PP**: layers split into `pp` flop-balanced stages; GPipe microbatch
//!   schedule with its `(m + pp − 1)` bubble;
//! * **TP** within a stage: states and matmul compute divide by `tp`; each
//!   block pays Megatron's four activation all-reduces per microbatch over
//!   the `tp` group;
//! * **DP** across replicas: gradient all-reduce of the per-device shard
//!   (`stage/tp`) — or, for 3D+OSDP, the OSDP search engine plans per-op
//!   DP/ZDP modes *within the dp group* and contributes its comm time and
//!   sharded memory instead.
//!
//! The best feasible (dp, tp, pp, m) is reported ("we tune the combinations
//! of parallel strategies for hybrid parallelism and report the one with
//! the best performance", §4.1).

use super::pp::assign_stages;
use super::{Estimate, Strategy};
use crate::config::{Cluster, SearchConfig};
use crate::cost::Profiler;
use crate::model::{ModelDesc, OpKind};
use crate::planner::dfs;

pub struct ThreeD;
pub struct ThreeDOsdp;

/// Factorizations dp·tp·pp = n.
pub fn factorizations(n: usize) -> Vec<(usize, usize, usize)> {
    let mut out = Vec::new();
    for dp in 1..=n {
        if n % dp != 0 {
            continue;
        }
        let rest = n / dp;
        for tp in 1..=rest {
            if rest % tp != 0 {
                continue;
            }
            out.push((dp, tp, rest / tp));
        }
    }
    out
}

struct StageAgg {
    states: f64,
    act_per_sample: f64,
    flops_per_sample: f64,
    param_bytes: f64,
    layers: usize,
    op_indices: Vec<usize>,
}

fn aggregate(model: &ModelDesc, stages: &[Vec<usize>]) -> Vec<StageAgg> {
    stages
        .iter()
        .map(|ops| {
            let mut layers = std::collections::BTreeSet::new();
            let mut agg = StageAgg {
                states: 0.0,
                act_per_sample: 0.0,
                flops_per_sample: 0.0,
                param_bytes: 0.0,
                layers: 0,
                op_indices: ops.clone(),
            };
            for &i in ops {
                let op = &model.ops[i];
                agg.states += op.state_bytes();
                agg.act_per_sample += op.act_bytes_per_sample;
                agg.flops_per_sample += op.flops_per_sample;
                agg.param_bytes += op.param_bytes();
                if let Some(l) = op.layer {
                    layers.insert(l);
                }
            }
            agg.layers = layers.len().max(1);
            agg
        })
        .collect()
}

/// Estimate one (dp, tp, pp) composition; `use_osdp` swaps the DP gradient
/// sync for an OSDP plan over the dp group.
fn compose(model: &ModelDesc, cluster: &Cluster, search: &SearchConfig,
           dp: usize, tp: usize, pp: usize, use_osdp: bool)
           -> Option<Estimate> {
    let n_stages = pp;
    let stages = if n_stages == 1 {
        vec![(0..model.ops.len()).collect::<Vec<_>>()]
    } else {
        assign_stages(model, n_stages)?
    };
    let aggs = aggregate(model, &stages);
    let (alpha, beta) = cluster.ring_link();
    let tpf = tp as f64;
    let dpf = dp as f64;

    // bottleneck stage: compute and memory
    let hot = aggs
        .iter()
        .max_by(|a, b| {
            a.flops_per_sample.partial_cmp(&b.flops_per_sample).unwrap()
        })
        .unwrap();
    let fat = aggs
        .iter()
        .max_by(|a, b| a.states.partial_cmp(&b.states).unwrap())
        .unwrap();

    // TP activation sync per sample in the hot stage (4 all-reduces per
    // block over the tp group)
    let tp_sync_per_sample = if tp > 1 {
        let bytes = (model.seq * model.hidden) as f64 * crate::model::F32;
        let t_ar = 2.0 * (tpf - 1.0) * (alpha + bytes * beta / tpf);
        4.0 * hot.layers as f64 * t_ar
    } else {
        0.0
    };

    // OSDP sub-model of the fat stage with TP-sharded parameters
    let sub_profiler = if use_osdp && dp > 1 {
        let mut sub = ModelDesc {
            name: format!("{}-stage", model.name),
            ops: fat.op_indices.iter().map(|&i| {
                let mut op = model.ops[i].clone();
                if tp > 1 && op.kind != OpKind::LayerNorm {
                    op.params /= tpf;
                    if let Some((a, b)) = op.matmul_dims {
                        op.matmul_dims = Some((a, (b / tp).max(1)));
                    }
                }
                op
            }).collect(),
            seq: model.seq,
            layers: fat.layers,
            hidden: model.hidden,
        };
        // plan at the paper's coarse granularity: fast + faithful
        sub = sub.fuse_paper_granularity();
        let sub_cluster = Cluster { n_devices: dp, ..cluster.clone() };
        Some(Profiler::new(&sub, &sub_cluster, &SearchConfig {
            paper_granularity: false, // already fused above
            ..search.clone()
        }))
    } else {
        None
    };

    let mut best: Option<Estimate> = None;
    // pp == 1 degenerates to DP×TP: the replica runs its whole batch at
    // once (no pipeline, no microbatching penalty)
    let mb_options: &[usize] =
        if pp == 1 { &[usize::MAX] } else { &[1, 2, 4, 8] };
    for &mb_opt in mb_options {
    for m in 1..=search.max_batch {
        // pp==1: m is the per-replica batch, one "microbatch" of size m
        let (mb, m) = if mb_opt == usize::MAX { (m, 1) } else { (mb_opt, m) };
        let eff = crate::cost::time::batch_efficiency(mb);
        let mf = m as f64;
        // per-microbatch stage time at microbatch size mb
        let stage_t = mb as f64 * hot.flops_per_sample
            / (tpf * cluster.flops * eff)
            + mb as f64 * tp_sync_per_sample;
        let boundary = if pp > 1 {
            alpha + (model.seq * model.hidden) as f64 * crate::model::F32
                * beta
        } else {
            0.0
        };
        let pipe = (mf + pp as f64 - 1.0) * (stage_t + 2.0 * boundary);

        let samples = m * mb;
        // DP dimension: plain grad all-reduce or OSDP plan
        let (sync, peak) = match &sub_profiler {
            Some(p) => {
                match dfs::search(p, cluster.mem_limit, samples) {
                    None => break, // no feasible plan at this m
                    Some((choice, cost, _)) => {
                        let fixed: f64 = p
                            .tables
                            .iter()
                            .zip(&choice)
                            .map(|(t, &c)| t.options[c].time_fixed())
                            .sum();
                        (fixed, cost.peak_mem)
                    }
                }
            }
            None => {
                let shard_params = fat.param_bytes / tpf;
                let sync = if dp > 1 {
                    2.0 * (dpf - 1.0) * (alpha + shard_params * beta / dpf)
                } else {
                    0.0
                };
                let peak = fat.states / tpf
                    + samples as f64 * fat.act_per_sample;
                (sync, peak)
            }
        };
        if peak > cluster.mem_limit {
            break;
        }
        let iter = pipe + sync;
        let global = dp * samples;
        let throughput = global as f64 / iter;
        if best.as_ref().map(|e| throughput > e.throughput).unwrap_or(true) {
            best = Some(Estimate {
                strategy: if use_osdp { "3D+OSDP" } else { "3D" }.into(),
                feasible: true,
                reason: None,
                global_batch: global,
                iter_time: iter,
                throughput,
                peak_mem: peak,
                detail: format!("dp={dp} tp={tp} pp={pp} m={m}x{mb}"),
            });
        }
    }
    }
    best
}

fn best_composition(model: &ModelDesc, cluster: &Cluster,
                    search: &SearchConfig, use_osdp: bool) -> Estimate {
    let name = if use_osdp { "3D+OSDP" } else { "3D" };
    let mut best: Option<Estimate> = None;
    for (dp, tp, pp) in factorizations(cluster.n_devices) {
        if pp > model.layers {
            continue;
        }
        if let Some(e) = compose(model, cluster, search, dp, tp, pp, use_osdp)
        {
            if best.as_ref().map(|b| e.throughput > b.throughput)
                .unwrap_or(true)
            {
                best = Some(e);
            }
        }
    }
    best.unwrap_or_else(|| Estimate::infeasible(name, "OOM"))
}

impl Strategy for ThreeD {
    fn name(&self) -> &'static str {
        "3D"
    }

    fn estimate(&self, model: &ModelDesc, cluster: &Cluster,
                search: &SearchConfig) -> Estimate {
        best_composition(model, cluster, search, false)
    }
}

impl Strategy for ThreeDOsdp {
    fn name(&self) -> &'static str {
        "3D+OSDP"
    }

    fn estimate(&self, model: &ModelDesc, cluster: &Cluster,
                search: &SearchConfig) -> Estimate {
        best_composition(model, cluster, search, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GptDims, build_gpt};

    #[test]
    fn factorizations_multiply_to_n() {
        for n in [1usize, 4, 8, 16] {
            let fs = factorizations(n);
            assert!(!fs.is_empty());
            for (dp, tp, pp) in fs {
                assert_eq!(dp * tp * pp, n);
            }
        }
        assert_eq!(factorizations(8).len(), 10); // 3 exps of 2 -> C(5,2)=10
    }

    #[test]
    fn three_d_feasible_on_tight_memory() {
        let m = build_gpt(&GptDims::uniform("t", 5000, 128, 8, 384, 4));
        // limit below DP needs but fine for sharded hybrid
        let c = Cluster { mem_limit: m.state_bytes() * 0.3,
                          ..Cluster::rtx_titan(8, 8.0) };
        let s = SearchConfig { max_batch: 16, ..Default::default() };
        let e = ThreeD.estimate(&m, &c, &s);
        assert!(e.feasible, "{:?}", e.reason);
        assert!(e.peak_mem <= c.mem_limit);
        assert!(e.detail.contains("dp="));
    }

    #[test]
    fn osdp_variant_at_least_as_good() {
        let m = build_gpt(&GptDims::uniform("t", 5000, 128, 8, 384, 4));
        let c = Cluster { mem_limit: m.state_bytes() * 0.5,
                          ..Cluster::rtx_titan(8, 8.0) };
        let s = SearchConfig { max_batch: 8, granularities: vec![0, 4],
                               ..Default::default() };
        let plain = ThreeD.estimate(&m, &c, &s);
        let osdp = ThreeDOsdp.estimate(&m, &c, &s);
        assert!(osdp.feasible);
        // OSDP's plan space includes the plain DP sync as one point
        assert!(osdp.throughput >= plain.throughput * 0.98,
                "3D+OSDP {} vs 3D {}", osdp.throughput, plain.throughput);
    }

    #[test]
    fn pp_degree_respects_layer_count() {
        let m = build_gpt(&GptDims::uniform("ws", 5000, 128, 2, 1024, 8));
        let c = Cluster::rtx_titan(8, 16.0);
        let s = SearchConfig { max_batch: 8, ..Default::default() };
        let e = ThreeD.estimate(&m, &c, &s);
        assert!(e.feasible);
        // pp can't exceed 2 layers
        let pp: usize = e.detail.split("pp=").nth(1).unwrap()
            .split(' ').next().unwrap().parse().unwrap();
        assert!(pp <= 2);
    }
}
