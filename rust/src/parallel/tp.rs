//! Megatron-LM-style tensor parallelism baseline.
//!
//! Every matmul's weight is partitioned N ways (column- then row-parallel
//! pairs), so model states shrink to 1/N per device while each transformer
//! block pays two all-reduces of the full activation in forward and two in
//! backward (Megatron's `f`/`g` operators). Activations stay full-size on
//! every device; compute divides by N.

use super::{Estimate, Strategy};
use crate::config::{Cluster, SearchConfig};
use crate::model::{ModelDesc, OpKind};

pub struct MegatronTp;

impl Strategy for MegatronTp {
    fn name(&self) -> &'static str {
        "TP"
    }

    fn estimate(&self, model: &ModelDesc, cluster: &Cluster,
                search: &SearchConfig) -> Estimate {
        let n = cluster.n_devices as f64;
        let (alpha, beta) = cluster.ring_link();

        let states = model.state_bytes() / n;
        let act_per_sample: f64 = model.act_bytes_per_sample(); // replicated
        let gamma_raw = model.flops_per_sample() / cluster.flops / n;

        // per-layer sync: 2 all-reduces fwd + 2 bwd over (seq·hidden·b)
        // bytes; all-reduce = 2(N-1)/N · bytes·β + 2(N-1)·α per op
        let act_row = |hidden: usize| {
            (model.seq * hidden) as f64 * crate::model::F32
        };
        let mut per_sample_sync = 0.0;
        for op in &model.ops {
            if op.kind == OpKind::Attention {
                // one block ≈ attention + mlp: 4 all-reduces total, use the
                // block's hidden size
                let h = op.act_bytes_per_sample
                    / (model.seq as f64 * crate::model::F32);
                let h = h.min(model.hidden as f64) as usize;
                let bytes = act_row(h.max(1));
                let t_ar = 2.0 * (n - 1.0) * (alpha + bytes * beta / n);
                per_sample_sync += 4.0 * t_ar;
            }
        }

        let mut best: Option<Estimate> = None;
        for b in 1..=search.max_batch {
            let bf = b as f64;
            let peak = states + bf * act_per_sample;
            if peak > cluster.mem_limit {
                break;
            }
            let eff = crate::cost::time::batch_efficiency(b);
            let iter = bf * (gamma_raw / eff + per_sample_sync);
            let throughput = bf / iter;
            if best.as_ref().map(|e| throughput > e.throughput).unwrap_or(true)
            {
                best = Some(Estimate {
                    strategy: "TP".into(),
                    feasible: true,
                    reason: None,
                    global_batch: b,
                    iter_time: iter,
                    throughput,
                    peak_mem: peak,
                    detail: format!("{}-way tensor parallel, b={b}",
                                    cluster.n_devices),
                });
            }
        }
        best.unwrap_or_else(|| Estimate::infeasible("TP", "OOM"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GptDims, build_gpt};
    use crate::parallel::Ddp;

    fn model() -> ModelDesc {
        build_gpt(&GptDims::uniform("t", 5000, 128, 4, 384, 4))
    }

    #[test]
    fn tp_states_shrink_by_n() {
        let m = model();
        let c = Cluster::rtx_titan(8, 64.0);
        let s = SearchConfig { max_batch: 1, ..Default::default() };
        let e = MegatronTp.estimate(&m, &c, &s);
        assert!(e.feasible);
        let expect = m.state_bytes() / 8.0 + m.act_bytes_per_sample();
        assert!((e.peak_mem - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn tp_slower_than_dp_when_memory_free() {
        // frequent activation all-reduces make TP lose without memory
        // pressure — the paper's motivation for not using TP alone
        let m = model();
        let c = Cluster::rtx_titan(8, 1024.0);
        let s = SearchConfig { max_batch: 16, ..Default::default() };
        let tp = MegatronTp.estimate(&m, &c, &s);
        let dp = Ddp.estimate(&m, &c, &s);
        assert!(tp.throughput < dp.throughput);
    }

    #[test]
    fn tp_fits_where_dp_cannot() {
        let m = model();
        let c = Cluster { mem_limit: m.state_bytes() * 0.4,
                          ..Cluster::rtx_titan(8, 8.0) };
        let s = SearchConfig { max_batch: 4, ..Default::default() };
        assert!(!Ddp.estimate(&m, &c, &s).feasible);
        assert!(MegatronTp.estimate(&m, &c, &s).feasible);
    }
}
