//! Data-parallel family: DDP (all-DP), FSDP (all-ZDP), and OSDP itself
//! (the scheduler over the per-op decision space; base = no splitting).

use super::{Estimate, Strategy};
use crate::config::{Cluster, SearchConfig};
use crate::cost::Profiler;
use crate::model::ModelDesc;
use crate::planner::Scheduler;

/// Sweep batch sizes for a *fixed* plan predicate (all-DP or all-ZDP) and
/// return the best feasible throughput.
fn fixed_plan_estimate(name: &str, model: &ModelDesc, cluster: &Cluster,
                       search: &SearchConfig,
                       pred: impl Fn(&crate::cost::Decision) -> bool)
                       -> Estimate {
    let profiler = Profiler::new(model, cluster, &SearchConfig {
        granularities: vec![0],
        ..search.clone()
    });
    let choice = profiler.index_of(&pred);
    let mut best: Option<Estimate> = None;
    for b in 1..=search.max_batch {
        let cost = profiler.evaluate(&choice, b);
        if cost.peak_mem > cluster.mem_limit {
            break;
        }
        let throughput = cost.throughput(b, cluster.n_devices);
        if best.as_ref().map(|e| throughput > e.throughput).unwrap_or(true) {
            best = Some(Estimate {
                strategy: name.into(),
                feasible: true,
                reason: None,
                global_batch: b * cluster.n_devices,
                iter_time: cost.time,
                throughput,
                peak_mem: cost.peak_mem,
                detail: format!("b/device={b}"),
            });
        }
    }
    best.unwrap_or_else(|| Estimate::infeasible(name, "OOM"))
}

/// PyTorch-DDP-style vanilla data parallel: full replica everywhere,
/// all-reduce gradient sync (2 rounds).
pub struct Ddp;

impl Strategy for Ddp {
    fn name(&self) -> &'static str {
        "DP"
    }

    fn estimate(&self, model: &ModelDesc, cluster: &Cluster,
                search: &SearchConfig) -> Estimate {
        fixed_plan_estimate("DP", model, cluster, search,
                            |d| d.is_pure_dp())
    }
}

/// FairScale-FSDP / ZeRO-3: every operator sharded (3 comm rounds, 1/N
/// states). Pinned to the *global* sharding scope — the baseline shards
/// over all N devices like ZeRO does, even when the planner's menu also
/// offers node-local scopes.
pub struct Fsdp;

impl Strategy for Fsdp {
    fn name(&self) -> &'static str {
        "FSDP"
    }

    fn estimate(&self, model: &ModelDesc, cluster: &Cluster,
                search: &SearchConfig) -> Estimate {
        fixed_plan_estimate("FSDP", model, cluster, search,
                            |d| d.is_pure_zdp() && !d.is_node_scoped())
    }
}

/// Run the OSDP scheduler with a given granularity menu.
fn osdp_estimate(name: &str, model: &ModelDesc, cluster: &Cluster,
                 search: &SearchConfig, granularities: Vec<usize>)
                 -> Estimate {
    let cfg = SearchConfig { granularities, ..search.clone() };
    let profiler = Profiler::new(model, cluster, &cfg);
    match Scheduler::new(&profiler, cluster.mem_limit, search.max_batch).run()
    {
        Err(_) => Estimate::infeasible(name, "OOM"),
        Ok(res) => {
            let c = &res.candidates[res.best];
            let (dp, zdp, mixed) = c.plan.mode_counts();
            Estimate {
                strategy: name.into(),
                feasible: true,
                reason: None,
                global_batch: c.plan.batch * cluster.n_devices,
                iter_time: c.plan.cost.time,
                throughput: c.throughput,
                peak_mem: c.plan.cost.peak_mem,
                detail: format!(
                    "b/device={} plan[{dp} DP,{zdp} ZDP,{mixed} mixed] {:.0}% split",
                    c.plan.batch,
                    c.plan.split_fraction() * 100.0
                ),
            }
        }
    }
}

/// OSDP without operator splitting (the paper's "OSDP-base").
pub struct OsdpBase;

impl Strategy for OsdpBase {
    fn name(&self) -> &'static str {
        "OSDP-base"
    }

    fn estimate(&self, model: &ModelDesc, cluster: &Cluster,
                search: &SearchConfig) -> Estimate {
        osdp_estimate("OSDP-base", model, cluster, search, vec![0])
    }
}

/// Full OSDP: per-operator DP/ZDP with operator splitting.
pub struct Osdp;

impl Strategy for Osdp {
    fn name(&self) -> &'static str {
        "OSDP"
    }

    fn estimate(&self, model: &ModelDesc, cluster: &Cluster,
                search: &SearchConfig) -> Estimate {
        // The full menu's plan space strictly contains the no-splitting
        // space, but the node-budgeted (anytime) search can land lower on
        // the bigger space; take the better of the two so OSDP provably
        // dominates OSDP-base.
        let full = osdp_estimate("OSDP", model, cluster, search,
                                 search.granularities.clone());
        let base = osdp_estimate("OSDP", model, cluster, search, vec![0]);
        if base.feasible && base.throughput > full.throughput {
            base
        } else {
            full
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GIB;
    use crate::model::{GptDims, build_gpt};

    fn model() -> ModelDesc {
        build_gpt(&GptDims::uniform("t", 5000, 128, 4, 384, 4))
    }

    #[test]
    fn fsdp_feasible_where_dp_oom() {
        let m = model();
        // states = 16·params; pick a limit between ZDP and DP needs
        let states = m.state_bytes();
        let c = Cluster { mem_limit: states * 0.5,
                          ..Cluster::rtx_titan(8, 8.0) };
        let s = SearchConfig { max_batch: 8, ..Default::default() };
        let dp = Ddp.estimate(&m, &c, &s);
        let fsdp = Fsdp.estimate(&m, &c, &s);
        assert!(!dp.feasible);
        assert_eq!(dp.reason.as_deref(), Some("OOM"));
        assert!(fsdp.feasible);
    }

    #[test]
    fn dp_faster_than_fsdp_when_both_fit() {
        let m = model();
        let c = Cluster::rtx_titan(8, 64.0);
        let s = SearchConfig { max_batch: 4, ..Default::default() };
        let dp = Ddp.estimate(&m, &c, &s);
        let fsdp = Fsdp.estimate(&m, &c, &s);
        assert!(dp.feasible && fsdp.feasible);
        assert!(dp.throughput > fsdp.throughput);
    }

    #[test]
    fn osdp_splitting_helps_when_gather_is_the_wall() {
        // Wide-shallow-ish op: the ZDP gather transient dominates; only
        // splitting fits under the limit.
        let m = build_gpt(&GptDims::uniform("ws", 2000, 128, 2, 2048, 8));
        let zdp_gather = 2.0 * 2048.0 * 4.0 * 2048.0 * 4.0; // rough floor
        let c = Cluster {
            mem_limit: (m.state_bytes() / 8.0) * 1.05 + zdp_gather,
            ..Cluster::rtx_titan(8, 8.0)
        };
        let s = SearchConfig { max_batch: 4, granularities: vec![0, 4, 8],
                               ..Default::default() };
        let base = OsdpBase.estimate(&m, &c, &s);
        let full = Osdp.estimate(&m, &c, &s);
        assert!(full.feasible);
        assert!(full.throughput >= base.throughput,
                "splitting can't hurt: {} vs {}", full.throughput,
                base.throughput);
    }

    #[test]
    fn estimates_respect_limit() {
        let m = model();
        let c = Cluster::rtx_titan(8, 2.0);
        let s = SearchConfig { max_batch: 16, granularities: vec![0, 4],
                               ..Default::default() };
        for e in [Ddp.estimate(&m, &c, &s), Fsdp.estimate(&m, &c, &s),
                  OsdpBase.estimate(&m, &c, &s), Osdp.estimate(&m, &c, &s)] {
            if e.feasible {
                assert!(e.peak_mem <= 2.0 * GIB, "{}: {}", e.strategy,
                        e.peak_mem);
            }
        }
    }
}
