//! GPipe-style pipeline parallelism baseline.
//!
//! The model's layers are split into `N` contiguous stages balanced by
//! compute; a global batch of `m` unit microbatches flows through the
//! pipeline. Per-iteration time follows the GPipe bubble formula
//! `(m + N − 1) · t_stage` plus point-to-point boundary-activation
//! transfers; per-device memory is the stage's full model states (PP does
//! not shard within a stage) plus *all* in-flight microbatch activations
//! (GPipe's schedule without recomputation).
//!
//! The paper marks PP "N/A" when the model has fewer layers than devices
//! (W&S at 8 GPUs) — reproduced here.

use super::{Estimate, Strategy};
use crate::config::{Cluster, SearchConfig};
use crate::model::{ModelDesc, Operator};

pub struct Gpipe;

/// Assign each op to a stage: contiguous layer ranges balanced by flops;
/// embed joins the first stage, lnf/head the last.
pub fn assign_stages(model: &ModelDesc, n_stages: usize)
                     -> Option<Vec<Vec<usize>>> {
    if model.layers < n_stages {
        return None;
    }
    // balance layers by per-layer flops
    let mut layer_flops = vec![0.0f64; model.layers];
    for op in &model.ops {
        if let Some(l) = op.layer {
            layer_flops[l] += op.flops_per_sample;
        }
    }
    let total: f64 = layer_flops.iter().sum();
    let per_stage = total / n_stages as f64;
    let mut boundaries = Vec::with_capacity(n_stages + 1); // layer starts
    boundaries.push(0usize);
    let mut acc = 0.0;
    for (l, f) in layer_flops.iter().enumerate() {
        acc += f;
        if acc >= per_stage * boundaries.len() as f64
            && boundaries.len() < n_stages
            && l + 1 < model.layers
        {
            boundaries.push(l + 1);
        }
    }
    while boundaries.len() < n_stages {
        // degenerate balance: split remaining layers evenly
        let last = *boundaries.last().unwrap();
        boundaries.push(last + 1);
    }
    boundaries.push(model.layers);

    let stage_of_layer = |l: usize| -> usize {
        (0..n_stages)
            .find(|&s| l >= boundaries[s] && l < boundaries[s + 1])
            .unwrap()
    };
    let mut stages: Vec<Vec<usize>> = vec![Vec::new(); n_stages];
    for (i, op) in model.ops.iter().enumerate() {
        let s = match op.layer {
            Some(l) => stage_of_layer(l),
            None => {
                if op.name == "embed" {
                    0
                } else {
                    n_stages - 1
                }
            }
        };
        stages[s].push(i);
    }
    Some(stages)
}

/// Per-stage aggregates.
struct StageCost {
    states: f64,
    act_per_sample: f64,
    flops_per_sample: f64,
    /// Activation bytes crossing to the next stage, per sample.
    boundary_bytes: f64,
}

fn stage_costs(model: &ModelDesc, stages: &[Vec<usize>]) -> Vec<StageCost> {
    stages
        .iter()
        .map(|ops| {
            let sel: Vec<&Operator> =
                ops.iter().map(|&i| &model.ops[i]).collect();
            let states = sel.iter().map(|o| o.state_bytes()).sum();
            let act = sel.iter().map(|o| o.act_bytes_per_sample).sum();
            let flops = sel.iter().map(|o| o.flops_per_sample).sum();
            // boundary: hidden-state row per sequence position
            let h = sel
                .iter()
                .filter_map(|o| o.matmul_dims.map(|(_, out)| out))
                .last()
                .unwrap_or(model.hidden);
            let boundary =
                (model.seq * h.min(model.hidden)) as f64 * crate::model::F32;
            StageCost {
                states,
                act_per_sample: act,
                flops_per_sample: flops,
                boundary_bytes: boundary,
            }
        })
        .collect()
}

impl Strategy for Gpipe {
    fn name(&self) -> &'static str {
        "PP"
    }

    fn estimate(&self, model: &ModelDesc, cluster: &Cluster,
                search: &SearchConfig) -> Estimate {
        let n = cluster.n_devices;
        let stages = match assign_stages(model, n) {
            None => {
                return Estimate::infeasible(
                    "PP",
                    &format!("N/A (needs >= {n} layers, model has {})",
                             model.layers),
                );
            }
            Some(s) => s,
        };
        let costs = stage_costs(model, &stages);
        let (alpha, beta) = cluster.ring_link();
        let max_boundary = costs
            .iter()
            .take(n - 1)
            .map(|c| c.boundary_bytes)
            .fold(0.0f64, f64::max);

        let mut best: Option<Estimate> = None;
        // sweep microbatch size (GEMM efficiency vs bubble trade-off) and
        // microbatch count
        for mb in [1usize, 2, 4, 8] {
            let eff = crate::cost::time::batch_efficiency(mb);
            let max_stage_t = costs
                .iter()
                .map(|c| mb as f64 * c.flops_per_sample
                     / (cluster.flops * eff))
                .fold(0.0f64, f64::max);
            let bound_t = alpha + mb as f64 * max_boundary * beta;
            for m in 1..=search.max_batch {
                let mf = m as f64;
                let global = m * mb;
                // memory: worst stage = states + ALL in-flight microbatch
                // activations (GPipe stores every microbatch's)
                let peak = costs
                    .iter()
                    .map(|c| c.states + global as f64 * c.act_per_sample)
                    .fold(0.0f64, f64::max);
                if peak > cluster.mem_limit {
                    break;
                }
                let iter = (mf + n as f64 - 1.0)
                    * (max_stage_t + 2.0 * bound_t);
                let throughput = global as f64 / iter;
                if best.as_ref().map(|e| throughput > e.throughput)
                    .unwrap_or(true)
                {
                    best = Some(Estimate {
                        strategy: "PP".into(),
                        feasible: true,
                        reason: None,
                        global_batch: global,
                        iter_time: iter,
                        throughput,
                        peak_mem: peak,
                        detail: format!(
                            "{n} stages, {m} microbatches x {mb}"),
                    });
                }
            }
        }
        best.unwrap_or_else(|| Estimate::infeasible("PP", "OOM"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GptDims, build_gpt};

    #[test]
    fn na_when_fewer_layers_than_devices() {
        let m = build_gpt(&GptDims::uniform("ws", 2000, 128, 2, 512, 4));
        let c = Cluster::rtx_titan(8, 8.0);
        let e = Gpipe.estimate(&m, &c, &SearchConfig::default());
        assert!(!e.feasible);
        assert!(e.reason.unwrap().starts_with("N/A"));
    }

    #[test]
    fn stages_cover_all_ops_once() {
        let m = build_gpt(&GptDims::uniform("t", 2000, 64, 8, 128, 4));
        let stages = assign_stages(&m, 4).unwrap();
        let mut seen = vec![false; m.ops.len()];
        for st in &stages {
            for &i in st {
                assert!(!seen[i], "op {i} in two stages");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        // embed first, head last
        assert!(stages[0].contains(&0));
        assert!(stages[3].contains(&(m.ops.len() - 1)));
    }

    #[test]
    fn stage_layers_contiguous() {
        let m = build_gpt(&GptDims::uniform("t", 2000, 64, 9, 128, 4));
        let stages = assign_stages(&m, 3).unwrap();
        for st in &stages {
            let mut layers: Vec<usize> = st
                .iter()
                .filter_map(|&i| m.ops[i].layer)
                .collect();
            layers.dedup();
            for w in layers.windows(2) {
                assert!(w[1] == w[0] || w[1] == w[0] + 1, "gap in stage");
            }
        }
    }

    #[test]
    fn more_microbatches_amortize_bubble() {
        // throughput at the chosen point should beat m=1
        let m = build_gpt(&GptDims::uniform("t", 2000, 128, 8, 256, 4));
        let c = Cluster::rtx_titan(8, 64.0);
        let s = SearchConfig { max_batch: 64, ..Default::default() };
        let e = Gpipe.estimate(&m, &c, &s);
        assert!(e.feasible);
        assert!(e.global_batch > 1, "picked m={}", e.global_batch);
    }

    #[test]
    fn pipeline_shards_states_across_stages() {
        let m = build_gpt(&GptDims::uniform("t", 2000, 128, 8, 256, 4));
        let c = Cluster::rtx_titan(8, 64.0);
        let s = SearchConfig { max_batch: 1, ..Default::default() };
        let e = Gpipe.estimate(&m, &c, &s);
        // worst stage well under the whole model's states
        assert!(e.peak_mem < m.state_bytes() * 0.6);
    }
}
