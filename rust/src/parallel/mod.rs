//! Parallel-strategy baselines (the counterparts in Figures 5/6/8/9):
//! PyTorch-DDP-style data parallel, FairScale-FSDP/ZeRO, GPipe pipeline
//! parallel, Megatron tensor parallel, DeepSpeed-style 3D parallelism, and
//! OSDP itself (base = no splitting, full = with operator splitting), plus
//! 3D+OSDP (OSDP replacing the DP dimension).
//!
//! Every strategy produces an [`Estimate`] from the same (α, β, γ) cost
//! substrate, like the paper runs every baseline on the same server: each
//! sweeps its free parameters (batch size, microbatching, 3D degrees) and
//! reports its best feasible throughput under the memory limit.

pub mod dp;
pub mod pp;
pub mod threed;
pub mod tp;

pub use dp::{Ddp, Fsdp, Osdp, OsdpBase};
pub use pp::Gpipe;
pub use threed::{ThreeD, ThreeDOsdp};
pub use tp::MegatronTp;

use crate::config::{Cluster, SearchConfig};
use crate::model::ModelDesc;

/// A strategy's best operating point under the memory limit.
#[derive(Debug, Clone)]
pub struct Estimate {
    pub strategy: String,
    pub feasible: bool,
    /// "OOM" or "N/A (...)" when infeasible (the paper's figure annotations).
    pub reason: Option<String>,
    /// Global samples per iteration at the chosen operating point.
    pub global_batch: usize,
    pub iter_time: f64,
    /// Cluster-wide samples/second.
    pub throughput: f64,
    pub peak_mem: f64,
    /// Free-form detail (plan shape, chosen 3D degrees, …).
    pub detail: String,
}

impl Estimate {
    pub fn infeasible(strategy: &str, reason: &str) -> Estimate {
        Estimate {
            strategy: strategy.into(),
            feasible: false,
            reason: Some(reason.into()),
            global_batch: 0,
            iter_time: f64::INFINITY,
            throughput: 0.0,
            peak_mem: f64::INFINITY,
            detail: String::new(),
        }
    }
}

/// A parallel training strategy that can estimate its best throughput.
pub trait Strategy {
    fn name(&self) -> &'static str;

    /// Best feasible operating point for `model` on `cluster`.
    fn estimate(&self, model: &ModelDesc, cluster: &Cluster,
                search: &SearchConfig) -> Estimate;
}

/// All Figure-5 pure strategies in paper order.
pub fn pure_strategies() -> Vec<Box<dyn Strategy>> {
    vec![
        Box::new(Ddp),
        Box::new(Gpipe),
        Box::new(MegatronTp),
        Box::new(Fsdp),
        Box::new(OsdpBase),
        Box::new(Osdp),
    ]
}

/// The hybrid strategies (Figure 5/6 right-hand bars).
pub fn hybrid_strategies() -> Vec<Box<dyn Strategy>> {
    vec![Box::new(ThreeD), Box::new(ThreeDOsdp)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Cluster;
    use crate::model::{GptDims, build_gpt};

    /// Cross-strategy sanity on a mid-size model with generous memory:
    /// everything feasible, DP fastest or tied (no memory pressure).
    #[test]
    fn with_unlimited_memory_dp_wins_or_ties() {
        let m = build_gpt(&GptDims::uniform("t", 5000, 128, 8, 256, 4));
        let c = Cluster::rtx_titan(8, 1024.0); // 1 TiB: memory never binds
        let s = SearchConfig { max_batch: 32, granularities: vec![0],
                               ..Default::default() };
        let dp = Ddp.estimate(&m, &c, &s);
        assert!(dp.feasible);
        for strat in pure_strategies() {
            let e = strat.estimate(&m, &c, &s);
            assert!(e.feasible, "{} infeasible", strat.name());
            assert!(
                e.throughput <= dp.throughput * 1.001,
                "{} ({}) beat DP ({}) without memory pressure",
                strat.name(),
                e.throughput,
                dp.throughput
            );
        }
    }

    /// OSDP dominates both DP and FSDP by construction (its plan space
    /// contains both extremes).
    #[test]
    fn osdp_dominates_dp_and_fsdp() {
        let m = build_gpt(&GptDims::uniform("t", 5000, 128, 4, 384, 4));
        let c = Cluster::rtx_titan(8, 0.35); // tight-ish limit
        let s = SearchConfig { max_batch: 64, granularities: vec![0],
                               ..Default::default() };
        let dp = Ddp.estimate(&m, &c, &s);
        let fsdp = Fsdp.estimate(&m, &c, &s);
        let osdp = OsdpBase.estimate(&m, &c, &s);
        assert!(osdp.feasible);
        let floor = dp.throughput.max(fsdp.throughput);
        assert!(
            osdp.throughput >= floor * 0.999,
            "OSDP {} must dominate max(DP {}, FSDP {})",
            osdp.throughput,
            dp.throughput,
            fsdp.throughput
        );
    }
}
