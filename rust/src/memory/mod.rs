//! Per-device memory tracker: categorized allocation accounting with peak
//! tracking and OOM detection against the device limit (`M_limit`).
//!
//! Used by the discrete-event simulator (per-op residency) and the trainer
//! (real buffer accounting), and asserted against the analytic cost model
//! in integration tests — the two must agree for the planner's feasibility
//! decisions to mean anything.

use std::fmt;

/// Memory category, mirroring the paper's three factors plus the gather
/// transient.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    /// Model states: parameters, gradients, optimizer moments.
    States,
    /// Stored activations (scale with batch).
    Activations,
    /// Operator workspaces (attention scores etc.).
    Workspace,
    /// ZDP re-gather transients (unsharded params / full gradients).
    Gather,
}

pub const CATEGORIES: [Category; 4] = [
    Category::States,
    Category::Activations,
    Category::Workspace,
    Category::Gather,
];

impl Category {
    fn index(self) -> usize {
        match self {
            Category::States => 0,
            Category::Activations => 1,
            Category::Workspace => 2,
            Category::Gather => 3,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Category::States => "states",
            Category::Activations => "activations",
            Category::Workspace => "workspace",
            Category::Gather => "gather",
        }
    }
}

/// Out-of-memory failure.
#[derive(Debug, Clone, PartialEq)]
pub struct OomError {
    pub requested: f64,
    pub in_use: f64,
    pub limit: f64,
    pub category: Category,
}

impl fmt::Display for OomError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "OOM: requested {:.0} B of {} with {:.0}/{:.0} B in use",
            self.requested,
            self.category.label(),
            self.in_use,
            self.limit
        )
    }
}

impl std::error::Error for OomError {}

/// The tracker. All quantities in bytes (f64: sizes come from the analytic
/// model; exactness to the byte is not meaningful).
#[derive(Debug, Clone)]
pub struct MemoryTracker {
    limit: f64,
    current: [f64; 4],
    peak: f64,
    peak_by_cat: [f64; 4],
}

impl MemoryTracker {
    pub fn new(limit: f64) -> MemoryTracker {
        assert!(limit > 0.0);
        MemoryTracker {
            limit,
            current: [0.0; 4],
            peak: 0.0,
            peak_by_cat: [0.0; 4],
        }
    }

    pub fn limit(&self) -> f64 {
        self.limit
    }

    pub fn in_use(&self) -> f64 {
        self.current.iter().sum()
    }

    pub fn in_use_by(&self, cat: Category) -> f64 {
        self.current[cat.index()]
    }

    /// High-water mark of total usage.
    pub fn peak(&self) -> f64 {
        self.peak
    }

    pub fn peak_by(&self, cat: Category) -> f64 {
        self.peak_by_cat[cat.index()]
    }

    /// Allocate; fails (leaving state unchanged) if the limit would be
    /// exceeded.
    pub fn alloc(&mut self, cat: Category, bytes: f64) -> Result<(), OomError> {
        debug_assert!(bytes >= 0.0);
        if self.in_use() + bytes > self.limit {
            return Err(OomError {
                requested: bytes,
                in_use: self.in_use(),
                limit: self.limit,
                category: cat,
            });
        }
        self.current[cat.index()] += bytes;
        self.peak = self.peak.max(self.in_use());
        self.peak_by_cat[cat.index()] =
            self.peak_by_cat[cat.index()].max(self.current[cat.index()]);
        Ok(())
    }

    /// Free bytes from a category (clamped at zero with a debug assert).
    pub fn free(&mut self, cat: Category, bytes: f64) {
        let c = &mut self.current[cat.index()];
        debug_assert!(
            *c + 1e-6 >= bytes,
            "freeing {bytes} from {} with only {c}",
            cat.label()
        );
        *c = (*c - bytes).max(0.0);
    }

    /// Free everything in a category, returning how much was in use.
    pub fn drain(&mut self, cat: Category) -> f64 {
        std::mem::take(&mut self.current[cat.index()])
    }

    /// Render a one-line usage summary.
    pub fn describe(&self) -> String {
        use crate::util::fmt_bytes;
        format!(
            "peak {} / limit {} (states {}, act {}, ws {}, gather {})",
            fmt_bytes(self.peak),
            fmt_bytes(self.limit),
            fmt_bytes(self.peak_by_cat[0]),
            fmt_bytes(self.peak_by_cat[1]),
            fmt_bytes(self.peak_by_cat[2]),
            fmt_bytes(self.peak_by_cat[3]),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut t = MemoryTracker::new(1000.0);
        t.alloc(Category::States, 400.0).unwrap();
        t.alloc(Category::Activations, 300.0).unwrap();
        assert_eq!(t.in_use(), 700.0);
        t.free(Category::Activations, 300.0);
        assert_eq!(t.in_use(), 400.0);
        assert_eq!(t.peak(), 700.0);
    }

    #[test]
    fn oom_rejected_without_state_change() {
        let mut t = MemoryTracker::new(100.0);
        t.alloc(Category::States, 80.0).unwrap();
        let err = t.alloc(Category::Gather, 30.0).unwrap_err();
        assert_eq!(err.in_use, 80.0);
        assert_eq!(err.limit, 100.0);
        assert_eq!(t.in_use(), 80.0); // unchanged
        // still room for a smaller request
        t.alloc(Category::Gather, 20.0).unwrap();
    }

    #[test]
    fn peak_tracks_transients() {
        let mut t = MemoryTracker::new(1000.0);
        t.alloc(Category::States, 500.0).unwrap();
        for _ in 0..4 {
            t.alloc(Category::Gather, 200.0).unwrap();
            t.free(Category::Gather, 200.0);
        }
        assert_eq!(t.peak(), 700.0);
        assert_eq!(t.peak_by(Category::Gather), 200.0);
        assert_eq!(t.in_use(), 500.0);
    }

    #[test]
    fn drain_empties_category() {
        let mut t = MemoryTracker::new(1000.0);
        t.alloc(Category::Workspace, 123.0).unwrap();
        assert_eq!(t.drain(Category::Workspace), 123.0);
        assert_eq!(t.in_use_by(Category::Workspace), 0.0);
    }

    #[test]
    fn describe_mentions_peak() {
        let mut t = MemoryTracker::new(2048.0);
        t.alloc(Category::States, 1024.0).unwrap();
        let d = t.describe();
        assert!(d.contains("1.00 KiB"), "{d}");
        assert!(d.contains("2.00 KiB"), "{d}");
    }
}
