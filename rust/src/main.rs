//! OSDP command-line interface — the L3 leader entrypoint.
//!
//! ```text
//! osdp zoo                              Table 1 (model statistics)
//! osdp gantt                            Figure 1 (DP vs ZDP op gantt)
//! osdp plan --setting 48L/1024H ...     search an execution plan
//! osdp serve                            cached/coalescing plan service
//! osdp cache-serve --listen ADDR        shared second cache tier
//! osdp query --setting ... --batch 4    one-shot through the plan cache
//! osdp fig5|fig6|fig8|fig9 [--mem 8]    regenerate a figure
//! osdp fig7                             splitting sweep table
//! osdp search-time [--mem 8]            §3.2 search-cost table
//! osdp headline [--mem 8]               paper headline speedups
//! osdp train --model tiny --workers 4   real distributed training
//! osdp calibrate                        measure device FLOP/s via PJRT
//! ```

use osdp::cli::Args;
use osdp::config::{Cluster, SearchConfig};
use osdp::cost::Profiler;
use osdp::figures::{self, Quality};
use osdp::metrics::{speedup, speedup_vs_best};
use osdp::model::zoo;
use osdp::planner::{Engine, ParallelConfig, Scheduler, parallel};
use osdp::service::{Answer, CacheConfig, ClusterSpec, PlanError, PlanQuery,
                    PlanService, QueryResponse, QueryShape, server};
use osdp::train::{ShardMode, TrainConfig, train};

fn main() {
    let args = Args::from_env();
    let quality =
        if args.flag("full") { Quality::Full } else { Quality::Quick };
    match args.command.as_str() {
        "zoo" => print!("{}", figures::table1()),
        "gantt" => print!("{}", figures::fig1_gantt()),
        "fig5" => {
            let fig = figures::fig5(args.f64_or("mem", 8.0), quality);
            print!("{}", fig.render());
            maybe_csv(&args, &fig.to_csv());
        }
        "fig6" => {
            let fig = figures::fig6(args.f64_or("mem", 16.0), quality);
            print!("{}", fig.render());
            maybe_csv(&args, &fig.to_csv());
        }
        "fig6-scopes" => {
            let fig = figures::fig6_scopes(args.f64_or("mem", 16.0), quality);
            print!("{}", fig.render());
            if let Some(s) = speedup(&fig, "OSDP+scopes", "OSDP-global") {
                println!("hybrid scopes vs global-only planning: max \
                          {:.0}%, avg {:.0}%",
                         (s.max - 1.0) * 100.0, (s.avg - 1.0) * 100.0);
            }
            maybe_csv(&args, &fig.to_csv());
        }
        "fig7" => {
            let (t, _) = figures::fig7();
            println!("== Figure 7: operator splitting sweep (ZDP matmul, \
                      b=8, N=8) ==");
            print!("{}", t.render());
        }
        "fig8" => {
            let fig = figures::fig8(args.f64_or("mem", 8.0), quality);
            print!("{}", fig.render());
            if let Some(s) = speedup(&fig, "OSDP", "OSDP-base") {
                println!("splitting speedup: max {:.0}%, avg {:.0}% \
                          (paper: 3%-92%)",
                         (s.max - 1.0) * 100.0, (s.avg - 1.0) * 100.0);
            }
            maybe_csv(&args, &fig.to_csv());
        }
        "fig9" => {
            let fig = figures::fig9(args.f64_or("mem", 8.0), quality);
            print!("{}", fig.render());
            if let Some(s) = speedup(&fig, "OSDP", "FSDP") {
                println!("OSDP vs FSDP under checkpointing: max {:.1}%, \
                          avg {:.1}% (paper: max 108.3%, avg 52.9%)",
                         (s.max - 1.0) * 100.0, (s.avg - 1.0) * 100.0);
            }
            maybe_csv(&args, &fig.to_csv());
        }
        "search-time" => {
            let t = figures::search_times(args.f64_or("mem", 8.0), quality);
            println!("== Search-engine cost per zoo setting (paper: \
                      9-307 s) ==");
            print!("{}", t.render());
        }
        "headline" => headline(&args, quality),
        "plan" => plan(&args),
        "serve" => serve(&args),
        "cache-serve" => cache_serve(&args),
        "query" => service_query(&args),
        "replan" => service_replan(&args),
        "train" => run_train(&args),
        "calibrate" => calibrate(&args),
        "" | "help" | "--help" => usage(),
        other => {
            eprintln!("unknown command '{other}'\n");
            usage();
            std::process::exit(2);
        }
    }
}

fn usage() {
    let doc = "osdp — Optimal Sharded Data Parallel (IJCAI 2023 reproduction)

commands:
  zoo                                Table 1 model statistics
  gantt                              Figure 1 DP-vs-ZDP gantt chart
  plan    --setting 48L/1024H [--devices 8] [--mem 8] [--g 0,4]
          [--ckpt] [--batch-cap 64] [--fine]
          [--cluster C]      rtx_titan (default, --devices sets N) or
                             two_server_a100 (16 devices, 2x8 nodes)
          [--no-scopes]      restrict sharding to the paper's global scope
                             (multi-node menus otherwise also offer
                             node-local ZDP: states sharded per node,
                             gathers on the intra link — plan labels
                             carry an @node suffix)
          [--threads N]      sweep/search worker threads (default: all cores)
          [--split-depth D]  parallel tree-split depth (default 3)
          [--batch B]        search one batch size with the parallel
                             engine instead of sweeping
          [--engine E]       frontier (default): per-class composition
                             frontiers built once per sweep and merged
                             per batch; bb: folded branch-and-bound
                             ground truth (identical result)
          [--no-fold]        plan per operator instead of per equivalence
                             class (identical result, exponentially more
                             search nodes on symmetric models)
  serve   [--cache-dir D] [--cache-cap 256] [--listen ADDR]
          [--workers N] [--warmup 8] [--idle-timeout-ms 30000]
          [--queue-cap 64] [--metrics] [--metrics-listen ADDR]
          [--remote ADDR] [--remote-deadline-ms 5]
          line-oriented plan service: one request per line in ('query
          setting=48L/1024H mem=8 batch=4', 'sweep ...', 'replan ...
          new-devices=4', 'stats', 'metrics', 'trace [ID]', 'quit',
          'shutdown'), one JSON document per line out. Identical
          queries are answered from the plan cache, concurrent identical
          queries coalesce into one search, and cache misses warm-start
          from neighboring entries (provably bit-identical results).
          Default transport is stdin/stdout; --listen ADDR serves the
          same grammar over TCP with a bounded worker pool (--workers,
          0 = one per core), per-connection idle timeouts, and a
          graceful 'shutdown' verb that drains in-flight plans. The
          first stdout line is {"addr":...,"kind":"listening","ok":true}
          so drivers can resolve ':0' ephemeral ports. On a cost-model
          epoch bump the hottest --warmup entries of the stale disk
          cache are replanned (warm-started from their old choice
          vectors) before the listener accepts traffic. --metrics dumps
          counters + latency histograms as JSON on exit (also when the
          listener dies of consecutive accept errors).
          --metrics-listen ADDR binds a separate Prometheus scrape
          endpoint: any line (or HTTP GET) answers the text exposition
          — the same numbers the 'metrics' verb wraps in JSON. The
          'trace' verb lists the last 64 request traces; 'trace ID'
          returns one span tree + search convergence timeline.
          --remote ADDR wires a second cache tier (an osdp cache-serve
          instance) under the local cache: read-through on misses,
          write-behind on stores, every operation under a hard
          --remote-deadline-ms budget, consecutive failures tripping a
          circuit breaker to local-only mode. A dead, slow, or lying
          remote degrades service to local-only — it never changes an
          answer and never fails a query.
  cache-serve [--listen ADDR] [--cache-cap 4096] [--workers 2]
          [--idle-timeout-ms 30000] [--queue-cap 64]
          standalone shared cache tier speaking newline-delimited
          'get <request-line>', 'put <entry-json>', 'near <hex> <k>',
          'stats', 'quit', 'shutdown' — entries are the same versioned
          choice-vector format the disk cache persists, so any number
          of serve instances share plans through one tier
  query   --setting S (--batch B | [--batch-cap 64])
          [--mem 8] [--devices 8] [--cluster C] [--g 0,4] [--ckpt]
          [--fine] [--no-scopes] [--engine E] [--threads N] [--no-warm]
          [--cache-dir D] [--json] [--trace]
          [--remote ADDR] [--remote-deadline-ms 5]
          one-shot request through the same plan service (a --cache-dir
          makes the cache persistent across invocations); --trace
          prints the request's span tree and the search's incumbent
          timeline on stderr
  replan  --setting S (--batch B | [--batch-cap 64]) [query knobs...]
          (--new-devices M | --new-cluster C | --new-mem G |
           --sweep-clusters) [--cache-dir D] [--json]
          elastic re-plan: the cached plan for the old cluster is
          projected onto the changed hardware and warm-seeds a full
          search there (bit-identical to a cold search, fewer nodes).
          --sweep-clusters instead walks the rtx_titan device ladder
          (N, N/2, ..., 1) re-planning each rung from the last feasible
          one, and reports the smallest cluster the model still fits on
  fig5    [--mem 8] [--full] [--csv out.csv]
  fig6    [--mem 16] [--full] [--csv out.csv]
  fig6-scopes [--mem 16] [--full]    hybrid- vs global-scope planning on
                                     the two-server topology
  fig7
  fig8    [--mem 8] [--full]
  fig9    [--mem 8] [--full]
  search-time [--mem 8]
  headline [--mem 8] [--full]        paper headline speedup summary
  train   [--model tiny|e2e] [--workers 4] [--steps 20] [--mode dp|zdp]
          [--seed 7] [--artifacts DIR] [--log 5]
  calibrate [--artifacts DIR]        measure device FLOP/s";
    println!("{doc}");
}

fn maybe_csv(args: &Args, csv: &str) {
    if let Some(path) = args.get("csv") {
        std::fs::write(path, csv).expect("writing csv");
        eprintln!("wrote {path}");
    }
}

fn plan(args: &Args) {
    let setting = args.get_or("setting", "48L/1024H");
    let entry = zoo()
        .into_iter()
        .find(|e| e.setting == setting)
        .unwrap_or_else(|| {
            eprintln!("unknown setting '{setting}'; available:");
            for e in zoo() {
                eprintln!("  {} ({})", e.setting, e.family.label());
            }
            std::process::exit(2);
        });
    let cluster = match args.get_or("cluster", "rtx_titan") {
        "rtx_titan" => Cluster::rtx_titan(args.usize_or("devices", 8),
                                          args.f64_or("mem", 8.0)),
        "two_server_a100" => {
            // fixed 16-device / 2-node topology: reject a conflicting
            // --devices instead of silently planning for other hardware
            if args.usize_opt("devices").is_some() {
                eprintln!("--cluster two_server_a100 is a fixed 2x8 \
                           topology; drop --devices (or use --cluster \
                           rtx_titan)");
                std::process::exit(2);
            }
            Cluster::two_server_a100(args.f64_or("mem", 8.0))
        }
        other => {
            eprintln!("--cluster must be 'rtx_titan' or 'two_server_a100', \
                       got '{other}'");
            std::process::exit(2);
        }
    };
    if let Err(e) = cluster.validate() {
        eprintln!("invalid cluster: {e}");
        std::process::exit(2);
    }
    let search = SearchConfig {
        max_batch: args.usize_or("batch-cap", 64),
        granularities: args.usize_list_or("g", &[0, 4]),
        checkpointing: args.flag("ckpt"),
        paper_granularity: !args.flag("fine"),
        hybrid_scopes: !args.flag("no-scopes"),
    };
    println!(
        "model {} ({}): {:.2}B params, {} ops ({} fine)",
        entry.model.name,
        entry.family.label(),
        entry.model.param_count() / 1e9,
        entry.model.fuse_paper_granularity().n_ops(),
        entry.model.n_ops(),
    );
    let profiler = Profiler::new(&entry.model, &cluster, &search);
    let menus = profiler.menu_reduction();
    let threads = args
        .usize_opt("threads")
        .unwrap_or_else(parallel::default_threads);
    let split_depth =
        args.usize_or("split-depth", parallel::DEFAULT_SPLIT_DEPTH);
    // --no-fold (the historical escape hatch) means the per-operator
    // B&B, whatever --engine says; otherwise frontier is the default and
    // --engine bb selects the folded branch-and-bound ground truth.
    let engine = if args.flag("no-fold") {
        Engine::UnfoldedBb
    } else {
        match Engine::parse(args.get_or("engine", "frontier")) {
            Some(e) => e,
            None => {
                eprintln!("--engine must be 'frontier' or 'bb', got '{}'",
                          args.get_or("engine", ""));
                std::process::exit(2);
            }
        }
    };
    println!(
        "plan space: 10^{:.1} plans over {} ops ({} -> {} menu options \
         after dominance pruning); limit {}; {} threads; {} engine",
        profiler.log10_plan_space(),
        profiler.n_ops(),
        menus.raw,
        menus.kept,
        osdp::util::fmt_bytes(cluster.mem_limit),
        threads,
        engine.label(),
    );
    if cluster.crosses_nodes() {
        println!(
            "sharding scopes: {} ({} nodes x {} devices; node-local \
             gathers ride the intra link, global pays the inter-node \
             bottleneck)",
            if search.hybrid_scopes {
                "global + node-local"
            } else {
                "global only (--no-scopes)"
            },
            cluster.n_nodes(),
            cluster.devices_per_node,
        );
    }
    let fr = osdp::planner::fold_report(&profiler);
    println!(
        "symmetry fold{}: {}",
        if engine == Engine::UnfoldedBb {
            " (DISABLED via --no-fold)"
        } else {
            ""
        },
        fr.describe(),
    );
    // --batch B: one parallel search instead of a sweep
    if let Some(b) = args.usize_opt("batch") {
        let cfg = ParallelConfig { threads, split_depth, engine,
                                   ..Default::default() };
        let t0 = std::time::Instant::now();
        match osdp::planner::parallel_search(&profiler, cluster.mem_limit, b,
                                             &cfg)
        {
            None => println!("NO FEASIBLE PLAN at b={b}"),
            Some((choice, _cost, stats)) => {
                let plan = osdp::planner::ExecutionPlan::from_choice(
                    &profiler, choice, b);
                println!(
                    "parallel {} (split depth {split_depth}): {} nodes, \
                     {:.2}s{}",
                    engine.label(),
                    stats.nodes,
                    t0.elapsed().as_secs_f64(),
                    if stats.complete { "" } else { " [budget expired]" },
                );
                println!("best plan: {}", plan.describe(&profiler));
                println!("  memory: {}",
                         figures::explain_plan(&profiler, &plan.choice, b));
                println!("  throughput {:.1} samples/s across {} devices",
                         plan.throughput(cluster.n_devices),
                         cluster.n_devices);
            }
        }
        return;
    }

    let t0 = std::time::Instant::now();
    match Scheduler::new(&profiler, cluster.mem_limit, search.max_batch)
        .with_threads(threads)
        .with_engine(engine)
        .run()
    {
        Err(inf) => println!(
            "NO FEASIBLE PLAN (even all-ZDP at b=1 exceeds the limit){}",
            if inf.complete() { "" } else { " [node budget expired]" }
        ),
        Ok(res) => {
            let c = &res.candidates[res.best];
            println!(
                "sweep on {} threads: {}, {:.2}s",
                threads,
                res.stats.describe(),
                t0.elapsed().as_secs_f64()
            );
            // the sweep's one-time frontier build, reported from the
            // result so the CLI never builds the frontiers twice
            if let Some(f) = &res.frontier {
                println!("composition frontiers: {}", f.describe());
            }
            println!("best plan: {}", c.plan.describe(&profiler));
            println!("  memory: {}",
                     figures::explain_plan(&profiler, &c.plan.choice,
                                           c.plan.batch));
            println!(
                "  throughput {:.1} samples/s across {} devices",
                c.throughput, cluster.n_devices
            );
            for cand in &res.candidates {
                println!(
                    "    b={:<3} -> {:>8.1} samples/s (peak {})",
                    cand.plan.batch,
                    cand.throughput,
                    osdp::util::fmt_bytes(cand.plan.cost.peak_mem)
                );
            }
        }
    }
}

fn cache_config(args: &Args) -> CacheConfig {
    CacheConfig {
        capacity: args.usize_or("cache-cap", 256),
        disk_dir: args.get("cache-dir").map(std::path::PathBuf::from),
    }
}

fn plan_query_from_args(args: &Args) -> PlanQuery {
    let mut q = PlanQuery::batch(args.get_or("setting", "48L/1024H"),
                                 args.f64_or("mem", 8.0), 1);
    q.cluster.preset = args.get_or("cluster", "rtx_titan").to_string();
    q.cluster.devices = args.usize_opt("devices");
    q.search.granularities = args.usize_list_or("g", &[0, 4]);
    q.search.checkpointing = args.flag("ckpt");
    q.search.paper_granularity = !args.flag("fine");
    q.search.hybrid_scopes = !args.flag("no-scopes");
    q.threads = args.usize_opt("threads").unwrap_or(0);
    q.warm = !args.flag("no-warm");
    q.engine = match Engine::parse(args.get_or("engine", "frontier")) {
        Some(e) => e,
        None => {
            eprintln!("--engine must be 'frontier' or 'bb', got '{}'",
                      args.get_or("engine", ""));
            std::process::exit(2);
        }
    };
    q.shape = match args.usize_opt("batch") {
        Some(b) => QueryShape::Batch(b),
        None => QueryShape::Sweep { max_batch: args.usize_or("batch-cap",
                                                            64) },
    };
    q
}

fn serve(args: &Args) {
    use osdp::service::{Frontend, FrontendConfig, MetricsHandler,
                        TeardownHook, Telemetry, render_metrics};
    use std::io::Write as _;
    use std::sync::Arc;

    let (mut service, stale) = PlanService::open(cache_config(args));
    attach_remote_from_args(args, &mut service);
    let service = Arc::new(service);
    let telemetry = Arc::new(Telemetry::new());

    // Epoch-bump warm-up, strictly before any traffic: when the disk
    // cache was rejected for a cost-model epoch change, replay its
    // hottest K queries (seeded with their old choice vectors) so the
    // first real callers hit a warm cache, not a cold one.
    let warmup_k = args.usize_or("warmup", 8);
    if !stale.is_empty() && warmup_k > 0 {
        let report = service.warm_up(&stale, warmup_k, Some(&telemetry));
        eprintln!(
            "osdp serve: epoch warm-up replanned {}/{} stale entries\
             {}",
            report.replanned,
            report.candidates,
            if report.failed > 0 {
                format!(" ({} failed)", report.failed)
            } else {
                String::new()
            }
        );
    }

    // --metrics-listen: a separate scrape endpoint with its own tiny
    // pool and its own (throwaway) wire telemetry — scrapes must not
    // perturb the counters they report. Started before the main
    // listener so the page is available the moment traffic is.
    let metrics_frontend = match args.get("metrics-listen") {
        None => None,
        Some(maddr) => {
            let handler = Arc::new(MetricsHandler {
                service: Arc::clone(&service),
                telemetry: Arc::clone(&telemetry),
            });
            let mcfg = FrontendConfig {
                addr: maddr.to_string(),
                workers: 1,
                idle_timeout: std::time::Duration::from_millis(5_000),
                queue_cap: 16,
            };
            match Frontend::start_with(handler, Arc::new(Telemetry::new()),
                                       mcfg)
            {
                Ok(f) => Some(f),
                Err(e) => {
                    eprintln!("serve: cannot bind metrics {maddr}: {e}");
                    std::process::exit(1);
                }
            }
        }
    };

    if let Some(addr) = args.get("listen") {
        let cfg = FrontendConfig {
            addr: addr.to_string(),
            workers: args.usize_or("workers", 0),
            idle_timeout: std::time::Duration::from_millis(
                args.usize_or("idle-timeout-ms", 30_000) as u64,
            ),
            queue_cap: args.usize_or("queue-cap", 64),
        };
        // a listener dying of consecutive accept errors still dumps its
        // final counters (--metrics) instead of vanishing silently
        let teardown: Option<TeardownHook> = if args.flag("metrics") {
            let service = Arc::clone(&service);
            let telemetry = Arc::clone(&telemetry);
            Some(Box::new(move || {
                eprintln!("osdp serve: listener giving up after \
                           consecutive accept errors");
                eprintln!("{}", render_metrics(&service.stats(),
                                               service.cache_len(),
                                               &telemetry,
                                               service.breaker_state()));
            }))
        } else {
            None
        };
        let frontend = match Frontend::start_hooked(Arc::clone(&service),
                                                    Arc::clone(&telemetry),
                                                    cfg, teardown)
        {
            Ok(f) => f,
            Err(e) => {
                eprintln!("serve: cannot bind {addr}: {e}");
                std::process::exit(1);
            }
        };
        // first stdout line announces the bound address so drivers can
        // resolve a ':0' ephemeral port without racing the log output
        println!(
            "{{\"addr\":\"{}\",\"kind\":\"listening\",\"ok\":true}}",
            frontend.local_addr()
        );
        // the scrape endpoint's address rides on a second stdout line
        // (drivers that don't scrape just ignore it)
        if let Some(mf) = &metrics_frontend {
            println!(
                "{{\"addr\":\"{}\",\"kind\":\"metrics-listening\",\
                 \"ok\":true}}",
                mf.local_addr()
            );
        }
        let _ = std::io::stdout().flush();
        // blocks until a client sends 'shutdown' (graceful drain)
        frontend.join();
    } else {
        if let Some(mf) = &metrics_frontend {
            // stdout is the response stream here; announce on stderr
            eprintln!("osdp serve: metrics on {}", mf.local_addr());
        }
        eprintln!("osdp serve: ready (one request per line; 'query \
                   setting=48L/1024H mem=8 batch=4', 'sweep ...', \
                   'replan ... new-devices=4', 'stats', 'quit', \
                   'shutdown')");
        let stdin = std::io::stdin();
        let mut stdout = std::io::stdout();
        if let Err(e) = server::serve_loop_with(&service, Some(&telemetry),
                                                stdin.lock(), &mut stdout)
        {
            eprintln!("serve: io error: {e}");
            std::process::exit(1);
        }
    }
    if let Some(mf) = metrics_frontend {
        mf.shutdown();
        mf.join();
    }
    eprintln!("osdp serve: done — {}", service.stats().describe());
    if args.flag("metrics") {
        eprintln!("{}", render_metrics(&service.stats(),
                                       service.cache_len(), &telemetry,
                                       service.breaker_state()));
    }
}

/// Standalone second cache tier: the cache-store protocol handler behind
/// the same TCP front-end (bounded pool, framing, fault injection,
/// graceful shutdown) the plan service uses.
fn cache_serve(args: &Args) {
    use osdp::service::{CacheServerHandler, Frontend, FrontendConfig,
                        Telemetry};
    use std::io::Write as _;
    use std::sync::Arc;

    let addr = args.get_or("listen", "127.0.0.1:0").to_string();
    let handler =
        Arc::new(CacheServerHandler::new(args.usize_or("cache-cap", 4096)));
    let telemetry = Arc::new(Telemetry::new());
    let cfg = FrontendConfig {
        addr,
        workers: args.usize_or("workers", 2),
        idle_timeout: std::time::Duration::from_millis(
            args.usize_or("idle-timeout-ms", 30_000) as u64,
        ),
        queue_cap: args.usize_or("queue-cap", 64),
    };
    let frontend = match Frontend::start_with(handler,
                                              Arc::clone(&telemetry), cfg) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("cache-serve: cannot bind: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "{{\"addr\":\"{}\",\"kind\":\"listening\",\"ok\":true}}",
        frontend.local_addr()
    );
    let _ = std::io::stdout().flush();
    frontend.join();
    eprintln!("osdp cache-serve: done");
}

/// Wire `--remote ADDR` (and `--remote-deadline-ms`) under a service:
/// read-through / write-behind L2 with a deadline budget and a circuit
/// breaker. No remote flag means no tier — zero overhead.
fn attach_remote_from_args(args: &Args, service: &mut PlanService) {
    use osdp::service::{RemoteConfig, RemoteTier};
    if let Some(addr) = args.get("remote") {
        let mut cfg = RemoteConfig::new(addr);
        cfg.deadline = std::time::Duration::from_millis(
            args.usize_or("remote-deadline-ms", 5).max(1) as u64,
        );
        service.attach_remote(RemoteTier::start(cfg));
    }
}

fn service_query(args: &Args) {
    let q = plan_query_from_args(args);
    let mut service = PlanService::new(cache_config(args));
    attach_remote_from_args(args, &mut service);
    let outcome = service.query(&q);
    // --trace: the request-scoped span tree and convergence timeline,
    // on stderr so --json stdout stays a single parseable line
    if args.flag("trace") {
        if let Some(t) = service.tracer().last() {
            eprintln!("{}", t.render_text());
        } else {
            eprintln!("(tracing compiled out — no trace recorded)");
        }
    }
    report_query_outcome(args, &service, outcome);
}

/// The changed cluster for `osdp replan`: the query's own cluster with
/// the `--new-*` overrides applied. A preset change drops the old
/// device count (it may not apply to the new topology); restate it via
/// `--new-devices`.
fn new_cluster_from_args(args: &Args, q: &PlanQuery) -> ClusterSpec {
    let new_devices = args.usize_opt("new-devices");
    let new_preset = args.get("new-cluster").map(str::to_string);
    let new_mem = args.get("new-mem").map(|v| {
        v.parse::<f64>().unwrap_or_else(|_| {
            eprintln!("--new-mem: bad number '{v}'");
            std::process::exit(2);
        })
    });
    if new_devices.is_none() && new_preset.is_none() && new_mem.is_none()
        && !args.flag("sweep-clusters")
    {
        eprintln!("replan needs at least one of --new-devices / \
                   --new-cluster / --new-mem / --sweep-clusters");
        std::process::exit(2);
    }
    ClusterSpec {
        preset: new_preset
            .clone()
            .unwrap_or_else(|| q.cluster.preset.clone()),
        devices: match (new_devices, &new_preset) {
            (Some(d), _) => Some(d),
            (None, Some(_)) => None,
            (None, None) => q.cluster.devices,
        },
        mem_gib: new_mem.unwrap_or(q.cluster.mem_gib),
    }
}

fn service_replan(args: &Args) {
    let q = plan_query_from_args(args);
    let new_cluster = new_cluster_from_args(args, &q);
    let mut service = PlanService::new(cache_config(args));
    attach_remote_from_args(args, &mut service);
    if args.flag("sweep-clusters") {
        let rungs = service.replan_sweep_clusters(&q, &new_cluster, None);
        if args.flag("json") {
            println!("{}", server::render_capacity(&rungs));
            if rungs.is_err() {
                std::process::exit(1);
            }
            return;
        }
        match rungs {
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
            Ok(rungs) => {
                println!("capacity sweep ({} rungs):", rungs.len());
                for r in &rungs {
                    match &r.outcome {
                        Ok(resp) => {
                            let plan = match &resp.answer {
                                Answer::Plan { plan, .. } => plan,
                                Answer::Sweep { plans, best, .. } => {
                                    &plans[*best]
                                }
                            };
                            println!(
                                "  N={:<4} b={:<3} -> {:>8.1} samples/s \
                                 (peak {}, {})",
                                r.devices,
                                plan.batch,
                                plan.throughput(resp.n_devices),
                                osdp::util::fmt_bytes(plan.cost.peak_mem),
                                resp.source.label(),
                            );
                        }
                        Err(e) => println!("  N={:<4} -> {}", r.devices,
                                           e.kind()),
                    }
                }
                match rungs
                    .iter()
                    .filter(|r| r.outcome.is_ok())
                    .map(|r| r.devices)
                    .min()
                {
                    Some(min) => {
                        println!("fits down to {min} devices");
                    }
                    None => {
                        println!("no probed cluster fits this model");
                        std::process::exit(1);
                    }
                }
            }
        }
        return;
    }
    let outcome = service.replan(&q, &new_cluster);
    report_query_outcome(args, &service, outcome);
}

fn report_query_outcome(args: &Args, service: &PlanService,
                        outcome: Result<QueryResponse, PlanError>) {
    if args.flag("json") {
        println!("{}", server::render_response(&outcome));
        if outcome.is_err() {
            std::process::exit(1);
        }
        return;
    }
    match outcome {
        Err(e @ PlanError::Infeasible { .. }) => {
            eprintln!("{e}");
            std::process::exit(1);
        }
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
        Ok(resp) => {
            println!("source: {} (key {})", resp.source.label(),
                     resp.key.id());
            let print_plan = |p: &osdp::planner::ExecutionPlan| {
                println!(
                    "  b={:<3} time={} peak={} -> {:>8.1} samples/s \
                     across {} devices",
                    p.batch,
                    osdp::util::fmt_time(p.cost.time),
                    osdp::util::fmt_bytes(p.cost.peak_mem),
                    p.throughput(resp.n_devices),
                    resp.n_devices,
                );
            };
            match &resp.answer {
                Answer::Plan { plan, stats } => {
                    println!("plan ({} nodes{}):", stats.nodes,
                             if stats.complete { "" }
                             else { ", budget expired" });
                    print_plan(plan);
                }
                Answer::Sweep { plans, best, stats } => {
                    println!("sweep winner ({}):", stats.describe());
                    print_plan(&plans[*best]);
                    println!("candidates:");
                    for p in plans {
                        print_plan(p);
                    }
                }
            }
            println!("service: {}", service.stats().describe());
        }
    }
}

fn headline(args: &Args, quality: Quality) {
    let mem = args.f64_or("mem", 8.0);
    println!("running Figure 5 ({mem:.0}G) ...");
    let f5 = figures::fig5(mem, quality);
    print!("{}", f5.render());
    let pct = |x: f64| (x - 1.0) * 100.0;
    if let Some(s) = speedup(&f5, "OSDP", "FSDP") {
        println!("OSDP vs FSDP: max {:.0}%, avg {:.0}% (paper N&D: max 23%, \
                  avg 22%)", pct(s.max), pct(s.avg));
    }
    if let Some(s) = speedup_vs_best(&f5, "OSDP",
                                     &["OSDP-base", "3D", "3D+OSDP"]) {
        println!("OSDP vs best pure baseline: max {:.0}%, avg {:.0}% \
                  (paper: up to 174% on N&D)", pct(s.max), pct(s.avg));
    }
    if let Some(s) = speedup(&f5, "3D+OSDP", "3D") {
        println!("3D+OSDP vs 3D: max {:.0}%, avg {:.0}% (paper: max 73%, \
                  avg 31%)", pct(s.max), pct(s.avg));
    }
    if let Some(s) = speedup_vs_best(&f5, "3D+OSDP", &[]) {
        println!("3D+OSDP vs all others: max {:.0}%, avg {:.0}% (paper: \
                  max 184%, avg 38%; headline 2.84x)", pct(s.max), pct(s.avg));
    }
}

fn run_train(args: &Args) {
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(osdp::runtime::default_artifact_dir);
    if !artifacts.join("manifest.json").exists() {
        eprintln!("no artifacts at {artifacts:?}; run `make artifacts`");
        std::process::exit(1);
    }
    let mode = match args.get_or("mode", "zdp") {
        "dp" => ShardMode::Dp,
        "zdp" => ShardMode::Zdp,
        other => {
            eprintln!("--mode must be dp or zdp, got '{other}'");
            std::process::exit(2);
        }
    };
    let workers = args.usize_or("workers", 4);
    let cluster = Cluster::rtx_titan(workers, args.f64_or("mem", 8.0));
    let cfg = TrainConfig {
        model: args.get_or("model", "tiny").to_string(),
        n_workers: workers,
        steps: args.usize_or("steps", 20),
        mode,
        seed: args.usize_or("seed", 7) as i32,
        topology: osdp::fabric::Topology::from_cluster(&cluster),
        mem_limit: cluster.mem_limit,
        log_every: args.usize_or("log", 5),
        device_flops: cluster.flops,
        reshard_after_forward: !args.flag("no-reshard"),
    };
    println!(
        "training {} on {} workers ({:?}), {} steps ...",
        cfg.model, cfg.n_workers, cfg.mode, cfg.steps
    );
    match train(artifacts, cfg) {
        Err(e) => {
            eprintln!("training failed: {e:?}");
            std::process::exit(1);
        }
        Ok(rep) => {
            println!(
                "loss {:.4} -> {:.4} over {} steps",
                rep.first_loss(),
                rep.last_loss(),
                rep.steps.len()
            );
            println!(
                "wall {:.1}s | simulated {:.3}s | {} sent/worker | peak {}",
                rep.wall_seconds,
                rep.sim_seconds,
                osdp::util::fmt_bytes(rep.bytes_sent_per_worker as f64),
                osdp::util::fmt_bytes(rep.peak_mem),
            );
        }
    }
}

fn calibrate(args: &Args) {
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(osdp::runtime::default_artifact_dir);
    let mut rt = match osdp::runtime::Runtime::open(&artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("open runtime: {e:?} (run `make artifacts`)");
            std::process::exit(1);
        }
    };
    let x = vec![1.0f32; 512 * 512];
    let xt = || osdp::runtime::HostTensor::f32m(&x, 512, 512);
    // warmup (compiles)
    rt.execute("calib_matmul.hlo.txt", &[xt(), xt()]).unwrap();
    let iters = 20;
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        rt.execute("calib_matmul.hlo.txt", &[xt(), xt()]).unwrap();
    }
    let secs = t0.elapsed().as_secs_f64() / iters as f64;
    let flops = 2.0 * 512f64.powi(3) / secs;
    println!("matmul 512^3: {:.3} ms -> {:.2} GFLOP/s", secs * 1e3,
             flops / 1e9);
    println!("suggested config: [cluster] flops = {:.3e}", flops);
}
