//! Minimal benchmarking harness (criterion substitute — the offline build
//! has no external crates). Warmup + timed iterations + outlier-robust
//! summary, plus a text reporter the `benches/*.rs` binaries share.

use crate::util::stats::Summary;
use std::time::Instant;

/// One benchmark's measured samples (seconds per iteration).
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub summary: Summary,
    pub iters_per_sample: usize,
}

impl Measurement {
    pub fn per_iter(&self) -> f64 {
        self.summary.mean / self.iters_per_sample as f64
    }
}

/// Bench runner with fixed warmup/sample counts (deterministic wall-clock
/// budget, unlike criterion's adaptive sampling).
pub struct Bencher {
    pub warmup_iters: usize,
    pub samples: usize,
    pub iters_per_sample: usize,
    results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 3,
            samples: 10,
            iters_per_sample: 1,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new(warmup_iters: usize, samples: usize,
               iters_per_sample: usize) -> Bencher {
        Bencher {
            warmup_iters,
            samples,
            iters_per_sample,
            results: Vec::new(),
        }
    }

    /// Time `f`; the closure's return value is black-boxed to keep the
    /// optimizer honest.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T)
                    -> &Measurement {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..self.iters_per_sample {
                black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64());
        }
        self.results.push(Measurement {
            name: name.to_string(),
            summary: Summary::of(&samples),
            iters_per_sample: self.iters_per_sample,
        });
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// criterion-style report lines.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for m in &self.results {
            out.push_str(&format!(
                "{:<44} {:>12}/iter  (p50 {:>12}, rsd {:>5.1}%)\n",
                m.name,
                crate::util::fmt_time(m.per_iter()),
                crate::util::fmt_time(
                    m.summary.p50 / m.iters_per_sample as f64
                ),
                m.summary.rsd() * 100.0,
            ));
        }
        out
    }
}

/// Prevent the optimizer from eliding a value (std::hint::black_box
/// wrapper, kept here so benches don't depend on unstable features).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let mut b = Bencher::new(1, 5, 10);
        let m = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(m.per_iter() > 0.0);
        assert_eq!(m.iters_per_sample, 10);
    }

    #[test]
    fn report_contains_names() {
        let mut b = Bencher::default();
        b.bench("alpha", || 1 + 1);
        b.bench("beta", || 2 + 2);
        let r = b.report();
        assert!(r.contains("alpha") && r.contains("beta"));
        assert_eq!(r.lines().count(), 2);
    }
}
