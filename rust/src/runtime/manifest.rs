//! Typed view over `artifacts/manifest.json` (written by aot.py): model
//! configs, packed-parameter layouts, artifact inventories.

use crate::util::json::Json;
use anyhow::{Context, Result, anyhow};
use std::path::Path;

/// One leaf in the packed parameter vector.
#[derive(Debug, Clone, PartialEq)]
pub struct LayoutEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
    pub size: usize,
}

/// Adam hyperparameters baked into the artifacts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdamParams {
    pub lr: f64,
    pub b1: f64,
    pub b2: f64,
    pub eps: f64,
}

/// One lowered model configuration.
#[derive(Debug, Clone)]
pub struct ConfigEntry {
    pub name: String,
    pub vocab: usize,
    pub seq: usize,
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    pub param_count: usize,
    /// Packed vector length (padded to `pad`).
    pub packed_len: usize,
    pub pad: usize,
    pub batch_per_worker: usize,
    pub shard_degrees: Vec<usize>,
    pub adam: AdamParams,
    pub layout: Vec<LayoutEntry>,
}

impl ConfigEntry {
    pub fn shard_len(&self, n: usize) -> usize {
        assert!(self.packed_len % n == 0,
                "packed_len {} not divisible by {n}", self.packed_len);
        self.packed_len / n
    }

    pub fn artifact(&self, role: &str) -> String {
        format!("{}_{role}.hlo.txt", self.name)
    }

    pub fn adam_artifact(&self, shard_degree: usize) -> String {
        format!("{}_adam_p{shard_degree}.hlo.txt", self.name)
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub configs: Vec<ConfigEntry>,
    pub files: Vec<String>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut configs = Vec::new();
        let cfgs = root
            .get("configs")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest missing 'configs'"))?;
        for (name, c) in cfgs {
            let u = |k: &str| -> Result<usize> {
                c.get(k)
                    .as_usize()
                    .ok_or_else(|| anyhow!("config {name}: bad '{k}'"))
            };
            let layout = c
                .get("layout")
                .as_arr()
                .ok_or_else(|| anyhow!("config {name}: bad layout"))?
                .iter()
                .map(|e| -> Result<LayoutEntry> {
                    Ok(LayoutEntry {
                        name: e
                            .get("name")
                            .as_str()
                            .ok_or_else(|| anyhow!("layout name"))?
                            .to_string(),
                        offset: e
                            .get("offset")
                            .as_usize()
                            .ok_or_else(|| anyhow!("layout offset"))?,
                        shape: e
                            .get("shape")
                            .as_arr()
                            .ok_or_else(|| anyhow!("layout shape"))?
                            .iter()
                            .filter_map(Json::as_usize)
                            .collect(),
                        size: e
                            .get("size")
                            .as_usize()
                            .ok_or_else(|| anyhow!("layout size"))?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let adam = AdamParams {
                lr: c.get("adam").get("lr").as_f64().unwrap_or(3e-4),
                b1: c.get("adam").get("b1").as_f64().unwrap_or(0.9),
                b2: c.get("adam").get("b2").as_f64().unwrap_or(0.999),
                eps: c.get("adam").get("eps").as_f64().unwrap_or(1e-8),
            };
            configs.push(ConfigEntry {
                name: name.clone(),
                vocab: u("vocab")?,
                seq: u("seq")?,
                layers: u("layers")?,
                hidden: u("hidden")?,
                heads: u("heads")?,
                param_count: u("param_count")?,
                packed_len: u("packed_len")?,
                pad: u("pad")?,
                batch_per_worker: u("batch_per_worker")?,
                shard_degrees: c
                    .get("shard_degrees")
                    .as_arr()
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_else(|| vec![1, 2, 4, 8]),
                adam,
                layout,
            });
        }
        let files = root
            .get("files")
            .as_obj()
            .map(|o| o.keys().cloned().collect())
            .unwrap_or_default();
        Ok(Manifest { configs, files })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigEntry> {
        self.configs
            .iter()
            .find(|c| c.name == name)
            .ok_or_else(|| anyhow!("config '{name}' not in manifest \
                (have: {:?})", self.configs.iter().map(|c| &c.name)
                .collect::<Vec<_>>()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "configs": {
        "tiny": {
          "vocab": 512, "seq": 64, "layers": 2, "hidden": 64, "heads": 2,
          "slice_granularity": 4, "param_count": 136960,
          "packed_len": 136960, "pad": 8, "batch_per_worker": 4,
          "shard_degrees": [1, 2, 4, 8],
          "adam": {"lr": 3e-4, "b1": 0.9, "b2": 0.999, "eps": 1e-8},
          "layout": [
            {"name": "wte", "offset": 0, "shape": [512, 64], "size": 32768},
            {"name": "wpe", "offset": 32768, "shape": [64, 64], "size": 4096}
          ]
        }
      },
      "files": {"tiny_init.hlo.txt": {"bytes": 10}}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let c = m.config("tiny").unwrap();
        assert_eq!(c.packed_len, 136960);
        assert_eq!(c.shard_len(4), 34240);
        assert_eq!(c.layout[1].name, "wpe");
        assert_eq!(c.layout[1].offset, 32768);
        assert_eq!(c.adam.lr, 3e-4);
        assert_eq!(c.artifact("grad_step"), "tiny_grad_step.hlo.txt");
        assert_eq!(c.adam_artifact(4), "tiny_adam_p4.hlo.txt");
        assert_eq!(m.files, vec!["tiny_init.hlo.txt"]);
    }

    #[test]
    fn missing_config_is_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.config("nope").is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        let dir = crate::runtime::default_artifact_dir();
        let path = dir.join("manifest.json");
        if !path.exists() {
            eprintln!("SKIP: no artifacts");
            return;
        }
        let m = Manifest::load(&path).unwrap();
        let tiny = m.config("tiny").unwrap();
        // layout covers param_count exactly
        let total: usize = tiny.layout.iter().map(|l| l.size).sum();
        assert_eq!(total, tiny.param_count);
        assert!(tiny.packed_len >= total);
        assert_eq!(tiny.packed_len % tiny.pad, 0);
    }
}
