//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) produced by `python/compile/aot.py` and executes them
//! on the CPU PJRT client. This is the only place python-authored compute
//! enters the rust system — and python itself is never on this path.
//!
//! Interchange is HLO *text* (see aot.py's module docs for the 64-bit-id
//! proto incompatibility this sidesteps).
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so a [`Runtime`] is per-thread:
//! each trainer worker opens its own client and compiles its own
//! executables; the [`Manifest`] is plain data and freely shared.

pub mod manifest;

pub use manifest::{ConfigEntry, LayoutEntry, Manifest};

use anyhow::{Context, Result, anyhow};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Per-thread PJRT runtime: client + executable cache over one artifact
/// directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.json`, creates the CPU
    /// PJRT client).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?}"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: HashMap::new() })
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Load + compile an artifact by file name (cached).
    pub fn load(&mut self, file: &str) -> Result<()> {
        if self.cache.contains_key(file) {
            return Ok(());
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {file}: {e:?}"))?;
        self.cache.insert(file.to_string(), exe);
        Ok(())
    }

    /// Execute a loaded artifact. All our AOT modules are lowered with
    /// `return_tuple=True`, so the outputs come back as one tuple literal,
    /// decomposed here.
    ///
    /// Inputs are uploaded through `buffer_from_host_buffer` and executed
    /// with `execute_b` so the device input buffers are owned (and freed)
    /// on the rust side — the crate's literal-taking `execute` leaks every
    /// input buffer per call (xla_rs.cc `buffer.release()` without a
    /// matching free), which OOM-killed long training runs.
    pub fn execute(&mut self, file: &str, inputs: &[HostTensor])
                   -> Result<Vec<xla::Literal>> {
        self.load(file)?;
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| t.upload(&self.client))
            .collect::<Result<_>>()?;
        let exe = self.cache.get(file).unwrap();
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&bufs)
            .map_err(|e| anyhow!("executing {file}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {file}: {e:?}"))?;
        decompose(tuple)
    }

    /// Number of executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}

/// Split a (possibly 1-ary) tuple literal into its elements.
pub fn decompose(mut lit: xla::Literal) -> Result<Vec<xla::Literal>> {
    match lit.decompose_tuple() {
        Ok(parts) if !parts.is_empty() => Ok(parts),
        Ok(_) => Ok(vec![]),
        Err(_) => Ok(vec![lit]), // not a tuple: single output
    }
}

// ---------------------------------------------------------------------------
// Host tensors (borrowed input data + shape) and literal helpers
// ---------------------------------------------------------------------------

/// Borrowed host data + shape, uploaded per execute call.
pub enum HostTensor<'a> {
    F32(&'a [f32], Vec<usize>),
    I32(&'a [i32], Vec<usize>),
}

impl<'a> HostTensor<'a> {
    /// 1-D f32 vector.
    pub fn f32v(v: &'a [f32]) -> HostTensor<'a> {
        HostTensor::F32(v, vec![v.len()])
    }

    /// i32 scalar (rank 0).
    pub fn i32s(v: &'a [i32; 1]) -> HostTensor<'a> {
        HostTensor::I32(v, vec![])
    }

    /// 2-D i32 matrix.
    pub fn i32m(v: &'a [i32], rows: usize, cols: usize) -> HostTensor<'a> {
        assert_eq!(v.len(), rows * cols);
        HostTensor::I32(v, vec![rows, cols])
    }

    /// 2-D f32 matrix.
    pub fn f32m(v: &'a [f32], rows: usize, cols: usize) -> HostTensor<'a> {
        assert_eq!(v.len(), rows * cols);
        HostTensor::F32(v, vec![rows, cols])
    }

    fn upload(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        match self {
            HostTensor::F32(data, dims) => client
                .buffer_from_host_buffer::<f32>(data, dims, None)
                .map_err(|e| anyhow!("upload f32 {dims:?}: {e:?}")),
            HostTensor::I32(data, dims) => client
                .buffer_from_host_buffer::<i32>(data, dims, None)
                .map_err(|e| anyhow!("upload i32 {dims:?}: {e:?}")),
        }
    }
}

/// f32 scalar from a literal (accepts rank-0 or single-element).
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow!("literal to f32 vec: {e:?}"))?;
    v.first().copied().ok_or_else(|| anyhow!("empty literal"))
}

/// Vec<f32> from a literal.
pub fn vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to f32 vec: {e:?}"))
}


/// Locate the repo's artifact directory for tests/examples: env var
/// `OSDP_ARTIFACTS`, else `<crate>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("OSDP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

/// True when artifacts exist (tests skip politely otherwise; `make
/// artifacts` builds them).
pub fn artifacts_available() -> bool {
    default_artifact_dir().join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        if !artifacts_available() {
            eprintln!("SKIP: run `make artifacts` first");
            return None;
        }
        Some(Runtime::open(default_artifact_dir()).unwrap())
    }

    #[test]
    fn calib_matmul_numerics() {
        let Some(mut rt) = runtime() else { return };
        // x = I (512), w = ramp: result must equal w
        let mut x = vec![0.0f32; 512 * 512];
        for i in 0..512 {
            x[i * 512 + i] = 1.0;
        }
        let w: Vec<f32> = (0..512 * 512).map(|i| (i % 97) as f32).collect();
        let out = rt
            .execute("calib_matmul.hlo.txt", &[
                HostTensor::f32m(&x, 512, 512),
                HostTensor::f32m(&w, 512, 512),
            ])
            .unwrap();
        let y = vec_f32(&out[0]).unwrap();
        assert_eq!(y.len(), 512 * 512);
        for (a, b) in y.iter().zip(&w) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn split_demo_matches_direct_matmul_at_all_granularities() {
        // The Pallas operator-splitting kernel, AOT-compiled, loaded and
        // run from rust: same numbers at every granularity.
        let Some(mut rt) = runtime() else { return };
        let x: Vec<f32> =
            (0..256 * 1024).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let w: Vec<f32> =
            (0..1024 * 1024).map(|i| ((i % 7) as f32 - 3.0) * 0.05).collect();
        let mut results: Vec<Vec<f32>> = Vec::new();
        for g in [1usize, 2, 4, 8] {
            let out = rt
                .execute(&format!("split_demo_g{g}.hlo.txt"),
                         &[HostTensor::f32m(&x, 256, 1024),
                           HostTensor::f32m(&w, 1024, 1024)])
                .unwrap();
            results.push(vec_f32(&out[0]).unwrap());
        }
        for r in &results[1..] {
            for (a, b) in r.iter().zip(&results[0]) {
                assert!((a - b).abs() < 1e-2, "{a} vs {b}");
            }
        }
        // spot-check vs direct f64 matmul on one row
        for col in [0usize, 511, 1023] {
            let want: f64 = (0..1024)
                .map(|k| x[k] as f64 * w[k * 1024 + col] as f64)
                .sum();
            let got = results[0][col] as f64;
            assert!((got - want).abs() < 0.05, "col {col}: {got} vs {want}");
        }
    }

    #[test]
    fn executable_cache_reuses() {
        let Some(mut rt) = runtime() else { return };
        rt.load("calib_matmul.hlo.txt").unwrap();
        rt.load("calib_matmul.hlo.txt").unwrap();
        assert_eq!(rt.cached(), 1);
    }
}
