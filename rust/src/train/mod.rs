//! End-to-end distributed trainer: real GPT training over the simulated
//! device fabric.
//!
//! Each worker thread owns a PJRT runtime (compiling the AOT artifacts),
//! its parameter/optimizer state (full replicas under DP, `1/N` shards
//! under ZDP — exactly FSDP's layout), and an endpoint on the fabric.
//! Every training step moves *real bytes* through the ring collectives:
//!
//! * **DP** step: local `grad_step` → ring all-reduce of gradients →
//!   full-vector Adam.
//! * **ZDP** step: ring all-gather of parameter shards → local `grad_step`
//!   → ring reduce-scatter of gradients → per-shard Adam (ZeRO's
//!   partitioned optimizer).
//!
//! Both must produce bit-identical-ish loss trajectories (same global
//! batch, averaging is associative up to f32 rounding) — asserted in
//! `rust/tests/train_e2e.rs`. The fabric's logical clocks yield the
//! simulated iteration time alongside the wall time.
//!
//! Per-operator mode granularity (the planner's output) drives the
//! *simulated* timeline and memory accounting; the physical data path
//! shards at whole-vector granularity because the AOT train step is one
//! HLO module (DESIGN.md §4 records this substitution).

pub mod data;

pub use data::Corpus;

use crate::collectives::{all_gather, all_reduce, reduce_scatter};
use crate::fabric::{self, Topology};
use crate::memory::{Category, MemoryTracker};
use crate::runtime::{HostTensor, Runtime, scalar_f32, vec_f32};
use anyhow::{Context, Result, anyhow};
use std::path::PathBuf;
use std::sync::Arc;

/// How parameters and optimizer state are laid out across workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardMode {
    /// Full replica per worker (vanilla DP).
    Dp,
    /// 1/N shard per worker (ZDP / FSDP / ZeRO-3).
    Zdp,
}

/// Training run settings.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Manifest config name ("tiny", "e2e", "gpt100m").
    pub model: String,
    pub n_workers: usize,
    pub steps: usize,
    pub mode: ShardMode,
    pub seed: i32,
    /// Simulated link/topology (defaults to the RTX-TITAN preset).
    pub topology: Topology,
    /// Device memory limit for the per-worker tracker (bytes).
    pub mem_limit: f64,
    /// Log every k steps (0 = silent).
    pub log_every: usize,
    /// Simulated device FLOP/s for the logical clock's compute charges
    /// (the (α,β,γ) model's γ; defaults to the RTX-TITAN preset). Wall
    /// time is recorded separately.
    pub device_flops: f64,
    /// ZeRO-3/FSDP semantics: parameters are freed after forward and
    /// re-gathered for backward, so ZDP pays the paper's full 3-round
    /// pattern (2 gathers + 1 reduce-scatter = 1.5× DP bytes). Our AOT
    /// train step is one HLO module, so the re-gather is performed
    /// back-to-back before execution — same bytes, same (α,β) time, the
    /// memory transient is unchanged. `false` = ZeRO-2-ish gather-once.
    pub reshard_after_forward: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        let c = crate::config::Cluster::rtx_titan(4, 8.0);
        TrainConfig {
            model: "tiny".into(),
            n_workers: 4,
            steps: 20,
            mode: ShardMode::Zdp,
            seed: 0,
            topology: Topology::from_cluster(&c),
            mem_limit: c.mem_limit,
            log_every: 0,
            device_flops: c.flops,
            reshard_after_forward: true,
        }
    }
}

/// One step's record.
#[derive(Debug, Clone)]
pub struct StepLog {
    pub step: usize,
    /// Global-batch mean loss.
    pub loss: f64,
    /// Wall-clock seconds of this step on the slowest worker.
    pub wall: f64,
}

/// Whole-run report.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: Vec<StepLog>,
    /// Simulated fabric seconds (per the (α,β) link model).
    pub sim_seconds: f64,
    /// Payload bytes each worker pushed through the fabric.
    pub bytes_sent_per_worker: u64,
    /// Peak tracked memory per worker (bytes; states+gather only — real
    /// activations live inside XLA).
    pub peak_mem: f64,
    pub wall_seconds: f64,
}

impl TrainReport {
    pub fn first_loss(&self) -> f64 {
        self.steps.first().map(|s| s.loss).unwrap_or(f64::NAN)
    }

    pub fn last_loss(&self) -> f64 {
        self.steps.last().map(|s| s.loss).unwrap_or(f64::NAN)
    }

    /// Samples/second by simulated time.
    pub fn sim_throughput(&self, global_batch: usize) -> f64 {
        (self.steps.len() * global_batch) as f64 / self.sim_seconds.max(1e-30)
    }
}

/// Run a training job on the fabric. Blocks until done.
pub fn train(artifact_dir: PathBuf, cfg: TrainConfig) -> Result<TrainReport> {
    let n = cfg.n_workers;
    anyhow::ensure!(n >= 1, "need at least one worker");
    let cfg = Arc::new(cfg);
    let dir = Arc::new(artifact_dir);
    let t0 = std::time::Instant::now();

    let cfg2 = cfg.clone();
    let results = fabric::run_timed(n, cfg.topology.clone(), move |ep| {
        worker(ep, &dir, &cfg2)
    });

    let wall = t0.elapsed().as_secs_f64();
    let mut per_worker = Vec::new();
    let mut sim_seconds = 0.0f64;
    for (res, clock) in results {
        per_worker.push(res.map_err(|e| anyhow!("worker failed: {e:?}"))?);
        sim_seconds = sim_seconds.max(clock);
    }

    // loss logs are identical across workers (all-reduced); take rank 0
    let w0 = &per_worker[0];
    let steps = w0.steps.clone();
    Ok(TrainReport {
        steps,
        sim_seconds,
        bytes_sent_per_worker: w0.bytes_sent,
        peak_mem: per_worker
            .iter()
            .map(|w| w.peak_mem)
            .fold(0.0, f64::max),
        wall_seconds: wall,
    })
}

struct WorkerOut {
    steps: Vec<StepLog>,
    bytes_sent: u64,
    peak_mem: f64,
}

fn worker(ep: &mut fabric::Endpoint, dir: &PathBuf, cfg: &TrainConfig)
          -> Result<WorkerOut> {
    let n = ep.n;
    let rank = ep.rank;
    let mut rt = Runtime::open(dir.as_path())
        .context("opening artifact runtime")?;
    let mc = rt.manifest.config(&cfg.model)?.clone();
    anyhow::ensure!(
        mc.shard_degrees.contains(&n),
        "no adam artifact for {n} workers (have {:?})",
        mc.shard_degrees
    );
    let p_len = mc.packed_len;
    let shard_len = mc.shard_len(n);
    let (shard_off, shard_deg, adam_file) = match cfg.mode {
        ShardMode::Dp => (0usize, 1usize, mc.adam_artifact(1)),
        ShardMode::Zdp => (rank * shard_len, n, mc.adam_artifact(n)),
    };
    let my_len = p_len / shard_deg;

    let mut mem = MemoryTracker::new(cfg.mem_limit);

    // ---- init: every worker evaluates the same seeded init artifact, so
    // replicas agree without a broadcast (ZDP keeps only its slice).
    let init_out = rt
        .execute(&mc.artifact("init"), &[HostTensor::i32s(&[cfg.seed])])
        .context("init artifact")?;
    let full_init = vec_f32(&init_out[0])?;
    anyhow::ensure!(full_init.len() == p_len, "init length mismatch");
    let mut params: Vec<f32> =
        full_init[shard_off..shard_off + my_len].to_vec();
    let mut m_state = vec![0.0f32; my_len];
    let mut v_state = vec![0.0f32; my_len];
    drop(full_init);
    // states: params + grads + m + v at fp32
    mem.alloc(Category::States, (my_len * 4 * 4) as f64)
        .map_err(|e| anyhow!("{e}"))?;

    let corpus = Corpus::new(cfg.seed as u64, mc.vocab);
    let b = mc.batch_per_worker;
    // analytic compute seconds per step on the *simulated* device:
    // ≈ 6 FLOPs per parameter per token (fwd+bwd), at the configured rate
    let sim_compute = 6.0 * mc.param_count as f64
        * (b * mc.seq) as f64
        / cfg.device_flops
        / crate::cost::time::batch_efficiency(b);
    let grad_file = mc.artifact("grad_step");
    let mut steps = Vec::with_capacity(cfg.steps);

    for step in 1..=cfg.steps {
        let t_step = std::time::Instant::now();
        // -- assemble full parameters
        let full: Vec<f32> = match cfg.mode {
            ShardMode::Dp => params.clone(),
            ShardMode::Zdp => {
                mem.alloc(Category::Gather, (p_len * 4) as f64)
                    .map_err(|e| anyhow!("{e}"))?;
                if cfg.reshard_after_forward {
                    // ZeRO-3's backward re-gather (see TrainConfig docs):
                    // physically move the bytes so traffic and simulated
                    // time match FSDP's 2-gather pattern
                    drop(all_gather(ep, &params, p_len));
                }
                all_gather(ep, &params, p_len)
            }
        };

        // -- local microbatch + grad step (real XLA execution)
        let tokens =
            corpus.batch(step as u64, rank as u64, b, mc.seq + 1);
        let out = rt
            .execute(&grad_file, &[
                HostTensor::f32v(&full),
                HostTensor::i32m(&tokens, b, mc.seq + 1),
            ])
            .context("grad_step")?;
        let local_loss = scalar_f32(&out[0])? as f64;
        let grads = vec_f32(&out[1])?;
        if cfg.mode == ShardMode::Zdp {
            mem.free(Category::Gather, (p_len * 4) as f64);
        }
        // charge the simulated compute time for this worker's microbatch
        ep.compute(sim_compute);

        // -- gradient sync (real bytes through the ring)
        let inv_n = 1.0 / n as f32;
        let my_grads: Vec<f32> = match cfg.mode {
            ShardMode::Dp => {
                let summed = all_reduce(ep, &grads);
                summed.iter().map(|g| g * inv_n).collect()
            }
            ShardMode::Zdp => {
                let shard = reduce_scatter(ep, &grads);
                shard.iter().map(|g| g * inv_n).collect()
            }
        };
        drop(grads);

        // -- optimizer on our slice (ZeRO partitioned update)
        let step_i = [step as i32];
        let upd = rt
            .execute(&adam_file, &[
                HostTensor::f32v(&params),
                HostTensor::f32v(&my_grads),
                HostTensor::f32v(&m_state),
                HostTensor::f32v(&v_state),
                HostTensor::i32s(&step_i),
            ])
            .context("adam")?;
        params = vec_f32(&upd[0])?;
        m_state = vec_f32(&upd[1])?;
        v_state = vec_f32(&upd[2])?;

        // -- global mean loss for the log (tiny collective)
        let mean_loss =
            all_reduce(ep, &[local_loss as f32])[0] as f64 / n as f64;
        let wall = t_step.elapsed().as_secs_f64();
        if cfg.log_every > 0 && step % cfg.log_every == 0 && rank == 0 {
            eprintln!(
                "step {step:>4}  loss {mean_loss:.4}  wall {:.2}s  sim {:.4}s",
                wall,
                ep.now()
            );
        }
        steps.push(StepLog { step, loss: mean_loss, wall });
    }

    Ok(WorkerOut {
        steps,
        bytes_sent: ep.bytes_sent,
        peak_mem: mem.peak(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifact_dir};

    fn base_cfg(mode: ShardMode, workers: usize, steps: usize) -> TrainConfig {
        TrainConfig {
            model: "tiny".into(),
            n_workers: workers,
            steps,
            mode,
            seed: 7,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn tiny_zdp_loss_decreases() {
        if !artifacts_available() {
            eprintln!("SKIP: run `make artifacts`");
            return;
        }
        let rep = train(default_artifact_dir(),
                        base_cfg(ShardMode::Zdp, 2, 12)).unwrap();
        assert_eq!(rep.steps.len(), 12);
        assert!(rep.last_loss() < rep.first_loss(),
                "loss {} -> {}", rep.first_loss(), rep.last_loss());
        assert!(rep.bytes_sent_per_worker > 0);
        assert!(rep.sim_seconds > 0.0);
    }

    #[test]
    fn dp_and_zdp_trajectories_match() {
        // The central numerical claim: mode changes *where* states live,
        // not the math. Same seed + same global batch => same losses.
        if !artifacts_available() {
            eprintln!("SKIP: run `make artifacts`");
            return;
        }
        let dp = train(default_artifact_dir(),
                       base_cfg(ShardMode::Dp, 2, 6)).unwrap();
        let zdp = train(default_artifact_dir(),
                        base_cfg(ShardMode::Zdp, 2, 6)).unwrap();
        for (a, b) in dp.steps.iter().zip(&zdp.steps) {
            assert!(
                (a.loss - b.loss).abs() < 5e-4,
                "step {}: DP {} vs ZDP {}",
                a.step,
                a.loss,
                b.loss
            );
        }
        // ZDP moves more bytes (gathers) than DP's single all-reduce round
        assert!(zdp.bytes_sent_per_worker > dp.bytes_sent_per_worker / 2);
    }

    #[test]
    fn zdp_memory_smaller_than_dp() {
        if !artifacts_available() {
            eprintln!("SKIP: run `make artifacts`");
            return;
        }
        let dp = train(default_artifact_dir(),
                       base_cfg(ShardMode::Dp, 4, 2)).unwrap();
        let zdp = train(default_artifact_dir(),
                        base_cfg(ShardMode::Zdp, 4, 2)).unwrap();
        // states shrink 4x; the gather transient adds back ~P fp32
        assert!(zdp.peak_mem < dp.peak_mem,
                "zdp {} dp {}", zdp.peak_mem, dp.peak_mem);
    }
}
