//! Synthetic tiny-corpus generator: a deterministic token stream with
//! strong learnable structure (a noisy affine bigram process), so the
//! trainer's loss curve has real signal to descend on.
//!
//! Every batch is a pure function of (seed, step, rank) — reruns and
//! DP-vs-ZDP comparisons see identical data.

use crate::util::rng::Rng;

/// A virtual corpus over a vocabulary.
#[derive(Debug, Clone)]
pub struct Corpus {
    seed: u64,
    vocab: usize,
    /// Affine bigram parameters (derived from the seed).
    mult: u64,
    add: u64,
}

impl Corpus {
    pub fn new(seed: u64, vocab: usize) -> Corpus {
        assert!(vocab >= 4);
        let mut r = Rng::new(seed ^ 0xC0FFEE);
        // odd multiplier keeps the map bijective on power-of-two vocabs and
        // non-degenerate elsewhere
        let mult = 2 * r.below(vocab as u64 / 2).max(1) + 1;
        let add = r.below(vocab as u64);
        Corpus { seed, vocab, mult, add }
    }

    /// Next token under the noiseless bigram rule.
    pub fn successor(&self, t: u32) -> u32 {
        ((t as u64 * self.mult + self.add) % self.vocab as u64) as u32
    }

    /// One `(rows × cols)` token batch (row-major), 10% uniform noise.
    pub fn batch(&self, step: u64, rank: u64, rows: usize, cols: usize)
                 -> Vec<i32> {
        let mut r = Rng::new(
            self.seed
                .wrapping_mul(0x9e37_79b9_7f4a_7c15)
                .wrapping_add(step << 20)
                .wrapping_add(rank),
        );
        let mut out = Vec::with_capacity(rows * cols);
        for _ in 0..rows {
            let mut t = r.below(self.vocab as u64) as u32;
            out.push(t as i32);
            for _ in 1..cols {
                t = if r.chance(0.1) {
                    r.below(self.vocab as u64) as u32
                } else {
                    self.successor(t)
                };
                out.push(t as i32);
            }
        }
        out
    }

    /// Theoretical floor of the next-token cross-entropy under the 10%
    /// noise model: `0.9·ln(1/0.9)`-ish mixture (useful to eyeball
    /// convergence; exact value depends on vocab size).
    pub fn loss_floor(&self) -> f64 {
        let p_correct: f64 = 0.9 + 0.1 / self.vocab as f64;
        let p_other = 0.1 / self.vocab as f64;
        -(p_correct * p_correct.ln()
            + (self.vocab as f64 - 1.0) * p_other * p_other.ln())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_batches() {
        let c = Corpus::new(7, 512);
        assert_eq!(c.batch(3, 1, 4, 65), c.batch(3, 1, 4, 65));
        assert_ne!(c.batch(3, 1, 4, 65), c.batch(4, 1, 4, 65));
        assert_ne!(c.batch(3, 1, 4, 65), c.batch(3, 2, 4, 65));
    }

    #[test]
    fn tokens_in_vocab() {
        let c = Corpus::new(1, 100);
        for t in c.batch(0, 0, 8, 33) {
            assert!((0..100).contains(&t));
        }
    }

    #[test]
    fn mostly_bigram_structured() {
        let c = Corpus::new(42, 512);
        let rows = 16;
        let cols = 65;
        let batch = c.batch(0, 0, rows, cols);
        let mut follows = 0;
        let mut total = 0;
        for r in 0..rows {
            for i in 0..cols - 1 {
                let a = batch[r * cols + i] as u32;
                let b = batch[r * cols + i + 1] as u32;
                total += 1;
                if c.successor(a) == b {
                    follows += 1;
                }
            }
        }
        let frac = follows as f64 / total as f64;
        assert!(frac > 0.8 && frac < 0.98, "structure fraction {frac}");
    }

    #[test]
    fn loss_floor_sane() {
        let c = Corpus::new(0, 512);
        let f = c.loss_floor();
        assert!(f > 0.0 && f < 1.5, "floor {f}");
    }
}
