//! Figure/table regeneration: one function per table and figure in the
//! paper's evaluation section, shared by the CLI (`osdp fig5`, …) and the
//! bench harnesses (`benches/fig*_*.rs`).
//!
//! | Paper artifact | Function |
//! |---|---|
//! | Table 1 (model zoo stats)             | [`table1`]   |
//! | Figure 1 (DP vs ZDP op gantt)         | [`fig1_gantt`] |
//! | Figure 5 (end-to-end, 8 devices)      | [`fig5`]     |
//! | Figure 6 (end-to-end, 2×8 devices)    | [`fig6`]     |
//! | Figure 7 (splitting: mem & time vs g) | [`fig7`]     |
//! | Figure 8 (OSDP ± splitting)           | [`fig8`]     |
//! | Figure 9 (OSDP vs FSDP + checkpointing) | [`fig9`]   |
//! | §3.2 search-time claim (9–307 s)      | [`search_times`] |

use crate::config::{Cluster, SearchConfig};
use crate::cost::{Decision, Profiler, op_memory, op_comm_time, op_compute_time};
use crate::metrics::FigureData;
use crate::model::{GptDims, ModelDesc, build_gpt, zoo};
use crate::parallel::{Osdp, Strategy, hybrid_strategies, pure_strategies};
use crate::planner::Scheduler;
use crate::sim;
use crate::util::table::Table;

/// Effort preset: `Quick` for interactive CLI runs, `Full` for benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Quality {
    Quick,
    Full,
}

impl Quality {
    fn search(&self) -> SearchConfig {
        match self {
            Quality::Quick => SearchConfig {
                max_batch: 32,
                granularities: vec![0, 4],
                checkpointing: false,
                paper_granularity: true,
                ..Default::default()
            },
            Quality::Full => SearchConfig {
                max_batch: 64,
                granularities: vec![0, 2, 4, 8],
                checkpointing: false,
                paper_granularity: true,
                ..Default::default()
            },
        }
    }
}

/// Table 1: the model zoo statistics.
pub fn table1() -> String {
    let mut t = Table::new(vec![
        "Model", "Setting", "Layer Num", "Operator Num", "Hidden Size",
        "Param. Num",
    ]);
    for e in zoo() {
        let fused = e.model.fuse_paper_granularity();
        t.row(vec![
            e.family.label().to_string(),
            e.setting.clone(),
            e.model.layers.to_string(),
            fused.n_ops().to_string(),
            e.model.hidden.to_string(),
            format!("{:.2}B", e.model.param_count() / 1e9),
        ]);
    }
    format!("== Table 1: Statistics of Models ==\n{}", t.render())
}

/// Figure 1: the gantt chart of one operator processed in DP vs ZDP mode.
pub fn fig1_gantt() -> String {
    let m = single_matmul_model(1024, 1024);
    let c = Cluster::rtx_titan(8, 8.0);
    let dp = sim::simulate(&m, &vec![Decision::DP; m.ops.len()], &c, 4,
                           false, false);
    let zdp = sim::simulate(&m, &vec![Decision::ZDP; m.ops.len()], &c, 4,
                            false, false);
    format!(
        "== Figure 1: one operator, DP vs ZDP ==\n-- DP mode --\n{}\n-- ZDP mode --\n{}",
        sim::render_gantt(&dp, 64),
        sim::render_gantt(&zdp, 64)
    )
}

/// A one-matmul model (used by Figures 1 and 7).
fn single_matmul_model(hidden: usize, seq: usize) -> ModelDesc {
    let mut m = build_gpt(&GptDims::uniform("op", 64, seq, 1, hidden, 8));
    // keep only the mlp_up matmul (h -> 4h, the paper's huge-op shape)
    m.ops.retain(|o| o.name == "l0.mlp_up");
    m.name = format!("matmul-{hidden}x{}", 4 * hidden);
    m
}

/// End-to-end strategy comparison over the zoo on one cluster.
fn end_to_end(title: &str, cluster: &Cluster, search: &SearchConfig,
              include_hybrid: bool) -> FigureData {
    let mut fig = FigureData::new(title);
    for entry in zoo() {
        let mut strats = pure_strategies();
        if include_hybrid {
            strats.extend(hybrid_strategies());
        }
        for s in strats {
            let est = s.estimate(&entry.model, cluster, search);
            fig.push(entry.family.label(), &entry.setting, est);
        }
    }
    fig
}

/// Figure 5: 8 devices (RTX-TITAN-like), memory limit in GiB.
pub fn fig5(mem_gib: f64, q: Quality) -> FigureData {
    let cluster = Cluster::rtx_titan(8, mem_gib);
    end_to_end(
        &format!("Figure 5: end-to-end, 8 devices, {mem_gib:.0}G limit"),
        &cluster,
        &q.search(),
        true,
    )
}

/// Figure 6: 16 devices across two servers (A100-like, 100 Gb/s).
///
/// Pinned to the paper's `{DP, ZDP-over-N}` search space
/// (`hybrid_scopes: false`) so the reproduction stays comparable to the
/// published figure — node-local sharding would otherwise lift the OSDP
/// rows far above anything the paper's formulation can express. The
/// scope dimension's effect on this topology is its own figure,
/// [`fig6_scopes`].
pub fn fig6(mem_gib: f64, q: Quality) -> FigureData {
    let cluster = Cluster::two_server_a100(mem_gib);
    let search = SearchConfig { hybrid_scopes: false, ..q.search() };
    end_to_end(
        &format!("Figure 6: end-to-end, 16 devices / 2 servers, \
                  {mem_gib:.0}G limit"),
        &cluster,
        &search,
        true,
    )
}

/// Scope ablation on the Figure-6 topology: OSDP planning over hybrid
/// sharding scopes (global + node-local, the default) vs the same planner
/// restricted to the paper's global-only space, with FSDP as the common
/// baseline. The gap between the two OSDP rows is what the per-operator
/// scope dimension buys on a bandwidth-asymmetric cluster.
pub fn fig6_scopes(mem_gib: f64, q: Quality) -> FigureData {
    let cluster = Cluster::two_server_a100(mem_gib);
    let mut fig = FigureData::new(&format!(
        "Figure 6b: hybrid sharding scopes, 16 devices / 2 servers, \
         {mem_gib:.0}G limit"
    ));
    let scoped = q.search(); // hybrid_scopes defaults on
    let global = SearchConfig { hybrid_scopes: false, ..scoped.clone() };
    for entry in zoo() {
        let mut hybrid = Osdp.estimate(&entry.model, &cluster, &scoped);
        hybrid.strategy = "OSDP+scopes".into();
        fig.push(entry.family.label(), &entry.setting, hybrid);
        let mut flat = Osdp.estimate(&entry.model, &cluster, &global);
        flat.strategy = "OSDP-global".into();
        fig.push(entry.family.label(), &entry.setting, flat);
        let fsdp = crate::parallel::Fsdp.estimate(&entry.model, &cluster,
                                                  &scoped);
        fig.push(entry.family.label(), &entry.setting, fsdp);
    }
    fig
}

/// Figure 7 rows: (hidden, granularity, peak memory MiB, time ms) for a
/// single ZDP matmul (batch 8, 8 devices).
pub fn fig7() -> (Table, Vec<(usize, usize, f64, f64)>) {
    let c = Cluster::rtx_titan(8, 24.0);
    let b = 8;
    let mut rows = Vec::new();
    let mut t = Table::new(vec![
        "hidden", "granularity", "peak mem (MiB)", "time (ms)",
    ]);
    for hidden in [768usize, 1024, 8192, 12288] {
        let m = single_matmul_model(hidden, 1024);
        let op = &m.ops[0];
        for g in [0usize, 2, 4, 8, 16] {
            let d = Decision::zdp_at(g);
            let mem = op_memory(op, d, b, &c, false);
            let peak = mem.total();
            let time = op_comm_time(op, d, &c, false)
                + op_compute_time(op, d, &c, b, false);
            rows.push((hidden, g, peak / (1024.0 * 1024.0), time * 1e3));
            t.row(vec![
                hidden.to_string(),
                g.to_string(),
                format!("{:.1}", peak / (1024.0 * 1024.0)),
                format!("{:.2}", time * 1e3),
            ]);
        }
    }
    (t, rows)
}

/// Figure 8: OSDP with vs without operator splitting across the zoo.
pub fn fig8(mem_gib: f64, q: Quality) -> FigureData {
    let cluster = Cluster::rtx_titan(8, mem_gib);
    let mut fig = FigureData::new(&format!(
        "Figure 8: OSDP ± operator splitting, 8 devices, {mem_gib:.0}G"
    ));
    let search = q.search();
    for entry in zoo() {
        for s in [&crate::parallel::OsdpBase as &dyn Strategy,
                  &crate::parallel::Osdp] {
            let est = s.estimate(&entry.model, &cluster, &search);
            fig.push(entry.family.label(), &entry.setting, est);
        }
    }
    fig
}

/// Figure 9: OSDP vs FSDP with checkpointing enabled.
pub fn fig9(mem_gib: f64, q: Quality) -> FigureData {
    let cluster = Cluster::rtx_titan(8, mem_gib);
    let mut fig = FigureData::new(&format!(
        "Figure 9: OSDP vs FSDP with checkpointing, 8 devices, {mem_gib:.0}G"
    ));
    let search = SearchConfig { checkpointing: true, ..q.search() };
    for entry in zoo() {
        for s in [&crate::parallel::Fsdp as &dyn Strategy,
                  &crate::parallel::Osdp] {
            let est = s.estimate(&entry.model, &cluster, &search);
            fig.push(entry.family.label(), &entry.setting, est);
        }
    }
    fig
}

/// §3.2: wall-clock of the full scheduler per zoo setting ("it takes merely
/// 9-307 seconds in our experiments").
pub fn search_times(mem_gib: f64, q: Quality) -> Table {
    let cluster = Cluster::rtx_titan(8, mem_gib);
    let search = q.search();
    let mut t = Table::new(vec![
        "model", "setting", "ops", "batches", "nodes", "seconds",
    ]);
    for entry in zoo() {
        let profiler = Profiler::new(&entry.model, &cluster, &search);
        let t0 = std::time::Instant::now();
        let res = Scheduler::new(&profiler, cluster.mem_limit,
                                 search.max_batch).run();
        let secs = t0.elapsed().as_secs_f64();
        match res {
            Ok(r) => t.row(vec![
                entry.family.label().to_string(),
                entry.setting.clone(),
                profiler.n_ops().to_string(),
                r.candidates.len().to_string(),
                r.total_nodes.to_string(),
                format!("{secs:.2}"),
            ]),
            Err(_) => t.row(vec![
                entry.family.label().to_string(),
                entry.setting.clone(),
                profiler.n_ops().to_string(),
                "0".into(),
                "0".into(),
                format!("{secs:.2}"),
            ]),
        };
    }
    t
}

/// Memory-cost breakdown of a plan (used by `osdp plan` to explain fits).
pub fn explain_plan(profiler: &Profiler, choice: &[usize], b: usize)
                    -> String {
    let mut states = 0.0;
    let mut act = 0.0;
    let mut trans: f64 = 0.0;
    for (t, &c) in profiler.tables.iter().zip(choice) {
        let o = &t.options[c];
        states += o.states;
        act += b as f64 * t.act_per_sample;
        trans = trans.max(o.gather + b as f64 * t.workspace_per_sample);
    }
    format!(
        "states {} + activations {} + transient {} = {}",
        crate::util::fmt_bytes(states),
        crate::util::fmt_bytes(act),
        crate::util::fmt_bytes(trans),
        crate::util::fmt_bytes(states + act + trans)
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{speedup, speedup_vs_best};

    #[test]
    fn table1_mentions_every_family() {
        let t = table1();
        for f in ["N&D", "W&S", "I&C"] {
            assert!(t.contains(f), "{t}");
        }
    }

    #[test]
    fn fig1_shows_three_zdp_collectives() {
        let g = fig1_gantt();
        assert!(g.contains("DP mode"));
        // ZDP section has gather events, DP section doesn't
        let (dp_part, zdp_part) = g.split_once("-- ZDP mode --").unwrap();
        assert!(!dp_part.contains("fwd-gather"));
        assert!(zdp_part.contains("fwd-gather"));
        assert!(zdp_part.contains("bwd-gather"));
        assert!(zdp_part.contains("grad-sync"));
    }

    #[test]
    fn fig7_memory_monotone_time_tradeoff() {
        let (_, rows) = fig7();
        // per hidden size: memory strictly decreases with g (g>=2)
        for h in [768usize, 1024, 8192, 12288] {
            let mems: Vec<f64> = rows.iter().filter(|r| r.0 == h)
                .map(|r| r.2).collect();
            assert_eq!(mems.len(), 5);
            for w in mems.windows(2) {
                assert!(w[1] <= w[0] + 1e-9, "h={h}: {w:?}");
            }
            // ~50% reduction claim: g=2 cuts the gather roughly in half,
            // total peak must drop noticeably
            assert!(mems[1] < mems[0]);
        }
        // small ops: time grows with g
        let small_times: Vec<f64> = rows.iter().filter(|r| r.0 == 768)
            .map(|r| r.3).collect();
        assert!(small_times.last().unwrap() > small_times.first().unwrap());
    }

    /// Mini fig6-scopes: hybrid-scope planning never loses to global-only
    /// planning on the two-server topology (its plan space is a strict
    /// superset) and strictly beats it under memory pressure.
    #[test]
    fn fig6_scope_ablation_shape() {
        let m = crate::model::build_gpt(
            &crate::model::GptDims::uniform("t", 4000, 128, 4, 512, 8));
        let cluster = Cluster::two_server_a100(16.0);
        // memory pressure: all-DP must not fit, so sharding is forced
        let cluster = Cluster { mem_limit: m.state_bytes() * 0.6, ..cluster };
        let scoped = SearchConfig {
            max_batch: 8,
            granularities: vec![0],
            paper_granularity: true,
            ..Default::default()
        };
        let global = SearchConfig { hybrid_scopes: false, ..scoped.clone() };
        let hybrid = Osdp.estimate(&m, &cluster, &scoped);
        let flat = Osdp.estimate(&m, &cluster, &global);
        assert!(hybrid.feasible && flat.feasible);
        assert!(hybrid.throughput >= flat.throughput * 0.999,
                "hybrid {} must not lose to global {}",
                hybrid.throughput, flat.throughput);
        assert!(hybrid.throughput > flat.throughput * 1.05,
                "node-local gathers should win clearly across the slow \
                 link: {} vs {}", hybrid.throughput, flat.throughput);
    }

    /// The marquee shape-check: a small Figure-5-style run where OSDP must
    /// dominate DP and FSDP and 3D+OSDP must dominate 3D.
    #[test]
    fn fig5_shape_holds_on_reduced_zoo() {
        // one setting per family to keep the test quick
        let cluster = Cluster::rtx_titan(8, 8.0);
        let search = SearchConfig {
            max_batch: 8,
            granularities: vec![0, 4],
            checkpointing: false,
            paper_granularity: true,
            ..Default::default()
        };
        let mut fig = FigureData::new("mini-fig5");
        for entry in zoo().into_iter().take(2) {
            for s in pure_strategies() {
                fig.push(entry.family.label(), &entry.setting,
                         s.estimate(&entry.model, &cluster, &search));
            }
        }
        let vs_fsdp = speedup(&fig, "OSDP", "FSDP").unwrap();
        assert!(vs_fsdp.avg >= 1.0, "OSDP vs FSDP avg {}", vs_fsdp.avg);
        let vs_best = speedup_vs_best(&fig, "OSDP", &["OSDP-base"]);
        if let Some(s) = vs_best {
            assert!(s.max >= 1.0, "OSDP must match the best baseline");
        }
    }
}

/// Debug helper: per-op memory breakdown of the minimum-memory plan.
pub fn debug_min_mem(setting: &str, mem_gib: f64) -> String {
    let entry = zoo().into_iter().find(|e| e.setting == setting).unwrap();
    let cluster = Cluster::rtx_titan(8, mem_gib);
    let search = SearchConfig {
        granularities: vec![0, 4, 8, 16],
        paper_granularity: true,
        ..Default::default()
    };
    let p = Profiler::new(&entry.model, &cluster, &search);
    let mut out = String::new();
    let mut states = 0.0;
    let mut act = 0.0;
    let mut trans: f64 = 0.0;
    for t in &p.tables {
        let min_states = t.min_states;
        let min_trans = t.min_gather + t.workspace_per_sample;
        states += min_states;
        act += t.act_per_sample;
        trans = trans.max(min_trans);
        out.push_str(&format!(
            "{:<12} states>={:>10} act/sample={:>10} trans>={:>10}\n",
            t.name,
            crate::util::fmt_bytes(min_states),
            crate::util::fmt_bytes(t.act_per_sample),
            crate::util::fmt_bytes(min_trans)));
    }
    out.push_str(&format!(
        "TOTAL b=1: states {} + act {} + trans {} = {}\n",
        crate::util::fmt_bytes(states),
        crate::util::fmt_bytes(act),
        crate::util::fmt_bytes(trans),
        crate::util::fmt_bytes(states + act + trans)));
    out
}
