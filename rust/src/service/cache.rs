//! The plan cache: an in-memory LRU over [`QueryKey`]s with optional
//! JSON persistence (the production-planner pattern — cf. the Apollo
//! router's query-plan cache — made trivially sound here because OSDP
//! plans are deterministic and bit-exact, so a cached plan *is* the
//! answer, not an approximation of it).
//!
//! Entries store **choice vectors only** (small integers), never plan
//! costs: costs are re-derived through `Profiler::evaluate` on every
//! hit, which is deterministic, avoids any float round-tripping through
//! the JSON layer, and means a served hit is bit-identical to the search
//! that populated it. The on-disk file is versioned by
//! [`CACHE_SCHEMA_VERSION`] and [`COST_MODEL_EPOCH`]; a file from
//! another epoch or schema is rejected wholesale (counted, never
//! half-loaded), and individual entries are re-validated against the
//! live profiler's menus at hit time so a corrupt or stale entry demotes
//! to a miss instead of panicking the query path.

use super::key::{CACHE_SCHEMA_VERSION, COST_MODEL_EPOCH, QueryKey,
                 QueryShape};
use crate::cost::Profiler;
use crate::util::json::{self, Json};
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;

/// Cache sizing + persistence knobs.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// In-memory entry cap; least-recently-used entries evict beyond it.
    pub capacity: usize,
    /// Directory for the persistent cache file (`plan_cache.json`);
    /// `None` keeps the cache memory-only.
    pub disk_dir: Option<PathBuf>,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig { capacity: 256, disk_dir: None }
    }
}

/// A cached answer. Infeasibility is cached too: "nothing fits" cost a
/// full search to establish and is as deterministic as any plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CachedValue {
    /// The `(time, lex)`-optimal profiler-order choice vector for a
    /// [`QueryShape::Batch`] key.
    Plan { choice: Vec<usize> },
    /// No feasible plan at this key.
    Infeasible,
    /// A full sweep: per-batch winners for `b = 1..=choices.len()` and
    /// the throughput-best index.
    Sweep { choices: Vec<Vec<usize>>, best: usize },
}

impl CachedValue {
    /// Entry sanity against the live profiler: every choice vector must
    /// index real menu entries. A mismatch means the entry predates a
    /// table change the epoch failed to capture (or the file was edited)
    /// — callers demote it to a miss.
    pub fn validates_against(&self, profiler: &Profiler) -> bool {
        let ok = |choice: &[usize]| {
            choice.len() == profiler.n_ops()
                && choice
                    .iter()
                    .zip(&profiler.tables)
                    .all(|(&c, t)| c < t.options.len())
        };
        match self {
            CachedValue::Plan { choice } => ok(choice),
            CachedValue::Infeasible => true,
            CachedValue::Sweep { choices, best } => {
                !choices.is_empty()
                    && *best < choices.len()
                    && choices.iter().all(|c| ok(c))
            }
        }
    }
}

struct Slot {
    value: CachedValue,
    last_used: u64,
    /// Times this entry was served (popularity, not recency — the
    /// epoch-bump warm-up replans the *hottest* entries first).
    hits: u64,
    /// The canonical protocol line that produced this entry
    /// ([`super::server::request_line`]); lets a future epoch replay
    /// the query even though the old choice vector is stale.
    request: Option<String>,
}

/// A warm-up candidate harvested from an epoch-rejected disk file: the
/// request to replay, the old epoch's choice vector (a warm-start seed
/// — provably answer-preserving even across cost-model changes, since
/// seeds only prune), and how hot the entry was.
#[derive(Debug, Clone, PartialEq)]
pub struct StaleEntry {
    pub request: String,
    pub seed: Vec<usize>,
    pub hits: u64,
}

/// What loading the disk file produced, beyond live entries: how many
/// payloads were discarded as stale (wrong schema or epoch, or
/// individually unparseable) and how many corrupt payloads were
/// quarantined aside to `plan_cache.json.bad` for post-mortem instead
/// of being silently dropped.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DiskLoad {
    pub stale: u64,
    pub quarantined: u64,
}

/// LRU plan cache. All counters live in the owning service's
/// `ServiceStats`; this type only reports what happened per call.
pub struct PlanCache {
    cfg: CacheConfig,
    map: HashMap<QueryKey, Slot>,
    tick: u64,
}

impl PlanCache {
    /// Open a cache: empty, or primed from `disk_dir`'s
    /// `plan_cache.json` when one exists. Returns the cache, a
    /// [`DiskLoad`] report (stale rejections + quarantined corruption —
    /// a hostile file never aborts startup), and the warm-up candidates
    /// harvested from an epoch-rejected file: the old entries cannot be
    /// *served*, but the ones that recorded their request line can be
    /// *re-planned* before the listener opens ([`super::PlanService::
    /// warm_up`]).
    pub fn open(cfg: CacheConfig) -> (PlanCache, DiskLoad, Vec<StaleEntry>) {
        let mut cache = PlanCache { cfg, map: HashMap::new(), tick: 0 };
        let (load, harvest) = cache.load_disk();
        (cache, load, harvest)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Look up a key, refreshing its recency and popularity. The caller
    /// counts the hit/miss.
    pub fn get(&mut self, key: &QueryKey) -> Option<&CachedValue> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(key).map(|slot| {
            slot.last_used = tick;
            slot.hits += 1;
            &slot.value
        })
    }

    /// Look up a key **without** touching recency or popularity — the
    /// replan path reads the old plan as projection material, which is
    /// not a serve and must not perturb LRU order or warm-up ranking.
    pub fn peek(&self, key: &QueryKey) -> Option<&CachedValue> {
        self.map.get(key).map(|slot| &slot.value)
    }

    /// Drop an entry (a hit that failed validation).
    pub fn remove(&mut self, key: &QueryKey) {
        self.map.remove(key);
    }

    /// Insert (or replace) an entry; returns how many entries the LRU
    /// cap evicted to make room.
    pub fn insert(&mut self, key: QueryKey, value: CachedValue) -> u64 {
        self.insert_requested(key, value, None)
    }

    /// [`PlanCache::insert`] carrying the canonical request line that
    /// produced the entry. Replacing an existing entry keeps its
    /// accumulated hit count (popularity describes the *key*, not one
    /// epoch's value) and keeps its request line if the new insert has
    /// none (sweep-derived per-batch entries inherit theirs).
    pub fn insert_requested(&mut self, key: QueryKey, value: CachedValue,
                            request: Option<String>) -> u64 {
        self.tick += 1;
        let (hits, request) = match self.map.remove(&key) {
            Some(old) => (old.hits, request.or(old.request)),
            None => (0, request),
        };
        self.map.insert(
            key,
            Slot { value, last_used: self.tick, hits, request },
        );
        let mut evicted = 0;
        while self.map.len() > self.cfg.capacity.max(1) {
            // O(n) scan — the cap is a few hundred entries and eviction
            // is off the planning hot path. Recency ties cannot happen
            // (every touch gets a fresh tick).
            let Some(oldest) = self
                .map
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| *k)
            else {
                break;
            };
            self.map.remove(&oldest);
            evicted += 1;
        }
        evicted
    }

    /// The warm-start neighbor of `key`: the feasible single-batch entry
    /// sharing its structural fingerprint (any batch, any memory limit —
    /// but not the key itself, which would have been a hit) whose
    /// `(batch distance, limit distance)` to the query is smallest.
    /// Deterministic: the rank tuple ends in the entry's own
    /// `(batch, limit bits)`, which is unique per key, so map iteration
    /// order cannot leak through.
    pub fn neighbor(&self, key: &QueryKey)
                    -> Option<(Vec<usize>, usize)> {
        self.neighbors(key, 1).into_iter().next()
    }

    /// The `k` nearest warm-start neighbors of `key`, closest first,
    /// under the same deterministic rank as [`PlanCache::neighbor`].
    /// The richer warm-start path repairs each candidate seed and
    /// offers the search the best repaired one — never worse than the
    /// single-neighbor seed, because that seed is always among the
    /// candidates considered.
    pub fn neighbors(&self, key: &QueryKey, k: usize)
                     -> Vec<(Vec<usize>, usize)> {
        let target_b = match key.shape {
            QueryShape::Batch(b) => b,
            QueryShape::Sweep { .. } => 1,
        };
        let mem_q = key.mem_limit();
        let mut ranked: Vec<_> = self
            .map
            .iter()
            .filter(|(kk, _)| kk.structure == key.structure && **kk != *key)
            .filter_map(|(kk, slot)| {
                let QueryShape::Batch(nb) = kk.shape else { return None };
                let CachedValue::Plan { choice } = &slot.value else {
                    return None;
                };
                let mem_dist = (kk.mem_limit() - mem_q).abs();
                Some((
                    (nb.abs_diff(target_b), mem_dist.to_bits(), nb,
                     kk.mem_limit_bits),
                    choice,
                    nb,
                ))
            })
            .collect();
        ranked.sort_by_key(|(rank, _, _)| *rank);
        ranked
            .into_iter()
            .take(k)
            .map(|(_, choice, nb)| (choice.clone(), nb))
            .collect()
    }

    // ----- persistence -----

    fn disk_path(&self) -> Option<PathBuf> {
        self.cfg.disk_dir.as_ref().map(|d| d.join("plan_cache.json"))
    }

    /// The serialized disk image: target path + JSON document (`None`
    /// without a `disk_dir`). Pure in-memory work — the owning service
    /// snapshots this under its lock and performs the actual write
    /// *outside* it ([`write_cache_file`]), so slow disks never stall
    /// concurrent cache hits.
    pub fn serialize(&self) -> Option<(PathBuf, String)> {
        let path = self.disk_path()?;
        let mut entries = BTreeMap::new();
        for (k, slot) in &self.map {
            let mut v = value_to_json(&slot.value);
            if let Json::Obj(o) = &mut v {
                if slot.hits > 0 {
                    o.insert("hits".into(), Json::Num(slot.hits as f64));
                }
                if let Some(req) = &slot.request {
                    o.insert("req".into(), Json::Str(req.clone()));
                }
            }
            entries.insert(k.id(), v);
        }
        let mut doc = BTreeMap::new();
        doc.insert("schema".to_string(),
                   Json::Num(CACHE_SCHEMA_VERSION as f64));
        doc.insert("epoch".to_string(), Json::Num(COST_MODEL_EPOCH as f64));
        doc.insert("entries".to_string(), Json::Obj(entries));
        Some((path, json::to_string(&Json::Obj(doc))))
    }

    /// Write every entry to disk (no-op without a `disk_dir`). Errors
    /// are returned, not panicked — a read-only disk degrades the
    /// service to memory-only caching.
    pub fn persist(&self) -> Result<(), String> {
        match self.serialize() {
            None => Ok(()),
            Some((path, doc)) => write_cache_file(&path, &doc),
        }
    }

    /// Load the disk file into the (empty) cache. Returns a
    /// [`DiskLoad`] report — stale entries discarded because the file's
    /// schema or epoch does not match or individual payloads do not
    /// parse, plus how much corruption was quarantined to
    /// `plan_cache.json.bad` — and the warm-up candidates harvested
    /// from an epoch-rejected file. Never errors: a hostile file
    /// demotes to an empty cache, never a failed startup.
    fn load_disk(&mut self) -> (DiskLoad, Vec<StaleEntry>) {
        let none = DiskLoad::default();
        let Some(path) = self.disk_path() else { return (none, vec![]) };
        let Ok(text) = std::fs::read_to_string(&path) else {
            return (none, vec![]);
        };
        // An unparseable or structurally wrong file (zero-length,
        // torn by a pre-crash-safety writer, hand-edited) is moved
        // aside whole: the evidence survives for post-mortem and the
        // next persist cannot be shadowed by the corpse.
        let doc = match Json::parse(&text) {
            Ok(doc) if doc.get("entries").as_obj().is_some() => doc,
            _ => {
                quarantine_file(&path);
                return (DiskLoad { stale: 1, quarantined: 1 }, vec![]);
            }
        };
        let schema = doc.get("schema").as_usize();
        let epoch = doc.get("epoch").as_usize();
        let entries = doc.get("entries").as_obj().unwrap();
        if schema != Some(CACHE_SCHEMA_VERSION as usize)
            || epoch != Some(COST_MODEL_EPOCH as usize)
        {
            let harvest = if schema == Some(CACHE_SCHEMA_VERSION as usize)
            {
                // same schema, different cost-model epoch: the values
                // are stale but the *queries* are not — harvest every
                // entry that knows how to replay itself
                entries.values().filter_map(stale_entry_from_json)
                       .collect()
            } else {
                vec![] // unknown schema: don't guess at field meanings
            };
            let load = DiskLoad { stale: entries.len() as u64,
                                  quarantined: 0 };
            return (load, harvest);
        }
        let mut load = none;
        let mut bad = BTreeMap::new();
        for (id, v) in entries {
            match (QueryKey::from_id(id), value_from_json(v)) {
                (Some(key), Some(value)) => {
                    let req =
                        v.get("req").as_str().map(|s| s.to_string());
                    self.insert_requested(key, value, req);
                    // restore persisted popularity (insert zeroes it)
                    if let Some(slot) = self.map.get_mut(&key) {
                        slot.hits =
                            v.get("hits").as_usize().unwrap_or(0) as u64;
                    }
                }
                _ => {
                    // a right-epoch file with an entry that does not
                    // decode is real corruption, not staleness —
                    // quarantine the payload instead of erasing it
                    load.stale += 1;
                    load.quarantined += 1;
                    bad.insert(id.clone(), v.clone());
                }
            }
        }
        if !bad.is_empty() {
            quarantine_entries(&path, bad);
        }
        (load, vec![])
    }
}

/// Where corrupt cache material is parked (`plan_cache.json.bad`).
fn quarantine_path(path: &std::path::Path) -> PathBuf {
    path.with_extension("json.bad")
}

/// Move a wholly corrupt cache file aside. Best-effort: if even the
/// rename fails (read-only dir), fall back to deleting so the corpse
/// cannot shadow future persists; if that fails too, the per-entry
/// validation at hit time still protects the query path.
fn quarantine_file(path: &std::path::Path) {
    if std::fs::rename(path, quarantine_path(path)).is_err() {
        let _ = std::fs::remove_file(path);
    }
}

/// Park individually corrupt entries (from an otherwise healthy file)
/// in the quarantine file as their own JSON document. Best-effort.
fn quarantine_entries(path: &std::path::Path, bad: BTreeMap<String, Json>) {
    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::Num(CACHE_SCHEMA_VERSION as f64));
    doc.insert("epoch".to_string(), Json::Num(COST_MODEL_EPOCH as f64));
    doc.insert("entries".to_string(), Json::Obj(bad));
    let _ = std::fs::write(quarantine_path(path),
                           json::to_string(&Json::Obj(doc)));
}

/// Warm-up candidate from one epoch-rejected disk entry: needs a
/// request line and a choice vector to seed with (the sweep's is its
/// best batch; cached infeasibility has nothing to replay — the new
/// epoch may well make it feasible, but there is no seed, and warm-up
/// replays are meant to be cheap).
fn stale_entry_from_json(v: &Json) -> Option<StaleEntry> {
    let request = v.get("req").as_str()?.to_string();
    let hits = v.get("hits").as_usize().unwrap_or(0) as u64;
    let seed = match v.get("kind").as_str()? {
        "plan" => choice_from_json(v.get("choice"))?,
        "sweep" => {
            let best = v.get("best").as_usize()?;
            choice_from_json(v.get("choices").idx(best))?
        }
        _ => return None,
    };
    Some(StaleEntry { request, seed, hits })
}

/// Write a serialized cache image ([`PlanCache::serialize`]) to disk,
/// creating the parent directory as needed.
///
/// Crash-safe: the document is written to a temp file **in the same
/// directory** and renamed over the target, so the live file is only
/// ever replaced by a complete image — a crash mid-write leaves at
/// worst a truncated `.tmp` next to an intact cache, and the loader
/// never reads `.tmp` files. Two racing persists both write full
/// images, so last-rename-wins is sound (a loser whose temp was
/// renamed out from under it reports an error and the caller retries).
pub fn write_cache_file(path: &std::path::Path, doc: &str)
                        -> Result<(), String> {
    if crate::util::faults::cache_write_fails() {
        return Err(format!("writing {path:?}: injected cache-io fault"));
    }
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("creating {dir:?}: {e}"))?;
    }
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, doc).map_err(|e| format!("writing {tmp:?}: {e}"))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("renaming {tmp:?} -> {path:?}: {e}"))
}

pub(crate) fn choice_to_json(choice: &[usize]) -> Json {
    Json::Arr(choice.iter().map(|&c| Json::Num(c as f64)).collect())
}

pub(crate) fn choice_from_json(v: &Json) -> Option<Vec<usize>> {
    v.as_arr()?.iter().map(Json::as_usize).collect()
}

pub(crate) fn value_to_json(v: &CachedValue) -> Json {
    let mut o = BTreeMap::new();
    match v {
        CachedValue::Plan { choice } => {
            o.insert("kind".into(), Json::Str("plan".into()));
            o.insert("choice".into(), choice_to_json(choice));
        }
        CachedValue::Infeasible => {
            o.insert("kind".into(), Json::Str("infeasible".into()));
        }
        CachedValue::Sweep { choices, best } => {
            o.insert("kind".into(), Json::Str("sweep".into()));
            o.insert("best".into(), Json::Num(*best as f64));
            o.insert(
                "choices".into(),
                Json::Arr(choices.iter().map(|c| choice_to_json(c))
                                 .collect()),
            );
        }
    }
    Json::Obj(o)
}

pub(crate) fn value_from_json(v: &Json) -> Option<CachedValue> {
    match v.get("kind").as_str()? {
        "plan" => Some(CachedValue::Plan {
            choice: choice_from_json(v.get("choice"))?,
        }),
        "infeasible" => Some(CachedValue::Infeasible),
        "sweep" => {
            let best = v.get("best").as_usize()?;
            let choices: Option<Vec<Vec<usize>>> = v
                .get("choices")
                .as_arr()?
                .iter()
                .map(choice_from_json)
                .collect();
            let choices = choices?;
            if best >= choices.len() {
                return None;
            }
            Some(CachedValue::Sweep { choices, best })
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::key::StructKey;

    fn key(b: usize, mem: f64) -> QueryKey {
        QueryKey {
            structure: StructKey([1, 2]),
            mem_limit_bits: mem.to_bits(),
            shape: QueryShape::Batch(b),
        }
    }

    fn plan(c: Vec<usize>) -> CachedValue {
        CachedValue::Plan { choice: c }
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let (mut cache, load, harvest) =
            PlanCache::open(CacheConfig { capacity: 2, disk_dir: None });
        assert_eq!(load, DiskLoad::default());
        assert!(harvest.is_empty());
        assert!(cache.is_empty());
        assert_eq!(cache.insert(key(1, 8e9), plan(vec![0])), 0);
        assert_eq!(cache.insert(key(2, 8e9), plan(vec![1])), 0);
        // touch batch 1 so batch 2 is the LRU victim
        assert!(cache.get(&key(1, 8e9)).is_some());
        assert_eq!(cache.insert(key(3, 8e9), plan(vec![2])), 1);
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&key(2, 8e9)).is_none(), "LRU entry evicted");
        assert!(cache.get(&key(1, 8e9)).is_some());
        assert!(cache.get(&key(3, 8e9)).is_some());
    }

    #[test]
    fn neighbor_prefers_closest_batch_then_limit() {
        let (mut cache, _, _) = PlanCache::open(CacheConfig::default());
        cache.insert(key(1, 8e9), plan(vec![10]));
        cache.insert(key(6, 8e9), plan(vec![60]));
        cache.insert(key(4, 9e9), plan(vec![49]));
        cache.insert(key(4, 7e9), plan(vec![47]));
        // infeasible and sweep entries are never neighbors
        cache.insert(key(5, 8e9), CachedValue::Infeasible);
        // exact key is excluded even though it matches best
        cache.insert(key(4, 8e9), plan(vec![48]));
        let (choice, nb) = cache.neighbor(&key(4, 8e9)).unwrap();
        // batch distance 0 beats distance 1; among the b=4 entries the
        // limit distance decides (1e9 both ways -> tie broken by the
        // rank tuple's trailing mem bits: 7e9 < 9e9 as bits)
        assert_eq!(nb, 4);
        assert_eq!(choice, vec![47]);
        // a sweep key's neighbor target is b=1
        let sweep = QueryKey {
            shape: QueryShape::Sweep { max_batch: 16 },
            ..key(0, 8e9)
        };
        let (choice, nb) = cache.neighbor(&sweep).unwrap();
        assert_eq!((choice, nb), (vec![10], 1));
        // no structural sibling -> no neighbor
        let other = QueryKey { structure: StructKey([9, 9]), ..key(4, 8e9) };
        assert!(cache.neighbor(&other).is_none());
    }

    #[test]
    fn neighbors_rank_deterministically_and_contain_the_neighbor() {
        let (mut cache, _, _) = PlanCache::open(CacheConfig::default());
        cache.insert(key(1, 8e9), plan(vec![10]));
        cache.insert(key(6, 8e9), plan(vec![60]));
        cache.insert(key(4, 9e9), plan(vec![49]));
        cache.insert(key(4, 7e9), plan(vec![47]));
        cache.insert(key(5, 8e9), CachedValue::Infeasible);
        cache.insert(key(4, 8e9), plan(vec![48]));
        let near = cache.neighbors(&key(4, 8e9), 3);
        // the single nearest neighbor always leads the K-nearest list
        assert_eq!(near[0], cache.neighbor(&key(4, 8e9)).unwrap());
        assert_eq!(near, vec![(vec![47], 4), (vec![49], 4), (vec![60], 6)]);
        // asking for more than exist returns everything, still ranked
        assert_eq!(cache.neighbors(&key(4, 8e9), 99).len(), 4);
        assert!(cache.neighbors(&key(4, 8e9), 0).is_empty());
    }

    #[test]
    fn disk_round_trip_and_epoch_rejection() {
        let dir = std::env::temp_dir().join(format!(
            "osdp-cache-test-{}-{}",
            std::process::id(),
            "roundtrip"
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = CacheConfig { capacity: 16, disk_dir: Some(dir.clone()) };
        let (mut cache, load, _) = PlanCache::open(cfg.clone());
        assert_eq!(load.stale, 0);
        cache.insert_requested(key(4, 8e9), plan(vec![0, 2, 1]),
                               Some("query setting=t mem=8 batch=4 g=0"
                                        .into()));
        cache.insert_requested(
            key(1, 8e9).with_shape(QueryShape::Sweep { max_batch: 8 }),
            CachedValue::Sweep { choices: vec![vec![0], vec![1]], best: 1 },
            Some("sweep setting=t mem=8 batch-cap=8 g=0".into()),
        );
        cache.insert(key(9, 8e9), CachedValue::Infeasible);
        // popularity: hit the b=4 plan twice so it outranks the sweep
        assert!(cache.get(&key(4, 8e9)).is_some());
        assert!(cache.get(&key(4, 8e9)).is_some());
        cache.persist().unwrap();

        let (mut reloaded, load, harvest) = PlanCache::open(cfg.clone());
        assert_eq!(load, DiskLoad::default());
        assert!(harvest.is_empty(), "same epoch: nothing to replay");
        assert_eq!(reloaded.len(), 3);
        assert_eq!(reloaded.get(&key(4, 8e9)),
                   Some(&plan(vec![0, 2, 1])));
        assert_eq!(reloaded.get(&key(9, 8e9)),
                   Some(&CachedValue::Infeasible));
        // request lines and popularity survive the round trip (the two
        // persisted hits plus the get() just above)
        let slot = reloaded.map.get(&key(4, 8e9)).unwrap();
        assert_eq!(slot.hits, 3);
        assert_eq!(slot.request.as_deref(),
                   Some("query setting=t mem=8 batch=4 g=0"));

        // tamper with the epoch: the whole file must be rejected, but
        // entries carrying their request line become warm-up fodder
        let path = dir.join("plan_cache.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&text).unwrap();
        let mut obj = doc.as_obj().unwrap().clone();
        obj.insert("epoch".into(),
                   Json::Num((COST_MODEL_EPOCH + 1) as f64));
        std::fs::write(&path, json::to_string(&Json::Obj(obj))).unwrap();
        let (stale_cache, load, mut harvest) =
            PlanCache::open(cfg.clone());
        assert!(stale_cache.is_empty(), "stale epoch must load nothing");
        assert_eq!(load.stale, 3);
        assert_eq!(load.quarantined, 0, "stale is not corrupt");
        // the infeasible entry has no request/seed; the plan and sweep do
        harvest.sort_by(|a, b| b.hits.cmp(&a.hits));
        assert_eq!(harvest.len(), 2);
        assert_eq!(harvest[0].request,
                   "query setting=t mem=8 batch=4 g=0");
        assert_eq!(harvest[0].seed, vec![0, 2, 1]);
        assert_eq!(harvest[0].hits, 2);
        assert_eq!(harvest[1].request,
                   "sweep setting=t mem=8 batch-cap=8 g=0");
        assert_eq!(harvest[1].seed, vec![1], "sweep seeds its best batch");

        // an unknown *schema* harvests nothing (field meanings unknown)
        let mut obj2 = obj.clone();
        obj2.insert("schema".into(),
                    Json::Num((CACHE_SCHEMA_VERSION + 1) as f64));
        std::fs::write(&path, json::to_string(&Json::Obj(obj2))).unwrap();
        let (_, load, harvest) = PlanCache::open(cfg.clone());
        assert_eq!(load.stale, 3);
        assert!(harvest.is_empty());

        // a garbage file counts as one stale rejection AND is
        // quarantined aside so it cannot shadow the next persist
        std::fs::write(&path, "not json").unwrap();
        let (garbage, load, _) = PlanCache::open(cfg.clone());
        assert!(garbage.is_empty());
        assert_eq!(load, DiskLoad { stale: 1, quarantined: 1 });
        assert!(!path.exists(), "the corpse must not shadow persists");
        assert_eq!(
            std::fs::read_to_string(quarantine_path(&path)).unwrap(),
            "not json",
            "quarantine keeps the evidence"
        );
        // with the corpse gone, a fresh open is clean
        let (_, load, _) = PlanCache::open(cfg);
        assert_eq!(load, DiskLoad::default());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn zero_length_file_is_quarantined_not_fatal() {
        let dir = std::env::temp_dir().join(format!(
            "osdp-cache-test-{}-zero",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan_cache.json");
        std::fs::write(&path, "").unwrap();
        let cfg = CacheConfig { capacity: 4, disk_dir: Some(dir.clone()) };
        let (cache, load, harvest) = PlanCache::open(cfg);
        assert!(cache.is_empty());
        assert_eq!(load, DiskLoad { stale: 1, quarantined: 1 });
        assert!(harvest.is_empty());
        assert!(!path.exists());
        assert!(quarantine_path(&path).exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_entry_in_healthy_file_quarantines_just_the_payload() {
        let dir = std::env::temp_dir().join(format!(
            "osdp-cache-test-{}-entry",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = CacheConfig { capacity: 16, disk_dir: Some(dir.clone()) };
        let (mut cache, _, _) = PlanCache::open(cfg.clone());
        cache.insert(key(4, 8e9), plan(vec![0, 2, 1]));
        cache.insert(key(2, 8e9), plan(vec![1, 1, 1]));
        cache.persist().unwrap();
        // rot one entry: kind becomes nonsense, the other must survive
        let path = dir.join("plan_cache.json");
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap())
            .unwrap();
        let mut obj = doc.as_obj().unwrap().clone();
        let entries = obj.get_mut("entries").unwrap();
        let Json::Obj(e) = entries else { panic!() };
        let rot_id = key(2, 8e9).id();
        let mut rotted = BTreeMap::new();
        rotted.insert("kind".to_string(), Json::Str("eldritch".into()));
        e.insert(rot_id.clone(), Json::Obj(rotted));
        std::fs::write(&path, json::to_string(&Json::Obj(obj))).unwrap();

        let (mut reloaded, load, _) = PlanCache::open(cfg);
        assert_eq!(load, DiskLoad { stale: 1, quarantined: 1 });
        assert_eq!(reloaded.len(), 1, "healthy sibling survives");
        assert!(reloaded.get(&key(4, 8e9)).is_some());
        // the quarantine file carries exactly the rotted payload
        let bad = Json::parse(
            &std::fs::read_to_string(quarantine_path(&path)).unwrap(),
        )
        .unwrap();
        assert_eq!(
            bad.get("entries").get(&rot_id).get("kind").as_str(),
            Some("eldritch")
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn leftover_truncated_temp_never_shadows_the_live_file() {
        let dir = std::env::temp_dir().join(format!(
            "osdp-cache-test-{}-tmp",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = CacheConfig { capacity: 16, disk_dir: Some(dir.clone()) };
        let (mut cache, _, _) = PlanCache::open(cfg.clone());
        cache.insert(key(4, 8e9), plan(vec![0, 2, 1]));
        cache.persist().unwrap();
        let path = dir.join("plan_cache.json");
        assert!(path.exists());
        assert!(!path.with_extension("json.tmp").exists(),
                "a successful write leaves no temp behind");

        // simulate a crash mid-write: a torn temp next to a live file
        let torn = &std::fs::read_to_string(&path).unwrap()[..10];
        std::fs::write(path.with_extension("json.tmp"), torn).unwrap();
        let (mut reloaded, load, _) = PlanCache::open(cfg.clone());
        assert_eq!(load, DiskLoad::default(),
                   "the loader never looks at temp files");
        assert_eq!(reloaded.get(&key(4, 8e9)), Some(&plan(vec![0, 2, 1])));

        // the next persist replaces the torn temp and the live file
        // with complete images
        reloaded.insert(key(2, 8e9), plan(vec![1, 1, 1]));
        reloaded.persist().unwrap();
        assert!(!path.with_extension("json.tmp").exists());
        let (mut again, load, _) = PlanCache::open(cfg);
        assert_eq!(load, DiskLoad::default());
        assert_eq!(again.len(), 2);
        assert!(again.get(&key(2, 8e9)).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn values_validate_against_menu_shape() {
        let m = crate::model::build_gpt(
            &crate::model::GptDims::uniform("t", 1000, 64, 2, 128, 4));
        let c = crate::config::Cluster::rtx_titan(8, 8.0);
        let s = crate::config::SearchConfig {
            granularities: vec![0],
            ..Default::default()
        };
        let p = Profiler::new(&m, &c, &s);
        let good = p.index_of(|d| d.is_pure_dp());
        assert!(plan(good.clone()).validates_against(&p));
        assert!(CachedValue::Infeasible.validates_against(&p));
        let mut short = good.clone();
        short.pop();
        assert!(!plan(short).validates_against(&p));
        let mut wild = good.clone();
        wild[0] = 1_000_000;
        assert!(!plan(wild).validates_against(&p));
        assert!(CachedValue::Sweep { choices: vec![good.clone()], best: 0 }
            .validates_against(&p));
        assert!(!CachedValue::Sweep { choices: vec![good], best: 3 }
            .validates_against(&p));
        assert!(!CachedValue::Sweep { choices: vec![], best: 0 }
            .validates_against(&p));
    }
}
