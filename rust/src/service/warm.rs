//! Warm-started planning: turning a cached **neighbor** plan (same
//! model/cluster structure, different batch size or memory limit) into
//! an initial incumbent for a cache-miss search.
//!
//! The seed travels through two stages inside the engines
//! (`dfs::search_prefolded` / `parallel::search_seeded`):
//!
//! 1. **Repair** (`planner::greedy::search_from`): a neighbor plan that
//!    no longer fits verbatim at the queried `(mem_limit, b)` — e.g.
//!    the previous batch's optimum, one activation-step too big — is
//!    downgraded along greedy's best-memory-per-time moves until it
//!    fits. A plan one batch away is usually one or two downgrades from
//!    a near-optimal incumbent, where the cold greedy seed has to find
//!    the whole assignment from all-fastest.
//! 2. **Offer** (`SearchSpace::offer_warm`): the repaired plan is
//!    priced in search arithmetic and installed iff it `(time, lex)`-
//!    beats the greedy seed.
//!
//! # Why the result is bit-identical to a cold search
//!
//! The branch-and-bound walkers return the `(time, lex)`-minimum of
//! `{seed} ∪ {feasible leaves}` (see `planner::bound`'s exactness
//! argument — the pruning rules provably never hide that minimum). A
//! cold search seeds with the greedy plan; a warm search seeds with the
//! `(time, lex)`-better of the greedy plan and the re-priced neighbor.
//! Either way the seed is a *feasible full assignment*, and every
//! feasible full assignment is weakly `(time, lex)`-dominated by a leaf
//! of the search space: sorting its within-class decisions ascending
//! (the canonical monotone representative) changes no cost — all search
//! sums are grid-/byte-exact, so permuting interchangeable operators'
//! decisions is bitwise free — stays feasible, and is lexicographically
//! `≤` the assignment itself in the class-contiguous visit order. The
//! minimum over `{seed} ∪ {leaves}` therefore always equals the minimum
//! over `{leaves}` alone, whatever feasible seed is installed: the seed
//! can *prune* (it tightens the incumbent bound from node one) but can
//! never *change* the answer. For the frontier engine the same holds
//! because the `(time, lex)` optimum over the folded leaves is composed
//! of kept frontier points (`planner::frontier`'s dominance argument),
//! independent of the incumbent. Property-tested across all three
//! engines, serial and 8-threaded, in `rust/tests/plan_service.rs`, and
//! mirrored in f64 in `python/mirror/service_mirror.py`.
//!
//! The seed is priced in **search arithmetic** — `base_time` plus the
//! grid-exact `time_fixed` sum in visit order, exactly like the greedy
//! seed and every accepted leaf (`SearchSpace::offer_warm`) — never with
//! `evaluate()`'s unsnapped compute term, so exact ties against the
//! incumbent survive the strict `lb > bound` prune.
//!
//! # The proof extends to elastic replans
//!
//! [`super::replan`] feeds this same machinery a seed from a *different
//! cluster*: the old hardware's optimum, per-decision projected onto the
//! new profiler's menus ([`crate::cost::Decision::project`] +
//! [`crate::cost::OpCostTable::closest_option`]). Nothing in the
//! argument above cares where the seed came from — only that whatever
//! reaches `offer_warm` is a feasible full assignment *of the new
//! cluster's search space*, which the repair stage (and its
//! reject-don't-panic validation) guarantees exactly as it does for
//! neighbor seeds. A projected seed therefore also only prunes: the
//! replanned answer is bit-identical to a cold search on the new
//! cluster, and the old plan's only contribution is visited-node
//! savings. Property-tested in `rust/tests/replan_service.rs`.
//!
//! There is deliberately no code here: the repair lives with the greedy
//! planner (`crate::planner::greedy::search_from`, whose move loop it
//! reuses verbatim) and the install lives with the bound machinery
//! (`SearchSpace::offer_warm`, which owns the incumbent's arithmetic).
//! Both validate their inputs — wrong-length or out-of-menu seeds from a
//! stale cache entry are rejected, never panicked on — so a third copy
//! of that predicate would only drift. This module is the design's
//! documentation anchor; the property tests live in
//! `rust/tests/plan_service.rs` and the f64 mirror in
//! `python/mirror/service_mirror.py`.
