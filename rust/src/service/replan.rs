//! Elastic re-planning: answer "the cluster just changed under this
//! plan" as a service primitive (ROADMAP item 5).
//!
//! The failover planner was already built — it just wasn't exposed.
//! The warm-start machinery ([`super::warm`], `greedy::search_from`,
//! `offer_warm`) accepts *any* choice vector, repairs it to
//! feasibility by greedy downgrades, and installs it as the initial
//! incumbent of a **full** search, provably without changing the
//! answer. So device loss, device join, a node dropping out of a
//! [`crate::cost::Scope::Node`] group, or whole-node loss all reduce
//! to the same move:
//!
//! 1. look up the old cluster's cached choice vector (the exact key,
//!    or its nearest structural neighbor),
//! 2. **project** each decision onto the new cluster
//!    ([`crate::cost::Decision::project`] degrades scopes the new
//!    hierarchy cannot express, then
//!    [`crate::cost::OpCostTable::closest_option`] maps it into the
//!    new profiler's menu — exact when offered, deterministic-nearest
//!    otherwise),
//! 3. hand the projected vector to [`super::PlanService::query_seeded`]
//!    as a warm seed, which greedy-repairs it at the gate batch and
//!    runs the full search on the new cluster.
//!
//! Because a seed only ever *prunes* (the engines discard an incumbent
//! the moment anything beats it — the [`super::warm`] proof), the
//! replanned answer is **bit-identical to a cold search on the new
//! cluster**; the old plan only buys visited-node savings. That
//! property is pinned in `rust/tests/replan_service.rs` at 1 and 8
//! threads.
//!
//! The capacity sweep ([`PlanService::replan_sweep_clusters`]) runs the
//! inverse query — "what hardware does this model still fit on?" — by
//! walking a device-count ladder downward, re-planning each rung from
//! the last feasible one so the seeds cascade.

use super::telemetry::ObservedShape;
use super::{CachedValue, ClusterSpec, PlanError, PlanQuery, PlanService,
            QueryKey, QueryResponse, QueryShape, Telemetry, resolve_setting};
use crate::cost::Profiler;
use crate::planner;
use crate::util::sync::lock_recover;

/// One rung of a capacity sweep: the device count probed and what
/// re-planning onto it produced (`Err(Infeasible)` rungs are the
/// point — they locate the hardware floor).
#[derive(Debug)]
pub struct CapacityCandidate {
    pub devices: usize,
    pub outcome: Result<QueryResponse, PlanError>,
}

/// Project an old profiler's choice vector onto a new profiler's
/// menus, decision by decision. `None` when the vectors cannot
/// correspond (different op counts — a different model or search
/// config, not a cluster change — or an out-of-menu index).
pub fn project_choice(old: &Profiler, choice: &[usize],
                      new: &Profiler) -> Option<Vec<usize>> {
    if old.n_ops() != new.n_ops() || choice.len() != old.n_ops() {
        return None;
    }
    let mut out = Vec::with_capacity(choice.len());
    for ((&c, ot), nt) in choice.iter().zip(&old.tables).zip(&new.tables) {
        let d = ot.options.get(c)?.decision;
        out.push(nt.closest_option(&d.project(&new.cluster)));
    }
    Some(out)
}

impl PlanService {
    /// Re-plan `old` onto `new_cluster`: the old cluster's cached
    /// answer (or nearest neighbor) is projected onto the new
    /// hardware and warm-seeds a full search there. Returns exactly
    /// what a cold [`PlanService::query`] on the new cluster would —
    /// bit-identical plan, same cache/coalescing behavior — typically
    /// for fewer visited nodes. Counts `replans` (and
    /// `replan_repairs` when the projected plan needed greedy repair
    /// to fit the new hardware).
    pub fn replan(&self, old: &PlanQuery, new_cluster: &ClusterSpec)
                  -> Result<QueryResponse, PlanError> {
        old.validate()?;
        let old_resolved = old.cluster.resolve()?;
        let new_resolved = new_cluster.resolve()?;
        let new_q = PlanQuery { cluster: new_cluster.clone(), ..old.clone() };
        if old_resolved == new_resolved {
            // same hardware under a different spelling: nothing to
            // project, but it is still a (degenerate) replan
            lock_recover(&self.inner).stats.replans += 1;
            return self.query(&new_q);
        }
        let model = resolve_setting(&old.setting)?;
        let old_profiler = Profiler::new(&model, &old_resolved, &old.search);
        let old_key = QueryKey::for_query(&old_profiler,
                                          old_resolved.mem_limit, old.shape);
        // the old plan: exact entry first (peek — reading projection
        // material is not a serve and must not touch LRU order), else
        // the nearest structural neighbor on the old cluster
        let old_choice: Option<Vec<usize>> = {
            let guard = lock_recover(&self.inner);
            match guard.cache.peek(&old_key) {
                Some(CachedValue::Plan { choice }) => Some(choice.clone()),
                Some(CachedValue::Sweep { choices, best }) => {
                    choices.get(*best).cloned()
                }
                // cached infeasibility has no plan to carry over; a
                // cold miss falls back to the neighbor heuristic
                _ => guard.cache.neighbor(&old_key).map(|(c, _)| c),
            }
        };
        let old_choice = old_choice.filter(|c| {
            CachedValue::Plan { choice: c.clone() }
                .validates_against(&old_profiler)
        });
        let new_profiler = Profiler::new(&model, &new_resolved, &old.search);
        let seed = old_choice.as_ref().and_then(|c| {
            project_choice(&old_profiler, c, &new_profiler)
        });
        // did the old plan survive the move as-is? Repair the
        // projected vector at the gate batch exactly the way the
        // seeded search will; a changed (or unrepairable) vector
        // means the new hardware could not hold the old plan.
        let repaired = seed.as_ref().map(|s| {
            let b_gate = match old.shape {
                QueryShape::Batch(b) => b,
                QueryShape::Sweep { .. } => 1,
            };
            match planner::greedy_search_from(&new_profiler,
                                              new_resolved.mem_limit,
                                              b_gate, s)
            {
                Some((r, _)) => r != *s,
                None => true,
            }
        });
        {
            let mut guard = lock_recover(&self.inner);
            guard.stats.replans += 1;
            if repaired == Some(true) {
                guard.stats.replan_repairs += 1;
            }
        }
        self.query_seeded(&new_q, seed.as_deref())
    }

    /// Capacity sweep (the inverse query): starting from `start`'s
    /// device count, halve the cluster rung by rung down to one
    /// device, re-planning onto each rung **from the last feasible
    /// one** so warm seeds cascade down the ladder. Every rung's
    /// verdict is returned — the feasible rungs say what the model
    /// still fits on, the infeasible ones where the wall is. Only the
    /// size-parametric `rtx_titan` preset can sweep (the two-server
    /// topology is fixed hardware).
    ///
    /// Each rung is one real query; when `telemetry` is given it is
    /// observed per rung, so the pinned invariants (histogram counts
    /// == queries; hits + misses == queries − rejected) hold through
    /// a sweep exactly as through individual queries.
    pub fn replan_sweep_clusters(
        &self,
        old: &PlanQuery,
        start: &ClusterSpec,
        telemetry: Option<&Telemetry>,
    ) -> Result<Vec<CapacityCandidate>, PlanError> {
        if start.preset != "rtx_titan" {
            return Err(PlanError::BadRequest(format!(
                "sweep-clusters needs the size-parametric rtx_titan \
                 preset (got '{}')",
                start.preset
            )));
        }
        old.validate()?;
        start.resolve()?;
        let mut q = old.clone();
        let mut devices = start.devices.unwrap_or(8);
        let mut rungs = Vec::new();
        loop {
            let spec = ClusterSpec {
                preset: "rtx_titan".into(),
                devices: Some(devices),
                mem_gib: start.mem_gib,
            };
            let started = std::time::Instant::now();
            let outcome = self.replan(&q, &spec);
            if let Some(t) = telemetry {
                // every rung is a replan, whatever shape the original
                // query had — the replan lane is about the path taken
                // (cache bypass + reseed), not the answer's shape
                t.observe_query(
                    ObservedShape::Replan,
                    started.elapsed().as_secs_f64(),
                    &outcome,
                );
            }
            let feasible = outcome.is_ok();
            rungs.push(CapacityCandidate { devices, outcome });
            if feasible {
                q.cluster = spec;
            }
            if devices == 1 {
                return Ok(rungs);
            }
            devices /= 2;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Cluster, SearchConfig};
    use crate::model::{GptDims, build_gpt};

    fn profiler_for(cluster: &Cluster, grans: Vec<usize>) -> Profiler {
        let m = build_gpt(&GptDims::uniform("t", 1000, 64, 2, 128, 4));
        let s = SearchConfig { granularities: grans,
                               ..Default::default() };
        Profiler::new(&m, cluster, &s)
    }

    #[test]
    fn projection_round_trips_between_cluster_sizes() {
        // g=[0] menus hold exactly one pure-DP and one pure-ZDP entry,
        // so the extremes are unambiguous on both clusters
        let eight = profiler_for(&Cluster::rtx_titan(8, 8.0), vec![0]);
        let four = profiler_for(&Cluster::rtx_titan(4, 8.0), vec![0]);
        let dp = eight.index_of(|d| d.is_pure_dp());
        let zdp = eight.index_of(|d| d.is_pure_zdp());
        assert_eq!(project_choice(&eight, &dp, &four).unwrap(),
                   four.index_of(|d| d.is_pure_dp()));
        assert_eq!(project_choice(&eight, &zdp, &four).unwrap(),
                   four.index_of(|d| d.is_pure_zdp()));
        // projecting onto the same cluster is the identity, menus of
        // any granularity
        let rich = profiler_for(&Cluster::rtx_titan(8, 8.0), vec![0, 2]);
        let z = rich.index_of(|d| d.is_pure_zdp());
        assert_eq!(project_choice(&rich, &z, &rich).unwrap(), z);
    }

    #[test]
    fn projection_degrades_node_scope_to_single_node_hardware() {
        let two_node =
            profiler_for(&Cluster::two_server_a100(16.0), vec![0]);
        let one_node = profiler_for(&Cluster::rtx_titan(8, 8.0), vec![0]);
        let node_scoped =
            two_node.index_of(|d| d.is_pure_zdp() && d.is_node_scoped());
        let projected =
            project_choice(&two_node, &node_scoped, &one_node).unwrap();
        let mut scoped_ops = 0;
        for i in 0..one_node.n_ops() {
            // index_of falls back to option 0 where a menu offers no
            // node-scoped ZDP; only ops that really started node-scoped
            // exercise the degradation
            let src = two_node.tables[i].options[node_scoped[i]].decision;
            if !src.is_node_scoped() {
                continue;
            }
            scoped_ops += 1;
            let d = one_node.tables[i].options[projected[i]].decision;
            assert!(!d.is_node_scoped(),
                    "no node scope exists on one node");
            assert!(d.is_pure_zdp(), "sharding fraction preserved");
        }
        assert!(scoped_ops > 0,
                "two-server menus must offer node-scoped ZDP somewhere");
    }

    #[test]
    fn projection_rejects_mismatched_op_counts() {
        let p8 = profiler_for(&Cluster::rtx_titan(8, 8.0), vec![0, 2]);
        let other_model =
            build_gpt(&GptDims::uniform("u", 1000, 64, 4, 128, 4));
        let s = SearchConfig { granularities: vec![0, 2],
                               ..Default::default() };
        let po = Profiler::new(&other_model, &Cluster::rtx_titan(4, 8.0),
                               &s);
        let dp = p8.index_of(|d| d.is_pure_dp());
        assert!(project_choice(&p8, &dp, &po).is_none());
        assert!(project_choice(&p8, &dp[..1], &p8).is_none(),
                "wrong-length vectors cannot correspond");
        let wild = vec![usize::MAX; p8.n_ops()];
        assert!(project_choice(&p8, &wild, &p8).is_none(),
                "out-of-menu indices cannot project");
    }
}
