//! Canonical plan-query keys: the cache identity of a planning request.
//!
//! A query's answer depends on exactly three things: the Profiler's
//! per-operator cost tables (which already bake in the model, the
//! cluster's link/compute model, the granularity menu, checkpointing,
//! and the sharding-scope knob — see [`crate::cost::menu::table_key`]),
//! the device memory limit, and the query shape (one batch size, or a
//! sweep capped at `max_batch`). The key therefore fingerprints the
//! **profiler**, not the configuration text: two configs that spell the
//! same search problem differently (TOML field order, defaulted vs
//! explicit knobs, a `--cluster` preset vs its fields written out)
//! collide on the same key, while any search-relevant change — limit,
//! granularities, `hybrid_scopes`, checkpointing, a cost-model epoch
//! bump — changes it. Engine choice and thread count are deliberately
//! *not* part of the key: every engine returns the bit-identical
//! `(time, lex)` optimum at any thread count (the repo's load-bearing
//! invariant), so plans cached by one engine are valid answers for all.
//!
//! The memory limit and the shape stay outside the structural
//! fingerprint so the warm-start pass can find **neighbor** entries:
//! same structure, different batch or limit (see `super::warm`).

use crate::cost::Profiler;
use crate::cost::menu::table_key;

/// Cost-model epoch. Bump whenever the Profiler's cost semantics or the
/// choice-vector encoding changes in a way the table bits do not already
/// capture (they capture almost everything; the epoch is the belt to
/// their suspenders). Folded into every structural fingerprint, so
/// entries persisted by an older cost model can never be served.
pub const COST_MODEL_EPOCH: u64 = 5;

/// On-disk cache schema version (`super::cache`). Bump on any change to
/// the persisted JSON layout; mismatching files are rejected wholesale.
pub const CACHE_SCHEMA_VERSION: u64 = 1;

/// 128-bit structural fingerprint: two independent FNV-1a/64 lanes over
/// the search-relevant word stream (epoch, cluster shape, per-table
/// [`crate::cost::menu::TableKey`] bits). Two lanes because a single
/// 64-bit FNV is too collidable to gate cache correctness on; jointly
/// colliding both lanes on real inputs is vanishingly unlikely, and the
/// cost-model epoch bounds the blast radius of any collision to one
/// epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct StructKey(pub [u64; 2]);

impl StructKey {
    /// Hex spelling used in the on-disk cache and log lines.
    pub fn hex(&self) -> String {
        format!("{:016x}{:016x}", self.0[0], self.0[1])
    }

    /// Parse [`StructKey::hex`] (32 hex digits).
    pub fn from_hex(s: &str) -> Option<StructKey> {
        if s.len() != 32 {
            return None;
        }
        let hi = u64::from_str_radix(&s[..16], 16).ok()?;
        let lo = u64::from_str_radix(&s[16..], 16).ok()?;
        Some(StructKey([hi, lo]))
    }
}

/// What the query asks for: one batch size, or the Scheduler's sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QueryShape {
    /// Plan a single per-device batch size.
    Batch(usize),
    /// Sweep batch sizes `1..=max_batch` and keep the throughput winner.
    Sweep { max_batch: usize },
}

impl QueryShape {
    /// Compact spelling (`b4` / `s64`) for the on-disk key.
    pub fn tag(&self) -> String {
        match self {
            QueryShape::Batch(b) => format!("b{b}"),
            QueryShape::Sweep { max_batch } => format!("s{max_batch}"),
        }
    }

    /// Parse [`QueryShape::tag`]. Total: any malformed tag (empty,
    /// multi-byte lead, bad number) is `None`, never a panic — this
    /// parses on-disk cache keys.
    pub fn from_tag(s: &str) -> Option<QueryShape> {
        let kind = s.get(..1)?;
        let n: usize = s.get(1..)?.parse().ok()?;
        match kind {
            "b" => Some(QueryShape::Batch(n)),
            "s" => Some(QueryShape::Sweep { max_batch: n }),
            _ => None,
        }
    }
}

/// The full cache key: structural fingerprint + memory limit + shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QueryKey {
    pub structure: StructKey,
    /// `mem_limit.to_bits()` — exact, no float round-tripping.
    pub mem_limit_bits: u64,
    pub shape: QueryShape,
}

impl QueryKey {
    /// Build the key for a planning query. The profiler must be the one
    /// the search will run on (the fingerprint covers its tables
    /// bit-for-bit).
    pub fn for_query(profiler: &Profiler, mem_limit: f64,
                     shape: QueryShape) -> QueryKey {
        QueryKey {
            structure: fingerprint(profiler),
            mem_limit_bits: mem_limit.to_bits(),
            shape,
        }
    }

    /// The memory limit this key was built at.
    pub fn mem_limit(&self) -> f64 {
        f64::from_bits(self.mem_limit_bits)
    }

    /// Same structure and limit, different shape — how a sweep names the
    /// per-batch entries it populates.
    pub fn with_shape(&self, shape: QueryShape) -> QueryKey {
        QueryKey { shape, ..*self }
    }

    /// Canonical string id: `<struct hex>-<mem bits hex>-<shape>`. Used
    /// as the on-disk entry name and the request-coalescing key.
    pub fn id(&self) -> String {
        format!("{}-{:016x}-{}", self.structure.hex(), self.mem_limit_bits,
                self.shape.tag())
    }

    /// Parse [`QueryKey::id`].
    pub fn from_id(s: &str) -> Option<QueryKey> {
        let mut parts = s.splitn(3, '-');
        let structure = StructKey::from_hex(parts.next()?)?;
        let mem_limit_bits = u64::from_str_radix(parts.next()?, 16).ok()?;
        let shape = QueryShape::from_tag(parts.next()?)?;
        Some(QueryKey { structure, mem_limit_bits, shape })
    }
}

/// Structural fingerprint of a profiler (plus the cluster shape the
/// throughput report depends on), via the two FNV lanes.
pub fn fingerprint(profiler: &Profiler) -> StructKey {
    let mut lanes = [Fnv::new(FNV_OFFSET), Fnv::new(FNV_OFFSET_ALT)];
    let mut feed = |w: u64| {
        for l in &mut lanes {
            l.write_u64(w);
        }
    };
    feed(COST_MODEL_EPOCH);
    feed(profiler.cluster.n_devices as u64);
    feed(profiler.cluster.devices_per_node as u64);
    feed(profiler.n_ops() as u64);
    for t in &profiler.tables {
        let key = table_key(t);
        let bits = key.bits();
        feed(bits.len() as u64);
        for &w in bits {
            feed(w);
        }
    }
    StructKey([lanes[0].finish(), lanes[1].finish()])
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// Second lane: FNV-1a seeded with the golden-ratio constant instead of
/// the standard offset basis, so the lanes disagree on any single-lane
/// collision.
const FNV_OFFSET_ALT: u64 = 0x9e37_79b9_7f4a_7c15;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over bytes (little-endian u64 feeding).
struct Fnv(u64);

impl Fnv {
    fn new(offset: u64) -> Fnv {
        Fnv(offset)
    }

    fn write_u64(&mut self, w: u64) {
        for byte in w.to_le_bytes() {
            self.0 ^= byte as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Cluster, SearchConfig};
    use crate::model::{GptDims, build_gpt};

    fn profiler(grans: Vec<usize>) -> Profiler {
        let m = build_gpt(&GptDims::uniform("t", 1000, 64, 2, 128, 4));
        let c = Cluster::rtx_titan(8, 8.0);
        let s = SearchConfig { granularities: grans, ..Default::default() };
        Profiler::new(&m, &c, &s)
    }

    #[test]
    fn fnv_lanes_match_reference_vectors() {
        // Cross-language fixture shared with python/mirror/
        // service_mirror.py: FNV-1a/64 of the single word 0x6f736470
        // ("osdp" LE) from both lane offsets.
        let mut a = Fnv::new(FNV_OFFSET);
        a.write_u64(0x6f73_6470);
        let mut b = Fnv::new(FNV_OFFSET_ALT);
        b.write_u64(0x6f73_6470);
        assert_eq!(a.finish(), 0xc57a_be0d_2d23_77bb);
        assert_eq!(b.finish(), 0x065f_a0a7_968e_0c6b);
    }

    #[test]
    fn same_profiler_same_key_different_menus_differ() {
        let a = fingerprint(&profiler(vec![0, 4]));
        let b = fingerprint(&profiler(vec![0, 4]));
        let c = fingerprint(&profiler(vec![0, 2, 4]));
        assert_eq!(a, b);
        assert_ne!(a, c, "granularity change must change the key");
    }

    #[test]
    fn limit_and_shape_stay_outside_the_structure() {
        let p = profiler(vec![0]);
        let a = QueryKey::for_query(&p, 8e9, QueryShape::Batch(4));
        let b = QueryKey::for_query(&p, 9e9, QueryShape::Batch(4));
        let c = QueryKey::for_query(&p, 8e9, QueryShape::Batch(5));
        let d = QueryKey::for_query(&p, 8e9,
                                    QueryShape::Sweep { max_batch: 4 });
        assert_eq!(a.structure, b.structure);
        assert_eq!(a.structure, c.structure);
        assert_ne!(a, b, "limit is part of the key");
        assert_ne!(a, c, "batch is part of the key");
        assert_ne!(a, d, "shape is part of the key");
        assert_eq!(a.mem_limit(), 8e9);
        assert_eq!(a.with_shape(QueryShape::Batch(5)), c);
    }

    #[test]
    fn id_round_trips() {
        let p = profiler(vec![0]);
        for shape in [QueryShape::Batch(7),
                      QueryShape::Sweep { max_batch: 64 }] {
            let k = QueryKey::for_query(&p, 8.5e9, shape);
            assert_eq!(QueryKey::from_id(&k.id()), Some(k));
        }
        assert_eq!(QueryKey::from_id("garbage"), None);
        assert_eq!(QueryKey::from_id(""), None);
        let k = QueryKey::for_query(&p, 8.5e9, QueryShape::Batch(1));
        assert_eq!(StructKey::from_hex(&k.structure.hex()),
                   Some(k.structure));
        assert_eq!(QueryShape::from_tag("b12"), Some(QueryShape::Batch(12)));
        assert_eq!(QueryShape::from_tag("s3"),
                   Some(QueryShape::Sweep { max_batch: 3 }));
        assert_eq!(QueryShape::from_tag("x3"), None);
    }

    #[test]
    fn cluster_shape_enters_the_structure() {
        let m = build_gpt(&GptDims::uniform("t", 1000, 64, 2, 128, 4));
        let s = SearchConfig { granularities: vec![0],
                               ..Default::default() };
        let p8 = Profiler::new(&m, &Cluster::rtx_titan(8, 8.0), &s);
        let p4 = Profiler::new(&m, &Cluster::rtx_titan(4, 8.0), &s);
        assert_ne!(fingerprint(&p8), fingerprint(&p4));
    }
}
