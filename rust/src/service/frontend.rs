//! The concurrent socket front-end: a TCP listener speaking the same
//! newline-delimited protocol as `osdp serve` on stdin, dispatching
//! into one shared [`PlanService`] from a bounded worker pool (the
//! router-style front-end: acceptor thread → bounded queue → N
//! workers, each owning one connection at a time).
//!
//! Everything downstream is already thread-safe and deterministic —
//! the cache/coalescer core guarantees that N concurrent identical
//! queries run **one** planner search and that every caller gets the
//! bit-identical optimum — so the front-end's whole job is honest
//! plumbing:
//!
//! * **Bounded queue.** Accepted connections park in a fixed-capacity
//!   channel (a hand-rolled `Mutex<VecDeque>` + condvar pair — the
//!   crossbeam shape, vendored because the build is offline). When all
//!   workers are busy and the queue is full, the acceptor blocks, and
//!   the kernel's listen backlog is the overflow — backpressure, not
//!   unbounded thread spawn.
//! * **Per-connection framing.** Requests are single lines, capped at
//!   [`MAX_LINE`] bytes; an over-long or unparseable line answers a
//!   structured `bad-request` JSON error. Reads poll with a short
//!   timeout so an idle connection is dropped after
//!   `FrontendConfig::idle_timeout` and a shutdown is noticed promptly.
//! * **Graceful shutdown.** The `shutdown` verb (or
//!   [`Frontend::shutdown`]) stops the acceptor, lets every in-flight
//!   request finish and flush its response, drains already-accepted
//!   connections, then joins. No plan in progress is abandoned.
//!
//! Concurrency properties are pinned end-to-end over real sockets in
//! `rust/tests/service_frontend.rs` and re-driven against the release
//! binary in CI's concurrency job.

use super::server::{LineOutcome, handle_line_full};
use super::telemetry::{Counter, Telemetry};
use super::PlanService;
use crate::util::sync::{lock_recover, wait_recover};
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Request lines larger than this are rejected (and the connection
/// closed) — nothing in the protocol grammar comes close.
pub const MAX_LINE: usize = 16 * 1024;

/// How often a blocked read wakes up to check the idle clock and the
/// shutdown flag.
const POLL_TICK: Duration = Duration::from_millis(50);

/// After this many *consecutive* `accept(2)` failures the acceptor
/// gives up: the front-end tears down exactly as if `shutdown` had
/// been requested (in-flight requests still drain), and the teardown
/// hook — if one was installed via [`Frontend::start_with_hooks`] —
/// runs first. `osdp serve --metrics` wires that hook to the stderr
/// metrics dump, so a listener dying of fd exhaustion still reports
/// its final counters instead of vanishing silently. Each failure
/// also ticks [`Counter::AcceptErrors`]; any successful accept resets
/// the run.
pub const FATAL_ACCEPT_ERRORS: u32 = 32;

/// Runs once if the acceptor dies of consecutive accept failures.
pub type TeardownHook = Box<dyn Fn() + Send + 'static>;

// ---------------------------------------------------------------------
// Bounded MPMC channel (vendored crossbeam-style stub)
// ---------------------------------------------------------------------

struct ChannelState<T> {
    queue: VecDeque<T>,
    closed: bool,
}

/// A bounded multi-producer multi-consumer channel: `send` blocks when
/// full, `recv` blocks when empty, `close` wakes everyone. After
/// `close`, `recv` still drains queued items before returning `None` —
/// that drain is what makes front-end shutdown graceful for
/// connections accepted but not yet picked up.
pub struct Channel<T> {
    state: Mutex<ChannelState<T>>,
    cap: usize,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> Channel<T> {
    pub fn bounded(cap: usize) -> Channel<T> {
        Channel {
            state: Mutex::new(ChannelState {
                queue: VecDeque::new(),
                closed: false,
            }),
            cap: cap.max(1),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Blocks while the channel is full; `Err(item)` if it was closed.
    ///
    /// All four channel entry points take the state lock through
    /// [`lock_recover`]/[`wait_recover`]: a worker that panics while
    /// holding it (resurrected panics are a designed-for event under
    /// fault injection) poisons the mutex, and a bare `unwrap` here
    /// would then wedge the acceptor and every surviving worker. The
    /// queue itself is always structurally valid — each critical
    /// section completes its `VecDeque` mutation before any code that
    /// can unwind.
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut st = lock_recover(&self.state);
        loop {
            if st.closed {
                return Err(item);
            }
            if st.queue.len() < self.cap {
                st.queue.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            st = wait_recover(&self.not_full, st);
        }
    }

    /// Non-blocking send: `Err(item)` when the channel is full or
    /// closed. The remote tier's write-behind path uses this — a slow
    /// or dead remote must shed puts, never stall a planner thread.
    pub fn try_send(&self, item: T) -> Result<(), T> {
        let mut st = lock_recover(&self.state);
        if st.closed || st.queue.len() >= self.cap {
            return Err(item);
        }
        st.queue.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocks until an item arrives; `None` once closed **and** empty.
    pub fn recv(&self) -> Option<T> {
        let mut st = lock_recover(&self.state);
        loop {
            if let Some(item) = st.queue.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = wait_recover(&self.not_empty, st);
        }
    }

    pub fn close(&self) {
        lock_recover(&self.state).closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.state).queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------
// Front-end proper
// ---------------------------------------------------------------------

/// What a front-end serves: one request line in, one response line and
/// a connection verdict out. The plan service and the cache-tier server
/// both sit behind the same acceptor/worker/framing machinery through
/// this trait — the transport owns connections, timeouts, and panics;
/// the handler owns the protocol grammar.
pub trait LineHandler: Send + Sync + 'static {
    fn handle(&self, line: &str) -> (String, LineOutcome);
}

/// The plan service behind the standard grammar, with its telemetry
/// attached (the handler bumps `BadRequests` and observes query
/// latency; the transport bumps the connection-level counters).
struct ServiceHandler {
    service: Arc<PlanService>,
    telemetry: Arc<Telemetry>,
}

impl LineHandler for ServiceHandler {
    fn handle(&self, line: &str) -> (String, LineOutcome) {
        handle_line_full(&self.service, Some(&self.telemetry), line)
    }
}

/// The `--metrics-listen` scrape endpoint: any request line is answered
/// with the full Prometheus text exposition, then the connection
/// closes. A line that looks like an HTTP request (`GET ...`) gets
/// minimal HTTP/1.0 framing first, so a real Prometheus scraper (or
/// `curl`) reads the same page `nc` gets raw. The endpoint runs behind
/// its own [`Frontend`] with its own [`Telemetry`] — scrapes are not
/// service traffic and must not perturb the counters they report.
pub struct MetricsHandler {
    pub service: Arc<PlanService>,
    /// The *service's* telemetry — the numbers being scraped.
    pub telemetry: Arc<Telemetry>,
}

impl LineHandler for MetricsHandler {
    fn handle(&self, line: &str) -> (String, LineOutcome) {
        let page = super::telemetry::render_prometheus(
            &self.service.stats(),
            self.service.cache_len(),
            &self.telemetry,
            self.service.breaker_state(),
            self.service.tracer().span_histograms(),
        );
        let response = if line.starts_with("GET ") {
            format!(
                "HTTP/1.0 200 OK\r\nContent-Type: text/plain; \
                 version=0.0.4\r\nConnection: close\r\n\r\n{page}"
            )
        } else {
            page
        };
        (response, LineOutcome::Quit)
    }
}

#[derive(Debug, Clone)]
pub struct FrontendConfig {
    /// Bind address (`127.0.0.1:0` picks an ephemeral port; read it
    /// back from [`Frontend::local_addr`]).
    pub addr: String,
    /// Worker threads; `0` means the planner's hardware default.
    pub workers: usize,
    /// Idle connections are dropped after this long without a complete
    /// request line.
    pub idle_timeout: Duration,
    /// Accepted-connection queue bound (backpressure depth).
    pub queue_cap: usize,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            addr: "127.0.0.1:0".into(),
            workers: 0,
            idle_timeout: Duration::from_secs(30),
            queue_cap: 64,
        }
    }
}

/// A running front-end: acceptor + workers, stoppable and joinable.
pub struct Frontend {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Frontend {
    /// Bind, spawn the pool, and start accepting. The service and
    /// telemetry are shared — a caller keeps its own `Arc` clones to
    /// inspect stats while the front-end runs.
    pub fn start(
        service: Arc<PlanService>,
        telemetry: Arc<Telemetry>,
        cfg: FrontendConfig,
    ) -> std::io::Result<Frontend> {
        let handler = Arc::new(ServiceHandler {
            service,
            telemetry: Arc::clone(&telemetry),
        });
        Frontend::start_with(handler, telemetry, cfg)
    }

    /// [`Frontend::start`] with a fatal-accept-error teardown hook
    /// (see [`FATAL_ACCEPT_ERRORS`]).
    pub fn start_hooked(
        service: Arc<PlanService>,
        telemetry: Arc<Telemetry>,
        cfg: FrontendConfig,
        teardown: Option<TeardownHook>,
    ) -> std::io::Result<Frontend> {
        let handler = Arc::new(ServiceHandler {
            service,
            telemetry: Arc::clone(&telemetry),
        });
        Frontend::start_with_hooks(handler, telemetry, cfg, teardown)
    }

    /// The generic core: any [`LineHandler`] behind the same bounded
    /// pool, framing, fault-injection, and graceful-shutdown plumbing.
    pub fn start_with<H: LineHandler>(
        handler: Arc<H>,
        telemetry: Arc<Telemetry>,
        cfg: FrontendConfig,
    ) -> std::io::Result<Frontend> {
        Frontend::start_with_hooks(handler, telemetry, cfg, None)
    }

    /// [`Frontend::start_with`] plus an optional teardown hook that
    /// fires if the acceptor dies of [`FATAL_ACCEPT_ERRORS`]
    /// consecutive accept failures (the hook does *not* fire on a
    /// requested shutdown — the caller is present for those).
    pub fn start_with_hooks<H: LineHandler>(
        handler: Arc<H>,
        telemetry: Arc<Telemetry>,
        cfg: FrontendConfig,
        teardown: Option<TeardownHook>,
    ) -> std::io::Result<Frontend> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let workers = match cfg.workers {
            0 => crate::planner::parallel::default_threads(),
            w => w,
        };
        let shutdown = Arc::new(AtomicBool::new(false));
        let conns: Arc<Channel<TcpStream>> =
            Arc::new(Channel::bounded(cfg.queue_cap));

        let acceptor = {
            let conns = Arc::clone(&conns);
            let shutdown = Arc::clone(&shutdown);
            let telemetry = Arc::clone(&telemetry);
            thread::spawn(move || {
                let mut failures = 0u32;
                for stream in listener.incoming() {
                    if shutdown.load(Ordering::SeqCst) {
                        break; // the wake-up connection itself is dropped
                    }
                    let stream = match stream {
                        Ok(s) => {
                            failures = 0;
                            s
                        }
                        Err(_) => {
                            // transient (aborted handshake, fd
                            // pressure): count it and keep listening.
                            // A long unbroken run means the listener
                            // itself is wedged — tear down gracefully
                            // rather than spin on a dead socket.
                            telemetry.bump(Counter::AcceptErrors);
                            failures += 1;
                            if failures >= FATAL_ACCEPT_ERRORS {
                                shutdown.store(true, Ordering::SeqCst);
                                if let Some(hook) = &teardown {
                                    hook();
                                }
                                break;
                            }
                            continue;
                        }
                    };
                    telemetry.bump(Counter::Connections);
                    if conns.send(stream).is_err() {
                        break;
                    }
                }
                // closing here (not in shutdown()) keeps the drain
                // ordering: everything accepted before the shutdown was
                // observed is already queued and will be served
                conns.close();
            })
        };

        let workers = (0..workers)
            .map(|_| {
                let conns = Arc::clone(&conns);
                let handler = Arc::clone(&handler);
                let telemetry = Arc::clone(&telemetry);
                let shutdown = Arc::clone(&shutdown);
                let idle = cfg.idle_timeout;
                thread::spawn(move || {
                    // Self-healing dispatch: a panic anywhere in a
                    // served request (a planner bug, an injected
                    // fault) unwinds out of serve_connection — the
                    // peer sees its connection drop, nothing more —
                    // and the same OS thread re-enters the dispatch
                    // loop. The pool can NEVER shrink from panics: the
                    // existing PoisonGuard covers the coalesced
                    // flight, this loop covers the thread.
                    loop {
                        let run = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| {
                                while let Some(stream) = conns.recv() {
                                    serve_connection(&*handler, &telemetry,
                                                     &shutdown, addr,
                                                     stream, idle);
                                }
                            }),
                        );
                        match run {
                            // channel closed and drained: a clean exit
                            Ok(()) => break,
                            Err(_) => {
                                telemetry.bump(Counter::WorkerRestarts);
                            }
                        }
                    }
                })
            })
            .collect();

        Ok(Frontend { addr, shutdown, acceptor: Some(acceptor), workers })
    }

    /// The bound address (resolves `:0` to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request a graceful stop: no new connections, in-flight requests
    /// finish and flush. Idempotent; `join` to wait for the drain.
    pub fn shutdown(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // the acceptor may be parked in accept(2); poke it awake
        let _ = TcpStream::connect(self.addr);
    }

    /// Wait for the acceptor and every worker to finish (all accepted
    /// connections served or dropped). Call [`Frontend::shutdown`]
    /// first, or issue the protocol's `shutdown` verb.
    pub fn join(mut self) {
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Why a connection's read loop stopped waiting for a line.
enum ReadOutcome {
    Line(String),
    Eof,
    IdleTimeout,
    TooLong,
    Shutdown,
    Error,
}

/// Serve one connection to completion: lines in, JSON lines out.
fn serve_connection<H: LineHandler>(
    handler: &H,
    telemetry: &Telemetry,
    shutdown: &AtomicBool,
    addr: SocketAddr,
    stream: TcpStream,
    idle_timeout: Duration,
) {
    // short poll so the idle clock and shutdown flag are checked even
    // while blocked on a silent peer
    if stream.set_read_timeout(Some(POLL_TICK)).is_err() {
        return;
    }
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        match read_request_line(&mut reader, shutdown, idle_timeout) {
            ReadOutcome::Eof | ReadOutcome::Error => return,
            ReadOutcome::Shutdown => return,
            ReadOutcome::IdleTimeout => {
                telemetry.bump(Counter::ConnTimeouts);
                let _ = writeln!(
                    writer,
                    "{{\"detail\":\"idle connection closed\",\
                     \"error\":\"timeout\",\"ok\":false}}"
                );
                return;
            }
            ReadOutcome::TooLong => {
                telemetry.bump(Counter::Requests);
                telemetry.bump(Counter::BadRequests);
                let _ = writeln!(
                    writer,
                    "{{\"detail\":\"request line exceeds {MAX_LINE} \
                     bytes\",\"error\":\"bad-request\",\"ok\":false}}"
                );
                // framing is lost; drop the connection — but drain what
                // the peer already sent first, so close() is a clean FIN
                // and not an RST that could destroy the error response
                // in flight (bounded: 1 MiB or one poll tick of silence)
                let mut sink = [0u8; 4096];
                let mut drained = 0usize;
                while drained < (1 << 20) {
                    match reader.get_mut().read(&mut sink) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => drained += n,
                    }
                }
                return;
            }
            ReadOutcome::Line(line) => {
                let line = line.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                telemetry.bump(Counter::Requests);
                let (response, outcome) = handler.handle(line);
                // Fault-injection boundary (`OSDP_FAULTS` sock-reset):
                // tear the response mid-line and slam the connection —
                // the client sees a truncated, non-newline-terminated
                // fragment. Injected *after* handle_line_full so all
                // accounting for the request is already done, exactly
                // like a real reset between serve and flush.
                if crate::util::faults::sock_reset_fires() {
                    let torn = &response.as_bytes()[..response.len() / 2];
                    let _ = writer.write_all(torn);
                    let _ = writer.flush();
                    // the verb's server-side effects already happened;
                    // a torn `shutdown` ack must still shut down or
                    // chaos could make the server immortal
                    if matches!(outcome, LineOutcome::Shutdown)
                        && !shutdown.swap(true, Ordering::SeqCst)
                    {
                        let _ = TcpStream::connect(addr);
                    }
                    return;
                }
                if writeln!(writer, "{response}").is_err()
                    || writer.flush().is_err()
                {
                    return;
                }
                match outcome {
                    LineOutcome::Continue => {}
                    LineOutcome::Quit => {
                        // An HTTP-framed answer (the metrics endpoint)
                        // closes after one response, but the client's
                        // remaining header lines are still unread — a
                        // bare close would RST and could destroy the
                        // page in flight. Drain briefly so the close
                        // is a clean FIN (bounded: 1 MiB or ~5 ms of
                        // silence).
                        if response.starts_with("HTTP/") {
                            let s = reader.get_mut();
                            let _ = s.set_read_timeout(Some(
                                Duration::from_millis(5),
                            ));
                            let mut sink = [0u8; 4096];
                            let mut drained = 0usize;
                            while drained < (1 << 20) {
                                match s.read(&mut sink) {
                                    Ok(0) | Err(_) => break,
                                    Ok(n) => drained += n,
                                }
                            }
                        }
                        return;
                    }
                    LineOutcome::Shutdown => {
                        // flag first, then wake the acceptor exactly
                        // like Frontend::shutdown — this worker then
                        // drains the queue like any other
                        if !shutdown.swap(true, Ordering::SeqCst) {
                            let _ = TcpStream::connect(addr);
                        }
                        return;
                    }
                }
            }
        }
    }
}

/// Assemble one `\n`-terminated line from a polling reader, charging
/// wait time against the idle budget and watching the shutdown flag.
/// Time spent *receiving* a partial line still counts as idle — a
/// trickling client cannot hold a worker forever.
fn read_request_line<R: Read>(
    reader: &mut BufReader<R>,
    shutdown: &AtomicBool,
    idle_timeout: Duration,
) -> ReadOutcome {
    let mut line: Vec<u8> = Vec::new();
    let started = Instant::now();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return ReadOutcome::Shutdown;
        }
        match reader.fill_buf() {
            Ok([]) => return ReadOutcome::Eof,
            Ok(buf) => {
                let (chunk, newline) = match buf.iter().position(|&b| b == b'\n') {
                    Some(i) => (&buf[..i], true),
                    None => (buf, false),
                };
                if line.len() + chunk.len() > MAX_LINE {
                    let used = chunk.len() + usize::from(newline);
                    reader.consume(used);
                    return ReadOutcome::TooLong;
                }
                line.extend_from_slice(chunk);
                let used = chunk.len() + usize::from(newline);
                reader.consume(used);
                if newline {
                    return match String::from_utf8(line) {
                        Ok(s) => ReadOutcome::Line(s),
                        Err(_) => ReadOutcome::TooLong,
                    };
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if started.elapsed() >= idle_timeout {
                    return ReadOutcome::IdleTimeout;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return ReadOutcome::Error,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_is_fifo_and_bounded() {
        let ch: Channel<u32> = Channel::bounded(2);
        ch.send(1).unwrap();
        ch.send(2).unwrap();
        assert_eq!(ch.len(), 2);
        assert_eq!(ch.recv(), Some(1));
        assert_eq!(ch.recv(), Some(2));
        assert!(ch.is_empty());
    }

    #[test]
    fn channel_send_blocks_at_capacity_until_recv() {
        let ch: Arc<Channel<u32>> = Arc::new(Channel::bounded(1));
        ch.send(1).unwrap();
        let ch2 = Arc::clone(&ch);
        let t = thread::spawn(move || ch2.send(2).is_ok());
        thread::sleep(Duration::from_millis(30));
        assert_eq!(ch.len(), 1, "second send must be parked");
        assert_eq!(ch.recv(), Some(1));
        assert!(t.join().unwrap(), "parked send completes after recv");
        assert_eq!(ch.recv(), Some(2));
    }

    #[test]
    fn channel_try_send_sheds_when_full_or_closed() {
        let ch: Channel<u32> = Channel::bounded(1);
        assert_eq!(ch.try_send(1), Ok(()));
        assert_eq!(ch.try_send(2), Err(2), "full channel sheds, no block");
        assert_eq!(ch.recv(), Some(1));
        ch.close();
        assert_eq!(ch.try_send(3), Err(3), "closed channel refuses");
    }

    #[test]
    fn channel_close_drains_then_ends() {
        let ch: Channel<u32> = Channel::bounded(4);
        ch.send(7).unwrap();
        ch.close();
        assert_eq!(ch.send(8), Err(8), "send after close refuses");
        assert_eq!(ch.recv(), Some(7), "queued items drain after close");
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn channel_close_wakes_blocked_receivers() {
        let ch: Arc<Channel<u32>> = Arc::new(Channel::bounded(1));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let ch = Arc::clone(&ch);
                thread::spawn(move || ch.recv())
            })
            .collect();
        thread::sleep(Duration::from_millis(30));
        ch.close();
        for h in handles {
            assert_eq!(h.join().unwrap(), None);
        }
    }

    #[test]
    fn channel_survives_a_panic_while_holding_the_queue_lock() {
        let ch: Arc<Channel<u32>> = Arc::new(Channel::bounded(4));
        ch.send(1).unwrap();
        // poison the state mutex the way a panicking worker would:
        // die while holding it
        let ch2 = Arc::clone(&ch);
        let _ = thread::spawn(move || {
            let _guard = ch2.state.lock().unwrap();
            panic!("worker died holding the queue lock");
        })
        .join();
        assert!(ch.state.lock().is_err(), "the mutex really is poisoned");
        // every entry point must keep working: send, len, recv, and a
        // blocked recv woken by close
        ch.send(2).unwrap();
        assert_eq!(ch.len(), 2);
        assert_eq!(ch.recv(), Some(1));
        assert_eq!(ch.recv(), Some(2));
        let ch3 = Arc::clone(&ch);
        let blocked = thread::spawn(move || ch3.recv());
        thread::sleep(Duration::from_millis(30));
        ch.close();
        assert_eq!(blocked.join().unwrap(), None);
    }

    #[test]
    fn read_line_assembles_across_small_buffers() {
        let shutdown = AtomicBool::new(false);
        let data: &[u8] = b"query setting=x batch=1\nstats\n";
        let mut r = BufReader::with_capacity(4, data);
        let ReadOutcome::Line(l) =
            read_request_line(&mut r, &shutdown, Duration::from_secs(1))
        else {
            panic!("expected a line");
        };
        assert_eq!(l, "query setting=x batch=1");
        let ReadOutcome::Line(l) =
            read_request_line(&mut r, &shutdown, Duration::from_secs(1))
        else {
            panic!("expected a second line");
        };
        assert_eq!(l, "stats");
        assert!(matches!(
            read_request_line(&mut r, &shutdown, Duration::from_secs(1)),
            ReadOutcome::Eof
        ));
    }

    #[test]
    fn read_line_rejects_oversized_and_shutdown() {
        let shutdown = AtomicBool::new(false);
        let big = vec![b'x'; MAX_LINE + 2];
        let mut r = BufReader::new(&big[..]);
        assert!(matches!(
            read_request_line(&mut r, &shutdown, Duration::from_secs(1)),
            ReadOutcome::TooLong
        ));
        shutdown.store(true, Ordering::SeqCst);
        let mut r = BufReader::new(&b"pending"[..]);
        assert!(matches!(
            read_request_line(&mut r, &shutdown, Duration::from_secs(1)),
            ReadOutcome::Shutdown
        ));
    }
}
