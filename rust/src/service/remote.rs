//! The second cache tier: a standalone plan-cache server and the
//! hardened client that wires it underneath [`super::PlanService`].
//!
//! ## Server
//!
//! [`CacheServerHandler`] implements [`LineHandler`], so `osdp
//! cache-serve` reuses the front-end acceptor/worker/framing machinery
//! verbatim (bounded queues, idle timeouts, torn-write fault injection,
//! graceful shutdown). The grammar is newline-delimited, one JSON line
//! back per request:
//!
//! ```text
//! get <canonical request line>      -> {"hit":true,"entry":{...}} | {"hit":false}
//! put {"key":...,"req":...,...}     -> {"kind":"stored"} | {"error":"bad-request"}
//! near <struct-hex> <k>             -> {"entries":[{...},...]}
//! stats                             -> {"kind":"stats","entries":N,...}
//! quit | shutdown                   -> acknowledged, then acted on
//! ```
//!
//! Entries are exactly the versioned choice-vector-only format the L1
//! disk cache persists — `schema` + `epoch` + the [`cache::value_to_json`]
//! payload — keyed by the canonical [`super::server::request_line`]. The
//! server validates every `put` wholesale (wrong epoch, wrong schema,
//! unparseable vectors are rejected, never stored), so a healthy server
//! can only ever serve entries that were valid *when stored*; the client
//! still re-validates on fetch because the server may be lying.
//!
//! ## Client
//!
//! [`RemoteTier`] is read-through / write-behind under the service's L1:
//!
//! - every remote operation runs under a hard **deadline budget**
//!   (connect + write + read all share it; a slow-loris server that
//!   trickles bytes is cut off when the budget runs out),
//! - reads are single-shot (the deadline *is* the budget — retrying a
//!   read would multiply worst-case query latency); the **write-behind**
//!   path retries through [`BackoffPolicy`] since it burns no caller's
//!   clock,
//! - consecutive failures trip a **circuit breaker**
//!   (closed → open → half-open): while open, every operation is
//!   `Skipped` at zero cost, so a dead remote bounds added per-query
//!   latency at `threshold × deadline` over the whole outage,
//! - puts ride a bounded [`Channel`] drained by one writer thread;
//!   a full queue sheds the put (`try_send`) rather than block a query,
//! - a fetched entry is **quarantined** (demoted to a miss) unless its
//!   schema and epoch match, its key equals the requested key, and its
//!   value kind matches the key shape. Garbage never propagates.
//!
//! None of this can change an answer: a remote hit stores a choice
//! vector whose costs re-derive through `Profiler::evaluate`, and a
//! remote *candidate* (the `near` verb) is only ever offered as a
//! warm-start seed, which provably prunes without changing the
//! `(time, lex)` optimum. Any failure demotes to the local-only path.

use super::cache::{self, CachedValue};
use super::frontend::{Channel, LineHandler};
use super::key::{CACHE_SCHEMA_VERSION, COST_MODEL_EPOCH, QueryKey, QueryShape};
use super::server::LineOutcome;
use crate::util::backoff::BackoffPolicy;
use crate::util::json::{self, Json};
use crate::util::sync::lock_recover;
use std::collections::BTreeMap;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Longest request or response line either side will process.
const MAX_LINE: usize = super::frontend::MAX_LINE;

/// Cap on `near` fan-out, whatever the client asks for.
const NEAR_CAP: usize = 16;

// ---------------------------------------------------------------------------
// Wire format: one entry object, shared by put/get/near.
// ---------------------------------------------------------------------------

/// Serialize one cache entry for the wire: the L1 value payload plus
/// the identifying and versioning fields.
pub fn entry_to_json(key: &QueryKey, value: &CachedValue, req: &str) -> Json {
    let mut o = match cache::value_to_json(value) {
        Json::Obj(o) => o,
        _ => BTreeMap::new(),
    };
    o.insert("key".into(), Json::Str(key.id()));
    o.insert("req".into(), Json::Str(req.into()));
    o.insert("schema".into(), Json::Num(CACHE_SCHEMA_VERSION as f64));
    o.insert("epoch".into(), Json::Num(COST_MODEL_EPOCH as f64));
    Json::Obj(o)
}

/// Parse and validate one wire entry: schema and epoch must match this
/// build, the key id must parse, and the value kind must be consistent
/// with the key shape (a `plan` for a batch key, a `sweep` for a sweep
/// key). Anything else is `None` — the caller quarantines it.
pub fn entry_from_json(v: &Json) -> Option<(QueryKey, String, CachedValue)> {
    if v.get("schema").as_usize()? != CACHE_SCHEMA_VERSION as usize
        || v.get("epoch").as_usize()? != COST_MODEL_EPOCH as usize
    {
        return None;
    }
    let key = QueryKey::from_id(v.get("key").as_str()?)?;
    let req = v.get("req").as_str()?.to_string();
    if req.is_empty() {
        return None;
    }
    let value = cache::value_from_json(v)?;
    let consistent = match (&key.shape, &value) {
        (QueryShape::Batch(_), CachedValue::Plan { .. }) => true,
        (QueryShape::Sweep { .. }, CachedValue::Sweep { .. }) => true,
        (_, CachedValue::Infeasible) => true,
        _ => false,
    };
    consistent.then_some((key, req, value))
}

// ---------------------------------------------------------------------------
// Server side.
// ---------------------------------------------------------------------------

struct StoreSlot {
    key_id: String,
    entry: Json,
    last_used: u64,
}

/// The server's LRU entry store, keyed by the canonical request line.
struct CacheStore {
    cap: usize,
    map: HashMap<String, StoreSlot>,
    tick: u64,
}

impl CacheStore {
    fn new(cap: usize) -> CacheStore {
        CacheStore { cap: cap.max(1), map: HashMap::new(), tick: 0 }
    }

    fn get(&mut self, req: &str) -> Option<&Json> {
        self.tick += 1;
        let tick = self.tick;
        let slot = self.map.get_mut(req)?;
        slot.last_used = tick;
        Some(&slot.entry)
    }

    fn put(&mut self, key_id: String, req: String, entry: Json) {
        self.tick += 1;
        let tick = self.tick;
        self.map.insert(req, StoreSlot { key_id, entry, last_used: tick });
        while self.map.len() > self.cap {
            let victim = self
                .map
                .iter()
                .min_by_key(|(r, s)| (s.last_used, r.clone()))
                .map(|(r, _)| r.clone());
            match victim {
                Some(r) => {
                    self.map.remove(&r);
                }
                None => break,
            }
        }
    }

    /// Entries whose key shares `struct_hex` and holds a plain `plan`
    /// payload, ordered by key id for determinism. The *client* ranks
    /// them properly (it knows the target batch and memory limit); the
    /// server only narrows the candidate set.
    fn near(&self, struct_hex: &str, k: usize) -> Vec<&Json> {
        let prefix = format!("{struct_hex}-");
        let mut hits: Vec<(&String, &StoreSlot)> = self
            .map
            .values()
            .filter(|s| {
                s.key_id.starts_with(&prefix)
                    && s.entry.get("kind").as_str() == Some("plan")
            })
            .map(|s| (&s.key_id, s))
            .collect();
        hits.sort_by_key(|(id, _)| (*id).clone());
        hits.into_iter().take(k.min(NEAR_CAP)).map(|(_, s)| &s.entry).collect()
    }
}

/// The cache server's protocol handler: plugs into
/// [`super::Frontend::start_with`] behind the standard transport.
pub struct CacheServerHandler {
    store: Mutex<CacheStore>,
    gets: AtomicU64,
    hits: AtomicU64,
    puts: AtomicU64,
    bad_puts: AtomicU64,
    nears: AtomicU64,
}

impl CacheServerHandler {
    pub fn new(capacity: usize) -> CacheServerHandler {
        CacheServerHandler {
            store: Mutex::new(CacheStore::new(capacity)),
            gets: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            puts: AtomicU64::new(0),
            bad_puts: AtomicU64::new(0),
            nears: AtomicU64::new(0),
        }
    }

    pub fn entries(&self) -> usize {
        lock_recover(&self.store).map.len()
    }

    fn render_stats(&self) -> String {
        let mut o = BTreeMap::new();
        o.insert("ok".into(), Json::Bool(true));
        o.insert("kind".into(), Json::Str("stats".into()));
        o.insert("entries".into(), Json::Num(self.entries() as f64));
        o.insert("gets".into(),
                 Json::Num(self.gets.load(Ordering::Relaxed) as f64));
        o.insert("hits".into(),
                 Json::Num(self.hits.load(Ordering::Relaxed) as f64));
        o.insert("puts".into(),
                 Json::Num(self.puts.load(Ordering::Relaxed) as f64));
        o.insert("bad_puts".into(),
                 Json::Num(self.bad_puts.load(Ordering::Relaxed) as f64));
        o.insert("nears".into(),
                 Json::Num(self.nears.load(Ordering::Relaxed) as f64));
        json::to_string(&Json::Obj(o))
    }
}

fn bad_request(detail: &str) -> String {
    let mut o = BTreeMap::new();
    o.insert("ok".into(), Json::Bool(false));
    o.insert("error".into(), Json::Str("bad-request".into()));
    o.insert("detail".into(), Json::Str(detail.into()));
    json::to_string(&Json::Obj(o))
}

impl LineHandler for CacheServerHandler {
    fn handle(&self, line: &str) -> (String, LineOutcome) {
        let line = line.trim();
        let (verb, rest) = match line.split_once(char::is_whitespace) {
            Some((v, r)) => (v, r.trim()),
            None => (line, ""),
        };
        match verb {
            "get" => {
                self.gets.fetch_add(1, Ordering::Relaxed);
                let mut o = BTreeMap::new();
                o.insert("ok".into(), Json::Bool(true));
                o.insert("kind".into(), Json::Str("entry".into()));
                match lock_recover(&self.store).get(rest) {
                    Some(entry) if !rest.is_empty() => {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        o.insert("hit".into(), Json::Bool(true));
                        o.insert("entry".into(), entry.clone());
                    }
                    _ => {
                        o.insert("hit".into(), Json::Bool(false));
                    }
                }
                (json::to_string(&Json::Obj(o)), LineOutcome::Continue)
            }
            "put" => {
                self.puts.fetch_add(1, Ordering::Relaxed);
                let parsed = Json::parse(rest)
                    .ok()
                    .and_then(|doc| {
                        entry_from_json(&doc).map(|(k, r, _)| (k, r, doc))
                    });
                match parsed {
                    Some((key, req, doc)) => {
                        lock_recover(&self.store).put(key.id(), req, doc);
                        (
                            r#"{"kind":"stored","ok":true}"#.to_string(),
                            LineOutcome::Continue,
                        )
                    }
                    None => {
                        self.bad_puts.fetch_add(1, Ordering::Relaxed);
                        (
                            bad_request("put: not a valid cache entry"),
                            LineOutcome::Continue,
                        )
                    }
                }
            }
            "near" => {
                self.nears.fetch_add(1, Ordering::Relaxed);
                let mut parts = rest.split_whitespace();
                let (hex, k) = match (parts.next(), parts.next()) {
                    (Some(h), Some(k)) => match k.parse::<usize>() {
                        Ok(k) => (h, k),
                        Err(_) => {
                            return (
                                bad_request("near: k is not a number"),
                                LineOutcome::Continue,
                            )
                        }
                    },
                    _ => {
                        return (
                            bad_request("near: want <struct-hex> <k>"),
                            LineOutcome::Continue,
                        )
                    }
                };
                let store = lock_recover(&self.store);
                let entries: Vec<Json> =
                    store.near(hex, k).into_iter().cloned().collect();
                let mut o = BTreeMap::new();
                o.insert("ok".into(), Json::Bool(true));
                o.insert("kind".into(), Json::Str("near".into()));
                o.insert("entries".into(), Json::Arr(entries));
                (json::to_string(&Json::Obj(o)), LineOutcome::Continue)
            }
            "stats" => (self.render_stats(), LineOutcome::Continue),
            "quit" | "exit" => (
                r#"{"kind":"bye","ok":true}"#.to_string(),
                LineOutcome::Quit,
            ),
            "shutdown" => (
                r#"{"kind":"shutdown","ok":true}"#.to_string(),
                LineOutcome::Shutdown,
            ),
            "" => (bad_request("empty request"), LineOutcome::Continue),
            other => (
                bad_request(&format!("unknown verb `{other}`")),
                LineOutcome::Continue,
            ),
        }
    }
}

// ---------------------------------------------------------------------------
// Client side.
// ---------------------------------------------------------------------------

/// Remote-tier knobs. Defaults keep a healthy remote cheap (single-digit
/// millisecond budget) and a dead one cheaper (breaker trips after a
/// handful of consecutive failures, probes once per cooldown).
#[derive(Debug, Clone)]
pub struct RemoteConfig {
    /// `host:port` of the cache server.
    pub addr: String,
    /// Hard budget per remote operation: connect + write + read.
    pub deadline: Duration,
    /// Consecutive failures before the breaker opens.
    pub breaker_threshold: u32,
    /// How long the breaker stays open before admitting one probe.
    pub cooldown: Duration,
    /// Write-behind queue bound; a full queue sheds puts.
    pub queue_cap: usize,
    /// Retry schedule for write-behind puts (reads never retry).
    pub backoff: BackoffPolicy,
}

impl RemoteConfig {
    pub fn new(addr: &str) -> RemoteConfig {
        RemoteConfig {
            addr: addr.to_string(),
            deadline: Duration::from_millis(5),
            breaker_threshold: 3,
            cooldown: Duration::from_millis(250),
            queue_cap: 64,
            backoff: BackoffPolicy::new(3, 2, 16, 0x0d5e_c0de),
        }
    }
}

/// What one remote read produced. Everything except `Hit` demotes to an
/// L1 miss; nothing here is ever an error to the caller.
#[derive(Debug, Clone, PartialEq)]
pub enum RemoteOutcome {
    /// A validated entry for exactly the requested key.
    Hit(CachedValue),
    /// The server answered: it does not have the entry.
    Miss,
    /// The deadline budget ran out (connect, write, read, or slow-loris).
    Timeout,
    /// Connect/IO failure, EOF mid-response, or oversized response.
    Error,
    /// The server answered with bytes that failed validation.
    Garbage,
    /// The breaker is open (or the address never resolved): no I/O done.
    Skipped,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RemoteErr {
    Timeout,
    Io,
}

/// Circuit breaker: closed (counting consecutive failures) → open
/// (shedding at zero cost) → half-open (one probe after the cooldown).
enum BreakerState {
    Closed { fails: u32 },
    Open { since: Instant },
    HalfOpen,
}

struct Shared {
    cfg: RemoteConfig,
    addr: Option<SocketAddr>,
    breaker: Mutex<BreakerState>,
    errors: AtomicU64,
    timeouts: AtomicU64,
    breaker_open: AtomicU64,
}

impl Shared {
    /// May this operation touch the wire? Open→half-open transition
    /// happens here: after the cooldown exactly one caller is admitted
    /// as the probe; everyone else keeps shedding until it reports.
    fn admit(&self) -> bool {
        let mut st = lock_recover(&self.breaker);
        match &*st {
            BreakerState::Closed { .. } => true,
            BreakerState::Open { since } => {
                if since.elapsed() >= self.cfg.cooldown {
                    *st = BreakerState::HalfOpen;
                    true
                } else {
                    false
                }
            }
            BreakerState::HalfOpen => false,
        }
    }

    fn on_ok(&self) {
        *lock_recover(&self.breaker) = BreakerState::Closed { fails: 0 };
    }

    fn on_fail(&self) {
        let mut st = lock_recover(&self.breaker);
        let open = match &mut *st {
            BreakerState::Closed { fails } => {
                *fails += 1;
                *fails >= self.cfg.breaker_threshold
            }
            BreakerState::HalfOpen => true,
            BreakerState::Open { .. } => return,
        };
        if open {
            *st = BreakerState::Open { since: Instant::now() };
            self.breaker_open.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn state_label(&self) -> &'static str {
        match &*lock_recover(&self.breaker) {
            BreakerState::Closed { .. } => "closed",
            BreakerState::Open { .. } => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }

    /// One request/response exchange under the deadline budget. The
    /// fault hooks fire *before* any I/O so chaos runs cost exactly
    /// what the fault models (a slow server burns the remaining budget,
    /// an I/O fault is instant).
    fn roundtrip(&self, line: &str) -> Result<String, RemoteErr> {
        let Some(addr) = self.addr else { return Err(RemoteErr::Io) };
        let started = Instant::now();
        let deadline = self.cfg.deadline;
        let remaining = |started: Instant| {
            deadline
                .checked_sub(started.elapsed())
                .filter(|d| !d.is_zero())
        };
        if crate::util::faults::remote_io_fails() {
            return Err(RemoteErr::Io);
        }
        if crate::util::faults::remote_slow_fires() {
            // a slow server costs exactly the remaining budget, no more
            if let Some(left) = remaining(started) {
                std::thread::sleep(left);
            }
            return Err(RemoteErr::Timeout);
        }
        let map_io = |e: std::io::Error| match e.kind() {
            std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock => {
                RemoteErr::Timeout
            }
            _ => RemoteErr::Io,
        };
        let Some(budget) = remaining(started) else {
            return Err(RemoteErr::Timeout);
        };
        let stream = TcpStream::connect_timeout(&addr, budget).map_err(map_io)?;
        let _ = stream.set_nodelay(true);
        let Some(budget) = remaining(started) else {
            return Err(RemoteErr::Timeout);
        };
        let _ = stream.set_write_timeout(Some(budget));
        (&stream).write_all(line.as_bytes()).map_err(map_io)?;
        (&stream).write_all(b"\n").map_err(map_io)?;
        // read one line, re-arming the socket timeout with whatever
        // budget is left each pass: a slow-loris peer that trickles a
        // byte per recv cannot stretch the call past the deadline
        let mut reader = BufReader::new(&stream);
        let mut buf: Vec<u8> = Vec::new();
        loop {
            let Some(budget) = remaining(started) else {
                return Err(RemoteErr::Timeout);
            };
            let _ = stream.set_read_timeout(Some(budget));
            match reader.fill_buf() {
                Ok([]) => return Err(RemoteErr::Io), // EOF before newline
                Ok(chunk) => {
                    if let Some(i) = chunk.iter().position(|&b| b == b'\n') {
                        buf.extend_from_slice(&chunk[..i]);
                        reader.consume(i + 1);
                        break;
                    }
                    buf.extend_from_slice(chunk);
                    let n = chunk.len();
                    reader.consume(n);
                    if buf.len() > MAX_LINE {
                        return Err(RemoteErr::Io);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(map_io(e)),
            }
        }
        String::from_utf8(buf).map_err(|_| RemoteErr::Io)
    }
}

/// The L2 client owned by a [`super::PlanService`]. All methods are
/// non-blocking beyond the deadline budget and never return errors —
/// a [`RemoteOutcome`] says what happened and the caller's counters
/// record it.
pub struct RemoteTier {
    shared: Arc<Shared>,
    queue: Arc<Channel<String>>,
    /// Puts accepted but not yet fully processed by the writer thread
    /// (queued + in-flight) — what [`RemoteTier::flush`] waits on.
    pending: Arc<AtomicU64>,
    writer: Option<JoinHandle<()>>,
}

impl RemoteTier {
    /// Resolve the address once and start the write-behind thread. A
    /// hostname that never resolves yields a tier that `Skip`s
    /// everything — degraded, not fatal, exactly like a dead server.
    pub fn start(cfg: RemoteConfig) -> RemoteTier {
        let addr = cfg
            .addr
            .to_socket_addrs()
            .ok()
            .and_then(|mut it| it.next());
        let queue = Arc::new(Channel::bounded(cfg.queue_cap.max(1)));
        let shared = Arc::new(Shared {
            cfg,
            addr,
            breaker: Mutex::new(BreakerState::Closed { fails: 0 }),
            errors: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            breaker_open: AtomicU64::new(0),
        });
        let pending = Arc::new(AtomicU64::new(0));
        let writer = {
            let shared = Arc::clone(&shared);
            let queue = Arc::clone(&queue);
            let pending = Arc::clone(&pending);
            std::thread::spawn(move || {
                while let Some(line) = queue.recv() {
                    if shared.admit() {
                        let out = shared.cfg.backoff.retry(
                            |_| shared.roundtrip(&line).map(drop),
                            |_| {},
                        );
                        match out {
                            Ok(()) => shared.on_ok(),
                            Err(RemoteErr::Timeout) => {
                                shared
                                    .timeouts
                                    .fetch_add(1, Ordering::Relaxed);
                                shared.on_fail();
                            }
                            Err(RemoteErr::Io) => {
                                shared.errors.fetch_add(1, Ordering::Relaxed);
                                shared.on_fail();
                            }
                        }
                    } // else: breaker open, shed the put
                    pending.fetch_sub(1, Ordering::Release);
                }
            })
        };
        RemoteTier { shared, queue, pending, writer: Some(writer) }
    }

    /// Read-through lookup for exactly `key`, addressed by its
    /// canonical request line.
    pub fn get(&self, key: &QueryKey, req_line: &str) -> RemoteOutcome {
        let shared = &self.shared;
        if shared.addr.is_none() || !shared.admit() {
            return RemoteOutcome::Skipped;
        }
        match shared.roundtrip(&format!("get {req_line}")) {
            Err(RemoteErr::Timeout) => {
                shared.timeouts.fetch_add(1, Ordering::Relaxed);
                shared.on_fail();
                RemoteOutcome::Timeout
            }
            Err(RemoteErr::Io) => {
                shared.errors.fetch_add(1, Ordering::Relaxed);
                shared.on_fail();
                RemoteOutcome::Error
            }
            Ok(resp) => {
                // the transport worked: the breaker tracks availability,
                // so even a garbage payload counts as the server being up
                shared.on_ok();
                let resp = if crate::util::faults::remote_garbage_fires() {
                    mangle(&resp)
                } else {
                    resp
                };
                parse_get_response(&resp, key)
            }
        }
    }

    /// Warm-start candidates near `key`: `plan` entries sharing its
    /// structural fingerprint, re-validated and re-ranked locally by
    /// batch distance then memory distance (the same order the L1
    /// neighbor scan uses). Failures return no candidates — a warm
    /// start is an optimization, never worth an error.
    pub fn near(&self, key: &QueryKey, k: usize) -> Vec<(Vec<usize>, usize)> {
        let shared = &self.shared;
        if k == 0 || shared.addr.is_none() || !shared.admit() {
            return Vec::new();
        }
        let line = format!("near {} {}", key.structure.hex(), k.min(NEAR_CAP));
        let resp = match shared.roundtrip(&line) {
            Err(RemoteErr::Timeout) => {
                shared.timeouts.fetch_add(1, Ordering::Relaxed);
                shared.on_fail();
                return Vec::new();
            }
            Err(RemoteErr::Io) => {
                shared.errors.fetch_add(1, Ordering::Relaxed);
                shared.on_fail();
                return Vec::new();
            }
            Ok(resp) => {
                shared.on_ok();
                if crate::util::faults::remote_garbage_fires() {
                    mangle(&resp)
                } else {
                    resp
                }
            }
        };
        let Ok(doc) = Json::parse(&resp) else { return Vec::new() };
        if doc.get("ok").as_bool() != Some(true) {
            return Vec::new();
        }
        let Some(arr) = doc.get("entries").as_arr() else {
            return Vec::new();
        };
        let target_b = match key.shape {
            QueryShape::Batch(b) => b,
            QueryShape::Sweep { max_batch } => max_batch,
        };
        let target_mem = key.mem_limit();
        let mut ranked: Vec<((usize, u64, usize, u64), Vec<usize>)> = Vec::new();
        for e in arr {
            let Some((ekey, _req, value)) = entry_from_json(e) else {
                continue;
            };
            if ekey.structure != key.structure || ekey == *key {
                continue;
            }
            let (QueryShape::Batch(nb), CachedValue::Plan { choice }) =
                (ekey.shape, value)
            else {
                continue;
            };
            // rank mirrors PlanCache::neighbors: batch distance, then
            // memory distance, then the deterministic tiebreaks
            let mem_dist = (ekey.mem_limit() - target_mem).abs().to_bits();
            ranked.push((
                (nb.abs_diff(target_b), mem_dist, nb, ekey.mem_limit_bits),
                choice,
            ));
        }
        ranked.sort_by(|a, b| a.0.cmp(&b.0));
        ranked
            .into_iter()
            .take(k)
            .map(|((_, _, nb, _), choice)| (choice, nb))
            .collect()
    }

    /// Write-behind store: serialize now, enqueue, return immediately.
    /// A full queue sheds the entry — the remote tier is best-effort
    /// and must never block or slow a query.
    pub fn put(&self, key: &QueryKey, value: &CachedValue, req: &str) {
        if self.shared.addr.is_none() {
            return;
        }
        let line =
            format!("put {}", json::to_string(&entry_to_json(key, value, req)));
        self.pending.fetch_add(1, Ordering::Acquire);
        if self.queue.try_send(line).is_err() {
            self.pending.fetch_sub(1, Ordering::Release);
        }
    }

    /// Block until every accepted put has been fully processed —
    /// queued *and* in-flight (tests and CI cross-instance sharing;
    /// bounded by `timeout`).
    pub fn flush(&self, timeout: Duration) {
        let started = Instant::now();
        while self.pending.load(Ordering::Acquire) > 0
            && started.elapsed() < timeout
        {
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    pub fn errors(&self) -> u64 {
        self.shared.errors.load(Ordering::Relaxed)
    }

    pub fn timeouts(&self) -> u64 {
        self.shared.timeouts.load(Ordering::Relaxed)
    }

    pub fn breaker_open_count(&self) -> u64 {
        self.shared.breaker_open.load(Ordering::Relaxed)
    }

    pub fn breaker_state(&self) -> &'static str {
        self.shared.state_label()
    }
}

impl Drop for RemoteTier {
    fn drop(&mut self) {
        // drain what's queued (recv keeps yielding after close), then
        // reap the writer so a one-shot CLI's puts land before exit
        self.queue.close();
        if let Some(w) = self.writer.take() {
            let _ = w.join();
        }
    }
}

/// Corrupt a fetched payload deterministically: a control byte up
/// front guarantees the JSON parse fails, the truncated tail models a
/// torn response.
fn mangle(resp: &str) -> String {
    format!("\u{1}garbage {}", &resp[..resp.len() / 2])
}

fn parse_get_response(resp: &str, key: &QueryKey) -> RemoteOutcome {
    let Ok(doc) = Json::parse(resp) else { return RemoteOutcome::Garbage };
    if doc.get("ok").as_bool() != Some(true) {
        return RemoteOutcome::Garbage;
    }
    match doc.get("hit").as_bool() {
        Some(false) => RemoteOutcome::Miss,
        Some(true) => match entry_from_json(doc.get("entry")) {
            Some((ekey, _req, value)) if ekey == *key => {
                RemoteOutcome::Hit(value)
            }
            _ => RemoteOutcome::Garbage,
        },
        None => RemoteOutcome::Garbage,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::key::StructKey;

    fn key(b: usize) -> QueryKey {
        QueryKey {
            structure: StructKey([3, 4]),
            mem_limit_bits: 8e9f64.to_bits(),
            shape: QueryShape::Batch(b),
        }
    }

    fn entry_line(k: &QueryKey, v: &CachedValue, req: &str) -> String {
        json::to_string(&entry_to_json(k, v, req))
    }

    #[test]
    fn entry_roundtrips_and_rejects_wrong_versions() {
        let k = key(4);
        let v = CachedValue::Plan { choice: vec![0, 1] };
        let doc = entry_to_json(&k, &v, "plan mem:8000000000 batch:4");
        let (k2, req, v2) = entry_from_json(&doc).expect("roundtrip");
        assert_eq!(k2, k);
        assert_eq!(req, "plan mem:8000000000 batch:4");
        assert_eq!(v2, v);

        let mut o = match doc.clone() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        o.insert("epoch".into(), Json::Num(999.0));
        assert!(entry_from_json(&Json::Obj(o.clone())).is_none());
        o.insert("epoch".into(), Json::Num(COST_MODEL_EPOCH as f64));
        o.insert("schema".into(), Json::Num(999.0));
        assert!(entry_from_json(&Json::Obj(o)).is_none());
    }

    #[test]
    fn entry_rejects_shape_kind_mismatch() {
        // a sweep payload under a batch key is structural garbage
        let sweep = CachedValue::Sweep { choices: vec![vec![0]], best: 0 };
        let mut doc = entry_to_json(&key(4), &sweep, "r");
        if let Json::Obj(o) = &mut doc {
            o.insert("key".into(), Json::Str(key(4).id()));
        }
        assert!(entry_from_json(&doc).is_none());
        // infeasible is fine under any shape
        let doc = entry_to_json(&key(4), &CachedValue::Infeasible, "r");
        assert!(entry_from_json(&doc).is_some());
    }

    #[test]
    fn handler_speaks_the_grammar() {
        let h = CacheServerHandler::new(8);
        let k = key(4);
        let v = CachedValue::Plan { choice: vec![1, 2] };
        let req = "plan mem:8000000000 batch:4";

        let (resp, out) = h.handle(&format!("get {req}"));
        assert_eq!(out, LineOutcome::Continue);
        let doc = Json::parse(&resp).unwrap();
        assert_eq!(doc.get("hit").as_bool(), Some(false));

        let (resp, _) = h.handle(&format!("put {}", entry_line(&k, &v, req)));
        assert!(resp.contains("stored"), "{resp}");
        assert_eq!(h.entries(), 1);

        let (resp, _) = h.handle(&format!("get {req}"));
        let doc = Json::parse(&resp).unwrap();
        assert_eq!(doc.get("hit").as_bool(), Some(true));
        let (k2, _, v2) = entry_from_json(doc.get("entry")).unwrap();
        assert_eq!((k2, v2), (k, v.clone()));

        // malformed and version-skewed puts are rejected, never stored
        let (resp, _) = h.handle("put {not json");
        assert!(resp.contains("bad-request"));
        let skew = entry_line(&k, &v, req).replace(
            &format!("\"epoch\":{COST_MODEL_EPOCH}"),
            "\"epoch\":999",
        );
        let (resp, _) = h.handle(&format!("put {skew}"));
        assert!(resp.contains("bad-request"));
        assert_eq!(h.entries(), 1);

        let (resp, _) = h.handle("stats");
        let doc = Json::parse(&resp).unwrap();
        assert_eq!(doc.get("entries").as_usize(), Some(1));
        assert_eq!(doc.get("puts").as_usize(), Some(3));
        assert_eq!(doc.get("bad_puts").as_usize(), Some(2));

        let (_, out) = h.handle("quit");
        assert_eq!(out, LineOutcome::Quit);
        let (_, out) = h.handle("shutdown");
        assert_eq!(out, LineOutcome::Shutdown);
        let (resp, _) = h.handle("warp 9");
        assert!(resp.contains("bad-request"));
    }

    #[test]
    fn handler_near_filters_by_structure_and_kind() {
        let h = CacheServerHandler::new(8);
        for (b, choice) in [(2, vec![0, 0]), (8, vec![1, 1])] {
            let k = key(b);
            let line = entry_line(
                &k,
                &CachedValue::Plan { choice },
                &format!("plan mem:8000000000 batch:{b}"),
            );
            let (resp, _) = h.handle(&format!("put {line}"));
            assert!(resp.contains("stored"));
        }
        // an infeasible entry and a foreign structure must not surface
        let (resp, _) = h.handle(&format!(
            "put {}",
            entry_line(&key(3), &CachedValue::Infeasible, "r3")
        ));
        assert!(resp.contains("stored"));
        let hex = key(2).structure.hex();
        let (resp, _) = h.handle(&format!("near {hex} 8"));
        let doc = Json::parse(&resp).unwrap();
        let entries = doc.get("entries").as_arr().unwrap();
        assert_eq!(entries.len(), 2, "{resp}");
        let (resp, _) = h.handle("near deadbeef 4");
        let doc = Json::parse(&resp).unwrap();
        assert_eq!(doc.get("entries").as_arr().unwrap().len(), 0);
        let (resp, _) = h.handle("near");
        assert!(resp.contains("bad-request"));
    }

    #[test]
    fn store_evicts_least_recently_used() {
        let mut s = CacheStore::new(2);
        s.put("k1".into(), "r1".into(), Json::Null);
        s.put("k2".into(), "r2".into(), Json::Null);
        assert!(s.get("r1").is_some()); // refresh r1
        s.put("k3".into(), "r3".into(), Json::Null);
        assert!(s.get("r1").is_some());
        assert!(s.get("r2").is_none(), "LRU victim");
        assert!(s.get("r3").is_some());
    }

    #[test]
    fn breaker_walks_closed_open_halfopen_closed() {
        let mut cfg = RemoteConfig::new("127.0.0.1:1");
        cfg.breaker_threshold = 2;
        cfg.cooldown = Duration::from_millis(5);
        let shared = Shared {
            cfg,
            addr: None,
            breaker: Mutex::new(BreakerState::Closed { fails: 0 }),
            errors: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            breaker_open: AtomicU64::new(0),
        };
        assert_eq!(shared.state_label(), "closed");
        shared.on_fail();
        assert_eq!(shared.state_label(), "closed");
        assert!(shared.admit());
        shared.on_fail();
        assert_eq!(shared.state_label(), "open");
        assert_eq!(shared.breaker_open.load(Ordering::Relaxed), 1);
        assert!(!shared.admit(), "open sheds before the cooldown");
        std::thread::sleep(Duration::from_millis(6));
        assert!(shared.admit(), "cooldown admits one probe");
        assert_eq!(shared.state_label(), "half-open");
        assert!(!shared.admit(), "only one probe at a time");
        shared.on_ok();
        assert_eq!(shared.state_label(), "closed");
        // a failed probe re-opens and counts another transition
        shared.on_fail();
        shared.on_fail();
        std::thread::sleep(Duration::from_millis(6));
        assert!(shared.admit());
        shared.on_fail();
        assert_eq!(shared.state_label(), "open");
        assert_eq!(shared.breaker_open.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn unresolvable_tier_skips_everything() {
        let tier = RemoteTier::start(RemoteConfig::new("not a host"));
        let k = key(4);
        assert_eq!(tier.get(&k, "plan"), RemoteOutcome::Skipped);
        assert!(tier.near(&k, 4).is_empty());
        tier.put(&k, &CachedValue::Infeasible, "plan");
        tier.flush(Duration::from_millis(50));
        assert_eq!(tier.errors(), 0, "no I/O ever attempted");
    }

    #[test]
    fn mangled_payload_never_parses() {
        let resp = r#"{"hit":false,"kind":"entry","ok":true}"#;
        assert_eq!(
            parse_get_response(&mangle(resp), &key(4)),
            RemoteOutcome::Garbage
        );
    }
}
