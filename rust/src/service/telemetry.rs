//! Service telemetry: lock-free monotonic counters and fixed-bucket
//! latency histograms for the served planner (the router-telemetry
//! pattern — per-request counters plus a latency histogram per query
//! shape — sized for a hot path: every record is a handful of relaxed
//! atomic increments, no locks, no allocation).
//!
//! The split of responsibilities with [`super::ServiceStats`]:
//!
//! * `ServiceStats` counts what the **service core** did (cache hits and
//!   misses, coalesced followers, planner runs, warm-start accepts and
//!   rejects, saved infeasibility probes). It lives under the service's
//!   mutex because its transitions must be atomic with the cache
//!   operations they describe.
//! * [`Telemetry`] counts what the **wire surface** saw (connections,
//!   requests, malformed lines, query verdicts) and how long each query
//!   took, shape by shape. It is updated outside any lock, from
//!   whichever worker thread handled the request.
//!
//! Both surface through the protocol's `stats` verb and the `--metrics`
//! dump ([`render_metrics`] — one JSON document, stable field names, the
//! bucket bounds spelled out so downstream scrapers need no side
//! channel).
//!
//! Invariant the tests pin (telemetry-consistency, see
//! `rust/tests/service_frontend.rs`): every dispatched query is recorded
//! exactly once, so `histogram count == queries` per shape, and — since
//! the service counts one hit or one miss per query that reaches the
//! cache — `hits + misses == queries − rejected` (rejected = requests
//! that failed validation before the cache: unknown setting, invalid
//! cluster, bad parameters).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Histogram bucket upper bounds, in seconds. Fixed at compile time so
/// two deployments (or two CI runs) always bin identically; the final
/// implicit bucket catches everything above the last bound. Spacing is
/// roughly 1-3-10: cache hits land in the microsecond buckets, warm and
/// cold searches in the millisecond-to-second decades.
pub const LATENCY_BUCKETS_S: [f64; 11] = [
    1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0,
];

/// Bucket count including the overflow bucket.
pub const N_BUCKETS: usize = LATENCY_BUCKETS_S.len() + 1;

/// One fixed-bucket latency histogram (cumulative counts are derived at
/// render time; storage is per-bucket).
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    /// Sum in nanoseconds: saturating, monotonic, and exact far beyond
    /// any plausible service lifetime (2^64 ns ≈ 584 years).
    sum_ns: AtomicU64,
}

impl Histogram {
    /// A fresh all-zero histogram (pub: the tracer's per-span duration
    /// histograms reuse the bucket ladder).
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    /// Index of the bucket a latency falls in (the first bound it does
    /// not exceed; the overflow bucket otherwise).
    pub fn bucket_of(seconds: f64) -> usize {
        LATENCY_BUCKETS_S
            .iter()
            .position(|&b| seconds <= b)
            .unwrap_or(LATENCY_BUCKETS_S.len())
    }

    pub fn observe(&self, seconds: f64) {
        let s = if seconds.is_finite() && seconds >= 0.0 {
            seconds
        } else {
            0.0
        };
        self.buckets[Histogram::bucket_of(s)]
            .fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns
            .fetch_add((s * 1e9) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of observed seconds (nanosecond-exact accumulation).
    pub fn sum_s(&self) -> f64 {
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Per-bucket counts (same order as [`LATENCY_BUCKETS_S`], overflow
    /// last).
    pub fn snapshot(&self) -> [u64; N_BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Bucket-resolution quantile estimate: the upper bound of the first
    /// bucket whose cumulative count reaches `q * count` (the overflow
    /// bucket reports the last finite bound). `None` on an empty
    /// histogram.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let total = self.count();
        if total == 0 {
            return None;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64)
            .clamp(1, total);
        let mut cum = 0u64;
        for (i, c) in self.snapshot().iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Some(
                    *LATENCY_BUCKETS_S
                        .get(i)
                        .unwrap_or(LATENCY_BUCKETS_S.last().unwrap()),
                );
            }
        }
        Some(*LATENCY_BUCKETS_S.last().unwrap())
    }

    fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert(
            "bounds_s".into(),
            Json::Arr(LATENCY_BUCKETS_S.iter().map(|&b| Json::Num(b))
                          .collect()),
        );
        o.insert(
            "counts".into(),
            Json::Arr(self.snapshot().iter().map(|&c| Json::Num(c as f64))
                          .collect()),
        );
        o.insert("count".into(), Json::Num(self.count() as f64));
        o.insert(
            "sum_s".into(),
            Json::Num(self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9),
        );
        for (name, q) in [("p50_s", 0.5), ("p99_s", 0.99)] {
            if let Some(v) = self.quantile(q) {
                o.insert(name.into(), Json::Num(v));
            }
        }
        Json::Obj(o)
    }
}

/// The wire-surface counters. Names are the stable metric names the
/// `stats` verb and `--metrics` dump expose (README documents them).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// TCP connections accepted by the front-end.
    Connections,
    /// Connections dropped for exceeding the idle read timeout.
    ConnTimeouts,
    /// Protocol lines received (every verb, well-formed or not).
    Requests,
    /// Lines that failed to parse (unknown verb, bad parameter).
    BadRequests,
    /// `query`/`sweep` requests dispatched to the service.
    Queries,
    /// Queries rejected before planning (unknown setting, invalid
    /// cluster, out-of-bounds parameters) — these never reach the cache.
    Rejected,
    /// Queries answered with a (possibly cached) infeasibility verdict.
    Infeasible,
    /// Epoch-bump warm-up replans that completed.
    WarmupReplans,
    /// Warm-up candidates that failed to re-plan (unparseable request or
    /// planning error).
    WarmupFailures,
    /// Worker threads resurrected after a panic unwound their dispatch
    /// loop (the pool never shrinks; each restart is one panic
    /// survived).
    WorkerRestarts,
    /// `accept(2)` failures in the front-end's acceptor loop. Transient
    /// ones (EMFILE pressure, aborted handshakes) just tick this;
    /// [`super::frontend::FATAL_ACCEPT_ERRORS`] *consecutive* failures
    /// end the listener and fire its teardown hook.
    AcceptErrors,
}

const N_COUNTERS: usize = 11;

impl Counter {
    const ALL: [Counter; N_COUNTERS] = [
        Counter::Connections,
        Counter::ConnTimeouts,
        Counter::Requests,
        Counter::BadRequests,
        Counter::Queries,
        Counter::Rejected,
        Counter::Infeasible,
        Counter::WarmupReplans,
        Counter::WarmupFailures,
        Counter::WorkerRestarts,
        Counter::AcceptErrors,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Counter::Connections => "connections",
            Counter::ConnTimeouts => "conn_timeouts",
            Counter::Requests => "requests",
            Counter::BadRequests => "bad_requests",
            Counter::Queries => "queries",
            Counter::Rejected => "rejected",
            Counter::Infeasible => "infeasible",
            Counter::WarmupReplans => "warmup_replans",
            Counter::WarmupFailures => "warmup_failures",
            Counter::WorkerRestarts => "worker_restarts",
            Counter::AcceptErrors => "accept_errors",
        }
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// Which latency lane a dispatched request observes into. `Replan`
/// covers the `replan` verb (single replan and every capacity-sweep
/// rung) — before it existed replans folded into the batch/sweep lanes
/// and elastic re-planning latency was invisible.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObservedShape {
    Batch,
    Sweep,
    Replan,
}

/// Wire-surface telemetry: one instance per serving process, shared by
/// every worker thread (all methods take `&self`; everything inside is
/// atomic).
pub struct Telemetry {
    counters: [AtomicU64; N_COUNTERS],
    /// Latency of single-batch (`query`) requests.
    pub batch_latency: Histogram,
    /// Latency of `sweep` requests.
    pub sweep_latency: Histogram,
    /// Latency of `replan` requests (each capacity-sweep rung counts
    /// once, like any other dispatched query).
    pub replan_latency: Histogram,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry {
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            batch_latency: Histogram::new(),
            sweep_latency: Histogram::new(),
            replan_latency: Histogram::new(),
        }
    }

    pub fn bump(&self, c: Counter) {
        self.counters[c as usize].fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(&self, c: Counter) -> u64 {
        self.counters[c as usize].load(Ordering::Relaxed)
    }

    /// Record one dispatched query: shape-binned latency plus the
    /// verdict counters. Exactly one call per `PlanService::query`
    /// dispatch — the telemetry-consistency invariant
    /// (`batch + sweep + replan` histogram counts `== queries`) depends
    /// on it.
    pub fn observe_query(
        &self,
        shape: ObservedShape,
        seconds: f64,
        outcome: &Result<super::QueryResponse, super::PlanError>,
    ) {
        self.bump(Counter::Queries);
        match shape {
            ObservedShape::Batch => self.batch_latency.observe(seconds),
            ObservedShape::Sweep => self.sweep_latency.observe(seconds),
            ObservedShape::Replan => self.replan_latency.observe(seconds),
        }
        match outcome {
            Ok(_) => {}
            Err(super::PlanError::Infeasible { .. }) => {
                self.bump(Counter::Infeasible);
            }
            // Internal faults (a panicked flight leader, a poisoned
            // coalescer slot) are neither a client rejection nor a
            // verdict: the query already counted its cache miss, so
            // bumping `Rejected` here would break the pinned
            // `hits + misses == queries − rejected` invariant.
            Err(super::PlanError::Internal(_)) => {}
            Err(_) => self.bump(Counter::Rejected),
        }
    }

    /// Total queries recorded (both shapes).
    pub fn queries(&self) -> u64 {
        self.get(Counter::Queries)
    }

    /// The telemetry section of the `stats` verb / `--metrics` document.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        for c in Counter::ALL {
            o.insert(c.name().into(), Json::Num(self.get(c) as f64));
        }
        let mut lat = BTreeMap::new();
        lat.insert("batch".into(), self.batch_latency.to_json());
        lat.insert("sweep".into(), self.sweep_latency.to_json());
        lat.insert("replan".into(), self.replan_latency.to_json());
        o.insert("latency".into(), Json::Obj(lat));
        Json::Obj(o)
    }

    /// The three latency lanes as (shape label, histogram).
    pub fn latency_lanes(&self) -> [(&'static str, &Histogram); 3] {
        [("batch", &self.batch_latency),
         ("sweep", &self.sweep_latency),
         ("replan", &self.replan_latency)]
    }
}

impl Default for Telemetry {
    fn default() -> Self {
        Telemetry::new()
    }
}

/// The full metrics document: service-core counters + wire telemetry in
/// one JSON object (`osdp serve --metrics` prints it on shutdown; the
/// front-end bench records its frontend section next to it).
pub fn render_metrics(
    stats: &super::ServiceStats,
    cache_entries: usize,
    telemetry: &Telemetry,
    breaker: &str,
) -> String {
    let mut o = BTreeMap::new();
    o.insert("kind".into(), Json::Str("metrics".into()));
    o.insert("cache_entries".into(), Json::Num(cache_entries as f64));
    o.insert("breaker".into(), Json::Str(breaker.into()));
    let mut svc = BTreeMap::new();
    for (name, v) in stats.fields() {
        svc.insert(name.into(), Json::Num(v as f64));
    }
    o.insert("service".into(), Json::Obj(svc));
    o.insert("telemetry".into(), telemetry.to_json());
    crate::util::json::to_string(&Json::Obj(o))
}

fn prom_histogram(out: &mut String, metric: &str, label_key: &str,
                  label_val: &str, h: &Histogram) {
    let label = format!("{label_key}=\"{label_val}\"");
    let mut cum = 0u64;
    for (i, c) in h.snapshot().iter().enumerate() {
        cum += c;
        let le = match LATENCY_BUCKETS_S.get(i) {
            Some(b) => format!("{b}"),
            None => "+Inf".into(),
        };
        out.push_str(&format!(
            "{metric}_bucket{{{label},le=\"{le}\"}} {cum}\n"));
    }
    out.push_str(&format!("{metric}_sum{{{label}}} {}\n", h.sum_s()));
    out.push_str(&format!("{metric}_count{{{label}}} {}\n", h.count()));
}

/// Prometheus text exposition (version 0.0.4) of everything the `stats`
/// verb reports, plus the tracer's per-span duration histograms. Metric
/// names (README "Observability" documents them):
///
/// * `osdp_service_<field>_total` — every [`super::ServiceStats`]
///   counter, including the PR-8 remote-tier counters (`remote_hits`,
///   `remote_errors`, `breaker_open`, ...); values are **identical** to
///   the `stats` verb's `service` section, pinned by the integration
///   tests.
/// * `osdp_net_<name>_total` — every wire [`Counter`], identical to the
///   `stats` verb's `telemetry` section.
/// * `osdp_cache_entries` (gauge), `osdp_breaker_state{state=...}`
///   (one-hot gauge).
/// * `osdp_latency_seconds{shape="batch"|"sweep"|"replan"}` and
///   `osdp_span_seconds{span=<SPAN_NAMES>}` — histograms with
///   cumulative `_bucket{le=...}` / `_sum` / `_count` series.
pub fn render_prometheus(
    stats: &super::ServiceStats,
    cache_entries: usize,
    telemetry: &Telemetry,
    breaker: &str,
    spans: &[(&'static str, Histogram)],
) -> String {
    let mut out = String::new();
    out.push_str("# TYPE osdp_service counter\n");
    for (name, v) in stats.fields() {
        out.push_str(&format!("osdp_service_{name}_total {v}\n"));
    }
    out.push_str("# TYPE osdp_net counter\n");
    for c in Counter::ALL {
        out.push_str(&format!("osdp_net_{}_total {}\n", c.name(),
                              telemetry.get(c)));
    }
    out.push_str("# TYPE osdp_cache_entries gauge\n");
    out.push_str(&format!("osdp_cache_entries {cache_entries}\n"));
    out.push_str("# TYPE osdp_breaker_state gauge\n");
    for s in ["closed", "open", "half-open"] {
        out.push_str(&format!("osdp_breaker_state{{state=\"{s}\"}} {}\n",
                              u64::from(s == breaker)));
    }
    out.push_str("# TYPE osdp_latency_seconds histogram\n");
    for (shape, h) in telemetry.latency_lanes() {
        prom_histogram(&mut out, "osdp_latency_seconds", "shape", shape, h);
    }
    out.push_str("# TYPE osdp_span_seconds histogram\n");
    for (span, h) in spans {
        prom_histogram(&mut out, "osdp_span_seconds", "span", span, h);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_bin_and_quantile_estimates() {
        assert_eq!(Histogram::bucket_of(0.0), 0);
        assert_eq!(Histogram::bucket_of(1e-5), 0);
        assert_eq!(Histogram::bucket_of(1.1e-5), 1);
        assert_eq!(Histogram::bucket_of(0.5), 10);
        assert_eq!(Histogram::bucket_of(2.0), 11, "overflow bucket");
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), None, "empty histogram");
        for _ in 0..98 {
            h.observe(2e-5); // bucket 1 (<= 3e-5)
        }
        h.observe(0.02); // bucket 7 (<= 3e-2)
        h.observe(5.0); // overflow
        assert_eq!(h.count(), 100);
        let snap = h.snapshot();
        assert_eq!(snap[1], 98);
        assert_eq!(snap[7], 1);
        assert_eq!(snap[N_BUCKETS - 1], 1);
        assert_eq!(h.quantile(0.5), Some(3e-5));
        assert_eq!(h.quantile(0.99), Some(3e-2));
        // the overflow bucket quotes the last finite bound
        assert_eq!(h.quantile(1.0), Some(1.0));
    }

    #[test]
    fn observe_is_total_on_hostile_inputs() {
        let h = Histogram::new();
        h.observe(f64::NAN);
        h.observe(-1.0);
        h.observe(f64::INFINITY);
        assert_eq!(h.count(), 3, "every observation lands somewhere");
    }

    #[test]
    fn counters_round_trip_names() {
        let t = Telemetry::new();
        t.bump(Counter::Requests);
        t.bump(Counter::Requests);
        t.bump(Counter::BadRequests);
        assert_eq!(t.get(Counter::Requests), 2);
        assert_eq!(t.get(Counter::BadRequests), 1);
        let doc = t.to_json();
        assert_eq!(doc.get("requests").as_usize(), Some(2));
        assert_eq!(doc.get("bad_requests").as_usize(), Some(1));
        assert_eq!(doc.get("queries").as_usize(), Some(0));
        assert!(doc.get("latency").get("batch").get("counts").as_arr()
                   .is_some());
    }

    #[test]
    fn observe_query_feeds_shape_histograms_and_verdicts() {
        let t = Telemetry::new();
        t.observe_query(ObservedShape::Batch, 1e-4,
                        &Err(super::super::PlanError::Infeasible {
                            batch: Some(1),
                        }));
        t.observe_query(ObservedShape::Sweep, 2.0,
                        &Err(super::super::PlanError::UnknownSetting(
                            "x".into(),
                        )));
        t.observe_query(ObservedShape::Replan, 3e-3,
                        &Err(super::super::PlanError::InvalidCluster(
                            "y".into(),
                        )));
        assert_eq!(t.queries(), 3);
        assert_eq!(t.batch_latency.count(), 1);
        assert_eq!(t.sweep_latency.count(), 1);
        assert_eq!(t.replan_latency.count(), 1);
        // the pinned invariant: every query lands in exactly one lane
        assert_eq!(t.batch_latency.count() + t.sweep_latency.count()
                       + t.replan_latency.count(),
                   t.queries());
        assert_eq!(t.get(Counter::Infeasible), 1);
        assert_eq!(t.get(Counter::Rejected), 2);
        let lanes = t.to_json();
        assert_eq!(lanes.get("latency").get("replan").get("count")
                        .as_usize(),
                   Some(1));
    }

    #[test]
    fn internal_errors_count_as_queries_but_not_verdicts() {
        let t = Telemetry::new();
        t.observe_query(
            ObservedShape::Batch,
            1e-4,
            &Err(super::super::PlanError::Internal("leader panicked".into())),
        );
        assert_eq!(t.queries(), 1);
        assert_eq!(t.batch_latency.count(), 1);
        assert_eq!(t.get(Counter::Rejected), 0, "miss already counted");
        assert_eq!(t.get(Counter::Infeasible), 0);
    }

    #[test]
    fn prometheus_exposition_matches_the_json_document() {
        let t = Telemetry::new();
        t.bump(Counter::Requests);
        t.bump(Counter::Requests);
        t.observe_query(ObservedShape::Batch, 2e-5, &Err(
            super::super::PlanError::Infeasible { batch: None }));
        let stats = super::super::ServiceStats {
            queries: 1,
            misses: 1,
            ..Default::default()
        };
        let spans = [("descent", Histogram::new())];
        spans[0].1.observe(0.5);
        let text = render_prometheus(&stats, 7, &t, "open", &spans);
        let line = |needle: &str| {
            text.lines().find(|l| l.starts_with(needle))
                .unwrap_or_else(|| panic!("missing {needle}"))
                .rsplit(' ').next().unwrap().to_string()
        };
        assert_eq!(line("osdp_service_queries_total "), "1");
        assert_eq!(line("osdp_service_misses_total "), "1");
        assert_eq!(line("osdp_net_requests_total "), "2");
        assert_eq!(line("osdp_cache_entries "), "7");
        assert_eq!(line("osdp_breaker_state{state=\"open\"}"), "1");
        assert_eq!(line("osdp_breaker_state{state=\"closed\"}"), "0");
        assert_eq!(
            line("osdp_latency_seconds_count{shape=\"batch\"}"), "1");
        // buckets are cumulative: the +Inf bucket equals the count
        assert_eq!(
            line("osdp_latency_seconds_bucket{shape=\"batch\",le=\"+Inf\"}"),
            "1");
        assert_eq!(line("osdp_span_seconds_count{span=\"descent\"}"), "1");
        // every ServiceStats field and every wire counter is exposed
        for (name, _) in stats.fields() {
            assert!(text.contains(&format!("osdp_service_{name}_total ")),
                    "missing service field {name}");
        }
        for c in Counter::ALL {
            assert!(text.contains(&format!("osdp_net_{}_total ", c.name())));
        }
    }
}
