//! The **plan service**: OSDP's automated plan search behind a caching,
//! deduplicating, warm-starting service layer — the production-planner
//! architecture (cf. the Apollo router's query planner: a deterministic
//! planning traversal behind a plan cache with planning statistics, or
//! GSPMD's reusable auto-partitioner service) applied to sharded-data-
//! parallel planning. OSDP makes the pattern unusually clean: every
//! search engine returns the **bit-identical** `(time, lex)` optimum at
//! any thread count, so a cached plan, a coalesced plan, and a
//! warm-started plan are all *exactly* the plan a cold search would have
//! produced — property-tested, not hoped.
//!
//! The layers, front to back (each its own module):
//!
//! * [`key`] — canonical query identity: a fingerprint of the Profiler's
//!   bit-exact cost tables (the [`crate::cost::menu::TableKey`]
//!   discipline), the memory limit, and the query shape; versioned by a
//!   cost-model epoch.
//! * [`cache`] — in-memory LRU + optional on-disk JSON persistence;
//!   stores choice vectors only (costs re-derive bit-identically).
//! * [`coalesce`] — single-flight deduplication: N concurrent identical
//!   queries run one planner search.
//! * [`warm`] — cache-miss warm starts from neighbor entries (same
//!   structure, different batch/limit), provably result-preserving.
//! * [`server`] — the line-oriented request protocol behind `osdp serve`
//!   and `osdp query`.
//! * [`remote`] — an optional second cache tier (`osdp cache-serve` +
//!   the `--remote` client): read-through / write-behind under the L1,
//!   deadline-budgeted, circuit-broken, and quarantine-validated so a
//!   dead or lying remote degrades to local-only instead of failing or
//!   corrupting anything.
//!
//! Counters for all of it surface as [`ServiceStats`], alongside the
//! planner's own `DfsStats`/`SweepStats`/`FrontierStats`.

pub mod cache;
pub mod coalesce;
pub mod frontend;
pub mod key;
pub mod remote;
pub mod replan;
pub mod server;
pub mod telemetry;
pub mod trace;
pub mod warm;

pub use cache::{CacheConfig, CachedValue, DiskLoad, PlanCache, StaleEntry};
pub use coalesce::Coalescer;
pub use frontend::{FATAL_ACCEPT_ERRORS, Frontend, FrontendConfig,
                   LineHandler, MetricsHandler, TeardownHook};
pub use key::{COST_MODEL_EPOCH, QueryKey, QueryShape, StructKey};
pub use remote::{CacheServerHandler, RemoteConfig, RemoteOutcome, RemoteTier};
pub use replan::CapacityCandidate;
pub use server::{LineOutcome, Request, handle_line, handle_line_full,
                 request_line, serve_loop, serve_loop_with};
pub use telemetry::{Counter, ObservedShape, Telemetry, render_metrics,
                    render_prometheus};
pub use trace::{Trace, TraceCtx, Tracer};

use crate::config::{Cluster, SearchConfig};
use crate::cost::Profiler;
use crate::model::ModelDesc;
use crate::planner::scheduler::SweepStats;
use crate::planner::{self, DfsStats, Engine, ExecutionPlan, ParallelConfig,
                     Scheduler};
use crate::util::json::Json;
use crate::util::sync::lock_recover;
use std::fmt;
use std::sync::Mutex;

/// Structured failure of a served planning query. Every error the query
/// path can hit maps here — the service never panics on a request, no
/// matter how hostile (property: `rust/tests/plan_service.rs`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlanError {
    /// The search proved (under its node budget) that nothing fits the
    /// memory limit — at the requested batch, or at `b = 1` for sweeps.
    Infeasible { batch: Option<usize> },
    /// The setting names neither a zoo entry nor a valid `gpt:` spec.
    UnknownSetting(String),
    /// The cluster description is invalid or conflicts with a preset.
    InvalidCluster(String),
    /// Malformed or out-of-bounds request parameters.
    BadRequest(String),
    /// A fault inside the service itself (a panicked flight leader, a
    /// poisoned coalescer slot). Distinct from [`PlanError::BadRequest`]
    /// because the *request* was fine: telemetry must not count it as a
    /// rejection (the query already counted its cache miss, and
    /// `hits + misses == queries − rejected` is a pinned invariant).
    Internal(String),
}

impl PlanError {
    /// Stable machine-readable tag for the wire protocol.
    pub fn kind(&self) -> &'static str {
        match self {
            PlanError::Infeasible { .. } => "infeasible",
            PlanError::UnknownSetting(_) => "unknown-setting",
            PlanError::InvalidCluster(_) => "invalid-cluster",
            PlanError::BadRequest(_) => "bad-request",
            PlanError::Internal(_) => "internal",
        }
    }
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::Infeasible { batch: Some(b) } => {
                write!(f, "no feasible plan at b={b} (memory wall)")
            }
            PlanError::Infeasible { batch: None } => {
                write!(f, "no feasible plan at any batch size")
            }
            PlanError::UnknownSetting(s) => {
                write!(f, "unknown setting '{s}' (zoo name or \
                           gpt:vocab,seq,layers,hidden,heads)")
            }
            PlanError::InvalidCluster(m) => write!(f, "invalid cluster: {m}"),
            PlanError::BadRequest(m) => write!(f, "bad request: {m}"),
            PlanError::Internal(m) => write!(f, "internal error: {m}"),
        }
    }
}

impl std::error::Error for PlanError {}

/// Where a served answer came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Source {
    /// Cache hit: no planner ran.
    Cache,
    /// This caller joined another caller's in-flight search.
    Coalesced,
    /// L1 miss served from the remote cache tier: no planner ran.
    Remote,
    /// Cache miss planned with a warm-start incumbent from a neighbor
    /// entry.
    Warm,
    /// Cache miss planned cold.
    Cold,
}

impl Source {
    pub fn label(&self) -> &'static str {
        match self {
            Source::Cache => "cache",
            Source::Coalesced => "coalesced",
            Source::Remote => "remote",
            Source::Warm => "warm",
            Source::Cold => "cold",
        }
    }
}

/// Service-layer counters, surfaced next to the planner's own
/// `DfsStats`/`SweepStats`/`FrontierStats`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Queries answered from the cache.
    pub hits: u64,
    /// Queries that missed the cache (coalesced or planned).
    pub misses: u64,
    /// Cache entries written.
    pub inserts: u64,
    /// Entries evicted by the LRU cap.
    pub evictions: u64,
    /// Entries rejected as stale (epoch/schema mismatch on disk, or a
    /// live entry failing menu validation).
    pub stale_rejected: u64,
    /// Misses that joined another caller's in-flight search.
    pub coalesced: u64,
    /// Actual planner executions (the coalescing denominator).
    pub planner_runs: u64,
    /// Planner runs seeded with a feasible neighbor incumbent.
    pub warm_seeded: u64,
    /// Neighbor candidates rejected as infeasible at the queried
    /// batch/limit (the search then ran cold).
    pub warm_infeasible: u64,
    /// Failed cache persistence attempts (service degrades to
    /// memory-only).
    pub persist_errors: u64,
    /// b=1 completeness re-probes the structured scheduler verdict made
    /// unnecessary (each one used to be a full extra search).
    pub infeasible_probes_saved: u64,
    /// Elastic replans served ([`PlanService::replan`]): an old plan
    /// projected onto a changed cluster and re-searched.
    pub replans: u64,
    /// Replans whose projected seed needed greedy repair (or was
    /// unrepairable) on the new cluster — the old plan did not fit
    /// as-is.
    pub replan_repairs: u64,
    /// Transient cache-write failures absorbed by the bounded retry
    /// loop (each retry that had to happen counts once).
    pub cache_write_retries: u64,
    /// Corrupt disk-cache payloads moved aside to `plan_cache.json.bad`
    /// at startup instead of being served or silently dropped.
    pub quarantined_entries: u64,
    /// L1 misses answered by the remote cache tier (no planner ran).
    pub remote_hits: u64,
    /// Remote lookups the tier answered with "not cached".
    pub remote_misses: u64,
    /// Remote payloads that failed validation (garbage bytes, version
    /// skew, wrong key, menu mismatch) and were demoted to misses.
    pub remote_quarantined: u64,
    /// Remote operations that failed with an I/O error (merged from the
    /// tier's own atomics by [`PlanService::stats`]).
    pub remote_errors: u64,
    /// Remote operations cut off by the deadline budget.
    pub remote_timeouts: u64,
    /// Times the remote circuit breaker tripped open.
    pub breaker_open: u64,
}

impl ServiceStats {
    /// Every counter with its stable wire name (the `stats` verb and
    /// the `--metrics` dump both render from this, so they cannot
    /// drift).
    pub fn fields(&self) -> [(&'static str, u64); 21] {
        [
            ("hits", self.hits),
            ("misses", self.misses),
            ("inserts", self.inserts),
            ("evictions", self.evictions),
            ("stale_rejected", self.stale_rejected),
            ("coalesced", self.coalesced),
            ("planner_runs", self.planner_runs),
            ("warm_seeded", self.warm_seeded),
            ("warm_infeasible", self.warm_infeasible),
            ("persist_errors", self.persist_errors),
            ("infeasible_probes_saved", self.infeasible_probes_saved),
            ("replans", self.replans),
            ("replan_repairs", self.replan_repairs),
            ("cache_write_retries", self.cache_write_retries),
            ("quarantined_entries", self.quarantined_entries),
            ("remote_hits", self.remote_hits),
            ("remote_misses", self.remote_misses),
            ("remote_quarantined", self.remote_quarantined),
            ("remote_errors", self.remote_errors),
            ("remote_timeouts", self.remote_timeouts),
            ("breaker_open", self.breaker_open),
        ]
    }

    /// One-line human summary for CLI/bench reports.
    pub fn describe(&self) -> String {
        format!(
            "{} hits / {} misses ({} coalesced), {} planner runs \
             ({} warm-seeded, {} warm-infeasible), {} inserts, \
             {} evicted, {} stale",
            self.hits,
            self.misses,
            self.coalesced,
            self.planner_runs,
            self.warm_seeded,
            self.warm_infeasible,
            self.inserts,
            self.evictions,
            self.stale_rejected,
        )
    }
}

/// Cluster half of a query: a preset plus the knobs the CLI exposes.
/// Resolution canonicalizes — two spellings of the same hardware produce
/// the same [`Cluster`], hence the same cache key.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    /// `rtx_titan` or `two_server_a100`.
    pub preset: String,
    /// Device count (rtx_titan only; the two-server topology is fixed).
    pub devices: Option<usize>,
    pub mem_gib: f64,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec { preset: "rtx_titan".into(), devices: None,
                      mem_gib: 8.0 }
    }
}

impl ClusterSpec {
    pub fn resolve(&self) -> Result<Cluster, PlanError> {
        if !self.mem_gib.is_finite() || self.mem_gib <= 0.0 {
            return Err(PlanError::BadRequest(
                "mem must be a positive finite GiB value".into(),
            ));
        }
        let cluster = match self.preset.as_str() {
            "rtx_titan" => {
                Cluster::rtx_titan(self.devices.unwrap_or(8), self.mem_gib)
            }
            "two_server_a100" => {
                if let Some(d) = self.devices {
                    if d != 16 {
                        return Err(PlanError::InvalidCluster(format!(
                            "two_server_a100 is a fixed 2x8 topology \
                             (16 devices); got devices={d}"
                        )));
                    }
                }
                Cluster::two_server_a100(self.mem_gib)
            }
            other => {
                return Err(PlanError::InvalidCluster(format!(
                    "unknown preset '{other}' (rtx_titan | two_server_a100)"
                )));
            }
        };
        cluster.validate().map_err(PlanError::InvalidCluster)?;
        Ok(cluster)
    }
}

/// Request caps: a served planner must bound hostile inputs *before*
/// they become candidate-enumeration blowups.
pub const MAX_GRANULARITY: usize = 1024;
pub const MAX_GRANULARITIES: usize = 64;
pub const MAX_QUERY_THREADS: usize = 1024;
/// Largest batch size / sweep cap a request may ask for — a sweep is up
/// to this many full searches, so an unbounded cap would let one
/// request wedge the service (and every caller coalesced onto it).
pub const MAX_QUERY_BATCH: usize = 4096;
const MAX_CUSTOM_LAYERS: usize = 512;
/// Warm-start candidates considered per miss (explicit seed + local
/// neighbors, falling back to remote `near` candidates). Best-of-K by
/// repaired `(time, lex)` — small, because each candidate costs one
/// greedy repair.
const WARM_K: usize = 3;
const MAX_CUSTOM_DIM: usize = 1 << 20;

/// One planning request, shape included. Engine and thread count are
/// perf knobs only — they are *not* part of the cache key, because every
/// engine returns the bit-identical optimum.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanQuery {
    /// Zoo setting (`48L/1024H`) or custom
    /// `gpt:vocab,seq,layers,hidden,heads` spec.
    pub setting: String,
    pub cluster: ClusterSpec,
    pub search: SearchConfig,
    pub shape: QueryShape,
    pub engine: Engine,
    /// Worker threads (0 = hardware default).
    pub threads: usize,
    /// Allow warm-starting from cached neighbors (on by default; the
    /// result is identical either way).
    pub warm: bool,
}

impl PlanQuery {
    /// A single-batch query with the CLI's defaults (`osdp plan`'s
    /// granularity menu `{0, 4}` and the paper's coarse 2-ops/layer
    /// graph — the search space figures in the paper quote).
    pub fn batch(setting: &str, mem_gib: f64, b: usize) -> PlanQuery {
        PlanQuery {
            setting: setting.into(),
            cluster: ClusterSpec { mem_gib, ..Default::default() },
            search: SearchConfig {
                granularities: vec![0, 4],
                paper_granularity: true,
                ..Default::default()
            },
            shape: QueryShape::Batch(b),
            engine: Engine::Frontier,
            threads: 0,
            warm: true,
        }
    }

    /// A sweep query with defaults.
    pub fn sweep(setting: &str, mem_gib: f64, max_batch: usize) -> PlanQuery {
        PlanQuery {
            shape: QueryShape::Sweep { max_batch },
            ..PlanQuery::batch(setting, mem_gib, 1)
        }
    }

    fn validate(&self) -> Result<(), PlanError> {
        match self.shape {
            QueryShape::Batch(0) => {
                return Err(PlanError::BadRequest("batch must be >= 1".into()));
            }
            QueryShape::Sweep { max_batch: 0 } => {
                return Err(PlanError::BadRequest(
                    "batch-cap must be >= 1".into(),
                ));
            }
            QueryShape::Batch(b) | QueryShape::Sweep { max_batch: b }
                if b > MAX_QUERY_BATCH =>
            {
                return Err(PlanError::BadRequest(format!(
                    "batch size {b} too large (max {MAX_QUERY_BATCH})"
                )));
            }
            _ => {}
        }
        if self.search.granularities.len() > MAX_GRANULARITIES {
            return Err(PlanError::BadRequest(format!(
                "too many granularities (max {MAX_GRANULARITIES})"
            )));
        }
        if let Some(&g) = self
            .search
            .granularities
            .iter()
            .find(|&&g| g > MAX_GRANULARITY)
        {
            return Err(PlanError::BadRequest(format!(
                "granularity {g} too large (max {MAX_GRANULARITY})"
            )));
        }
        Ok(())
    }
}

/// Resolve a setting string to a model: a zoo name, or a custom
/// `gpt:vocab,seq,layers,hidden,heads` spec (scriptable and cheap —
/// serve-loop tests plan tiny models through the full stack).
pub fn resolve_setting(setting: &str) -> Result<ModelDesc, PlanError> {
    if let Some(spec) = setting.strip_prefix("gpt:") {
        let parts: Vec<usize> = spec
            .split(',')
            .map(|p| p.trim().parse::<usize>())
            .collect::<Result<_, _>>()
            .map_err(|_| {
                PlanError::BadRequest(format!(
                    "bad gpt spec '{spec}' (want vocab,seq,layers,hidden,\
                     heads)"
                ))
            })?;
        let [vocab, seq, layers, hidden, heads] = parts[..] else {
            return Err(PlanError::BadRequest(format!(
                "gpt spec '{spec}' needs exactly 5 fields"
            )));
        };
        if [vocab, seq, layers, hidden, heads].contains(&0) {
            return Err(PlanError::BadRequest(
                "gpt spec fields must all be >= 1".into(),
            ));
        }
        if layers > MAX_CUSTOM_LAYERS
            || vocab > MAX_CUSTOM_DIM
            || seq > MAX_CUSTOM_DIM
            || hidden > MAX_CUSTOM_DIM
        {
            return Err(PlanError::BadRequest(
                "gpt spec dimension out of range".into(),
            ));
        }
        if hidden % heads != 0 {
            return Err(PlanError::BadRequest(format!(
                "hidden ({hidden}) must be a multiple of heads ({heads})"
            )));
        }
        Ok(crate::model::build_gpt(&crate::model::GptDims::uniform(
            "custom", vocab, seq, layers, hidden, heads,
        )))
    } else {
        crate::model::zoo()
            .into_iter()
            .find(|e| e.setting == setting)
            .map(|e| e.model)
            .ok_or_else(|| PlanError::UnknownSetting(setting.into()))
    }
}

/// A served answer: the plan(s) plus the search diagnostics of the run
/// that produced them (zeroed, `complete`, for cache hits — nothing
/// ran).
#[derive(Debug, Clone)]
pub enum Answer {
    Plan { plan: ExecutionPlan, stats: DfsStats },
    Sweep { plans: Vec<ExecutionPlan>, best: usize, stats: SweepStats },
}

impl Answer {
    /// The headline plan (the sweep's throughput winner).
    pub fn best_plan(&self) -> &ExecutionPlan {
        match self {
            Answer::Plan { plan, .. } => plan,
            Answer::Sweep { plans, best, .. } => &plans[*best],
        }
    }

    /// Total search nodes behind this answer.
    pub fn nodes(&self) -> u64 {
        match self {
            Answer::Plan { stats, .. } => stats.nodes,
            Answer::Sweep { stats, .. } => stats.nodes,
        }
    }
}

/// What an epoch-bump warm-up accomplished ([`PlanService::warm_up`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WarmupReport {
    /// Hottest-K candidates selected for replay.
    pub candidates: usize,
    /// Replays that produced a cacheable verdict (plan or proven wall).
    pub replanned: usize,
    /// Replays that failed (unparseable request, invalid parameters).
    pub failed: usize,
}

/// A successful query: the answer, where it came from, and its key.
#[derive(Debug, Clone)]
pub struct QueryResponse {
    pub answer: Answer,
    pub source: Source,
    pub key: QueryKey,
    /// Devices the throughput figures are quoted over.
    pub n_devices: usize,
    /// Id of this query's trace in the service's ring (`trace <id>`
    /// fetches it); `None` under `--features no_trace`.
    pub trace_id: Option<String>,
}

struct Inner {
    cache: PlanCache,
    stats: ServiceStats,
    /// Unpersisted cache mutations pending (write-behind dirty flag, so
    /// a miss that inserted nothing does not rewrite the disk file).
    dirty: bool,
}

/// What a resolved flight hands every coalesced caller: the cacheable
/// value plus whether the search that produced it ran to completion
/// (followers must not report an anytime result as proven).
type FlightValue = Result<(CachedValue, bool), PlanError>;

/// The served planner: cache + coalescer + warm starts over the existing
/// engines. Thread-safe behind `&self`; one instance serves any number
/// of concurrent callers.
pub struct PlanService {
    inner: Mutex<Inner>,
    coalescer: Coalescer<FlightValue>,
    /// Optional second cache tier (read-through / write-behind). All
    /// remote failures degrade to the local-only path — attaching a
    /// dead or lying remote can never change an answer or fail a query.
    remote: Option<RemoteTier>,
    /// Request-scoped tracing: the completed-trace ring + per-span
    /// duration histograms. Observational only — nothing in the serve
    /// path reads a trace back (see [`trace`]).
    tracer: Tracer,
}

impl PlanService {
    pub fn new(cfg: CacheConfig) -> PlanService {
        PlanService::open(cfg).0
    }

    /// Open a service and surface the warm-up candidates harvested from
    /// an epoch-rejected disk cache (entries whose *values* are stale
    /// but whose request lines can be replayed —
    /// [`PlanService::warm_up`]). [`PlanService::new`] discards them.
    pub fn open(cfg: CacheConfig) -> (PlanService, Vec<StaleEntry>) {
        let (cache, load, harvest) = PlanCache::open(cfg);
        let service = PlanService {
            inner: Mutex::new(Inner {
                cache,
                stats: ServiceStats {
                    stale_rejected: load.stale,
                    quarantined_entries: load.quarantined,
                    ..Default::default()
                },
                dirty: false,
            }),
            coalescer: Coalescer::new(),
            remote: None,
            tracer: Tracer::new(),
        };
        (service, harvest)
    }

    /// Memory-only service with default sizing.
    pub fn in_memory() -> PlanService {
        PlanService::new(CacheConfig::default())
    }

    /// Wire a second cache tier underneath the L1 (`--remote`). Must be
    /// called before the service starts answering queries.
    pub fn attach_remote(&mut self, tier: RemoteTier) {
        self.remote = Some(tier);
    }

    /// The attached remote tier, if any (tests and shutdown draining).
    pub fn remote(&self) -> Option<&RemoteTier> {
        self.remote.as_ref()
    }

    /// Remote circuit-breaker state: `closed`/`open`/`half-open`, or
    /// `none` when no remote tier is attached.
    pub fn breaker_state(&self) -> &'static str {
        self.remote.as_ref().map_or("none", |r| r.breaker_state())
    }

    pub fn stats(&self) -> ServiceStats {
        let mut s = lock_recover(&self.inner).stats;
        // the transport-failure counters live in the tier's atomics
        // (they are bumped off the inner lock's hot path); merge them
        // here so every stats surface sees one consistent struct
        if let Some(r) = &self.remote {
            s.remote_errors = r.errors();
            s.remote_timeouts = r.timeouts();
            s.breaker_open = r.breaker_open_count();
        }
        s
    }

    /// Cached entry count (observability; the `stats` protocol verb).
    pub fn cache_len(&self) -> usize {
        lock_recover(&self.inner).cache.len()
    }

    /// The trace registry (`trace` verbs, `osdp query --trace`, and the
    /// Prometheus span histograms).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Epoch-bump warm-up: replay the hottest `k` queries harvested
    /// from an epoch-rejected disk cache ([`PlanService::open`]),
    /// seeding each with its previous-epoch choice vector, so a
    /// cost-model deploy re-fills the cache *before* the listener
    /// accepts traffic (the router's warm-up-on-schema-reload move).
    /// Ranking is hottest-first, ties broken by request line — fully
    /// deterministic. An infeasible verdict counts as replanned: the
    /// wall is cached knowledge too.
    pub fn warm_up(&self, stale: &[StaleEntry], k: usize,
                   telemetry: Option<&Telemetry>) -> WarmupReport {
        let mut ranked: Vec<&StaleEntry> = stale.iter().collect();
        ranked.sort_by(|a, b| {
            b.hits.cmp(&a.hits).then_with(|| a.request.cmp(&b.request))
        });
        ranked.truncate(k);
        let mut report = WarmupReport {
            candidates: ranked.len(),
            replanned: 0,
            failed: 0,
        };
        for entry in ranked {
            // Each replay is unwind-contained: warm-up runs *before*
            // the listener opens, on the main thread, where a panicked
            // search (e.g. an injected fault) would otherwise abort
            // the whole `osdp serve` startup. A crashed replay is just
            // a failed warm-up candidate.
            let replayed = match server::parse_request(&entry.request) {
                Ok(Request::Query(q)) => std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        matches!(
                            self.query_seeded(&q, Some(&entry.seed)),
                            Ok(_) | Err(PlanError::Infeasible { .. })
                        )
                    }),
                )
                .unwrap_or(false),
                _ => false,
            };
            if replayed {
                report.replanned += 1;
                if let Some(t) = telemetry {
                    t.bump(Counter::WarmupReplans);
                }
            } else {
                report.failed += 1;
                if let Some(t) = telemetry {
                    t.bump(Counter::WarmupFailures);
                }
            }
        }
        report
    }

    /// Answer one query through the cache → coalescer → warm-start →
    /// planner pipeline.
    pub fn query(&self, q: &PlanQuery) -> Result<QueryResponse, PlanError> {
        self.query_seeded(q, None)
    }

    /// [`PlanService::query`] with an explicit warm-start seed (the
    /// epoch-bump warm-up replays old entries seeded with their
    /// previous-epoch choice vectors). A seed only ever *prunes* — the
    /// engines discard an incumbent the moment anything beats it — so
    /// the answer is bit-identical to an unseeded query; an invalid or
    /// infeasible seed is simply ignored.
    pub fn query_seeded(&self, q: &PlanQuery, seed: Option<&[usize]>)
                        -> Result<QueryResponse, PlanError> {
        // Fault-injection boundary (`OSDP_FAULTS`): may sleep, may
        // panic. Deliberately *before* any accounting — an injected
        // crash must leave every counter exactly as if the query had
        // never arrived, so the telemetry invariants survive chaos
        // runs bit-for-bit. A no-op branch when faults are disabled.
        crate::util::faults::on_query_dispatch();
        // Tracing wraps the whole serve. It observes and never decides:
        // the traced path differs from the untraced one only in span
        // bookkeeping (pinned bit-identical in planner_properties.rs),
        // and the root guard closes on every exit — error returns
        // included — so finished traces always have closed trees.
        let ctx = self.tracer.begin();
        let root = ctx.as_ref().map(|c| c.span("query"));
        let mut result = self.query_inner(q, seed, ctx.as_ref());
        drop(root);
        if let Some(ctx) = ctx {
            if let Ok(r) = &mut result {
                r.trace_id = Some(ctx.id());
            }
            self.tracer.finish(ctx);
        }
        result
    }

    fn query_inner(&self, q: &PlanQuery, seed: Option<&[usize]>,
                   ctx: Option<&TraceCtx>)
                   -> Result<QueryResponse, PlanError> {
        let canon = ctx.map(|c| c.span("canonicalize"));
        q.validate()?;
        let cluster = q.cluster.resolve()?;
        let model = resolve_setting(&q.setting)?;
        let profiler = Profiler::new(&model, &cluster, &q.search);
        let key = QueryKey::for_query(&profiler, cluster.mem_limit, q.shape);
        if let Some(c) = ctx {
            // the trace id becomes deterministic here: key fingerprint
            // prefix + the per-process sequence number
            c.set_request(&key.id());
        }
        drop(canon);

        // ---- cache fast path
        {
            let l1 = ctx.map(|c| c.span("cache"));
            let mut guard = lock_recover(&self.inner);
            // reborrow so cache/stats borrows stay field-disjoint
            let inner = &mut *guard;
            if let Some(v) = inner.cache.get(&key) {
                if v.validates_against(&profiler) {
                    let v = v.clone();
                    inner.stats.hits += 1;
                    drop(guard);
                    if let Some(s) = &l1 {
                        s.meta("outcome", Json::Str("hit".into()));
                    }
                    drop(l1);
                    return self.answer_from_value(&profiler, key, v,
                                                  Source::Cache, true);
                }
                // stale live entry (menus changed under the epoch):
                // demote to a miss rather than serve garbage
                inner.cache.remove(&key);
                inner.stats.stale_rejected += 1;
                if let Some(s) = &l1 {
                    s.meta("outcome", Json::Str("stale".into()));
                }
            } else if let Some(s) = &l1 {
                s.meta("outcome", Json::Str("miss".into()));
            }
            inner.stats.misses += 1;
        }

        // ---- single-flight the planner run; a leader that unwinds
        // resolves its flight with the poison error so waiters never
        // hang (coalesce.rs)
        let poison: FlightValue =
            Err(PlanError::Internal("the planning leader panicked".into()));
        let mut led_outcome: Option<(Answer, Source)> = None;
        let (value, led) = self.coalescer.run(&key.id(), poison, || {
            match self.plan_miss(&profiler, q, &key, seed, ctx) {
                Ok((value, complete, answer, source)) => {
                    led_outcome = Some((answer, source));
                    Ok((value, complete))
                }
                Err(e) => Err(e),
            }
        });
        if led {
            let (value, complete) = value?;
            match led_outcome {
                Some((answer, source)) => Ok(QueryResponse {
                    answer,
                    source,
                    key,
                    n_devices: cluster.n_devices,
                    trace_id: None,
                }),
                // unreachable by construction (Ok value implies an
                // outcome); rebuild from the value rather than panic
                None => self.answer_from_value(&profiler, key, value,
                                               Source::Cold, complete),
            }
        } else {
            lock_recover(&self.inner).stats.coalesced += 1;
            let (value, complete) = value?;
            self.answer_from_value(&profiler, key, value,
                                   Source::Coalesced, complete)
        }
    }

    /// The miss path: seed/neighbor lookup → warm-or-cold search →
    /// cache population (plans only when the search ran to completion —
    /// budget-expired results are anytime, not canonical) → one persist.
    fn plan_miss(&self, profiler: &Profiler, q: &PlanQuery, key: &QueryKey,
                 seed: Option<&[usize]>, ctx: Option<&TraceCtx>)
                 -> Result<(CachedValue, bool, Answer, Source), PlanError> {
        // Double-checked cache read: a caller that missed the cache but
        // lost the flight-timing race (its would-be leader finished and
        // retired the flight before this caller reached the coalescer)
        // becomes a new "leader" — it must serve the freshly-cached
        // result, not run a second search. This is what makes "N
        // concurrent identical queries -> exactly one planner
        // execution" a guarantee rather than a likelihood.
        {
            let recheck = ctx.map(|c| {
                let s = c.span("cache");
                s.meta("recheck", Json::Bool(true));
                s
            });
            let mut guard = lock_recover(&self.inner);
            let inner = &mut *guard;
            if let Some(v) = inner.cache.get(key) {
                if v.validates_against(profiler) {
                    let v = v.clone();
                    // reclassify this query: it was counted as a miss
                    // on the outer check, but it is being served from
                    // the cache — keep hits + misses == queries
                    inner.stats.misses -= 1;
                    inner.stats.hits += 1;
                    drop(guard);
                    if let Some(s) = &recheck {
                        s.meta("outcome", Json::Str("hit".into()));
                    }
                    drop(recheck);
                    let answer =
                        self.answer_of(profiler, key, v.clone(), true)?;
                    return Ok((v, true, answer, Source::Cache));
                }
            }
        }
        // ---- L2 read-through: before paying for a planner run, ask
        // the remote tier (when one is attached) for the exact entry,
        // addressed by the canonical request line. A validated hit is
        // a choice vector whose costs re-derive locally, so serving it
        // is bit-identical to the search that populated it; anything
        // less than a validated hit — miss, timeout, I/O error, open
        // breaker, garbage — demotes to the local miss path below.
        if let Some(tier) = &self.remote {
            if let Some(req) = server::request_line(q) {
                let rspan = ctx.map(|c| {
                    let s = c.span("remote");
                    // breaker state *going in* is the decision that
                    // gates the call (`Skipped` = the breaker ate it);
                    // the span's own duration is the deadline spend
                    s.meta("breaker",
                           Json::Str(tier.breaker_state().into()));
                    s
                });
                let note = |label: &str| {
                    if let Some(s) = &rspan {
                        s.meta("outcome", Json::Str(label.into()));
                    }
                };
                match tier.get(key, &req) {
                    RemoteOutcome::Hit(v)
                        if v.validates_against(profiler) =>
                    {
                        note("hit");
                        {
                            let mut guard = lock_recover(&self.inner);
                            let inner = &mut *guard;
                            // reclassify: counted as a miss on the
                            // outer check, served by the second tier —
                            // hits + remote_hits + misses == queries
                            inner.stats.misses -= 1;
                            inner.stats.remote_hits += 1;
                            inner.stats.inserts += 1;
                            inner.stats.evictions +=
                                inner.cache.insert_requested(
                                    *key, v.clone(), Some(req));
                            inner.dirty = true;
                        }
                        drop(rspan);
                        {
                            let _p = ctx.map(|c| c.span("persist"));
                            self.persist();
                        }
                        let answer =
                            self.answer_of(profiler, key, v.clone(), true)?;
                        return Ok((v, true, answer, Source::Remote));
                    }
                    RemoteOutcome::Hit(_) | RemoteOutcome::Garbage => {
                        note("quarantined");
                        // the tier answered, but with an entry this
                        // build cannot trust: never served, only counted
                        lock_recover(&self.inner)
                            .stats
                            .remote_quarantined += 1;
                    }
                    RemoteOutcome::Miss => {
                        note("miss");
                        lock_recover(&self.inner).stats.remote_misses += 1;
                    }
                    RemoteOutcome::Timeout => note("timeout"),
                    RemoteOutcome::Error => note("error"),
                    RemoteOutcome::Skipped => note("skipped"),
                }
            }
        }
        // Warm-start candidates, best-of-K: the explicit seed (a
        // warm-up replay is the *same query's* old answer), the K
        // nearest local neighbors, and — only when the local cache has
        // nothing to offer — the remote tier's `near` candidates. Each
        // candidate is greedy-repaired at the queried limit and the
        // *best repaired incumbent* by `(time, lex)` is offered to the
        // engine. A seed only ever prunes, and best-of-K is at least
        // as tight as any single neighbor, so visited nodes can only
        // shrink relative to the old single-neighbor policy while the
        // answer stays bit-identical.
        let wspan = ctx.map(|c| c.span("warm"));
        let warm_choice = if q.warm {
            let mut candidates: Vec<Vec<usize>> = Vec::new();
            if let Some(s) = seed.filter(|s| {
                CachedValue::Plan { choice: s.to_vec() }
                    .validates_against(profiler)
            }) {
                candidates.push(s.to_vec());
            }
            let local =
                lock_recover(&self.inner).cache.neighbors(key, WARM_K);
            for (choice, _nb) in local {
                if !candidates.contains(&choice) {
                    candidates.push(choice);
                }
            }
            if candidates.is_empty() {
                if let Some(tier) = &self.remote {
                    for (choice, _nb) in tier.near(key, WARM_K) {
                        let valid = CachedValue::Plan {
                            choice: choice.clone(),
                        }
                        .validates_against(profiler);
                        if valid && !candidates.contains(&choice) {
                            candidates.push(choice);
                        }
                    }
                }
            }
            let b_gate = match key.shape {
                QueryShape::Batch(b) => b,
                QueryShape::Sweep { .. } => 1,
            };
            let had_candidates = !candidates.is_empty();
            if let Some(s) = &wspan {
                s.meta("candidates", Json::Num(candidates.len() as f64));
            }
            // (time bits, repaired lex) ranks repaired incumbents the
            // same way the engines rank plans, so "best" is exact
            let mut best: Option<((u64, Vec<usize>), Vec<usize>)> = None;
            for raw in candidates {
                let repair = ctx.map(|c| c.span("repair"));
                let Some((repaired, cost)) = planner::greedy_search_from(
                    profiler,
                    key.mem_limit(),
                    b_gate,
                    &raw,
                ) else {
                    if let Some(s) = &repair {
                        s.meta("feasible", Json::Bool(false));
                    }
                    continue;
                };
                if let Some(s) = &repair {
                    s.meta("feasible", Json::Bool(true));
                    s.meta("moved", Json::Bool(repaired != raw));
                }
                drop(repair);
                let rank = (cost.time.to_bits(), repaired);
                if best.as_ref().map_or(true, |(r, _)| rank < *r) {
                    best = Some((rank, raw));
                }
            }
            if best.is_none() && had_candidates {
                // every candidate was rejected as infeasible at this
                // batch/limit; the search runs cold
                lock_recover(&self.inner).stats.warm_infeasible += 1;
            }
            // Single-batch queries hand the engine the already-repaired
            // seed (its own repair then exits after one feasibility
            // check); sweeps keep the raw winner because every batch of
            // the sweep re-repairs it at its own size.
            best.map(|((_bits, repaired), raw)| match key.shape {
                QueryShape::Batch(_) => repaired,
                QueryShape::Sweep { .. } => raw,
            })
        } else {
            None
        };
        if let Some(s) = &wspan {
            s.meta("seeded", Json::Bool(warm_choice.is_some()));
        }
        drop(wspan);
        let source = if warm_choice.is_some() {
            Source::Warm
        } else {
            Source::Cold
        };
        {
            let mut inner = lock_recover(&self.inner);
            inner.stats.planner_runs += 1;
            if warm_choice.is_some() {
                inner.stats.warm_seeded += 1;
            }
        }
        let threads = match q.threads {
            0 => planner::parallel::default_threads(),
            t => t.min(MAX_QUERY_THREADS),
        };
        // canonical replay line stored beside the entry, so the *next*
        // cost-model epoch can re-plan this traffic before serving
        let req = server::request_line(q);

        // the planner clocks its own phases (prefold/frontier build vs
        // descent) and logs the convergence timeline; both surface as
        // closed spans + the trace's timeline below
        let mut search_trace =
            ctx.map(|_| planner::SearchTrace::default());
        let result = match key.shape {
            QueryShape::Batch(b) => {
                let cfg = ParallelConfig {
                    threads,
                    engine: q.engine,
                    ..Default::default()
                };
                let (outcome, stats) = planner::parallel_search_traced(
                    profiler,
                    key.mem_limit(),
                    b,
                    &cfg,
                    warm_choice.as_deref(),
                    search_trace.as_mut(),
                );
                self.record_search_spans(ctx, search_trace.take(), &stats,
                                         q.engine);
                match outcome {
                    None => {
                        // cache "nothing fits" only when it was proven
                        // (search ran to completion), never when the
                        // node budget expired first — an un-proven
                        // verdict must not poison future queries
                        if stats.complete {
                            self.store(*key, CachedValue::Infeasible,
                                       req);
                        }
                        Err(PlanError::Infeasible { batch: Some(b) })
                    }
                    Some((choice, _cost)) => {
                        let value =
                            CachedValue::Plan { choice: choice.clone() };
                        let complete = stats.complete;
                        if complete {
                            self.store(*key, value.clone(), req);
                        }
                        let plan = ExecutionPlan::from_choice(
                            profiler, choice, b);
                        Ok((value, complete,
                            Answer::Plan { plan, stats }, source))
                    }
                }
            }
            QueryShape::Sweep { max_batch } => {
                let mut sched =
                    Scheduler::new(profiler, key.mem_limit(), max_batch)
                        .with_threads(threads)
                        .with_engine(q.engine);
                if let Some(w) = warm_choice {
                    sched = sched.with_warm(w);
                }
                let sweep_outcome = sched.run_traced(search_trace.as_mut());
                let sweep_stats = match &sweep_outcome {
                    Ok(res) => DfsStats {
                        nodes: res.total_nodes,
                        complete: res.stats.complete,
                        ..DfsStats::default()
                    },
                    Err(inf) => inf.stats.clone(),
                };
                self.record_search_spans(ctx, search_trace.take(),
                                         &sweep_stats, q.engine);
                match sweep_outcome {
                    Err(infeasible) => {
                        // the scheduler's structured verdict carries the
                        // b=1 search's own completeness certificate, so
                        // the proven-wall check reads it directly — the
                        // extra b=1 re-probe this path used to run is
                        // gone (ROADMAP item 7); count the savings
                        self.inner
                            .lock()
                            .unwrap()
                            .stats
                            .infeasible_probes_saved += 1;
                        if infeasible.complete() {
                            self.store(*key, CachedValue::Infeasible,
                                       req);
                        }
                        Err(PlanError::Infeasible { batch: None })
                    }
                    Ok(res) => {
                        let choices: Vec<Vec<usize>> = res
                            .candidates
                            .iter()
                            .map(|c| c.plan.choice.clone())
                            .collect();
                        let value = CachedValue::Sweep {
                            choices: choices.clone(),
                            best: res.best,
                        };
                        if res.stats.complete {
                            self.store(*key, value.clone(), req);
                            // a sweep populates the per-batch entries
                            // (future single-batch queries hit, and
                            // neighbor lookups see every batch) plus the
                            // memory wall it proved; each entry stores
                            // its own shape's replay line
                            let batch_req = |b: usize| {
                                server::request_line(&PlanQuery {
                                    shape: QueryShape::Batch(b),
                                    ..q.clone()
                                })
                            };
                            for (i, ch) in choices.iter().enumerate() {
                                self.store(
                                    key.with_shape(QueryShape::Batch(i + 1)),
                                    CachedValue::Plan { choice: ch.clone() },
                                    batch_req(i + 1),
                                );
                            }
                            // the wall entry needs its own certificate:
                            // the failing search must have run to
                            // completion, not merely out of budget
                            if choices.len() < max_batch
                                && res.wall_complete
                            {
                                self.store(
                                    key.with_shape(QueryShape::Batch(
                                        choices.len() + 1,
                                    )),
                                    CachedValue::Infeasible,
                                    batch_req(choices.len() + 1),
                                );
                            }
                        }
                        let complete = res.stats.complete;
                        let answer = Answer::Sweep {
                            plans: res
                                .candidates
                                .into_iter()
                                .map(|c| c.plan)
                                .collect(),
                            best: res.best,
                            stats: res.stats,
                        };
                        Ok((value, complete, answer, source))
                    }
                }
            }
        };
        {
            let _p = ctx.map(|c| c.span("persist"));
            self.persist();
        }
        result
    }

    /// Surface a finished search's phase clocks and convergence
    /// timeline on the trace: closed `build`/`descent` spans (children
    /// of the root) with the frontier-build shape and node counts as
    /// metadata. No-op untraced.
    fn record_search_spans(&self, ctx: Option<&TraceCtx>,
                           tl: Option<planner::SearchTrace>,
                           stats: &DfsStats, engine: Engine) {
        let (Some(c), Some(tl)) = (ctx, tl) else { return };
        let mut build_meta = Vec::new();
        if let Some(f) = &tl.frontier {
            build_meta.push(("classes".to_string(),
                             Json::Num(f.classes as f64)));
            build_meta.push(("points".to_string(),
                             Json::Num(f.points as f64)));
            build_meta.push(("max_level_width".to_string(),
                             Json::Num(f.max_level_width as f64)));
        }
        c.closed_span("build", tl.build_s, build_meta);
        c.closed_span("descent", tl.descent_s, vec![
            ("engine".to_string(), Json::Str(engine.label().into())),
            ("nodes".to_string(), Json::Num(stats.nodes as f64)),
            ("complete".to_string(), Json::Bool(stats.complete)),
        ]);
        c.set_timeline(tl.timeline);
    }

    fn store(&self, key: QueryKey, value: CachedValue,
             request: Option<String>) {
        {
            let mut guard = lock_recover(&self.inner);
            let inner = &mut *guard;
            inner.stats.inserts += 1;
            inner.stats.evictions +=
                inner.cache.insert_requested(key, value.clone(),
                                             request.clone());
            inner.dirty = true;
        }
        // write-behind to the second tier: serialize and enqueue off
        // the lock; a full queue or open breaker sheds the put
        if let (Some(tier), Some(req)) = (&self.remote, request) {
            tier.put(&key, &value, &req);
        }
    }

    /// Write-behind: rewrite the disk file only when something was
    /// stored since the last successful persist (a miss that cached
    /// nothing — budget expired, double-check hit — costs no I/O). The
    /// image is snapshotted under the lock but *written outside it*, so
    /// a slow disk never stalls concurrent cache hits; the dirty flag
    /// is cleared optimistically and restored on a failed write (and a
    /// store racing the write re-sets it, so its data is re-persisted
    /// next time).
    ///
    /// Transient write failures (a flaky disk, a racing persist whose
    /// rename stole the temp file, an injected `cache-io` fault) get a
    /// bounded retry with short backoff — `cache_write_retries` counts
    /// each one — before the service gives up, restores the dirty flag,
    /// and degrades to memory-only until the next store tries again.
    fn persist(&self) {
        let snapshot = {
            let mut guard = lock_recover(&self.inner);
            let inner = &mut *guard;
            if !inner.dirty {
                return;
            }
            inner.dirty = false;
            inner.cache.serialize()
        };
        let Some((path, doc)) = snapshot else { return };
        // fixed seed: the persist path replays an identical jittered
        // schedule every run, so fault-injected counter tests stay exact
        let policy =
            crate::util::backoff::BackoffPolicy::new(3, 2, 8, 0x9e75);
        let wrote = policy.retry(
            |_| cache::write_cache_file(&path, &doc),
            |_| {
                lock_recover(&self.inner).stats.cache_write_retries += 1;
            },
        );
        if wrote.is_err() {
            let mut guard = lock_recover(&self.inner);
            guard.dirty = true;
            guard.stats.persist_errors += 1;
        }
    }

    /// Rebuild a served answer from a cached or flight-shared value
    /// (hits and coalesced followers). Costs re-derive through
    /// `Profiler::evaluate`, which is deterministic — the response is
    /// bit-identical to the search that populated the entry. `complete`
    /// is the originating search's certificate (always true for real
    /// cache hits, which are only written under it; possibly false for
    /// a coalesced copy of an anytime result — a follower must not
    /// report an unproven plan as proven).
    fn answer_from_value(&self, profiler: &Profiler, key: QueryKey,
                         value: CachedValue, source: Source,
                         complete: bool)
                         -> Result<QueryResponse, PlanError> {
        Ok(QueryResponse {
            answer: self.answer_of(profiler, &key, value, complete)?,
            source,
            key,
            n_devices: profiler.cluster.n_devices,
            trace_id: None,
        })
    }

    /// The served [`Answer`] for a cached value under `key`'s shape
    /// (`Err` for cached infeasibility).
    fn answer_of(&self, profiler: &Profiler, key: &QueryKey,
                 value: CachedValue, complete: bool)
                 -> Result<Answer, PlanError> {
        let served_stats = DfsStats { complete, ..Default::default() };
        let answer = match (value, key.shape) {
            (CachedValue::Infeasible, shape) => {
                let batch = match shape {
                    QueryShape::Batch(b) => Some(b),
                    QueryShape::Sweep { .. } => None,
                };
                return Err(PlanError::Infeasible { batch });
            }
            (CachedValue::Plan { choice }, QueryShape::Batch(b)) => {
                Answer::Plan {
                    plan: ExecutionPlan::from_choice(profiler, choice, b),
                    stats: served_stats,
                }
            }
            (CachedValue::Sweep { choices, best },
             QueryShape::Sweep { .. }) => Answer::Sweep {
                plans: choices
                    .into_iter()
                    .enumerate()
                    .map(|(i, ch)| {
                        ExecutionPlan::from_choice(profiler, ch, i + 1)
                    })
                    .collect(),
                best,
                stats: SweepStats { complete, ..Default::default() },
            },
            // value/shape mismatch: impossible through this service's
            // writes; surface as a structured error, never a panic
            _ => {
                return Err(PlanError::BadRequest(
                    "cache entry shape mismatch".into(),
                ));
            }
        };
        Ok(answer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn errors_have_kinds_and_messages() {
        for (e, kind) in [
            (PlanError::Infeasible { batch: Some(3) }, "infeasible"),
            (PlanError::Infeasible { batch: None }, "infeasible"),
            (PlanError::UnknownSetting("x".into()), "unknown-setting"),
            (PlanError::InvalidCluster("y".into()), "invalid-cluster"),
            (PlanError::BadRequest("z".into()), "bad-request"),
            (PlanError::Internal("w".into()), "internal"),
        ] {
            assert_eq!(e.kind(), kind);
            assert!(!e.to_string().is_empty());
        }
    }

    #[test]
    fn cluster_spec_canonicalizes_and_rejects() {
        let a = ClusterSpec::default().resolve().unwrap();
        let b = ClusterSpec {
            preset: "rtx_titan".into(),
            devices: Some(8),
            mem_gib: 8.0,
        }
        .resolve()
        .unwrap();
        assert_eq!(a, b, "default devices == explicit 8");
        assert!(matches!(
            ClusterSpec { preset: "tpu".into(), ..Default::default() }
                .resolve(),
            Err(PlanError::InvalidCluster(_))
        ));
        assert!(matches!(
            ClusterSpec {
                preset: "two_server_a100".into(),
                devices: Some(8),
                mem_gib: 8.0
            }
            .resolve(),
            Err(PlanError::InvalidCluster(_))
        ));
        for mem in [f64::NAN, f64::INFINITY, 0.0, -1.0] {
            assert!(matches!(
                ClusterSpec { mem_gib: mem, ..Default::default() }.resolve(),
                Err(PlanError::BadRequest(_))
            ), "mem={mem} must be rejected");
        }
        assert!(matches!(
            ClusterSpec { devices: Some(0), ..Default::default() }.resolve(),
            Err(PlanError::InvalidCluster(_))
        ));
    }

    #[test]
    fn settings_resolve_zoo_and_custom() {
        assert!(resolve_setting("48L/1024H").is_ok());
        assert!(matches!(resolve_setting("nope"),
                         Err(PlanError::UnknownSetting(_))));
        let m = resolve_setting("gpt:1000,64,2,128,4").unwrap();
        assert!(m.n_ops() > 0);
        for bad in [
            "gpt:1000,64,2,128",       // too few fields
            "gpt:1000,64,2,128,4,9",   // too many
            "gpt:a,b,c,d,e",           // not numbers
            "gpt:1000,64,0,128,4",     // zero layers
            "gpt:1000,64,2,130,4",     // heads don't divide hidden
            "gpt:1000,64,9999,128,4",  // out of range
        ] {
            assert!(matches!(resolve_setting(bad),
                             Err(PlanError::BadRequest(_))), "{bad}");
        }
    }

    #[test]
    fn query_validation_caps_hostile_inputs() {
        let mut q = PlanQuery::batch("gpt:1000,64,1,128,4", 8.0, 0);
        assert!(matches!(q.validate(), Err(PlanError::BadRequest(_))));
        q.shape = QueryShape::Sweep { max_batch: 0 };
        assert!(matches!(q.validate(), Err(PlanError::BadRequest(_))));
        q.shape = QueryShape::Batch(MAX_QUERY_BATCH + 1);
        assert!(matches!(q.validate(), Err(PlanError::BadRequest(_))));
        q.shape = QueryShape::Sweep { max_batch: MAX_QUERY_BATCH + 1 };
        assert!(matches!(q.validate(), Err(PlanError::BadRequest(_))));
        q.shape = QueryShape::Batch(1);
        q.search.granularities = vec![0, usize::MAX];
        assert!(matches!(q.validate(), Err(PlanError::BadRequest(_))));
        q.search.granularities = vec![0; MAX_GRANULARITIES + 1];
        assert!(matches!(q.validate(), Err(PlanError::BadRequest(_))));
        q.search.granularities = vec![0, 4];
        assert!(q.validate().is_ok());
    }
}
