//! In-flight request coalescing ("single-flight"): N concurrent
//! identical queries run the planner **once**; the other N−1 callers
//! block on the leader's flight and share its result. Sound for OSDP
//! because planning is deterministic and bit-exact — every caller would
//! have computed the same answer, so sharing the leader's is not an
//! approximation.
//!
//! Ordering contract with the cache (see `super::PlanService`): the
//! leader inserts its result into the cache *inside* the computation,
//! before the flight resolves and is retired — so a caller that misses
//! the flight entirely (arrives after retirement) necessarily hits the
//! cache instead of becoming a second leader. The service's query path
//! returns structured `PlanError`s instead of panicking; should a
//! leader unwind anyway, a drop guard resolves its flight with the
//! caller-supplied `poison` value and retires it, so waiters get an
//! error instead of hanging and the key never becomes a permanent tar
//! pit.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

struct Flight<R> {
    slot: Mutex<Option<R>>,
    done: Condvar,
}

/// Single-flight gate, keyed by string (the service uses
/// `QueryKey::id()`).
pub struct Coalescer<R> {
    flights: Mutex<HashMap<String, Arc<Flight<R>>>>,
}

impl<R: Clone> Coalescer<R> {
    pub fn new() -> Coalescer<R> {
        Coalescer { flights: Mutex::new(HashMap::new()) }
    }

    /// Run `compute` under the key, coalescing with any in-flight run of
    /// the same key. Returns `(result, led)`: `led` is true for the one
    /// caller that actually computed; joiners get a clone of the
    /// leader's result. If `compute` unwinds, the flight resolves with
    /// `poison` (waiters see it; the panic still propagates here).
    pub fn run(&self, key: &str, poison: R,
               compute: impl FnOnce() -> R) -> (R, bool) {
        let existing = {
            let mut flights = self.flights.lock().unwrap();
            match flights.get(key) {
                Some(f) => Some(f.clone()),
                None => {
                    let f = Arc::new(Flight {
                        slot: Mutex::new(None),
                        done: Condvar::new(),
                    });
                    flights.insert(key.to_string(), f);
                    None
                }
            }
        };
        match existing {
            Some(flight) => {
                let mut slot = flight.slot.lock().unwrap();
                while slot.is_none() {
                    slot = flight.done.wait(slot).unwrap();
                }
                (slot.clone().expect("flight resolved"), false)
            }
            None => {
                let mut guard =
                    PoisonGuard { coalescer: self, key, poison: Some(poison) };
                let result = compute();
                guard.poison = None; // disarm: normal resolution below
                drop(guard);
                self.resolve(key, result.clone());
                (result, true)
            }
        }
    }

    /// Publish a flight's value (waking every joiner), then retire it.
    /// Publication happens BEFORE retirement: a joiner holding the Arc
    /// wakes with the value; a caller arriving after retirement starts
    /// fresh (and, per the module contract, hits the cache the leader
    /// filled). No-op if the flight is already gone.
    fn resolve(&self, key: &str, value: R) {
        let flight = self.flights.lock().unwrap().get(key).cloned();
        if let Some(f) = flight {
            *f.slot.lock().unwrap() = Some(value);
            f.done.notify_all();
            self.flights.lock().unwrap().remove(key);
        }
    }
}

/// Resolves the leader's flight with the poison value when the compute
/// closure unwinds (armed iff `poison` is still `Some` at drop).
struct PoisonGuard<'a, R: Clone> {
    coalescer: &'a Coalescer<R>,
    key: &'a str,
    poison: Option<R>,
}

impl<'a, R: Clone> Drop for PoisonGuard<'a, R> {
    fn drop(&mut self) {
        if let Some(p) = self.poison.take() {
            self.coalescer.resolve(self.key, p);
        }
    }
}

impl<R: Clone> Default for Coalescer<R> {
    fn default() -> Self {
        Coalescer::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Barrier;

    #[test]
    fn sequential_runs_each_lead() {
        let c: Coalescer<u32> = Coalescer::new();
        let (r1, led1) = c.run("k", 0, || 7);
        let (r2, led2) = c.run("k", 0, || 8);
        assert_eq!((r1, led1), (7, true));
        // the first flight retired, so the second run computes afresh
        assert_eq!((r2, led2), (8, true));
    }

    #[test]
    fn panicking_leader_poisons_waiters_instead_of_stranding_them() {
        let c: Coalescer<i64> = Coalescer::new();
        let entered = AtomicUsize::new(0);
        let release = AtomicUsize::new(0);
        let (leader_panicked, joiner_result) = std::thread::scope(|scope| {
            let leader = scope.spawn(|| {
                std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        c.run("k", -1, || {
                            entered.store(1, Ordering::SeqCst);
                            while release.load(Ordering::SeqCst) == 0 {
                                std::thread::yield_now();
                            }
                            panic!("planner exploded");
                        })
                    }),
                )
                .is_err()
            });
            while entered.load(Ordering::SeqCst) == 0 {
                std::thread::yield_now();
            }
            let joiner = scope.spawn(|| c.run("k", -2, || 99));
            // give the joiner time to attach to the in-flight entry,
            // then let the leader unwind
            std::thread::sleep(std::time::Duration::from_millis(100));
            release.store(1, Ordering::SeqCst);
            (leader.join().unwrap(), joiner.join().unwrap())
        });
        assert!(leader_panicked);
        // the joiner either coalesced onto the doomed flight (leader's
        // poison, led=false) or arrived after it was retired and
        // computed fresh (99, led=true) — it must never hang or see -2
        match joiner_result {
            (-1, false) | (99, true) => {}
            other => panic!("unexpected joiner outcome {other:?}"),
        }
        // the key is not a tar pit: a later run leads normally
        assert_eq!(c.run("k", -3, || 5), (5, true));
    }

    #[test]
    fn concurrent_identical_keys_compute_once() {
        let c: Coalescer<usize> = Coalescer::new();
        let runs = AtomicUsize::new(0);
        let joiners_started = AtomicUsize::new(0);
        let release = AtomicUsize::new(0);
        let results: Vec<(usize, bool)> = std::thread::scope(|scope| {
            // the leader computes while captive: its flight stays
            // in-flight until every joiner has reached run(), so the
            // joiners deterministically coalesce onto it
            let leader = scope.spawn(|| {
                c.run("k", 0, || {
                    runs.fetch_add(1, Ordering::SeqCst);
                    while release.load(Ordering::SeqCst) == 0 {
                        std::thread::yield_now();
                    }
                    42
                })
            });
            let joiners: Vec<_> = (0..7)
                .map(|_| {
                    scope.spawn(|| {
                        joiners_started.fetch_add(1, Ordering::SeqCst);
                        c.run("k", 0, || {
                            runs.fetch_add(1, Ordering::SeqCst);
                            42
                        })
                    })
                })
                .collect();
            while joiners_started.load(Ordering::SeqCst) < 7 {
                std::thread::yield_now();
            }
            // small grace between "joiner announced itself" and "joiner
            // looked the flight up" (a few instructions), then let the
            // leader finish
            std::thread::sleep(std::time::Duration::from_millis(100));
            release.store(1, Ordering::SeqCst);
            let mut out = vec![leader.join().unwrap()];
            out.extend(joiners.into_iter().map(|h| h.join().unwrap()));
            out
        });
        assert_eq!(runs.load(Ordering::SeqCst), 1,
                   "exactly one compute across 8 concurrent callers");
        assert_eq!(results.iter().filter(|(_, led)| *led).count(), 1);
        assert!(results[0].1, "the captive caller led");
        assert!(results.iter().all(|(r, _)| *r == 42));
    }

    #[test]
    fn distinct_keys_do_not_coalesce() {
        let c: Coalescer<&'static str> = Coalescer::new();
        let barrier = Barrier::new(2);
        let (a, b) = std::thread::scope(|scope| {
            let ha = scope.spawn(|| {
                barrier.wait();
                c.run("a", "poisoned", || "a")
            });
            let hb = scope.spawn(|| {
                barrier.wait();
                c.run("b", "poisoned", || "b")
            });
            (ha.join().unwrap(), hb.join().unwrap())
        });
        assert_eq!(a, ("a", true));
        assert_eq!(b, ("b", true));
    }
}
