//! Request-scoped tracing: a span tree per query, a convergence
//! timeline from the search, and a bounded ring of completed traces
//! queryable over the wire.
//!
//! Every query the service answers gets a [`Trace`]: a tree of named
//! [`Span`]s covering the pipeline stages (key canonicalization, L1
//! lookup, remote-tier get, warm-candidate collection + per-candidate
//! repair, prefold/frontier build, the search descent, cache persist)
//! plus the planner's convergence timeline
//! ([`crate::planner::progress`]). Trace ids are **deterministic**:
//! derived from the query-key fingerprint plus a per-process sequence
//! number — never wall-clock randomness — so the id of the Nth serve of
//! a given query is reproducible run to run. Span *durations* are wall
//! time (that is the point of attribution); everything else in a trace
//! is deterministic, and the timeline's x-axis is visited-node counts,
//! so two runs of the same deterministic search compare bit-for-bit.
//!
//! Tracing is observational by construction: the service decides
//! nothing based on a trace, spans are closed by [`SpanGuard`] drops
//! (so every exit path — including error returns — closes its tree),
//! and the whole layer compiles out under `--features no_trace`
//! ([`Tracer::begin`] then returns `None` and every instrumentation
//! site threads an `Option`).
//!
//! Completed traces land in a bounded ring (newest [`RING_CAP`] kept,
//! "lock-free-ish": one short mutex around a `VecDeque`, never held
//! across planning) served by the `trace` / `trace <id>` wire verbs and
//! `osdp query --trace`. Per-span duration histograms aggregate across
//! all finished traces and feed the Prometheus exposition
//! (`osdp_span_seconds{span=...}`, see
//! [`super::telemetry::render_prometheus`]).

use crate::planner::progress::Improvement;
use crate::util::json::{self, Json};
use std::collections::{BTreeMap, VecDeque};
use std::sync::Mutex;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use super::telemetry::Histogram;
use crate::util::sync::lock_recover;

/// Completed traces kept in the ring (oldest evicted first).
pub const RING_CAP: usize = 64;

/// Every span name the service emits, in canonical pipeline order.
/// Fixed so the per-span duration histograms are preallocated and the
/// README's span grammar is checkable against code.
pub const SPAN_NAMES: [&str; 9] = [
    "query",        // root: the whole serve
    "canonicalize", // validate + resolve + profiler + QueryKey
    "cache",        // L1 lookup (fast path and the in-flight recheck)
    "remote",       // L2 get: outcome, breaker decision, deadline spend
    "warm",         // candidate collection; "repair" children per seed
    "repair",       // one greedy repair of one warm candidate
    "build",        // prefold + per-class composition frontiers
    "descent",      // the branch-and-bound walk itself
    "persist",      // cache write-behind/persist
];

/// One node of a trace's span tree. No start timestamps — only the
/// duration and the tree position, so traces of the same query differ
/// only in measured wall time.
#[derive(Debug, Clone)]
pub struct Span {
    pub name: &'static str,
    /// Index of the parent span in [`Trace::spans`]; `None` for the root.
    pub parent: Option<usize>,
    /// Wall seconds between open and close.
    pub dur_s: f64,
    /// Stage-specific annotations (remote outcome, node counts, ...).
    pub meta: BTreeMap<String, Json>,
}

/// A finished trace: the span tree, the convergence timeline, and the
/// completeness verdict (`complete` ⇔ every opened span was closed).
#[derive(Debug, Clone)]
pub struct Trace {
    pub id: String,
    /// The canonical query key id (or a short label for pre-key failures).
    pub request: String,
    pub spans: Vec<Span>,
    pub timeline: Vec<Improvement>,
    pub complete: bool,
}

impl Trace {
    /// Full JSON rendering (the `trace <id>` verb). `time_bits` are hex
    /// strings: u64 exceeds the f64-exact integer range.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("id".into(), Json::Str(self.id.clone()));
        o.insert("request".into(), Json::Str(self.request.clone()));
        o.insert("complete".into(), Json::Bool(self.complete));
        o.insert(
            "spans".into(),
            Json::Arr(self.spans.iter().map(|s| {
                let mut so = BTreeMap::new();
                so.insert("name".into(), Json::Str(s.name.into()));
                so.insert("parent".into(), match s.parent {
                    Some(p) => Json::Num(p as f64),
                    None => Json::Null,
                });
                so.insert("dur_s".into(), Json::Num(s.dur_s));
                if !s.meta.is_empty() {
                    so.insert("meta".into(), Json::Obj(s.meta.clone()));
                }
                Json::Obj(so)
            }).collect()),
        );
        o.insert(
            "timeline".into(),
            Json::Arr(self.timeline.iter().map(|e| {
                let mut eo = BTreeMap::new();
                eo.insert("nodes".into(), Json::Num(e.nodes as f64));
                eo.insert("time_bits".into(),
                          Json::Str(format!("0x{:016x}", e.time_bits)));
                eo.insert("time_s".into(),
                          Json::Num(f64::from_bits(e.time_bits)));
                eo.insert("source".into(), Json::Str(e.source.label().into()));
                Json::Obj(eo)
            }).collect()),
        );
        Json::Obj(o)
    }

    /// One-line JSON summary (the bare `trace` verb's listing).
    pub fn summary_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("id".into(), Json::Str(self.id.clone()));
        o.insert("request".into(), Json::Str(self.request.clone()));
        o.insert("complete".into(), Json::Bool(self.complete));
        o.insert("spans".into(), Json::Num(self.spans.len() as f64));
        o.insert("events".into(), Json::Num(self.timeline.len() as f64));
        if let Some(root) = self.spans.first() {
            o.insert("dur_s".into(), Json::Num(root.dur_s));
        }
        Json::Obj(o)
    }

    /// Human rendering for `osdp query --trace`: the span tree indented
    /// by depth, then the convergence timeline.
    pub fn render_text(&self) -> String {
        let mut out = format!("trace {} ({})\n", self.id,
                              if self.complete { "complete" }
                              else { "INCOMPLETE" });
        let mut depth = vec![0usize; self.spans.len()];
        for (i, s) in self.spans.iter().enumerate() {
            depth[i] = s.parent.map_or(0, |p| depth[p] + 1);
            let meta = if s.meta.is_empty() {
                String::new()
            } else {
                format!("  {}", json::to_string(&Json::Obj(s.meta.clone())))
            };
            out.push_str(&format!("{}{} {:.6}s{}\n", "  ".repeat(depth[i]),
                                  s.name, s.dur_s, meta));
        }
        if !self.timeline.is_empty() {
            out.push_str("convergence (nodes -> time_s, source):\n");
            for e in &self.timeline {
                out.push_str(&format!("  {:>10} -> {:.9} ({})\n", e.nodes,
                                      f64::from_bits(e.time_bits),
                                      e.source.label()));
            }
        }
        out
    }
}

struct CtxInner {
    id: String,
    request: String,
    spans: Vec<Span>,
    stack: Vec<usize>,
    timeline: Vec<Improvement>,
    /// Spans opened but never closed (a panic unwound past a guard that
    /// could not re-lock, or a bug) — poisons `complete`.
    leaked: bool,
}

/// The under-construction trace for one in-flight query. Interior
/// mutability (one short-held mutex) so the service can thread a shared
/// `&TraceCtx` through closures and the coalescer without borrow
/// gymnastics.
pub struct TraceCtx {
    inner: Mutex<CtxInner>,
}

impl TraceCtx {
    fn new(seq: u64) -> TraceCtx {
        TraceCtx {
            inner: Mutex::new(CtxInner {
                // deterministic fallback for queries that fail before a
                // key exists; `set_request` upgrades it
                id: format!("t{seq:06}-invalid"),
                request: String::new(),
                spans: Vec::new(),
                stack: Vec::new(),
                timeline: Vec::new(),
                leaked: false,
            }),
        }
    }

    /// Stamp the canonical request (the query-key id) and derive the
    /// final trace id from its fingerprint prefix + the sequence
    /// number already embedded at construction.
    pub fn set_request(&self, key_id: &str) {
        let mut g = lock_recover(&self.inner);
        let seq_part = g.id.split('-').next().unwrap_or("t0").to_string();
        let fp: String = key_id.chars().take(12).collect();
        g.id = format!("{seq_part}-{fp}");
        g.request = key_id.to_string();
    }

    /// The trace id as currently known.
    pub fn id(&self) -> String {
        lock_recover(&self.inner).id.clone()
    }

    /// Open a child of the currently-open span (or the root). Closed by
    /// dropping the returned guard — every exit path closes its spans.
    pub fn span(&self, name: &'static str) -> SpanGuard<'_> {
        let mut g = lock_recover(&self.inner);
        let parent = g.stack.last().copied();
        let idx = g.spans.len();
        g.spans.push(Span {
            name,
            parent,
            dur_s: 0.0,
            meta: BTreeMap::new(),
        });
        g.stack.push(idx);
        SpanGuard { ctx: self, idx, started: Instant::now() }
    }

    /// Record an already-measured span as a child of the currently-open
    /// span — for phases the planner clocks internally (prefold/frontier
    /// build vs descent), where the duration arrives out-of-band.
    pub fn closed_span(&self, name: &'static str, dur_s: f64,
                       meta: Vec<(String, Json)>) {
        let mut g = lock_recover(&self.inner);
        let parent = g.stack.last().copied();
        g.spans.push(Span {
            name,
            parent,
            dur_s,
            meta: meta.into_iter().collect(),
        });
    }

    /// Install the search's convergence timeline.
    pub fn set_timeline(&self, timeline: Vec<Improvement>) {
        lock_recover(&self.inner).timeline = timeline;
    }

    fn finish(self) -> Trace {
        let inner = self.inner.into_inner()
            .unwrap_or_else(|p| p.into_inner());
        Trace {
            id: inner.id,
            request: inner.request,
            complete: inner.stack.is_empty() && !inner.leaked,
            spans: inner.spans,
            timeline: inner.timeline,
        }
    }
}

/// Closes its span on drop; carries span-scoped metadata.
pub struct SpanGuard<'a> {
    ctx: &'a TraceCtx,
    idx: usize,
    started: Instant,
}

impl SpanGuard<'_> {
    /// Attach one metadata entry to this span.
    pub fn meta(&self, key: &str, value: Json) {
        let mut g = lock_recover(&self.ctx.inner);
        let idx = self.idx;
        g.spans[idx].meta.insert(key.to_string(), value);
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        let mut g = lock_recover(&self.ctx.inner);
        let idx = self.idx;
        g.spans[idx].dur_s = self.started.elapsed().as_secs_f64();
        match g.stack.pop() {
            Some(top) if top == idx => {}
            // out-of-order close (should be unreachable — guards nest
            // lexically): keep the tree but flag the trace
            _ => g.leaked = true,
        }
    }
}

/// The service's trace registry: the per-process sequence counter, the
/// completed-trace ring, and per-span duration histograms.
pub struct Tracer {
    seq: AtomicU64,
    ring: Mutex<VecDeque<Trace>>,
    span_hist: [(&'static str, Histogram); SPAN_NAMES.len()],
}

impl Default for Tracer {
    fn default() -> Tracer {
        Tracer::new()
    }
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer {
            seq: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::with_capacity(RING_CAP)),
            span_hist: std::array::from_fn(|i| {
                (SPAN_NAMES[i], Histogram::new())
            }),
        }
    }

    /// Whether tracing is compiled in.
    pub fn enabled() -> bool {
        !cfg!(feature = "no_trace")
    }

    /// Begin a trace for one query (`None` under `--features no_trace`
    /// — the instrumentation sites all thread an `Option`, so compiling
    /// the layer out leaves a single never-true branch per site).
    pub fn begin(&self) -> Option<TraceCtx> {
        if !Tracer::enabled() {
            return None;
        }
        Some(TraceCtx::new(self.seq.fetch_add(1, Ordering::Relaxed)))
    }

    /// Finish a trace: feed the span-duration histograms and push it
    /// into the ring (oldest evicted past [`RING_CAP`]).
    pub fn finish(&self, ctx: TraceCtx) {
        let trace = ctx.finish();
        for s in &trace.spans {
            if let Some((_, h)) =
                self.span_hist.iter().find(|(n, _)| *n == s.name)
            {
                h.observe(s.dur_s);
            }
        }
        let mut ring = lock_recover(&self.ring);
        if ring.len() == RING_CAP {
            ring.pop_front();
        }
        ring.push_back(trace);
    }

    /// Summaries of every ring entry, oldest first (the `trace` verb).
    pub fn recent(&self) -> Vec<Json> {
        lock_recover(&self.ring).iter().map(|t| t.summary_json()).collect()
    }

    /// Full trace by id (the `trace <id>` verb).
    pub fn get(&self, id: &str) -> Option<Trace> {
        lock_recover(&self.ring).iter().find(|t| t.id == id).cloned()
    }

    /// The most recently finished trace (`osdp query --trace`, benches).
    pub fn last(&self) -> Option<Trace> {
        lock_recover(&self.ring).back().cloned()
    }

    /// Per-span duration histograms (name, histogram) for the
    /// Prometheus exposition.
    pub fn span_histograms(&self)
                           -> &[(&'static str, Histogram)] {
        &self.span_hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::progress::ImprovementSource;

    #[test]
    fn spans_nest_by_guard_scope_and_close_on_drop() {
        let tracer = Tracer::new();
        let Some(ctx) = tracer.begin() else { return }; // no_trace build
        {
            let root = ctx.span("query");
            root.meta("k", Json::Str("v".into()));
            {
                let _c = ctx.span("canonicalize");
            }
            let _d = ctx.span("descent");
        }
        ctx.set_timeline(vec![Improvement {
            nodes: 0,
            time_bits: 1.5f64.to_bits(),
            source: ImprovementSource::Greedy,
        }]);
        ctx.set_request("deadbeefdeadbeef-0-b4");
        tracer.finish(ctx);
        let t = tracer.last().unwrap();
        assert!(t.complete);
        assert_eq!(t.spans.len(), 3);
        assert_eq!(t.spans[0].parent, None);
        assert_eq!(t.spans[1].parent, Some(0));
        assert_eq!(t.spans[2].parent, Some(0));
        assert_eq!(t.spans[0].meta.get("k"), Some(&Json::Str("v".into())));
        // id = sequence prefix + 12 chars of the key fingerprint
        assert_eq!(t.id, "t000000-deadbeefdead");
        assert_eq!(t.request, "deadbeefdeadbeef-0-b4");
        // round-trips through the JSON writer/parser
        let parsed = Json::parse(&json::to_string(&t.to_json())).unwrap();
        assert_eq!(parsed.get("complete"), &Json::Bool(true));
        assert_eq!(parsed.get("timeline").idx(0).get("time_bits"),
                   &Json::Str(format!("0x{:016x}", 1.5f64.to_bits())));
        assert!(tracer.get(&t.id).is_some());
        assert!(tracer.get("t-nope").is_none());
    }

    #[test]
    fn unclosed_spans_poison_completeness() {
        let tracer = Tracer::new();
        let Some(ctx) = tracer.begin() else { return };
        let g = ctx.span("query");
        std::mem::forget(g); // simulate a span left open
        tracer.finish(ctx);
        assert!(!tracer.last().unwrap().complete);
    }

    #[test]
    fn ring_is_bounded_and_ids_are_sequential() {
        let tracer = Tracer::new();
        for _ in 0..(RING_CAP + 5) {
            let Some(ctx) = tracer.begin() else { return };
            let _g = ctx.span("query");
            drop(_g);
            tracer.finish(ctx);
        }
        let recent = tracer.recent();
        assert_eq!(recent.len(), RING_CAP);
        // oldest 5 evicted: first surviving id carries sequence 5
        assert_eq!(recent[0].get("id").as_str().unwrap(), "t000005-invalid");
    }
}
