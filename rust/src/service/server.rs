//! The plan service's wire surface: a line-oriented request loop (one
//! request per line in, one JSON document per line out) suitable for
//! scripting, piping, and tests — `osdp serve` binds it to
//! stdin/stdout, `osdp query` runs a single request through the same
//! code path.
//!
//! ```text
//! query setting=48L/1024H mem=8 batch=4 [devices=8] [cluster=PRESET]
//!       [g=0,4] [engine=frontier|bb] [threads=N] [ckpt] [fine]
//!       [no-scopes] [no-warm]
//! sweep setting=48L/1024H mem=8 [batch-cap=64] [...same knobs]
//! stats
//! quit
//! shutdown
//! ```
//!
//! `quit` ends one connection (or the stdin loop); `shutdown` asks the
//! whole socket front-end ([`super::frontend`]) to stop accepting and
//! drain — on the stdin loop the two are equivalent. The same grammar is
//! also the cache's *warm-up* format: every cached plan stores its
//! canonical request line ([`request_line`]), so an epoch bump can
//! re-plan yesterday's hottest queries before serving today's traffic.
//!
//! Settings are zoo names (`48L/1024H`) or custom
//! `gpt:vocab,seq,layers,hidden,heads` specs. Malformed requests answer
//! `{"ok":false,"error":"bad-request",...}` — the loop never panics and
//! never exits on bad input (error-path property tests in
//! `rust/tests/plan_service.rs`).

use super::telemetry::Telemetry;
use super::{Answer, PlanError, PlanQuery, PlanService, QueryResponse,
            QueryShape};
use crate::planner::Engine;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::io::{BufRead, Write};
use std::time::Instant;

/// One parsed protocol line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Query(PlanQuery),
    Stats,
    Quit,
    Shutdown,
}

/// What the transport should do after answering a line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LineOutcome {
    /// Keep reading from this connection.
    Continue,
    /// Close this connection; the service keeps running.
    Quit,
    /// Drain and stop the whole front-end.
    Shutdown,
}

/// Parse a protocol line. Strict: unknown keys are rejected so typos
/// fail loudly instead of planning the wrong thing.
pub fn parse_request(line: &str) -> Result<Request, PlanError> {
    let mut toks = line.split_whitespace();
    let verb = toks
        .next()
        .ok_or_else(|| PlanError::BadRequest("empty request".into()))?;
    match verb {
        "stats" => Ok(Request::Stats),
        "quit" | "exit" => Ok(Request::Quit),
        "shutdown" => Ok(Request::Shutdown),
        "query" | "sweep" => parse_query(verb, toks),
        other => Err(PlanError::BadRequest(format!(
            "unknown verb '{other}' (query | sweep | stats | quit | \
             shutdown)"
        ))),
    }
}

fn parse_query<'a>(verb: &str, toks: impl Iterator<Item = &'a str>)
                   -> Result<Request, PlanError> {
    let bad = PlanError::BadRequest;
    let mut q = PlanQuery::batch("", 8.0, 1);
    let mut setting = None;
    let mut batch = None;
    let mut batch_cap = 64usize;
    for tok in toks {
        match tok.split_once('=') {
            Some(("setting", v)) => setting = Some(v.to_string()),
            Some(("mem", v)) => {
                q.cluster.mem_gib = v
                    .parse()
                    .map_err(|_| bad(format!("mem: bad number '{v}'")))?;
            }
            Some(("devices", v)) => {
                q.cluster.devices = Some(parse_usize("devices", v)?);
            }
            Some(("cluster", v)) => q.cluster.preset = v.to_string(),
            Some(("g", v)) => {
                q.search.granularities = v
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(|s| parse_usize("g", s.trim()))
                    .collect::<Result<_, _>>()?;
            }
            Some(("engine", v)) => {
                q.engine = Engine::parse(v).ok_or_else(|| {
                    bad(format!("engine: want frontier|bb, got '{v}'"))
                })?;
            }
            Some(("threads", v)) => q.threads = parse_usize("threads", v)?,
            Some(("batch", v)) if verb == "query" => {
                batch = Some(parse_usize("batch", v)?);
            }
            Some(("batch-cap", v)) if verb == "sweep" => {
                batch_cap = parse_usize("batch-cap", v)?;
            }
            None if tok == "ckpt" => q.search.checkpointing = true,
            None if tok == "fine" => q.search.paper_granularity = false,
            None if tok == "no-scopes" => q.search.hybrid_scopes = false,
            None if tok == "no-warm" => q.warm = false,
            _ => {
                return Err(bad(format!(
                    "unexpected parameter '{tok}' for '{verb}'"
                )));
            }
        }
    }
    q.setting = setting
        .ok_or_else(|| bad("missing required setting=...".to_string()))?;
    // the shape is the single source of truth for the sweep cap
    // (SearchConfig::max_batch is unread on the service path)
    q.shape = match verb {
        "query" => QueryShape::Batch(
            batch.ok_or_else(|| bad("query needs batch=N".to_string()))?,
        ),
        _ => QueryShape::Sweep { max_batch: batch_cap },
    };
    Ok(Request::Query(q))
}

fn parse_usize(key: &str, v: &str) -> Result<usize, PlanError> {
    v.parse().map_err(|_| {
        PlanError::BadRequest(format!("{key}: bad integer '{v}'"))
    })
}

/// Canonical protocol line for a query — the inverse of
/// [`parse_request`]: any query the grammar can express round-trips,
/// `parse_request(&request_line(q)?) == Ok(Request::Query(q))` (pinned
/// in tests). Cache entries store this line so the epoch-bump warm-up
/// can replay yesterday's traffic through the ordinary request path.
///
/// `None` when the query is not expressible on one whitespace-split
/// line (a setting containing whitespace — impossible to create *via*
/// the protocol, possible via the API). `Engine::UnfoldedBb` serializes
/// as `bb`: engines are perf knobs outside the cache key, and every
/// engine returns the bit-identical optimum, so the replay is
/// answer-preserving.
pub fn request_line(q: &PlanQuery) -> Option<String> {
    if q.setting.is_empty() || q.setting.chars().any(|c| c.is_whitespace())
    {
        return None;
    }
    let mut s = String::new();
    match q.shape {
        QueryShape::Batch(b) => {
            s.push_str(&format!("query setting={} mem={} batch={b}",
                                q.setting, q.cluster.mem_gib));
        }
        QueryShape::Sweep { max_batch } => {
            s.push_str(&format!("sweep setting={} mem={} batch-cap={}",
                                q.setting, q.cluster.mem_gib, max_batch));
        }
    }
    if let Some(d) = q.cluster.devices {
        s.push_str(&format!(" devices={d}"));
    }
    if q.cluster.preset != "rtx_titan" {
        s.push_str(&format!(" cluster={}", q.cluster.preset));
    }
    let g: Vec<String> =
        q.search.granularities.iter().map(|g| g.to_string()).collect();
    s.push_str(&format!(" g={}", g.join(",")));
    if q.engine != Engine::Frontier {
        s.push_str(" engine=bb");
    }
    if q.threads != 0 {
        s.push_str(&format!(" threads={}", q.threads));
    }
    if q.search.checkpointing {
        s.push_str(" ckpt");
    }
    if !q.search.paper_granularity {
        s.push_str(" fine");
    }
    if !q.search.hybrid_scopes {
        s.push_str(" no-scopes");
    }
    if !q.warm {
        s.push_str(" no-warm");
    }
    Some(s)
}

/// Render a query outcome as the single-line JSON the protocol speaks.
pub fn render_response(outcome: &Result<QueryResponse, PlanError>)
                       -> String {
    let mut o = BTreeMap::new();
    match outcome {
        Err(e) => {
            o.insert("ok".into(), Json::Bool(false));
            o.insert("error".into(), Json::Str(e.kind().into()));
            o.insert("detail".into(), Json::Str(e.to_string()));
        }
        Ok(resp) => {
            o.insert("ok".into(), Json::Bool(true));
            o.insert("source".into(),
                     Json::Str(resp.source.label().into()));
            o.insert("key".into(), Json::Str(resp.key.id()));
            match &resp.answer {
                Answer::Plan { plan, stats } => {
                    o.insert("kind".into(), Json::Str("plan".into()));
                    o.insert("batch".into(),
                             Json::Num(plan.batch as f64));
                    o.insert("time_s".into(), Json::Num(plan.cost.time));
                    o.insert("peak_bytes".into(),
                             Json::Num(plan.cost.peak_mem));
                    o.insert(
                        "throughput".into(),
                        Json::Num(plan.throughput(resp.n_devices)),
                    );
                    o.insert("nodes".into(),
                             Json::Num(stats.nodes as f64));
                    o.insert("complete".into(),
                             Json::Bool(stats.complete));
                    o.insert(
                        "choice".into(),
                        Json::Arr(plan.choice.iter()
                                      .map(|&c| Json::Num(c as f64))
                                      .collect()),
                    );
                }
                Answer::Sweep { plans, best, stats } => {
                    let winner = &plans[*best];
                    o.insert("kind".into(), Json::Str("sweep".into()));
                    o.insert("best_batch".into(),
                             Json::Num(winner.batch as f64));
                    o.insert(
                        "throughput".into(),
                        Json::Num(winner.throughput(resp.n_devices)),
                    );
                    o.insert("nodes".into(),
                             Json::Num(stats.nodes as f64));
                    o.insert("complete".into(),
                             Json::Bool(stats.complete));
                    o.insert(
                        "candidates".into(),
                        Json::Arr(
                            plans
                                .iter()
                                .map(|p| {
                                    let mut c = BTreeMap::new();
                                    c.insert("batch".into(),
                                             Json::Num(p.batch as f64));
                                    c.insert(
                                        "throughput".into(),
                                        Json::Num(p.throughput(
                                            resp.n_devices)),
                                    );
                                    c.insert("peak_bytes".into(),
                                             Json::Num(p.cost.peak_mem));
                                    Json::Obj(c)
                                })
                                .collect(),
                        ),
                    );
                }
            }
        }
    }
    json::to_string(&Json::Obj(o))
}

fn render_stats(service: &PlanService, telemetry: Option<&Telemetry>)
                -> String {
    let s = service.stats();
    let mut o = BTreeMap::new();
    o.insert("ok".into(), Json::Bool(true));
    o.insert("kind".into(), Json::Str("stats".into()));
    o.insert("cache_entries".into(),
             Json::Num(service.cache_len() as f64));
    for (name, v) in s.fields() {
        o.insert(name.into(), Json::Num(v as f64));
    }
    if let Some(t) = telemetry {
        o.insert("telemetry".into(), t.to_json());
    }
    json::to_string(&Json::Obj(o))
}

/// Handle one protocol line; always returns exactly one JSON line (the
/// `quit`/`shutdown` acknowledgements included — the transport acts on
/// the returned [`LineOutcome`]). With a [`Telemetry`] attached, every
/// dispatched query is timed into its shape's histogram and the verdict
/// counters — exactly once, which is what makes the telemetry
/// consistency invariants (`histogram counts == queries`) exact.
pub fn handle_line_full(service: &PlanService,
                        telemetry: Option<&Telemetry>, line: &str)
                        -> (String, LineOutcome) {
    match parse_request(line) {
        Err(e) => {
            if let Some(t) = telemetry {
                t.bump(super::telemetry::Counter::BadRequests);
            }
            (render_response(&Err(e)), LineOutcome::Continue)
        }
        Ok(Request::Stats) => {
            (render_stats(service, telemetry), LineOutcome::Continue)
        }
        Ok(Request::Quit) => {
            (r#"{"kind":"bye","ok":true}"#.to_string(), LineOutcome::Quit)
        }
        Ok(Request::Shutdown) => (
            r#"{"kind":"shutdown","ok":true}"#.to_string(),
            LineOutcome::Shutdown,
        ),
        Ok(Request::Query(q)) => {
            let started = Instant::now();
            let outcome = service.query(&q);
            if let Some(t) = telemetry {
                let sweep =
                    matches!(q.shape, QueryShape::Sweep { .. });
                t.observe_query(sweep, started.elapsed().as_secs_f64(),
                                &outcome);
            }
            (render_response(&outcome), LineOutcome::Continue)
        }
    }
}

/// [`handle_line_full`] without telemetry, collapsed to the original
/// "stop reading?" boolean (both `quit` and `shutdown` stop a
/// single-connection loop).
pub fn handle_line(service: &PlanService, line: &str) -> (String, bool) {
    let (response, outcome) = handle_line_full(service, None, line);
    (response, outcome != LineOutcome::Continue)
}

/// The serve loop: read requests line by line, answer each with one
/// JSON line, stop at `quit`/`shutdown` or EOF. Blank lines and `#`
/// comments are ignored (scripts can be annotated).
pub fn serve_loop<R: BufRead, W: Write>(service: &PlanService, reader: R,
                                        writer: &mut W)
                                        -> std::io::Result<()> {
    serve_loop_with(service, None, reader, writer)
}

/// [`serve_loop`] with wire telemetry attached (the `--listen`-less
/// `osdp serve` still counts requests and latencies so `stats` tells
/// the same story on stdin as over a socket).
pub fn serve_loop_with<R: BufRead, W: Write>(
    service: &PlanService, telemetry: Option<&Telemetry>, reader: R,
    writer: &mut W,
) -> std::io::Result<()> {
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(t) = telemetry {
            t.bump(super::telemetry::Counter::Requests);
        }
        let (response, outcome) =
            handle_line_full(service, telemetry, line);
        writeln!(writer, "{response}")?;
        writer.flush()?;
        if outcome != LineOutcome::Continue {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_query_lines() {
        let r = parse_request(
            "query setting=gpt:1000,64,2,128,4 mem=4 batch=2 g=0,2 \
             threads=2 engine=bb ckpt no-warm",
        )
        .unwrap();
        let Request::Query(q) = r else { panic!("not a query") };
        assert_eq!(q.setting, "gpt:1000,64,2,128,4");
        assert_eq!(q.cluster.mem_gib, 4.0);
        assert_eq!(q.shape, QueryShape::Batch(2));
        assert_eq!(q.search.granularities, vec![0, 2]);
        assert_eq!(q.threads, 2);
        assert_eq!(q.engine, Engine::FoldedBb);
        assert!(q.search.checkpointing);
        assert!(!q.warm);
        assert!(q.search.paper_granularity, "coarse by default");
    }

    #[test]
    fn parses_sweep_lines_and_verbs() {
        let r = parse_request(
            "sweep setting=48L/1024H mem=8 batch-cap=16 fine no-scopes",
        )
        .unwrap();
        let Request::Query(q) = r else { panic!("not a query") };
        assert_eq!(q.shape, QueryShape::Sweep { max_batch: 16 });
        assert!(!q.search.paper_granularity);
        assert!(!q.search.hybrid_scopes);
        assert_eq!(parse_request("stats").unwrap(), Request::Stats);
        assert_eq!(parse_request("quit").unwrap(), Request::Quit);
        assert_eq!(parse_request("exit").unwrap(), Request::Quit);
    }

    #[test]
    fn rejects_malformed_lines() {
        for bad in [
            "",
            "frobnicate x=1",
            "query batch=1",                       // missing setting
            "query setting=x",                     // missing batch
            "query setting=x batch=nope",
            "query setting=x batch=1 mem=wat",
            "query setting=x batch=1 bogus=1",     // unknown key
            "query setting=x batch=1 batch-cap=4", // sweep-only key
            "sweep setting=x batch=4",             // query-only key
            "query setting=x batch=1 engine=warp",
            "query setting=x batch=1 g=1,x",
        ] {
            assert!(
                matches!(parse_request(bad),
                         Err(PlanError::BadRequest(_))),
                "'{bad}' must be rejected"
            );
        }
    }

    #[test]
    fn shutdown_verb_parses_and_acknowledges() {
        assert_eq!(parse_request("shutdown").unwrap(), Request::Shutdown);
        let service = super::super::PlanService::in_memory();
        let (resp, outcome) = handle_line_full(&service, None, "shutdown");
        assert_eq!(outcome, LineOutcome::Shutdown);
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true));
        assert_eq!(v.get("kind").as_str(), Some("shutdown"));
        // the boolean compat surface stops on shutdown too
        assert!(handle_line(&service, "shutdown").1);
        assert!(handle_line(&service, "quit").1);
        assert!(!handle_line(&service, "stats").1);
    }

    #[test]
    fn request_lines_round_trip_through_the_parser() {
        for line in [
            "query setting=gpt:1000,64,2,128,4 mem=4 batch=2 g=0,2 \
             threads=2 engine=bb ckpt no-warm",
            "query setting=48L/1024H mem=8 batch=1 g=0,4",
            "query setting=x mem=8.5 batch=3 devices=4 g=0 fine",
            "sweep setting=x mem=8 batch-cap=16 cluster=two_server_a100 \
             g=0,4 no-scopes",
            "sweep setting=x mem=8 batch-cap=64 g=0,4",
        ] {
            let Request::Query(q) = parse_request(line).unwrap() else {
                panic!("not a query: {line}");
            };
            let canon = request_line(&q).expect("expressible");
            let Request::Query(q2) = parse_request(&canon).unwrap() else {
                panic!("canonical line failed to parse: {canon}");
            };
            assert_eq!(q, q2, "round trip diverged for '{line}'");
        }
        // inexpressible settings refuse rather than emit a corrupt line
        let mut q = PlanQuery::batch("two words", 8.0, 1);
        assert_eq!(request_line(&q), None);
        q.setting = String::new();
        assert_eq!(request_line(&q), None);
        // the unfolded engine degrades to its folded ground-truth twin
        let mut q = PlanQuery::batch("x", 8.0, 1);
        q.engine = Engine::UnfoldedBb;
        let Request::Query(q2) =
            parse_request(&request_line(&q).unwrap()).unwrap()
        else {
            panic!("not a query");
        };
        assert_eq!(q2.engine, Engine::FoldedBb);
    }

    #[test]
    fn error_rendering_is_json() {
        let out = render_response(&Err(PlanError::UnknownSetting(
            "x".into(),
        )));
        let v = Json::parse(&out).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(false));
        assert_eq!(v.get("error").as_str(), Some("unknown-setting"));
    }
}
